# Layer-2: the JAX compute graph for one BFS layer step.
#
# `bfs_layer_step` composes the two Layer-1 Pallas kernels — racy vectorized
# exploration (Listing 1) followed by vectorized restoration (§3.3.2/§4) —
# into the function the Rust coordinator calls once per frontier batch per
# layer. This module is traced once by aot.py; Python never runs at request
# time.
#
# Fixed shapes per compiled artifact (AOT requires static shapes):
#   N — vertices in the graph (bitmap geometry, nodes constant);
#   W = ceil(N / 32) — bitmap words;
#   C — adjacency chunks per call (the Rust side splits a layer's frontier
#       adjacency into C-chunk batches and carries state between calls).

import jax
import jax.numpy as jnp

from .kernels import explore as explore_k
from .kernels import restore as restore_k

LANES = 16
BITS_PER_WORD = 32


def words_for(n: int) -> int:
    return (n + BITS_PER_WORD - 1) // BITS_PER_WORD


def bfs_layer_step(neigh, parents, vis_words, out_words, pred, *, nodes: int):
    """One batched layer step: explore chunks, then restore.

    Args:
      neigh:   i32[C, 16] adjacency chunks, -1 padded.
      parents: i32[C, 16] frontier vertex owning each lane, -1 padded.
      vis_words: i32[W] visited bitmap words.
      out_words: i32[W] output-queue bitmap words.
      pred:    i32[N] predecessor array.
      nodes:   N, baked into the artifact.

    Returns (out_words', vis_words', pred') — consistent state: restoration
    has already normalized every journal entry written by this call.
    """
    out1, pred1 = explore_k.explore(
        neigh, parents, vis_words, out_words, pred, nodes=nodes
    )
    out2, vis2, pred2 = restore_k.restore(out1, vis_words, pred1, nodes=nodes)
    return out2, vis2, pred2


def make_layer_step(n: int, chunks: int):
    """Bind static shapes and return (fn, example_args) ready for jit/lower."""
    w = words_for(n)

    def fn(neigh, parents, vis_words, out_words, pred):
        return bfs_layer_step(
            neigh, parents, vis_words, out_words, pred, nodes=n
        )

    example = (
        jax.ShapeDtypeStruct((chunks, LANES), jnp.int32),
        jax.ShapeDtypeStruct((chunks, LANES), jnp.int32),
        jax.ShapeDtypeStruct((w,), jnp.int32),
        jax.ShapeDtypeStruct((w,), jnp.int32),
        jax.ShapeDtypeStruct((n,), jnp.int32),
    )
    return fn, example
