# AOT pipeline: lower the Layer-2 jax function (which inlines the Layer-1
# Pallas kernels, interpret=True) to HLO **text** artifacts the Rust runtime
# loads through the `xla` crate's PJRT CPU client.
#
# HLO text — NOT lowered.compile()/.serialize() — is the interchange format:
# jax >= 0.5 emits HloModuleProto with 64-bit instruction ids, which the
# crate's pinned xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`). The
# text parser reassigns ids and round-trips cleanly. See
# /opt/xla-example/README.md and gen_hlo.py there.
#
# Usage: (from python/)  python -m compile.aot --out-dir ../artifacts
#
# Emits one artifact per size bucket plus a plain-text manifest the Rust
# artifact registry parses (no JSON — serde is not in the offline registry):
#
#   bfs_layer_n{N}_c{C}.hlo.txt
#   manifest.txt   lines: "bfs_layer <N> <C> <W> <filename>"

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# Size buckets compiled by default. Chosen so the pjrt_bfs example (SCALE
# 10-12 graphs) always finds a fitting bucket: N is the vertex count, C the
# number of 16-lane adjacency chunks handled per call.
DEFAULT_BUCKETS = (
    (1 << 10, 64),
    (1 << 12, 128),
    (1 << 14, 256),
)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_bucket(n: int, chunks: int) -> str:
    fn, example = model.make_layer_step(n, chunks)
    lowered = jax.jit(fn).lower(*example)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description="AOT-compile BFS layer-step artifacts")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=None,
        help="comma list of N:C pairs, e.g. 4096:128,16384:256",
    )
    args = ap.parse_args()

    buckets = DEFAULT_BUCKETS
    if args.buckets:
        buckets = tuple(
            (int(n), int(c))
            for n, c in (pair.split(":") for pair in args.buckets.split(","))
        )

    os.makedirs(args.out_dir, exist_ok=True)
    manifest_lines = []
    for n, chunks in buckets:
        text = build_bucket(n, chunks)
        name = f"bfs_layer_n{n}_c{chunks}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        w = model.words_for(n)
        manifest_lines.append(f"bfs_layer {n} {chunks} {w} {name}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"manifest: {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
