# Pure-numpy correctness oracle for the Layer-1 kernels.
#
# `ref_layer_step` computes the race-free, sequentially-consistent result of
# one BFS layer step: every valid lane is processed one at a time in (chunk,
# lane) order with *bit-granularity* updates — no word-store races, no lost
# updates. This is the semantic target the explore+restore kernel pair must
# reach: the paper's whole §3.3.2 argument is that racy-explore followed by
# restoration equals the race-free result (up to the benign predecessor
# race, which `valid_parents` captures).

import numpy as np

LANES = 16
BITS_PER_WORD = 32


def ref_layer_step(neigh, parents, vis_words, out_words, pred, *, nodes: int):
    """Sequential bit-granular oracle. Returns (out', vis', pred')."""
    neigh = np.asarray(neigh, dtype=np.int64)
    parents = np.asarray(parents, dtype=np.int64)
    out = np.array(out_words, dtype=np.uint32).copy()
    vis = np.array(vis_words, dtype=np.uint32).copy()
    p = np.array(pred, dtype=np.int64).copy()
    n = p.shape[0]

    # exploration: first writer wins (no lost updates, bit-granular)
    for c in range(neigh.shape[0]):
        for l in range(neigh.shape[1]):
            v = int(neigh[c, l])
            if v < 0:
                continue
            assert v < n, "neighbor out of range"
            w, b = divmod(v, BITS_PER_WORD)
            if (int(vis[w]) >> b) & 1 or (int(out[w]) >> b) & 1:
                continue
            out[w] |= np.uint32(1 << b)
            p[v] = parents[c, l] - nodes

    # restoration: normalize journal entries in non-zero words
    for w in range(out.shape[0]):
        if out[w] == 0:
            continue
        for b in range(BITS_PER_WORD):
            v = w * BITS_PER_WORD + b
            if v >= n:
                break
            if p[v] < 0:
                out[w] |= np.uint32(1 << b)
                vis[w] |= np.uint32(1 << b)
                p[v] += nodes

    return (
        out.astype(np.uint32).view(np.int32),
        vis.astype(np.uint32).view(np.int32),
        p.astype(np.int64),
    )


def valid_parents(neigh, parents):
    """Map vertex -> set of parents that could legally claim it this layer
    (the benign race of §3.2: any of them yields a correct spanning tree)."""
    out = {}
    neigh = np.asarray(neigh)
    parents = np.asarray(parents)
    for c in range(neigh.shape[0]):
        for l in range(neigh.shape[1]):
            v = int(neigh[c, l])
            if v >= 0:
                out.setdefault(v, set()).add(int(parents[c, l]))
    return out


def discovered_vertices(neigh, vis_words, out_words):
    """Vertices a layer step must newly discover: valid lanes whose bit is
    set in neither the visited nor the output bitmap."""
    vis = np.asarray(vis_words, dtype=np.uint32)
    out = np.asarray(out_words, dtype=np.uint32)
    found = set()
    for v in np.asarray(neigh).flatten():
        v = int(v)
        if v < 0:
            continue
        w, b = divmod(v, BITS_PER_WORD)
        if not ((int(vis[w]) >> b) & 1 or (int(out[w]) >> b) & 1):
            found.add(v)
    return found
