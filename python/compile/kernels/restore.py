# Layer-1 Pallas kernel: the vectorized restoration process (§3.3.2 + §4).
#
# The exploration kernel's word-granularity scatters lose bits on conflicts;
# the predecessor array (element-granularity, no bit races) holds a journal:
# every vertex discovered this layer has P[v] = parent - nodes < 0. This
# kernel sweeps the non-zero output-queue words and, for each journalled
# vertex, (re)sets its output bit, sets its visited bit, and adds `nodes`
# back — after which out/visited/pred are consistent for the next layer.
#
# Vectorization detail from the paper (§4, closing paragraph): a 32-bit word
# covers 32 vertices but the VPU holds 16 lanes, so each word is processed
# as a LOW half and a HIGH half of 16 lanes each. We keep that structure —
# the `half` loop below — because it is the paper's actual dataflow and the
# per-half horizontal OR is what the cost model prices.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 16
BITS_PER_WORD = 32


def _restore_kernel(out_in_ref, vis_in_ref, pred_in_ref,
                    out_ref, vis_ref, pred_ref, *, nodes: int):
    out_ref[...] = out_in_ref[...]
    vis_ref[...] = vis_in_ref[...]
    pred_ref[...] = pred_in_ref[...]
    W = out_in_ref.shape[0]
    N = pred_in_ref.shape[0]
    lane_iota = jnp.arange(LANES, dtype=jnp.int32)

    def word_body(w, _):
        word = out_ref[w]
        nonzero = word != 0                       # Alg 3 line 18
        pred_now = pred_ref[...]
        patch = jnp.int32(0)
        for half in range(2):                     # low / high 16-bit halves
            base_bit = half * LANES
            verts = w * BITS_PER_WORD + base_bit + lane_iota
            valid = (verts < N) & nonzero
            safe = jnp.where(valid, verts, 0)
            pv = pred_now[safe]                   # gather P
            mneg = valid & (pv < 0)               # journalled this layer
            bits = jnp.left_shift(jnp.int32(1), base_bit + lane_iota)
            # horizontal OR of the selected lanes (bits are distinct powers
            # of two, so a wrapping sum equals the OR)
            patch = patch | jnp.sum(jnp.where(mneg, bits, 0))
            # P[vertex] += nodes for repaired lanes
            for l in range(LANES):
                @pl.when(mneg[l])
                def _(l=l):
                    pred_ref[safe[l]] = pv[l] + nodes
        out_ref[w] = word | patch
        vis_ref[w] = vis_ref[w] | patch
        return 0

    jax.lax.fori_loop(0, W, word_body, 0)


def restore(out_words, vis_words, pred, *, nodes: int):
    """Run the restoration kernel. Returns (out', vis', pred')."""
    import functools
    W = out_words.shape[0]
    N = pred.shape[0]
    kernel = functools.partial(_restore_kernel, nodes=nodes)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ),
        interpret=True,
    )(out_words, vis_words, pred)
