# Layer-1 Pallas kernel: the vectorized adjacency-list exploration of the
# paper's Listing 1, adapted from Xeon Phi intrinsics to a Pallas dataflow.
#
# Hardware adaptation (DESIGN.md §7): the paper's unit of work is "one
# hardware thread gathers/masks/scatters one 16-lane chunk". Here a chunk is
# one row of the (C, 16) `neigh` block; the chunk loop is a sequential
# `fori_loop` (mirroring the per-thread serial chunk schedule); the bitmap
# word arrays live wholly in kernel memory — the Pallas analogue of the
# paper's bitmaps-fit-in-L2 argument (SCALE-20 visited = 128 KiB = VMEM
# resident).
#
# Semantics preserved bit-for-bit (these are load-bearing for the
# reproduction, and are asserted against the scalar oracle in ref.py):
#   * the filter mask is knot(kor(visited-bit, output-bit)) over the words
#     gathered *at chunk start* (Listing 1 step 2);
#   * the output-queue scatter is WORD granularity: lane l writes
#     stale_word[l] | bit[l]; later lanes of the same chunk overwrite
#     earlier lanes that hit the same word — the §3.3.2 bit race, kept, to
#     be repaired by the restoration kernel (restore.py);
#   * the predecessor write is the negative journal entry P[v] = parent -
#     nodes (Alg 3 line 12); lane order resolves duplicates (benign race).
#
# interpret=True is mandatory: real-TPU lowering emits a Mosaic custom-call
# the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 16
BITS_PER_WORD = 32


def _explore_kernel(neigh_ref, parent_ref, vis_ref, out_in_ref, pred_in_ref,
                    out_ref, pred_ref, *, nodes: int):
    """Process every (C, 16) chunk against the bitmap words.

    Inputs:  neigh (C,16) i32 — adjacency chunks, -1 padding;
             parent (C,16) i32 — frontier vertex owning each lane;
             vis (W,) i32 — visited bitmap words (read-only this phase);
             out_in (W,) i32, pred_in (N,) i32 — state to update.
    Outputs: out (W,) i32, pred (N,) i32.
    """
    out_ref[...] = out_in_ref[...]
    pred_ref[...] = pred_in_ref[...]
    num_chunks = neigh_ref.shape[0]
    vis_words = vis_ref[...]

    def chunk_body(c, _):
        neigh = neigh_ref[c, :]                      # 1.- load adjacency chunk
        parent = parent_ref[c, :]
        valid = neigh >= 0                           # peel/remainder/pad mask
        safe = jnp.where(valid, neigh, 0)
        vword = safe // BITS_PER_WORD                # 2.- word / bit offsets
        vbits = safe % BITS_PER_WORD
        bits = jnp.left_shift(jnp.int32(1), vbits)   # _mm512_sllv_epi32
        out_words_now = out_ref[...]                 # gather (chunk-start snapshot)
        vis_w = vis_words[vword]                     # _mm512_i32gather_epi32
        out_w = out_words_now[vword]
        seen = ((vis_w & bits) != 0) | ((out_w & bits) != 0)
        mask = valid & jnp.logical_not(seen)         # knot(kor(...)) ∧ chunk mask

        # 3.- scatter P and the output queue, lane by lane (ascending lane
        # order == highest lane wins on conflicts, as on the Phi).
        new_vals = out_w | bits
        for l in range(LANES):
            @pl.when(mask[l])
            def _(l=l):
                pred_ref[safe[l]] = parent[l] - nodes      # journal entry (< 0)
                out_ref[vword[l]] = new_vals[l]            # word-granular racy store
        return 0

    jax.lax.fori_loop(0, num_chunks, chunk_body, 0)


def explore(neigh, parents, vis_words, out_words, pred, *, nodes: int):
    """Run the exploration kernel. Returns (out_words', pred')."""
    C, lanes = neigh.shape
    assert lanes == LANES
    W = vis_words.shape[0]
    N = pred.shape[0]
    kernel = functools.partial(_explore_kernel, nodes=nodes)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((W,), jnp.int32),
            jax.ShapeDtypeStruct((N,), jnp.int32),
        ),
        interpret=True,
    )(neigh, parents, vis_words, out_words, pred)
