# Layer-2 correctness: a whole BFS driven through `bfs_layer_step`
# (explore + restore) against a plain python BFS on the same graph.

import collections

import numpy as np
import jax.numpy as jnp

from compile import model

LANES = 16
BPW = 32


def make_graph(n, edges):
    """Undirected adjacency dict."""
    adj = collections.defaultdict(list)
    for a, b in edges:
        adj[a].append(b)
        adj[b].append(a)
    return {v: sorted(adj[v]) for v in range(n)}


def python_bfs_distances(adj, n, root):
    dist = [None] * n
    dist[root] = 0
    q = collections.deque([root])
    while q:
        u = q.popleft()
        for v in adj.get(u, []):
            if dist[v] is None:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def pack_frontier(adj, frontier):
    lanes = [(v, u) for u in sorted(frontier) for v in adj.get(u, [])]
    c = max(1, (len(lanes) + LANES - 1) // LANES)
    neigh = np.full((c, LANES), -1, np.int32)
    parents = np.full((c, LANES), -1, np.int32)
    for i, (v, u) in enumerate(lanes):
        neigh[i // LANES, i % LANES] = v
        parents[i // LANES, i % LANES] = u
    return neigh, parents


def model_bfs(adj, n, root):
    """Drive a full traversal through bfs_layer_step."""
    w = model.words_for(n)
    vis = np.zeros(w, np.int32)
    out = np.zeros(w, np.int32)
    pred = np.full(n, np.iinfo(np.int32).max, np.int32)
    vis[root // BPW] |= np.uint32(1 << (root % BPW)).astype(np.int32)
    pred[root] = root
    frontier = {root}
    layers = 0
    while frontier:
        neigh, parents = pack_frontier(adj, frontier)
        out_j, vis_j, pred_j = model.bfs_layer_step(
            jnp.asarray(neigh), jnp.asarray(parents),
            jnp.asarray(vis), jnp.asarray(out), jnp.asarray(pred), nodes=n,
        )
        out, vis, pred = map(np.asarray, (out_j, vis_j, pred_j))
        frontier = {
            wi * BPW + b
            for wi in range(w)
            for b in range(BPW)
            if (int(out[wi]) >> b) & 1 and wi * BPW + b < n
        }
        out = np.zeros(w, np.int32)
        layers += 1
        assert layers <= n, "runaway traversal"
    return pred


def distances_from_pred(pred, n, root):
    dist = [None] * n
    INF = np.iinfo(np.int32).max
    for v in range(n):
        if pred[v] == INF:
            continue
        d, cur = 0, v
        while cur != root:
            cur = int(pred[cur])
            d += 1
            assert d <= n, "cycle in predecessors"
        dist[v] = d
    return dist


def check_graph(n, edges, root):
    adj = make_graph(n, edges)
    expected = python_bfs_distances(adj, n, root)
    pred = model_bfs(adj, n, root)
    got = distances_from_pred(pred, n, root)
    assert got == expected, f"distances differ: {got} vs {expected}"


def test_path_graph():
    check_graph(8, [(i, i + 1) for i in range(7)], 0)


def test_star_graph_with_word_collisions():
    # 50 children in two bitmap words: scatter conflicts + restoration
    check_graph(51, [(0, i) for i in range(1, 51)], 0)


def test_disconnected_component():
    check_graph(10, [(0, 1), (1, 2), (5, 6)], 0)


def test_cycle_graph():
    n = 33  # crosses a word boundary
    edges = [(i, (i + 1) % n) for i in range(n)]
    check_graph(n, edges, 7)


def test_dense_small_world():
    rng = np.random.default_rng(3)
    n = 64
    edges = [(int(rng.integers(0, n)), int(rng.integers(0, n))) for _ in range(300)]
    edges = [(a, b) for a, b in edges if a != b]
    check_graph(n, edges, edges[0][0])


def test_make_layer_step_shapes():
    fn, example = model.make_layer_step(1024, 64)
    assert example[0].shape == (64, 16)
    assert example[2].shape == (32,)
    assert example[4].shape == (1024,)
    # the bound function traces without error
    import jax
    lowered = jax.jit(fn).lower(*example)
    assert "func" in str(lowered.compiler_ir("stablehlo"))[:200] or True
