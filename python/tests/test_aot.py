# AOT pipeline tests: HLO text generation and manifest consistency.

import os
import subprocess
import sys

import jax

from compile import aot, model


def test_to_hlo_text_produces_parseable_module():
    fn, example = model.make_layer_step(256, 4)
    lowered = jax.jit(fn).lower(*example)
    text = aot.to_hlo_text(lowered)
    # an HLO text module with an entry computation and our three outputs
    assert "HloModule" in text
    assert "ENTRY" in text
    # parameters: neigh, parents, vis, out, pred
    assert text.count("parameter(") >= 5


def test_build_bucket_sizes_scale():
    small = aot.build_bucket(64, 2)
    big = aot.build_bucket(256, 4)
    assert "HloModule" in small and "HloModule" in big
    # shapes are baked: the bigger bucket mentions its pred length
    assert "s32[256" in big
    assert "s32[64" in small


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--buckets", "64:2"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert manifest == ["bfs_layer 64 2 2 bfs_layer_n64_c2.hlo.txt"]
    hlo = (out / "bfs_layer_n64_c2.hlo.txt").read_text()
    assert "HloModule" in hlo
