# Layer-1 correctness: the explore+restore Pallas kernel pair versus the
# sequential bit-granular oracle (ref.py).
#
# The key invariant (the paper's §3.3.2 claim): racy word-granularity
# exploration followed by restoration produces EXACTLY the race-free
# bitmaps, and a predecessor array that differs only by the benign race
# (any lane-supplied parent is legal).

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import explore as explore_k
from compile.kernels import ref as ref_k
from compile.kernels import restore as restore_k

LANES = 16
BPW = 32


def run_kernel_pair(neigh, parents, vis, out, pred, nodes):
    out1, pred1 = explore_k.explore(
        jnp.asarray(neigh, jnp.int32),
        jnp.asarray(parents, jnp.int32),
        jnp.asarray(vis, jnp.int32),
        jnp.asarray(out, jnp.int32),
        jnp.asarray(pred, jnp.int32),
        nodes=nodes,
    )
    out2, vis2, pred2 = restore_k.restore(out1, jnp.asarray(vis, jnp.int32), pred1, nodes=nodes)
    return np.asarray(out2), np.asarray(vis2), np.asarray(pred2)


def check_against_ref(neigh, parents, vis, out, pred, nodes):
    k_out, k_vis, k_pred = run_kernel_pair(neigh, parents, vis, out, pred, nodes)
    r_out, r_vis, r_pred = ref_k.ref_layer_step(neigh, parents, vis, out, pred, nodes=nodes)
    np.testing.assert_array_equal(k_out.view(np.uint32), np.asarray(r_out).view(np.uint32))
    np.testing.assert_array_equal(k_vis.view(np.uint32), np.asarray(r_vis).view(np.uint32))
    # predecessor: exact where no benign race is possible, member-of-set
    # otherwise
    vp = ref_k.valid_parents(neigh, parents)
    discovered = ref_k.discovered_vertices(neigh, vis, out)
    for v in range(nodes):
        if v in discovered:
            assert int(k_pred[v]) in vp[v], (
                f"vertex {v}: kernel parent {k_pred[v]} not in legal set {vp[v]}"
            )
            assert int(r_pred[v]) in vp[v]
        else:
            assert int(k_pred[v]) == int(np.asarray(pred)[v]), f"vertex {v} mutated"
    return k_out, k_vis, k_pred


def fresh_state(n):
    w = (n + BPW - 1) // BPW
    vis = np.zeros(w, np.int32)
    out = np.zeros(w, np.int32)
    pred = np.full(n, np.iinfo(np.int32).max, np.int32)
    return vis, out, pred


def pad_chunks(vertex_lists, n_chunks=None):
    """Pack (parent, [children]) pairs into (C,16) neigh/parents arrays."""
    lanes = []
    for parent, children in vertex_lists:
        for v in children:
            lanes.append((v, parent))
    C = max(1, (len(lanes) + LANES - 1) // LANES)
    if n_chunks is not None:
        C = n_chunks
    neigh = np.full((C, LANES), -1, np.int32)
    parents = np.full((C, LANES), -1, np.int32)
    for i, (v, p) in enumerate(lanes):
        neigh[i // LANES, i % LANES] = v
        parents[i // LANES, i % LANES] = p
    return neigh, parents


class TestExploreBasics:
    def test_empty_chunks_change_nothing(self):
        n = 64
        vis, out, pred = fresh_state(n)
        neigh = np.full((2, LANES), -1, np.int32)
        k_out, k_vis, k_pred = run_kernel_pair(neigh, neigh, vis, out, pred, n)
        assert not k_out.any()
        assert not k_vis.any()
        np.testing.assert_array_equal(k_pred, pred)

    def test_single_discovery(self):
        n = 64
        vis, out, pred = fresh_state(n)
        neigh, parents = pad_chunks([(3, [17])])
        k_out, k_vis, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert k_out[0] == np.int32(1 << 17)
        assert k_vis[0] == np.int32(1 << 17)
        assert k_pred[17] == 3

    def test_dense_word_collisions(self):
        # 63 children of one hub, packed into 2 bitmap words: maximal
        # intra-vector scatter conflicts; restoration must recover all.
        n = 64
        vis, out, pred = fresh_state(n)
        neigh, parents = pad_chunks([(0, list(range(1, 64)))])
        k_out, _, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert k_out[0] == np.uint32(0xFFFFFFFE).astype(np.int32)
        assert k_out[1] == np.uint32(0xFFFFFFFF).astype(np.int32)
        assert all(int(k_pred[v]) == 0 for v in range(1, 64))

    def test_visited_vertices_filtered(self):
        n = 64
        vis, out, pred = fresh_state(n)
        vis[0] = np.int32((1 << 5) | (1 << 9))
        pred[5] = 1
        pred[9] = 2
        neigh, parents = pad_chunks([(7, [5, 9, 11])])
        k_out, _, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert k_out[0] == np.int32(1 << 11)
        assert k_pred[5] == 1 and k_pred[9] == 2  # untouched
        assert k_pred[11] == 7

    def test_duplicate_vertex_in_chunk_benign_race(self):
        # same child from two parents within one chunk — either parent wins
        n = 64
        vis, out, pred = fresh_state(n)
        neigh, parents = pad_chunks([(2, [5]), (3, [5])])
        k_out, _, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert k_out[0] == np.int32(1 << 5)
        assert int(k_pred[5]) in (2, 3)

    def test_multi_chunk_cross_references(self):
        # chunk 1 rediscovers what chunk 0 found: must be filtered or at
        # worst re-journalled; restoration keeps the state exact either way
        n = 128
        vis, out, pred = fresh_state(n)
        neigh, parents = pad_chunks([(0, list(range(10, 26))), (1, list(range(20, 36)))])
        check_against_ref(neigh, parents, vis, out, pred, n)

    def test_existing_out_bits_survive(self):
        n = 96
        vis, out, pred = fresh_state(n)
        out[1] = np.int32(1 << 2)  # vertex 34 already queued this layer
        pred[34] = 9
        neigh, parents = pad_chunks([(4, [33, 34, 35])])
        k_out, _, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert (int(k_out[1]) >> 2) & 1, "pre-existing bit lost"
        assert k_pred[34] == 9

    def test_last_word_boundary(self):
        # N not a multiple of 32: the final partial word must stay in range
        n = 70  # words: 32+32+6
        vis, out, pred = fresh_state(n)
        neigh, parents = pad_chunks([(0, [63, 64, 69])])
        k_out, _, k_pred = check_against_ref(neigh, parents, vis, out, pred, n)
        assert (int(k_out[2]) >> 5) & 1  # vertex 69
        assert k_pred[69] == 0


class TestRestoreStandalone:
    def test_repairs_injected_lost_bit(self):
        # Fig 6 scenario at kernel level
        n = 64
        w = 2
        out = np.zeros(w, np.int32)
        vis = np.zeros(w, np.int32)
        pred = np.full(n, np.iinfo(np.int32).max, np.int32)
        pred[5] = 2 - n  # journalled, bit lost
        pred[9] = 3 - n  # journalled, bit present
        out[0] = np.int32(1 << 9)
        out2, vis2, pred2 = restore_k.restore(
            jnp.asarray(out), jnp.asarray(vis), jnp.asarray(pred), nodes=n
        )
        out2, vis2, pred2 = map(np.asarray, (out2, vis2, pred2))
        assert (int(out2[0]) >> 5) & 1 and (int(out2[0]) >> 9) & 1
        assert (int(vis2[0]) >> 5) & 1 and (int(vis2[0]) >> 9) & 1
        assert pred2[5] == 2 and pred2[9] == 3

    def test_skips_zero_words(self):
        # journal entry in a zero word must NOT be repaired (paper scans
        # only non-zero words; this state cannot arise from explore)
        n = 64
        out = np.zeros(2, np.int32)
        vis = np.zeros(2, np.int32)
        pred = np.full(n, np.iinfo(np.int32).max, np.int32)
        pred[40] = 1 - n  # word 1 is all-zero
        out2, vis2, pred2 = map(
            np.asarray,
            restore_k.restore(jnp.asarray(out), jnp.asarray(vis), jnp.asarray(pred), nodes=n),
        )
        assert out2[1] == 0 and vis2[1] == 0
        assert pred2[40] == 1 - n

    def test_idempotent(self):
        n = 64
        out = np.array([np.int32((1 << 3) | (1 << 20)), 0], np.int32)
        vis = np.zeros(2, np.int32)
        pred = np.full(n, np.iinfo(np.int32).max, np.int32)
        pred[3] = 7 - n
        pred[20] = 9 - n
        r1 = list(map(np.asarray, restore_k.restore(jnp.asarray(out), jnp.asarray(vis), jnp.asarray(pred), nodes=n)))
        r2 = list(map(np.asarray, restore_k.restore(jnp.asarray(r1[0]), jnp.asarray(r1[1]), jnp.asarray(r1[2]), nodes=n)))
        for a, b in zip(r1, r2):
            np.testing.assert_array_equal(a, b)

    def test_high_half_bit31(self):
        # bit 31 exercises the int32 sign bit in the patch arithmetic
        n = 64
        out = np.zeros(2, np.int32)
        vis = np.zeros(2, np.int32)
        pred = np.full(n, np.iinfo(np.int32).max, np.int32)
        pred[31] = 0 - n
        out[0] = np.int32(1)  # non-zero word (vertex 0's bit, pred >= 0)
        pred[0] = 5
        out2, vis2, pred2 = map(
            np.asarray,
            restore_k.restore(jnp.asarray(out), jnp.asarray(vis), jnp.asarray(pred), nodes=n),
        )
        assert (int(out2[0]) >> 31) & 1
        assert pred2[31] == 0


@st.composite
def layer_case(draw):
    n = draw(st.sampled_from([64, 96, 127, 256]))
    w = (n + BPW - 1) // BPW
    c = draw(st.integers(1, 4))
    rng = np.random.default_rng(draw(st.integers(0, 2**32 - 1)))
    # lanes: mix of valid vertices and -1 padding
    neigh = rng.integers(-1, n, size=(c, LANES)).astype(np.int32)
    parents = np.where(neigh >= 0, rng.integers(0, n, size=(c, LANES)), -1).astype(np.int32)
    # arbitrary pre-existing visited/out state with non-negative pred
    vis = rng.integers(0, 2**32, size=w, dtype=np.uint32).view(np.int32)
    out = rng.integers(0, 2**32, size=w, dtype=np.uint32).view(np.int32)
    # sparsify so some discoveries happen
    vis = np.where(rng.random(w) < 0.5, vis, 0).astype(np.int32)
    out = np.where(rng.random(w) < 0.3, out, 0).astype(np.int32)
    pred = rng.integers(0, n, size=n).astype(np.int32)
    return n, neigh, parents, vis, out, pred


@settings(max_examples=30, deadline=None)
@given(layer_case())
def test_hypothesis_kernel_matches_ref(case):
    n, neigh, parents, vis, out, pred = case
    check_against_ref(neigh, parents, vis, out, pred, n)


@settings(max_examples=10, deadline=None)
@given(layer_case())
def test_hypothesis_deterministic(case):
    n, neigh, parents, vis, out, pred = case
    a = run_kernel_pair(neigh, parents, vis, out, pred, n)
    b = run_kernel_pair(neigh, parents, vis, out, pred, n)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_explore_counts_lost_updates_exist():
    # sanity: with dense children the racy explore ALONE (no restore) loses
    # bits versus ref — proving the hazard is real, not vacuous.
    n = 64
    vis, out, pred = fresh_state(n)
    neigh, parents = pad_chunks([(0, list(range(1, 17)))])  # one full chunk, word 0
    out1, _ = explore_k.explore(
        jnp.asarray(neigh), jnp.asarray(parents), jnp.asarray(vis),
        jnp.asarray(out), jnp.asarray(pred), nodes=n,
    )
    out1 = np.asarray(out1)
    expected_bits = sum(1 << v for v in range(1, 17))
    assert int(out1[0]) != expected_bits, "expected lost updates in racy explore"
    assert bin(int(out1[0]) & 0xFFFFFFFF).count("1") < 16
