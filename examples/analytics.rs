//! The paper's §3 motivation in action: BFS as the building block for
//! graph analytics — connected components, shortest paths and Brandes'
//! betweenness centrality over an RMAT social-network-like graph. The
//! multi-source workloads (component sweeps, betweenness forward passes)
//! go through the batch-first `run_batch` entry point on the MS-BFS
//! engine, which answers 16 sources per shared traversal.
//!
//! ```bash
//! cargo run --release --example analytics
//! ```

use phi_bfs::apps::{betweenness_centrality, connected_components_batched, ShortestPaths};
use phi_bfs::bfs::multi_source::MultiSourceSellBfs;
use phi_bfs::graph::stats::DegreeStats;
use phi_bfs::graph::{Csr, RmatConfig};

fn main() {
    // a small "social network": SCALE 12, edgefactor 16
    let el = RmatConfig::graph500(12, 16).generate(7);
    let g = Csr::from_edge_list(12, &el);
    let engine = MultiSourceSellBfs { num_threads: 2, ..Default::default() };
    println!(
        "graph: {} vertices, {} directed edges",
        g.num_vertices(),
        g.num_directed_edges()
    );
    let deg = DegreeStats::compute(&g);
    println!(
        "degrees: max {} mean {:.1}; top-1% of vertices own {:.0}% of edges (small-world skew)",
        deg.max,
        deg.mean,
        deg.top1pct_edge_share * 100.0
    );

    // 1. connected components — seeds batched 16 per MS wave
    let comps = connected_components_batched(&g, &engine, 16);
    println!(
        "components: {} total, giant component = {} vertices ({:.1}%), {} isolated",
        comps.count,
        comps.giant_size(),
        100.0 * comps.giant_size() as f64 / g.num_vertices() as f64,
        comps.sizes().values().filter(|&&s| s == 1).count()
    );

    // 2. shortest paths from the top hub
    let hub = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let sp = ShortestPaths::compute(&g, hub, &engine);
    println!(
        "shortest paths from hub {hub} (degree {}): eccentricity {}",
        g.degree(hub),
        sp.eccentricity()
    );
    let far = (0..g.num_vertices() as u32)
        .filter(|&v| sp.distance(v).is_some())
        .max_by_key(|&v| sp.distance(v).unwrap())
        .unwrap();
    let path = sp.path_to(far).unwrap();
    println!("  farthest reachable vertex {far}: path {path:?}");

    // 3. sampled betweenness centrality (64 BFS sources, Bader-style) —
    //    the forward passes run as four shared 16-source MS waves
    let sources: Vec<u32> = (0..64u32).map(|i| (i * 61) % g.num_vertices() as u32).collect();
    let bc = betweenness_centrality(&g, &sources, &engine);
    let mut top: Vec<usize> = (0..g.num_vertices()).collect();
    top.sort_by(|&a, &b| bc[b].total_cmp(&bc[a]));
    println!("betweenness (sampled over {} sources), top 5:", sources.len());
    for &v in top.iter().take(5) {
        println!("  vertex {v:>5}  bc={:>12.1}  degree={}", bc[v], g.degree(v as u32));
    }
    println!("analytics OK");
}
