//! Three-layer composition proof: run BFS whose per-layer hot loop is the
//! AOT-compiled JAX/Pallas kernel (Listing 1 explore + restoration),
//! loaded from `artifacts/*.hlo.txt` and executed through the PJRT CPU
//! client — then cross-validate every distance against the native Rust
//! vectorized implementation.
//!
//! ```bash
//! make artifacts && cargo run --release --example pjrt_bfs
//! ```

use phi_bfs::bfs::policy::LayerPolicy;
use phi_bfs::bfs::validate::validate;
use phi_bfs::bfs::vectorized::{SimdOpts, VectorizedBfs};
use phi_bfs::bfs::BfsEngine;
use phi_bfs::graph::{Csr, RmatConfig};
use phi_bfs::runtime::bfs::PjrtBfs;

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());

    // a SCALE-10 Graph500 graph fits the n=1024 artifact bucket
    let scale = 10u32;
    let el = RmatConfig::graph500(scale, 8).generate(7);
    let g = Csr::from_edge_list(scale, &el);
    let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
    println!(
        "graph: {} vertices, {} directed edges, root {}",
        g.num_vertices(),
        g.num_directed_edges(),
        root
    );

    // Layer 3 → Layer 2 → Layer 1: the PJRT-backed engine
    let engine = PjrtBfs::from_dir(&artifact_dir)?;
    let t0 = std::time::Instant::now();
    let pjrt_result = engine.run_checked(&g, root)?;
    println!(
        "pjrt engine: reached {} vertices in {} layers ({:.2?} total, includes executable compile)",
        pjrt_result.tree.reached_count(),
        pjrt_result.trace.layers.len(),
        t0.elapsed()
    );
    for l in &pjrt_result.trace.layers {
        println!(
            "  layer {}: {:>5} in → {:>5} discovered  ({:>8} edge lanes)",
            l.layer, l.input_vertices, l.traversed, l.edges_scanned
        );
    }

    // the native emulated-VPU implementation on the same graph
    let native = VectorizedBfs {
        num_threads: 1,
        opts: SimdOpts::full(),
        policy: LayerPolicy::All,
        ..Default::default()
    }
    .run(&g, root);

    // cross-validate: identical distance maps (predecessors may differ by
    // the benign race; distances must not)
    let d_pjrt = pjrt_result.tree.distances().expect("pjrt tree valid");
    let d_native = native.tree.distances().expect("native tree valid");
    assert_eq!(d_pjrt, d_native, "pjrt and native BFS disagree");
    println!("cross-check: pjrt distances == native emulated-VPU distances ✓");

    // Graph500 five-check validation of the PJRT tree
    let report = validate(&g, &pjrt_result.tree);
    println!("validation:\n{}", report.summary());
    assert!(report.all_passed());

    println!("pjrt_bfs OK — Rust coordinator → XLA/PJRT → Pallas kernel all compose");
    Ok(())
}
