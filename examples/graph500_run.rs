//! End-to-end driver (the repository's E2E validation workload): a full
//! Graph500-style experiment — RMAT kernel 0, 64 random roots, the
//! engine ladder, five-check validation per tree, TEPS statistics with the
//! paper's harmonic-mean quirk, and a Phi-model projection of the same
//! measured workload. Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example graph500_run -- --scale 14 --engine simd
//! ```

use phi_bfs::cli::Args;
use phi_bfs::coordinator::engine::EngineKind;
use phi_bfs::harness::report::{mteps, sci, Table};
use phi_bfs::harness::runner::Experiment;
use phi_bfs::phi::cost::CostParams;
use phi_bfs::phi::{predict, Affinity, KncParams, WorkTrace};

fn main() -> anyhow::Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    argv.insert(0, "run".to_string());
    let args = Args::parse(argv)?;
    let scale: u32 = args.get("scale", 14)?;
    let edgefactor: usize = args.get("edgefactor", 16)?;
    let threads: usize = args.get("threads", 2)?;
    let engine_name = args.get_str("engine", "simd");
    let engine = EngineKind::parse(&engine_name, threads, &args.get_str("artifacts", "artifacts"))?;

    let mut exp = Experiment::new(scale, edgefactor, engine);
    exp.num_roots = args.get("roots", 64)?;
    exp.workers = args.get("workers", 1)?;
    exp.seed = args.get("seed", 1)?;

    println!("=== Graph500 end-to-end run ===");
    println!(
        "SCALE={scale} edgefactor={edgefactor} engine={engine_name} threads={threads} roots={}",
        exp.num_roots
    );
    let report = exp.run()?;
    println!(
        "kernel 0: {} vertices, {} directed edges in {:.3}s",
        report.num_vertices, report.num_directed_edges, report.construction_seconds
    );
    println!(
        "kernel 1: engine prepared once in {:.4}s (graph-level layouts + stats, \
         shared across all {} roots)",
        report.preparation_seconds,
        report.runs.len()
    );
    println!(
        "kernel 2: {} traversals, {} zero-TEPS (unconnected) roots, validation: {}",
        report.runs.len(),
        report.stats.zero_runs,
        if report.all_valid { "64/64 trees passed all 5 checks" } else { "FAILED" }
    );
    assert!(report.all_valid, "validation failed");

    let s = &report.stats;
    let mut t = Table::new(&["statistic", "TEPS", "MTEPS"]);
    t.row(&["min (connected)".into(), sci(s.min), mteps(s.min)]);
    t.row(&["max".into(), sci(s.max), mteps(s.max)]);
    t.row(&["arithmetic mean".into(), sci(s.arithmetic_mean), mteps(s.arithmetic_mean)]);
    t.row(&[
        "harmonic mean (graph500, unfiltered)".into(),
        sci(s.harmonic_mean_graph500),
        mteps(s.harmonic_mean_graph500),
    ]);
    t.row(&[
        "harmonic mean (filtered)".into(),
        sci(s.harmonic_mean_filtered),
        mteps(s.harmonic_mean_filtered),
    ]);
    print!("{}", t.render());
    if s.zero_runs > 0 && s.harmonic_mean_graph500 > s.max {
        println!(
            "note: unfiltered harmonic mean exceeds max TEPS — the §5.3 quirk, reproduced ({} zero-TEPS roots)",
            s.zero_runs
        );
    }

    // Phi-model projection of the measured workload (first connected root)
    if let Some(run) = report.runs.iter().find(|r| r.reached > 1) {
        let knc = KncParams::default();
        let cp = CostParams::default();
        let trace = WorkTrace::from_run(report.num_vertices, &run.trace);
        println!("\nXeon Phi projection of this workload (root {}):", run.root);
        for threads in [48usize, 118, 236] {
            let p = predict(&knc, &cp, &trace, threads, Affinity::Balanced);
            println!(
                "  {threads:>3} threads balanced → {} TEPS ({} MTEPS)",
                sci(p.teps),
                mteps(p.teps)
            );
        }
    }
    println!("graph500_run OK");
    Ok(())
}
