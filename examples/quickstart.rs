//! Quickstart: generate a Graph500 RMAT graph, run the paper's vectorized
//! BFS, validate the spanning tree, and print what happened.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use phi_bfs::bfs::policy::LayerPolicy;
use phi_bfs::bfs::validate::validate;
use phi_bfs::bfs::vectorized::{SimdOpts, VectorizedBfs};
use phi_bfs::bfs::BfsEngine;
use phi_bfs::graph::{Csr, RmatConfig};

fn main() {
    // 1. A SCALE-14 Graph500 graph: 16,384 vertices, ~262k generated edges.
    let config = RmatConfig::graph500(14, 16);
    let edges = config.generate(42);
    let graph = Csr::from_edge_list(14, &edges);
    println!(
        "graph: {} vertices, {} directed edges",
        graph.num_vertices(),
        graph.num_directed_edges()
    );

    // 2. Run the vectorized top-down BFS (Listing 1 on the emulated VPU,
    //    restoration process, SIMD on the heavy layers per §4.1). Engines
    //    are two-phase: prepare() binds the engine to the graph once
    //    (degree stats, aligned padded-CSR view), then run() traverses any
    //    number of roots against the shared prepared state.
    let algorithm = VectorizedBfs {
        num_threads: 4,
        opts: SimdOpts::full(),
        policy: LayerPolicy::heavy(),
        ..Default::default()
    };
    let prepared = algorithm.prepare(&graph).expect("prepare");
    let root = (0..graph.num_vertices() as u32)
        .max_by_key(|&v| graph.degree(v))
        .unwrap();
    let result = prepared.run(root);

    println!(
        "bfs from {}: reached {} vertices in {} layers",
        root,
        result.tree.reached_count(),
        result.trace.layers.len()
    );
    for layer in &result.trace.layers {
        println!(
            "  layer {}: {:>6} in, {:>8} edges, {:>6} discovered{}{}",
            layer.layer,
            layer.input_vertices,
            layer.edges_scanned,
            layer.traversed,
            if layer.vectorized { "  [simd]" } else { "  [scalar]" },
            if layer.restore_fixed > 0 {
                format!("  ({} lost bits restored)", layer.restore_fixed)
            } else {
                String::new()
            }
        );
    }

    // 3. The §3.3.2 machinery at work: scatter conflicts happened and were
    //    repaired.
    let vpu = result.trace.vpu_totals();
    println!(
        "vpu: {} full chunks, {} gather lanes, {} scatter conflicts (all repaired)",
        vpu.full_chunks, vpu.gather_lanes, vpu.scatter_conflicts
    );

    // 4. Graph500's five soft checks.
    let report = validate(&graph, &result.tree);
    println!("validation:\n{}", report.summary());
    assert!(report.all_passed());
    println!("quickstart OK");
}
