//! The §4.2/§6.2 thread-affinity and hyperthreading study:
//!
//! 1. Table 2 — 48 threads manually pinned 1..4 threads/core.
//! 2. The three `KMP_AFFINITY` strategies across partial populations
//!    (the "balanced is generally better" claim).
//! 3. The §6.2 hyperthreading sweep: slope breaks at 60/120/180 threads
//!    and the OS-core cliff past 236.
//!
//! ```bash
//! cargo run --release --example affinity_study
//! ```

use phi_bfs::harness::report::{mteps, sci, Table};
use phi_bfs::phi::cost::CostParams;
use phi_bfs::phi::{predict, Affinity, KncParams, WorkTrace};

fn main() {
    let knc = KncParams::default();
    let cp = CostParams::default();
    let trace =
        WorkTrace::synthesize_simd(1 << 20, phi_bfs::phi::trace::TABLE1_SCALE20, true, true);

    println!("=== Table 2: 48 threads, manual threads-per-core ===");
    let mut t = Table::new(&["#Threads", "Affinity", "Cores", "TEPS", "paper"]);
    for (k, paper) in (1..=4).zip(["4.69E+08", "2.67E+08", "1.89E+08", "1.42E+08"]) {
        let p = predict(&knc, &cp, &trace, 48, Affinity::Manual(k));
        t.row(&[
            "48".into(),
            format!("{k}T/C"),
            p.cores_used.to_string(),
            sci(p.teps),
            paper.into(),
        ]);
    }
    print!("{}", t.render());

    println!("\n=== KMP_AFFINITY strategies at partial population ===");
    let mut t = Table::new(&["Threads", "compact", "scatter", "balanced"]);
    for threads in [24usize, 48, 96, 118, 180, 236] {
        let row: Vec<String> = [Affinity::Compact, Affinity::Scatter, Affinity::Balanced]
            .iter()
            .map(|&a| mteps(predict(&knc, &cp, &trace, threads, a).teps))
            .collect();
        t.row(&[threads.to_string(), row[0].clone(), row[1].clone(), row[2].clone()]);
    }
    print!("{}", t.render());
    println!("(paper: \"balanced affinity was generally better\")");

    println!("\n=== Hyperthreading sweep (balanced): slope breaks at 60/120/180 ===");
    let mut t = Table::new(&["Threads", "T/C", "MTEPS", "ΔMTEPS/thread"]);
    let mut prev: Option<(usize, f64)> = None;
    for threads in [1usize, 30, 59, 90, 118, 150, 177, 200, 236, 240] {
        let p = predict(&knc, &cp, &trace, threads, Affinity::Balanced);
        let slope = prev
            .map(|(pt, pv)| (p.teps - pv) / 1e6 / (threads - pt) as f64)
            .map(|s| format!("{s:+.2}"))
            .unwrap_or_default();
        t.row(&[
            threads.to_string(),
            p.max_threads_per_core.to_string(),
            mteps(p.teps),
            slope,
        ]);
        prev = Some((threads, p.teps));
    }
    print!("{}", t.render());
    println!("(240 threads invade the OS core → the §6.2 cliff)");
    println!("affinity_study OK");
}
