//! A miniature property-testing kit (proptest is not in the offline crate
//! registry): seeded case generation with failure reporting that prints
//! the reproducing seed. No shrinking — cases are kept small instead.
//!
//! ```
//! use phi_bfs::prop::{forall, Gen};
//! forall("addition commutes", 64, |g| {
//!     let (a, b) = (g.int(0, 100), g.int(0, 100));
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::rng::Xoshiro256;

/// Per-case random source with convenience generators.
pub struct Gen {
    rng: Xoshiro256,
    /// Case index (exposed for size-scaling strategies).
    pub case: usize,
}

impl Gen {
    /// Integer in `[lo, hi]` inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as i64
    }

    /// usize in `[lo, hi]` inclusive.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    /// Uniform f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        self.rng.next_f64()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.next_bool(p)
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_index(xs.len())]
    }

    /// A vector of length `len` built by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Random edge list over `n` vertices (possibly with duplicates and
    /// self-loops, like the Graph500 raw stream).
    pub fn edges(&mut self, n: usize, m: usize) -> Vec<(u32, u32)> {
        self.vec(m, |g| (g.size(0, n - 1) as u32, g.size(0, n - 1) as u32))
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Xoshiro256 {
        &mut self.rng
    }
}

/// Default base seed ("PROPSEED" in ASCII).
pub const DEFAULT_SEED: u64 = 0x5052_4f50_5345_4544;

/// Run `body` on `cases` generated cases. On panic, re-raises with the
/// property name, case index and base seed so the failure is reproducible
/// with `forall_seeded`.
pub fn forall(name: &str, cases: usize, body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    forall_seeded(name, DEFAULT_SEED, cases, body)
}

/// `forall` with an explicit base seed (use the seed printed by a failure).
pub fn forall_seeded(
    name: &str,
    base_seed: u64,
    cases: usize,
    body: impl Fn(&mut Gen) + std::panic::RefUnwindSafe,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Xoshiro256::seed_from_u64(seed), case };
            body(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property {name:?} failed at case {case}/{cases} (base_seed={base_seed:#x}, case_seed={seed:#x}):\n{msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall("reverse twice is identity", 32, |g| {
            let len = g.size(0, 20);
            let v = g.vec(len, |g| g.int(-5, 5));
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn reports_failure_with_seed() {
        forall("always fails", 4, |g| {
            let x = g.int(0, 10);
            assert!(x > 100, "x={x}");
        });
    }

    #[test]
    fn gen_ranges() {
        forall("int in range", 64, |g| {
            let x = g.int(-3, 7);
            assert!((-3..=7).contains(&x));
        });
    }

    #[test]
    fn deterministic_per_base_seed() {
        let collect = |seed: u64| {
            let out = std::sync::Mutex::new(Vec::new());
            forall_seeded("collect", seed, 8, |g| {
                out.lock().unwrap().push(g.int(0, 1000));
            });
            out.into_inner().unwrap()
        };
        assert_eq!(collect(42), collect(42));
        assert_ne!(collect(42), collect(43));
    }

    #[test]
    fn edges_in_range() {
        forall("edges", 16, |g| {
            let n = g.size(2, 50);
            for (a, b) in g.edges(n, 30) {
                assert!((a as usize) < n && (b as usize) < n);
            }
        });
    }
}
