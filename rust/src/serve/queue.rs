//! The deadline-aware batching queue at the heart of the serve daemon.
//!
//! Requests accumulate **per graph** (a wave is one MS-BFS traversal over
//! one prepared graph, so waves never mix graphs) and a wave flushes when
//! either condition fires first:
//!
//! * **width** — the graph's accumulator reaches the configured batch
//!   width (16: the MS-BFS wave shape of `hybrid-sell-ms`), or
//! * **deadline** — the *earliest* `flush_by` instant among pending
//!   requests passes. Each request's `flush_by` is the enqueue time plus
//!   the queue-wide batch deadline, tightened to ¾ of the request's own
//!   deadline budget when it carries one — a request must leave the queue
//!   with a margin of its budget still in hand for the traversal itself.
//!
//! A draining queue ([`BatchQueue::drain`], the `SHUTDOWN` path) refuses
//! new requests but flushes everything already enqueued as whole
//! per-graph waves, so in-flight clients always get a reply before the
//! daemon exits.
//!
//! Dispatcher threads block in [`BatchQueue::pop_wave`]; connection
//! handlers call [`BatchQueue::push`] and then wait on their request's
//! reply channel. The queue itself never touches a socket or an engine —
//! it only decides *when* and *with what* a wave runs, which is what the
//! unit tests below pin down without any networking.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::Sender;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::Vertex;

/// One enqueued `BFS` request, waiting for its wave.
pub struct PendingBfs {
    pub root: Vertex,
    /// Absolute deadline of the *request* (None = unbounded): the wave's
    /// [`crate::bfs::RunControl`] deadline is derived from the tightest
    /// one in the wave at dispatch time.
    pub deadline: Option<Instant>,
    /// When the request entered the queue — the latency anchor: reply
    /// latency is measured from here, so it includes queueing time.
    pub enqueued: Instant,
    /// Flush the accumulating wave no later than this, even if the width
    /// has not been reached.
    pub flush_by: Instant,
    /// Reply channel back to the connection handler (a pre-formatted
    /// protocol line).
    pub reply: Sender<String>,
}

/// Why a wave left the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlushTrigger {
    /// The per-graph accumulator reached the batch width.
    Width,
    /// The oldest request's flush-by margin passed.
    Deadline,
    /// The queue is draining for shutdown.
    Drain,
}

impl FlushTrigger {
    /// The protocol token (`trigger=` value in a `BFS` reply).
    pub fn as_str(self) -> &'static str {
        match self {
            FlushTrigger::Width => "width",
            FlushTrigger::Deadline => "deadline",
            FlushTrigger::Drain => "drain",
        }
    }
}

#[derive(Default)]
struct QueueState {
    /// Per-graph accumulators, keyed by the registry's numeric graph id.
    pending: HashMap<u64, VecDeque<PendingBfs>>,
    draining: bool,
}

/// Per-graph accumulators + the flush policy. Shared by reference between
/// connection handlers (push) and dispatcher threads (pop).
pub struct BatchQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    width: usize,
    batch_deadline: Duration,
}

impl BatchQueue {
    pub fn new(width: usize, batch_deadline: Duration) -> Self {
        BatchQueue {
            state: Mutex::new(QueueState::default()),
            ready: Condvar::new(),
            width: width.max(1),
            batch_deadline,
        }
    }

    /// Roots per width-triggered wave (≥ 1).
    pub fn width(&self) -> usize {
        self.width
    }

    /// The queue-wide accumulation bound.
    pub fn batch_deadline(&self) -> Duration {
        self.batch_deadline
    }

    fn lock(&self) -> MutexGuard<'_, QueueState> {
        self.state.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Enqueue one request on `graph`'s accumulator. `Err` hands the
    /// request back when the queue is draining — the caller replies with
    /// a `shutting-down` error instead of enqueueing into the void.
    pub fn push(&self, graph: u64, req: PendingBfs) -> Result<(), PendingBfs> {
        let mut st = self.lock();
        if st.draining {
            return Err(req);
        }
        st.pending.entry(graph).or_default().push_back(req);
        // wake every dispatcher: one may flush by width while another
        // recomputes its deadline wait
        self.ready.notify_all();
        Ok(())
    }

    /// Requests currently accumulated across all graphs.
    pub fn depth(&self) -> usize {
        self.lock().pending.values().map(|q| q.len()).sum()
    }

    /// Switch to drain mode: refuse new pushes, flush what is pending,
    /// and make `pop_wave` return `None` once empty.
    pub fn drain(&self) {
        self.lock().draining = true;
        self.ready.notify_all();
    }

    /// Block until a wave is ready and take it: `(graph, requests,
    /// trigger)`. Width-full graphs flush first (exactly `width`
    /// requests, oldest first); otherwise the earliest expired `flush_by`
    /// flushes its whole graph accumulator. Returns `None` only when the
    /// queue is draining and empty — the dispatcher's exit signal.
    pub fn pop_wave(&self) -> Option<(u64, Vec<PendingBfs>, FlushTrigger)> {
        let mut st = self.lock();
        loop {
            // 1. width-triggered: any graph with a full wave flushes now
            let full = st.pending.iter().find(|(_, q)| q.len() >= self.width).map(|(&g, _)| g);
            if let Some(g) = full {
                let q = st.pending.get_mut(&g).expect("key found above");
                let wave: Vec<PendingBfs> = q.drain(..self.width).collect();
                if q.is_empty() {
                    st.pending.remove(&g);
                }
                return Some((g, wave, FlushTrigger::Width));
            }
            // 2. the earliest flush_by across graphs decides what's next
            let now = Instant::now();
            let next = st
                .pending
                .iter()
                .filter_map(|(&g, q)| q.iter().map(|p| p.flush_by).min().map(|t| (t, g)))
                .min_by_key(|&(t, _)| t);
            if st.draining {
                // drain mode: flush whatever is left, graph by graph
                // (still whole per-graph waves — never mixed)
                if let Some((_, g)) = next {
                    let q = st.pending.remove(&g).expect("key found above");
                    return Some((g, Vec::from(q), FlushTrigger::Drain));
                }
                return None;
            }
            match next {
                Some((t, g)) if t <= now => {
                    let q = st.pending.remove(&g).expect("key found above");
                    return Some((g, Vec::from(q), FlushTrigger::Deadline));
                }
                Some((t, _)) => {
                    // sleep until the earliest margin (or a push/drain)
                    let (guard, _timeout) = self
                        .ready
                        .wait_timeout(st, t.saturating_duration_since(now))
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                    st = guard;
                }
                None => {
                    st = self.ready.wait(st).unwrap_or_else(|poisoned| poisoned.into_inner());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// A pending request whose reply channel goes nowhere (these tests
    /// exercise flush policy, not dispatch).
    fn pending(root: Vertex, flush_in: Duration) -> PendingBfs {
        let (tx, _rx) = mpsc::channel();
        let now = Instant::now();
        PendingBfs { root, deadline: None, enqueued: now, flush_by: now + flush_in, reply: tx }
    }

    const FAR: Duration = Duration::from_secs(3600);

    #[test]
    fn full_wave_flushes_immediately_by_width() {
        let q = BatchQueue::new(4, FAR);
        for r in 0..4 {
            q.push(1, pending(r, FAR)).unwrap();
        }
        let t0 = Instant::now();
        let (g, wave, trigger) = q.pop_wave().expect("wave ready");
        assert!(t0.elapsed() < Duration::from_millis(500), "no deadline wait");
        assert_eq!(g, 1);
        assert_eq!(trigger, FlushTrigger::Width);
        assert_eq!(wave.iter().map(|p| p.root).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn width_flush_takes_exactly_width_oldest_first() {
        let q = BatchQueue::new(2, FAR);
        for r in 0..5 {
            q.push(1, pending(r, FAR)).unwrap();
        }
        let (_, wave, _) = q.pop_wave().unwrap();
        assert_eq!(wave.iter().map(|p| p.root).collect::<Vec<_>>(), vec![0, 1]);
        let (_, wave, _) = q.pop_wave().unwrap();
        assert_eq!(wave.iter().map(|p| p.root).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(q.depth(), 1, "the straggler keeps waiting for its margin");
    }

    #[test]
    fn lone_request_flushes_at_its_margin() {
        let q = BatchQueue::new(16, FAR);
        q.push(1, pending(7, Duration::from_millis(50))).unwrap();
        let t0 = Instant::now();
        let (g, wave, trigger) = q.pop_wave().expect("wave ready");
        let waited = t0.elapsed();
        assert_eq!((g, wave.len()), (1, 1));
        assert_eq!(trigger, FlushTrigger::Deadline);
        assert!(waited >= Duration::from_millis(30), "flushed early: {waited:?}");
        assert!(waited < Duration::from_secs(30), "flushed far too late: {waited:?}");
    }

    #[test]
    fn graphs_never_share_a_wave() {
        let q = BatchQueue::new(2, FAR);
        q.push(1, pending(10, FAR)).unwrap();
        q.push(2, pending(20, FAR)).unwrap();
        q.push(1, pending(11, FAR)).unwrap();
        let (g, wave, trigger) = q.pop_wave().unwrap();
        assert_eq!(g, 1, "only graph 1 has a full wave");
        assert_eq!(trigger, FlushTrigger::Width);
        assert_eq!(wave.iter().map(|p| p.root).collect::<Vec<_>>(), vec![10, 11]);
        // graph 2's lone request drains as its own wave
        q.drain();
        let (g, wave, trigger) = q.pop_wave().unwrap();
        assert_eq!((g, wave.len()), (2, 1));
        assert_eq!(trigger, FlushTrigger::Drain);
        assert!(q.pop_wave().is_none(), "drained and empty");
    }

    #[test]
    fn draining_queue_refuses_new_requests() {
        let q = BatchQueue::new(4, FAR);
        q.drain();
        assert!(q.push(1, pending(0, FAR)).is_err());
        assert!(q.pop_wave().is_none());
    }
}
