//! Per-graph circuit breaker: fail fast while a graph is sick, recover
//! without thundering herds.
//!
//! A graph whose waves keep failing — a hung engine being abandoned by the
//! watchdog over and over, a poisoned artifact, injected chaos — would
//! otherwise burn a dispatcher seat, a supervised worker, and every
//! client's deadline on each doomed wave. The breaker is the classic
//! three-state machine, scoped per loaded graph:
//!
//! * **Closed** — healthy. Wave failures increment a consecutive-failure
//!   streak; any wave success resets it. When the streak reaches the
//!   threshold the breaker trips to Open.
//! * **Open** — sick. `BFS` requests for the graph are fast-failed with
//!   `ERR unavailable <retry-after-ms> ...` *before* they touch the queue
//!   (the retry-after hint is the time left in the cooldown). Other graphs
//!   are untouched — the breaker is the isolation boundary between one
//!   sick graph and the rest of the daemon.
//! * **Half-open** — probing. Once the cooldown lapses, [`CircuitBreaker::probe`]
//!   hands exactly one caller (the server's dispatcher, which sends its
//!   own probe wave — recovery does not depend on client traffic) the
//!   right to run a trial wave. Success closes the breaker; failure
//!   re-opens it for another cooldown. Requests arriving mid-probe still
//!   fast-fail.
//!
//! The breaker itself is transport-agnostic and lock-cheap (one small
//! mutex per graph, touched once per request and once per wave outcome);
//! the server layers the protocol reply and metrics on top.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::coordinator::scheduler::lock_unpoisoned;

/// When a breaker trips and how long it stays open.
#[derive(Clone, Copy, Debug)]
pub struct BreakerPolicy {
    /// Consecutive wave failures that trip the breaker (clamped to ≥ 1).
    pub threshold: u32,
    /// How long the breaker stays open before a half-open probe may run.
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy { threshold: 3, cooldown: Duration::from_millis(500) }
    }
}

#[derive(Clone, Copy, Debug)]
enum State {
    Closed { consecutive_failures: u32 },
    Open { until: Instant },
    /// A probe wave is in flight; its outcome decides the next state.
    HalfOpen,
}

/// What the breaker says about an incoming `BFS` request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Closed (or probing): let the request through to the queue.
    Allow,
    /// Open: reject immediately; retry after this many milliseconds.
    FastFail { retry_after_ms: u64 },
}

/// One graph's breaker. Shared by reference between connection handlers
/// (admission) and dispatchers (wave outcomes + probes).
pub struct CircuitBreaker {
    policy: BreakerPolicy,
    state: Mutex<State>,
}

impl CircuitBreaker {
    pub fn new(policy: BreakerPolicy) -> Self {
        let policy = BreakerPolicy { threshold: policy.threshold.max(1), ..policy };
        CircuitBreaker { policy, state: Mutex::new(State::Closed { consecutive_failures: 0 }) }
    }

    /// Admission check for one `BFS` request at time `now`. Requests are
    /// admitted while the breaker is closed, and also while a probe is in
    /// flight *only* in the sense that the probe itself runs — client
    /// requests during Open and HalfOpen both fast-fail, so one probe wave
    /// (not a client stampede) decides recovery.
    pub fn admit(&self, now: Instant) -> Admission {
        let state = lock_unpoisoned(&self.state);
        match *state {
            State::Closed { .. } => Admission::Allow,
            State::Open { until } => {
                let left = until.saturating_duration_since(now);
                if left.is_zero() {
                    // cooldown over but no probe has run yet: keep
                    // fast-failing with a minimal hint until the
                    // dispatcher's probe settles the matter
                    Admission::FastFail { retry_after_ms: 1 }
                } else {
                    Admission::FastFail { retry_after_ms: (left.as_millis() as u64).max(1) }
                }
            }
            State::HalfOpen => Admission::FastFail {
                retry_after_ms: (self.policy.cooldown.as_millis() as u64).max(1),
            },
        }
    }

    /// True when the cooldown of an open breaker has lapsed and no probe
    /// is in flight: the caller (one dispatcher) wins the right to run the
    /// half-open probe wave and MUST report its outcome via
    /// [`CircuitBreaker::record_success`] / [`CircuitBreaker::record_failure`].
    pub fn probe(&self, now: Instant) -> bool {
        let mut state = lock_unpoisoned(&self.state);
        match *state {
            State::Open { until } if now >= until => {
                *state = State::HalfOpen;
                true
            }
            _ => false,
        }
    }

    /// A wave for this graph succeeded: closes a half-open breaker, resets
    /// the failure streak of a closed one.
    pub fn record_success(&self) {
        let mut state = lock_unpoisoned(&self.state);
        *state = State::Closed { consecutive_failures: 0 };
    }

    /// A wave for this graph failed (every root Failed, or the dispatch
    /// itself errored). Returns `true` when this failure *tripped* the
    /// breaker open (so the caller can count distinct opens, not failures).
    pub fn record_failure(&self, now: Instant) -> bool {
        let mut state = lock_unpoisoned(&self.state);
        match *state {
            State::Closed { consecutive_failures } => {
                let streak = consecutive_failures + 1;
                if streak >= self.policy.threshold {
                    *state = State::Open { until: now + self.policy.cooldown };
                    true
                } else {
                    *state = State::Closed { consecutive_failures: streak };
                    false
                }
            }
            // the probe failed: back to open for another cooldown (counted
            // as a re-open so HEALTH watchers see the flap)
            State::HalfOpen => {
                *state = State::Open { until: now + self.policy.cooldown };
                true
            }
            // already open (e.g. a straggler wave that was in flight when
            // the breaker tripped): stay open, don't extend the cooldown
            State::Open { .. } => false,
        }
    }

    /// One-word state name for `HEALTH`: `closed`, `open`, or `half-open`.
    pub fn state_name(&self) -> &'static str {
        match *lock_unpoisoned(&self.state) {
            State::Closed { .. } => "closed",
            State::Open { .. } => "open",
            State::HalfOpen => "half-open",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker(threshold: u32, cooldown_ms: u64) -> CircuitBreaker {
        CircuitBreaker::new(BreakerPolicy {
            threshold,
            cooldown: Duration::from_millis(cooldown_ms),
        })
    }

    #[test]
    fn stays_closed_below_the_threshold_and_success_resets_the_streak() {
        let b = breaker(3, 100);
        let now = Instant::now();
        assert_eq!(b.admit(now), Admission::Allow);
        assert!(!b.record_failure(now));
        assert!(!b.record_failure(now));
        b.record_success(); // streak back to 0
        assert!(!b.record_failure(now));
        assert!(!b.record_failure(now), "streak restarted after the success");
        assert_eq!(b.admit(now), Admission::Allow);
        assert_eq!(b.state_name(), "closed");
    }

    #[test]
    fn opens_at_the_threshold_and_fast_fails_with_a_retry_hint() {
        let b = breaker(2, 250);
        let now = Instant::now();
        assert!(!b.record_failure(now));
        assert!(b.record_failure(now), "the tripping failure reports the open");
        assert_eq!(b.state_name(), "open");
        match b.admit(now) {
            Admission::FastFail { retry_after_ms } => {
                assert!(
                    retry_after_ms >= 1 && retry_after_ms <= 250,
                    "hint {retry_after_ms} must be within the cooldown"
                );
            }
            Admission::Allow => panic!("open breaker must not admit"),
        }
        // mid-cooldown the hint shrinks with the clock
        match b.admit(now + Duration::from_millis(200)) {
            Admission::FastFail { retry_after_ms } => assert!(retry_after_ms <= 50),
            Admission::Allow => panic!("still within cooldown"),
        }
    }

    #[test]
    fn half_open_probe_closes_on_success() {
        let b = breaker(1, 50);
        let t0 = Instant::now();
        assert!(b.record_failure(t0));
        assert!(!b.probe(t0), "no probe before the cooldown lapses");
        let later = t0 + Duration::from_millis(51);
        assert!(b.probe(later), "cooldown over: probe granted");
        assert_eq!(b.state_name(), "half-open");
        assert!(!b.probe(later), "exactly one probe at a time");
        assert_ne!(b.admit(later), Admission::Allow, "clients fast-fail mid-probe");
        b.record_success();
        assert_eq!(b.state_name(), "closed");
        assert_eq!(b.admit(later), Admission::Allow);
    }

    #[test]
    fn half_open_probe_reopens_on_failure() {
        let b = breaker(1, 50);
        let t0 = Instant::now();
        assert!(b.record_failure(t0));
        let later = t0 + Duration::from_millis(51);
        assert!(b.probe(later));
        assert!(b.record_failure(later), "a failed probe re-opens (a counted open)");
        assert_eq!(b.state_name(), "open");
        assert!(!b.probe(later), "fresh cooldown must lapse before the next probe");
        assert!(b.probe(later + Duration::from_millis(51)));
    }

    #[test]
    fn straggler_failures_while_open_do_not_extend_the_cooldown() {
        let b = breaker(1, 50);
        let t0 = Instant::now();
        assert!(b.record_failure(t0));
        assert!(!b.record_failure(t0 + Duration::from_millis(25)), "not a new open");
        // the original cooldown still governs the probe
        assert!(b.probe(t0 + Duration::from_millis(51)));
    }

    #[test]
    fn zero_threshold_clamps_to_one() {
        let b = breaker(0, 50);
        assert!(b.record_failure(Instant::now()), "first failure trips");
    }
}
