//! The newline-delimited text protocol `phi-bfs serve` speaks.
//!
//! One request per line, one reply line per request, all ASCII:
//!
//! ```text
//! LOAD <path|rmat:SCALE:EF:SEED> [sigma]   → OK LOAD id=gN vertices=V directed_edges=E
//! BFS <graph-id> <root> [deadline-ms]      → OK BFS root=.. reached=.. edges=.. depth=..
//!                                            checksum=<16-hex> status=.. wave_width=..
//!                                            trigger=<width|deadline|drain> latency_ms=..
//! STATS                                    → OK STATS <ServeSnapshot line>
//! HEALTH                                   → OK HEALTH status=<ok|draining> accepting=..
//!                                            graphs=.. queue_depth=.. pressure_events=..
//!                                            watchdog_fires=.. hung_waves=..
//!                                            breakers=<id:state[:retry-ms],..|none>
//! SHUTDOWN                                 → OK SHUTDOWN draining
//! ```
//!
//! Every failure is a single structured line, `ERR <kind> <detail>`, with
//! `kind` one of `parse`, `load`, `unknown-graph`, `root-out-of-bounds`,
//! `rejected`, `unavailable`, `expired`, `over-budget`, `failed`,
//! `shutting-down`, `internal` — so a client can
//! dispatch on the kind token without parsing prose (mirroring how the
//! daemon itself dispatches on [`crate::coordinator::CoordinatorError`]).
//! `ERR unavailable` (an open circuit breaker) and `ERR rejected`
//! (admission control) both lead their detail with a retry-after hint in
//! milliseconds; `ERR expired` means the request's own deadline lapsed
//! while it sat in the queue. Request lines longer than the daemon's line
//! cap are answered `ERR parse line-too-long ...` and the connection
//! resynchronizes at the next newline.

use crate::Vertex;

/// Ceiling on a request's `deadline-ms` (one day): keeps
/// `Instant + Duration` arithmetic far from overflow while allowing any
/// deadline a real client would set.
pub const MAX_DEADLINE_MS: u64 = 86_400_000;

/// A parsed request line.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// `LOAD <spec> [sigma]` — load a graph (binary CSR file, edge-list
    /// file, or generated `rmat:SCALE:EF:SEED`), optionally with a SELL
    /// sorting window σ for the engines that take one.
    Load { spec: String, sigma: Option<usize> },
    /// `BFS <graph-id> <root> [deadline-ms]` — enqueue one traversal
    /// request; it joins the graph's accumulating wave.
    Bfs { graph: String, root: Vertex, deadline_ms: Option<u64> },
    /// `STATS` — one-line serving snapshot.
    Stats,
    /// `HEALTH` — one-line liveness/readiness report: accepting vs
    /// draining, queue depth, supervision counters, and every graph's
    /// circuit-breaker state.
    Health,
    /// `SHUTDOWN` — drain pending waves, then exit.
    Shutdown,
}

/// Parse one request line. The error string is ready to ship inside an
/// `ERR parse` reply.
pub fn parse_request(line: &str) -> Result<Request, String> {
    let mut it = line.split_whitespace();
    let Some(cmd) = it.next() else {
        return Err("empty request".to_string());
    };
    let req = match cmd.to_ascii_uppercase().as_str() {
        "LOAD" => {
            let spec = it
                .next()
                .ok_or("LOAD needs a graph spec (a file path or rmat:SCALE:EF:SEED)")?
                .to_string();
            let sigma = match it.next() {
                None => None,
                Some(s) => Some(
                    s.parse::<usize>()
                        .map_err(|_| format!("LOAD sigma: cannot parse {s:?}"))?,
                ),
            };
            Request::Load { spec, sigma }
        }
        "BFS" => {
            let graph = it.next().ok_or("BFS needs a graph id (from LOAD)")?.to_string();
            let root = it.next().ok_or("BFS needs a root vertex")?;
            let root: Vertex =
                root.parse().map_err(|_| format!("BFS root: cannot parse {root:?}"))?;
            let deadline_ms = match it.next() {
                None => None,
                Some(s) => {
                    let ms: u64 = s
                        .parse()
                        .map_err(|_| format!("BFS deadline-ms: cannot parse {s:?}"))?;
                    Some(ms.min(MAX_DEADLINE_MS))
                }
            };
            Request::Bfs { graph, root, deadline_ms }
        }
        "STATS" => Request::Stats,
        "HEALTH" => Request::Health,
        "SHUTDOWN" => Request::Shutdown,
        other => {
            return Err(format!(
                "unknown command {other:?} (try LOAD/BFS/STATS/HEALTH/SHUTDOWN)"
            ))
        }
    };
    if it.next().is_some() {
        return Err(format!("trailing arguments after {cmd}"));
    }
    Ok(req)
}

/// Render a structured error reply. `detail` is flattened to one line so
/// a multi-line error (an anyhow chain, a panic message) can never break
/// the one-reply-per-line framing.
pub fn err_line(kind: &str, detail: &str) -> String {
    let flat: String =
        detail.chars().map(|c| if c == '\n' || c == '\r' { ' ' } else { c }).collect();
    format!("ERR {kind} {flat}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_command() {
        assert_eq!(
            parse_request("LOAD rmat:10:8:1").unwrap(),
            Request::Load { spec: "rmat:10:8:1".into(), sigma: None }
        );
        assert_eq!(
            parse_request("LOAD /tmp/g.csr 128").unwrap(),
            Request::Load { spec: "/tmp/g.csr".into(), sigma: Some(128) }
        );
        assert_eq!(
            parse_request("BFS g1 42").unwrap(),
            Request::Bfs { graph: "g1".into(), root: 42, deadline_ms: None }
        );
        assert_eq!(
            parse_request("BFS g1 0 250").unwrap(),
            Request::Bfs { graph: "g1".into(), root: 0, deadline_ms: Some(250) }
        );
        assert_eq!(parse_request("STATS").unwrap(), Request::Stats);
        assert_eq!(parse_request("HEALTH").unwrap(), Request::Health);
        assert_eq!(parse_request("health").unwrap(), Request::Health, "case-insensitive");
        assert_eq!(parse_request("shutdown").unwrap(), Request::Shutdown, "case-insensitive");
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_request("").is_err());
        assert!(parse_request("NOPE").is_err());
        assert!(parse_request("LOAD").is_err(), "missing spec");
        assert!(parse_request("BFS g1").is_err(), "missing root");
        assert!(parse_request("BFS g1 notanumber").is_err());
        assert!(parse_request("BFS g1 0 -5").is_err(), "negative deadline");
        assert!(parse_request("STATS extra").is_err(), "trailing tokens");
        assert!(parse_request("LOAD spec 64 extra").is_err());
    }

    #[test]
    fn huge_deadlines_clamp() {
        let r = parse_request(&format!("BFS g1 0 {}", u64::MAX)).unwrap();
        assert_eq!(
            r,
            Request::Bfs { graph: "g1".into(), root: 0, deadline_ms: Some(MAX_DEADLINE_MS) }
        );
    }

    #[test]
    fn err_lines_never_contain_newlines() {
        let e = err_line("failed", "first\nsecond\r\nthird");
        assert!(!e.contains('\n') && !e.contains('\r'));
        assert!(e.starts_with("ERR failed "));
    }
}
