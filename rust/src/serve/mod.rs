//! BFS-as-a-service: the `phi-bfs serve` daemon.
//!
//! The paper's fastest configurations are *batch* engines — MS-BFS runs
//! 16 roots per shared traversal, and every prepared engine amortizes
//! its per-graph layout (SELL-16-σ build, degree stats, compiled
//! kernels) across roots. A one-shot CLI can only exploit that when the
//! caller happens to have 16 queries in hand; a daemon can *manufacture*
//! the batch shape from independent clients. That is this subsystem:
//!
//! * [`protocol`] — the newline-delimited text protocol
//!   (`LOAD`/`BFS`/`STATS`/`HEALTH`/`SHUTDOWN`, structured `ERR`
//!   replies).
//! * [`queue`] — the deadline-aware batching queue: per-graph
//!   accumulators that flush at batch width (a full MS-BFS wave) or at
//!   the oldest request's deadline margin, whichever first.
//! * [`server`] — the daemon itself: thread-per-connection acceptor
//!   (bounded line reads), dispatcher pool, wave dispatch through the
//!   supervised, resource-governed [`crate::coordinator::Coordinator`]
//!   (admission-control rejections re-queue after the shed's
//!   backpressure hint, with per-request deadline budgets recomputed),
//!   drain-then-exit shutdown.
//! * [`breaker`] — per-graph circuit breakers: consecutive wave failures
//!   trip a graph open (`ERR unavailable` fast-fails), a server-driven
//!   half-open probe wave closes it again.
//! * [`metrics`] — serving telemetry: lock-free latency histogram
//!   (p50/p99), queue depth, batch fill, flush triggers, artifact-cache
//!   hit rate — the `STATS` reply and the shutdown summary.
//! * [`client`] — the blocking line-protocol client used by the
//!   integration tests, the CI smoke driver (`phi-bfs client`), and the
//!   serving ablation's load generator.

pub mod breaker;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod queue;
pub mod server;

pub use breaker::{Admission, BreakerPolicy, CircuitBreaker};
pub use client::{kv, kv_f64, kv_hex, kv_u64, ServeClient};
pub use metrics::{ServeMetrics, ServeSnapshot};
pub use protocol::{err_line, parse_request, Request};
pub use queue::{BatchQueue, FlushTrigger, PendingBfs};
pub use server::{ServeOptions, Server, MAX_LINE_BYTES};
