//! Serving telemetry: request latency, queue depth, batch fill, and the
//! embedded coordinator counters — everything `STATS` and the shutdown
//! summary report.
//!
//! Latency is recorded into a fixed array of power-of-two-microsecond
//! buckets (lock-free atomics, no allocation on the request path), so
//! p50/p99 are bucket upper bounds: exact enough to steer batching knobs,
//! cheap enough to sit on every reply.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::time::Duration;

use super::queue::FlushTrigger;
use crate::coordinator::MetricsSnapshot;

/// Latency buckets: bucket `i` holds samples whose microsecond count has
/// bit-length `i` (range `[2^(i-1), 2^i)` µs; bucket 0 is `< 1 µs`). 40
/// buckets reach ~2^39 µs ≈ 6 days — every representable request.
const LATENCY_BUCKETS: usize = 40;

/// Power-of-two-bucket latency histogram.
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }
}

impl LatencyHistogram {
    pub fn record(&self, latency: Duration) {
        let micros = latency.as_micros().min(u64::MAX as u128) as u64;
        let idx = (64 - micros.leading_zeros() as usize).min(LATENCY_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
    }

    /// Upper bound (in ms) of the bucket holding quantile `q` ∈ [0, 1];
    /// 0.0 while the histogram is empty.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = ((total as f64) * q).ceil().max(1.0) as u64;
        let mut cumulative = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            cumulative += c;
            if cumulative >= target {
                return (1u64 << i.min(53)) as f64 / 1000.0;
            }
        }
        (1u64 << (LATENCY_BUCKETS - 1)) as f64 / 1000.0
    }
}

/// Live serving counters (interior-mutable, shared by reference across
/// connection handlers and dispatchers — same shape as
/// [`crate::coordinator::metrics::Metrics`]).
#[derive(Default)]
pub struct ServeMetrics {
    /// `BFS` requests accepted into the queue.
    requests: AtomicU64,
    /// Requests answered with an `OK BFS` line.
    ok: AtomicU64,
    /// Requests answered with an `ERR` line after being enqueued.
    failed: AtomicU64,
    /// Requests currently queued or in flight (gauge).
    queue_depth: AtomicUsize,
    /// High-water mark of `queue_depth`.
    queue_peak: AtomicUsize,
    /// Waves dispatched through the coordinator (successfully).
    waves: AtomicU64,
    /// Total roots across dispatched waves (`/ waves` = batch fill).
    wave_roots: AtomicU64,
    width_flushes: AtomicU64,
    deadline_flushes: AtomicU64,
    drain_flushes: AtomicU64,
    /// Waves the coordinator shed with `Rejected { retry_after_hint }`.
    rejected_waves: AtomicU64,
    /// Re-submissions after a rejected wave backed off.
    wave_retries: AtomicU64,
    graphs_loaded: AtomicU64,
    /// Per-graph circuit-breaker transitions into `Open`.
    breaker_opens: AtomicU64,
    /// `BFS` requests fast-failed with `ERR unavailable` while a breaker
    /// was open (they never touched the queue).
    breaker_fast_fails: AtomicU64,
    /// Half-open probe waves dispatched by the server itself.
    probe_waves: AtomicU64,
    /// Requests whose deadline lapsed while queued (answered `ERR expired`
    /// without a doomed dispatch).
    expired_requests: AtomicU64,
    /// Request lines rejected for exceeding the line-length cap.
    oversize_lines: AtomicU64,
    latency: LatencyHistogram,
}

impl ServeMetrics {
    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.queue_peak.fetch_max(depth, Ordering::Relaxed);
    }

    /// A wave of `n` requests left the queue for dispatch.
    pub fn record_wave_popped(&self, n: usize) {
        self.queue_depth.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn record_ok(&self, latency: Duration) {
        self.ok.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency);
    }

    pub fn record_failed(&self) {
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// A wave ran to a coordinator outcome: account its trigger and fill.
    pub fn record_wave(&self, trigger: FlushTrigger, roots: usize) {
        self.waves.fetch_add(1, Ordering::Relaxed);
        self.wave_roots.fetch_add(roots as u64, Ordering::Relaxed);
        let counter = match trigger {
            FlushTrigger::Width => &self.width_flushes,
            FlushTrigger::Deadline => &self.deadline_flushes,
            FlushTrigger::Drain => &self.drain_flushes,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_rejected_wave(&self) {
        self.rejected_waves.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_wave_retry(&self) {
        self.wave_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_graph_loaded(&self) {
        self.graphs_loaded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_breaker_open(&self) {
        self.breaker_opens.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_breaker_fast_fail(&self) {
        self.breaker_fast_fails.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_probe_wave(&self) {
        self.probe_waves.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_expired_request(&self) {
        self.expired_requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_oversize_line(&self) {
        self.oversize_lines.fetch_add(1, Ordering::Relaxed);
    }

    /// Point-in-time serving snapshot, embedding the coordinator's own
    /// counters (whose `Display` renders the shared tail of the line).
    pub fn snapshot(&self, coordinator: MetricsSnapshot) -> ServeSnapshot {
        let waves = self.waves.load(Ordering::Relaxed);
        let wave_roots = self.wave_roots.load(Ordering::Relaxed);
        ServeSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            ok: self.ok.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            p50_ms: self.latency.quantile_ms(0.50),
            p99_ms: self.latency.quantile_ms(0.99),
            queue_depth: self.queue_depth.load(Ordering::Relaxed),
            queue_peak: self.queue_peak.load(Ordering::Relaxed),
            waves,
            batch_fill: if waves > 0 { wave_roots as f64 / waves as f64 } else { 0.0 },
            width_flushes: self.width_flushes.load(Ordering::Relaxed),
            deadline_flushes: self.deadline_flushes.load(Ordering::Relaxed),
            drain_flushes: self.drain_flushes.load(Ordering::Relaxed),
            rejected_waves: self.rejected_waves.load(Ordering::Relaxed),
            wave_retries: self.wave_retries.load(Ordering::Relaxed),
            graphs_loaded: self.graphs_loaded.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_fast_fails: self.breaker_fast_fails.load(Ordering::Relaxed),
            probe_waves: self.probe_waves.load(Ordering::Relaxed),
            expired_requests: self.expired_requests.load(Ordering::Relaxed),
            oversize_lines: self.oversize_lines.load(Ordering::Relaxed),
            cache_hit_rate: if coordinator.jobs > 0 {
                (coordinator.artifact_cache_hits as f64 / coordinator.jobs as f64).min(1.0)
            } else {
                0.0
            },
            coordinator,
        }
    }
}

/// Point-in-time copy of the serving counters; rendered as one
/// `key=value` line by its `Display` (the `STATS` reply body and the
/// shutdown summary).
#[derive(Clone, Copy, Debug)]
pub struct ServeSnapshot {
    pub requests: u64,
    pub ok: u64,
    pub failed: u64,
    /// Median request latency (bucket upper bound, ms) — enqueue to reply.
    pub p50_ms: f64,
    /// 99th-percentile request latency (bucket upper bound, ms).
    pub p99_ms: f64,
    /// Requests queued or in flight right now.
    pub queue_depth: usize,
    pub queue_peak: usize,
    pub waves: u64,
    /// Mean roots per dispatched wave (the batching win: 16 ≈ every
    /// gather served a full MS-BFS wave).
    pub batch_fill: f64,
    pub width_flushes: u64,
    pub deadline_flushes: u64,
    pub drain_flushes: u64,
    pub rejected_waves: u64,
    pub wave_retries: u64,
    pub graphs_loaded: u64,
    /// Circuit-breaker transitions into `Open` across all graphs.
    pub breaker_opens: u64,
    /// Requests fast-failed with `ERR unavailable` by an open breaker.
    pub breaker_fast_fails: u64,
    /// Server-dispatched half-open probe waves.
    pub probe_waves: u64,
    /// Requests expired in the queue (answered without dispatch).
    pub expired_requests: u64,
    /// Request lines rejected at the line-length cap.
    pub oversize_lines: u64,
    /// Artifact-cache hit rate over coordinator jobs (a warm serving
    /// steady state sits near 1.0: every wave after a graph's first skips
    /// preparation).
    pub cache_hit_rate: f64,
    /// The embedded coordinator counters (aggregate TEPS lives here).
    pub coordinator: MetricsSnapshot,
}

impl std::fmt::Display for ServeSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "requests={} ok={} failed={} p50_ms={:.3} p99_ms={:.3} queue_depth={} \
             queue_peak={} waves={} batch_fill={:.2} width_flushes={} deadline_flushes={} \
             drain_flushes={} rejected_waves={} wave_retries={} graphs={} \
             breaker_opens={} breaker_fast_fails={} probe_waves={} expired={} \
             oversize_lines={} cache_hit_rate={:.2} | {}",
            self.requests,
            self.ok,
            self.failed,
            self.p50_ms,
            self.p99_ms,
            self.queue_depth,
            self.queue_peak,
            self.waves,
            self.batch_fill,
            self.width_flushes,
            self.deadline_flushes,
            self.drain_flushes,
            self.rejected_waves,
            self.wave_retries,
            self.graphs_loaded,
            self.breaker_opens,
            self.breaker_fast_fails,
            self.probe_waves,
            self.expired_requests,
            self.oversize_lines,
            self.cache_hit_rate,
            self.coordinator,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::Metrics;

    #[test]
    fn histogram_quantiles_are_bucket_upper_bounds() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_ms(0.5), 0.0, "empty histogram");
        // 99 fast samples (~100 µs) + 1 slow (~50 ms)
        for _ in 0..99 {
            h.record(Duration::from_micros(100));
        }
        h.record(Duration::from_millis(50));
        let p50 = h.quantile_ms(0.50);
        let p99 = h.quantile_ms(0.99);
        // 100 µs has bit-length 7 → bucket bound 2^7 µs = 0.128 ms
        assert!((p50 - 0.128).abs() < 1e-9, "p50 {p50}");
        assert!(p50 <= p99, "quantiles are monotone");
        assert!(p99 < 1.0, "p99 still in the fast buckets (99/100 samples)");
        assert!(h.quantile_ms(1.0) >= 32.0, "max lands in the ~50 ms bucket");
    }

    #[test]
    fn snapshot_aggregates_and_renders() {
        let m = ServeMetrics::default();
        for _ in 0..3 {
            m.record_request();
        }
        m.record_wave_popped(2);
        m.record_ok(Duration::from_millis(1));
        m.record_ok(Duration::from_millis(4));
        m.record_failed();
        m.record_wave(FlushTrigger::Width, 2);
        m.record_wave(FlushTrigger::Deadline, 1);
        m.record_rejected_wave();
        m.record_wave_retry();
        m.record_graph_loaded();
        m.record_breaker_open();
        m.record_breaker_fast_fail();
        m.record_breaker_fast_fail();
        m.record_probe_wave();
        m.record_expired_request();
        m.record_oversize_line();
        let coord = Metrics::default();
        let s = m.snapshot(coord.snapshot());
        assert_eq!((s.requests, s.ok, s.failed), (3, 2, 1));
        assert_eq!(s.queue_depth, 1);
        assert_eq!(s.queue_peak, 3);
        assert_eq!(s.waves, 2);
        assert!((s.batch_fill - 1.5).abs() < 1e-9);
        assert_eq!((s.width_flushes, s.deadline_flushes, s.drain_flushes), (1, 1, 0));
        assert_eq!((s.rejected_waves, s.wave_retries), (1, 1));
        assert_eq!((s.breaker_opens, s.breaker_fast_fails, s.probe_waves), (1, 2, 1));
        assert_eq!((s.expired_requests, s.oversize_lines), (1, 1));
        assert!(s.p50_ms > 0.0 && s.p50_ms <= s.p99_ms);
        let line = s.to_string();
        assert!(!line.contains('\n'));
        let keys = [
            "requests=3",
            "ok=2",
            "failed=1",
            "p50_ms=",
            "p99_ms=",
            "queue_depth=1",
            "batch_fill=1.50",
            "breaker_opens=1",
            "breaker_fast_fails=2",
            "probe_waves=1",
            "expired=1",
            "oversize_lines=1",
            "cache_hit_rate=",
            "teps=",
        ];
        for key in keys {
            assert!(line.contains(key), "{line:?} missing {key}");
        }
    }
}
