//! The `phi-bfs serve` daemon: a thread-per-connection TCP acceptor over
//! the deadline-aware [`BatchQueue`], dispatching accumulated waves
//! through a supervised, resource-governed [`Coordinator`].
//!
//! Threads, from the socket inward:
//!
//! * **acceptor** — blocks in `TcpListener::accept`, spawns one
//!   connection handler per client, exits when shutdown begins (woken by
//!   a self-connect).
//! * **connection handlers** — parse one request line at a time through a
//!   bounded line reader (lines are capped at [`MAX_LINE_BYTES`]; an
//!   oversize line is answered `ERR parse line-too-long` and the stream
//!   resynchronizes at the next newline, so a misbehaving client can
//!   never grow an unbounded buffer server-side). `LOAD`/`STATS`/`HEALTH`
//!   reply inline; `BFS` bounds-checks the root, consults the graph's
//!   [`CircuitBreaker`], enqueues a [`PendingBfs`] carrying a reply
//!   channel, and blocks on that channel (each connection is its own
//!   thread, so blocking here costs nothing); `SHUTDOWN` flips the daemon
//!   into drain mode.
//! * **dispatchers** — block in [`BatchQueue::pop_wave`], wrap each wave
//!   in a [`BfsJob::wave`], and submit it through the [`Supervisor`] (so
//!   a configured `--liveness-ms` budget arms the watchdog per wave). A
//!   wave the coordinator sheds with [`CoordinatorError::Rejected`] is
//!   re-submitted after the shed's `retry_after_hint` (lower-bounded by
//!   the jittered [`retry_backoff`] curve) up to the job retry budget —
//!   and each re-submission recomputes every surviving request's
//!   *remaining* deadline budget, answering already-expired requests with
//!   `ERR expired` instead of dispatching them doomed; every other error
//!   fans out to the wave's requests as structured `ERR` lines.
//! * **prober** — a detached scanner that, once an open breaker's
//!   cooldown lapses, dispatches the half-open probe wave itself, so a
//!   sick graph recovers (or re-opens) without depending on client
//!   traffic.
//!
//! Wave outcomes feed each graph's [`CircuitBreaker`]: enough consecutive
//! wave failures (hung waves abandoned by the watchdog included) trip it
//! open, after which `BFS` requests for that graph fast-fail with
//! `ERR unavailable <retry-after-ms> ...` before touching the queue —
//! one sick graph cannot starve the rest of the daemon.
//!
//! Shutdown is *drain-then-exit*: the queue refuses new requests, every
//! accumulated wave still dispatches (trigger `drain`), and
//! [`Server::wait`] joins acceptor → dispatchers → handlers before
//! returning the final [`ServeSnapshot`].

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::breaker::{Admission, BreakerPolicy, CircuitBreaker};
use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{err_line, parse_request, Request, MAX_DEADLINE_MS};
use super::queue::{BatchQueue, FlushTrigger, PendingBfs};
use crate::bfs::{RunControl, RunStatus};
use crate::coordinator::{
    retry_backoff, AdmissionPolicy, BfsJob, Coordinator, CoordinatorError, EngineKind, FaultPlan,
    RootOutcome, Supervisor,
};
use crate::graph::{Csr, RmatConfig};
use crate::rng::Xoshiro256;
use crate::Vertex;

/// How often a blocked connection read wakes up to re-check the shutdown
/// flag, so idle clients cannot hold a draining daemon open.
const READ_POLL: Duration = Duration::from_millis(200);

/// Cap on one request line (terminator excluded). Anything longer is
/// answered `ERR parse line-too-long` and discarded up to the next
/// newline — the connection survives, the buffer never grows past this.
pub const MAX_LINE_BYTES: usize = 64 * 1024;

/// How often the prober scans for open breakers whose cooldown lapsed.
const PROBE_POLL: Duration = Duration::from_millis(25);

/// Chaos faults (`fault_hang_waves` / `fault_fail_waves`) target the
/// first-loaded graph, so a chaos run can poison `g1` while `g2` proves
/// the blast radius stayed contained.
const CHAOS_TARGET_GRAPH: u64 = 1;

/// Everything `phi-bfs serve` configures; [`Server::bind`] consumes it.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub host: String,
    /// TCP port; 0 asks the OS for an ephemeral one (tests, CI smoke).
    pub port: u16,
    /// Engine template for every wave (per-graph sigma is patched in at
    /// dispatch when the `LOAD` carried one).
    pub engine: EngineKind,
    /// Coordinator worker threads per wave.
    pub workers: usize,
    /// Dispatcher threads pulling waves off the queue — the number of
    /// waves traversing concurrently.
    pub dispatchers: usize,
    /// Roots per width-triggered wave (16 = the MS-BFS wave shape).
    pub batch_width: usize,
    /// Queue-wide accumulation bound for deadline-triggered flushes.
    pub batch_deadline: Duration,
    /// Coordinator memory budget (None = ungoverned).
    pub mem_budget_mb: Option<usize>,
    /// Admission cap on concurrently running coordinator jobs.
    pub max_inflight: usize,
    /// Per-root retry budget inside a wave, and the dispatcher's bound on
    /// whole-wave re-submissions after admission-control rejections.
    pub max_attempts: usize,
    /// Per-wave liveness budget for the watchdog (`--liveness-ms`):
    /// `None` serves unsupervised (waves run inline on the dispatcher,
    /// the pre-watchdog behaviour), `Some` runs every wave on the
    /// supervisor pool with hang detection armed.
    pub liveness: Option<Duration>,
    /// Consecutive wave failures that trip a graph's circuit breaker.
    pub breaker_threshold: u32,
    /// How long a tripped breaker stays open before the half-open probe.
    pub breaker_cooldown: Duration,
    /// Chaos knob: the first N waves carry a synthetic memory-pressure
    /// fault so they shed as `Rejected` and exercise the retry path
    /// (requires a bounded budget to have any effect).
    pub fault_reject_waves: u64,
    /// Chaos knob: the first N waves dispatched for [`CHAOS_TARGET_GRAPH`]
    /// hang non-cooperatively ([`FaultPlan::hang_at`]) — requires a
    /// liveness budget, otherwise the hang would wedge a dispatcher.
    pub fault_hang_waves: u64,
    /// Chaos knob: the next N waves for [`CHAOS_TARGET_GRAPH`] (after any
    /// hang waves) fail deterministically ([`FaultPlan::fail_waves`]) —
    /// drives a breaker open and, once exhausted, closed again.
    pub fault_fail_waves: u64,
}

impl ServeOptions {
    pub fn new(engine: EngineKind) -> Self {
        ServeOptions {
            host: "127.0.0.1".to_string(),
            port: 0,
            engine,
            workers: 2,
            dispatchers: 2,
            batch_width: 16,
            batch_deadline: Duration::from_millis(10),
            mem_budget_mb: None,
            max_inflight: AdmissionPolicy::default().max_inflight,
            max_attempts: 3,
            liveness: None,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_millis(500),
            fault_reject_waves: 0,
            fault_hang_waves: 0,
            fault_fail_waves: 0,
        }
    }
}

/// A registry entry: the loaded CSR plus the sigma its `LOAD` requested
/// (applied to sigma-bearing engines at dispatch).
#[derive(Clone)]
struct LoadedGraph {
    graph: Arc<Csr>,
    sigma: Option<usize>,
}

/// State shared by the acceptor, every connection handler, every
/// dispatcher, and the prober.
struct ServerInner {
    opts: ServeOptions,
    addr: SocketAddr,
    /// Supervised execution layer over the shared coordinator: waves with
    /// a liveness budget run on its self-healing pool, the rest inline.
    supervisor: Supervisor,
    queue: BatchQueue,
    metrics: ServeMetrics,
    graphs: Mutex<HashMap<u64, LoadedGraph>>,
    /// One circuit breaker per loaded graph, created at `LOAD`.
    breakers: Mutex<HashMap<u64, Arc<CircuitBreaker>>>,
    next_graph_id: AtomicU64,
    next_job_id: AtomicU64,
    /// Waves handed to the coordinator so far — indexes the
    /// `fault_reject_waves` chaos gate deterministically.
    waves_dispatched: AtomicU64,
    /// Waves dispatched for [`CHAOS_TARGET_GRAPH`] while hang/fail chaos
    /// is armed — indexes those gates (probe waves count too, so a
    /// `fail_waves` budget can expire *through* the recovery probes).
    chaos_waves: AtomicU64,
    shutting_down: AtomicBool,
    /// Connection handler threads, joined by [`Server::wait`].
    handlers: Mutex<Vec<JoinHandle<()>>>,
}

impl ServerInner {
    fn coordinator(&self) -> &Coordinator {
        self.supervisor.coordinator()
    }
}

/// A bound, running daemon. Construct with [`Server::bind`]; block until
/// drained shutdown with [`Server::wait`].
pub struct Server {
    inner: Arc<ServerInner>,
    acceptor: Option<JoinHandle<()>>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener, start the dispatcher pool, the breaker prober,
    /// and the acceptor, and print the `listening on` line (flushed — CI
    /// greps it from a redirected pipe).
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        if opts.fault_hang_waves > 0 && opts.liveness.is_none() {
            bail!(
                "--fault-hang-waves requires --liveness-ms: an unsupervised hang would \
                 wedge a dispatcher forever"
            );
        }
        let listener = TcpListener::bind((opts.host.as_str(), opts.port))
            .with_context(|| format!("binding {}:{}", opts.host, opts.port))?;
        let addr = listener.local_addr().context("resolving the bound address")?;
        let coordinator = Arc::new(Coordinator::with_limits(
            opts.workers,
            opts.mem_budget_mb.map(|mb| mb.saturating_mul(1 << 20)),
            AdmissionPolicy { max_inflight: opts.max_inflight },
        ));
        let dispatchers_n = opts.dispatchers.max(1);
        // one pool seat per dispatcher plus one for the prober, so every
        // thread that can submit a supervised wave always finds a worker
        let supervisor = Supervisor::new(coordinator, dispatchers_n + 1);
        let queue = BatchQueue::new(opts.batch_width, opts.batch_deadline);
        let inner = Arc::new(ServerInner {
            opts,
            addr,
            supervisor,
            queue,
            metrics: ServeMetrics::default(),
            graphs: Mutex::new(HashMap::new()),
            breakers: Mutex::new(HashMap::new()),
            next_graph_id: AtomicU64::new(1),
            next_job_id: AtomicU64::new(1),
            waves_dispatched: AtomicU64::new(0),
            chaos_waves: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            handlers: Mutex::new(Vec::new()),
        });
        println!("phi-bfs serve: listening on {addr}");
        std::io::stdout().flush().ok();
        let dispatchers = (0..dispatchers_n)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || dispatcher_loop(&inner))
            })
            .collect();
        {
            // detached on purpose: a probe into a still-hung graph can
            // outlive the drain, and shutdown must not wait for it
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || prober_loop(&inner));
        }
        let acceptor = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || acceptor_loop(&inner, listener))
        };
        Ok(Server { inner, acceptor: Some(acceptor), dispatchers })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr
    }

    /// Block until the daemon shuts down (a client sent `SHUTDOWN`, or
    /// [`Server::begin_shutdown`] was called), every pending wave has
    /// drained, and every thread has exited. Returns the final snapshot —
    /// the shutdown summary.
    pub fn wait(mut self) -> ServeSnapshot {
        if let Some(acceptor) = self.acceptor.take() {
            acceptor.join().ok();
        }
        for d in self.dispatchers.drain(..) {
            d.join().ok();
        }
        let handlers = {
            let mut guard =
                self.inner.handlers.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            std::mem::take(&mut *guard)
        };
        for h in handlers {
            h.join().ok();
        }
        self.inner.metrics.snapshot(self.inner.coordinator().metrics().snapshot())
    }

    /// Start a drain-then-exit shutdown (idempotent): refuse new work,
    /// flush the queue, and wake the acceptor so it can exit.
    pub fn begin_shutdown(&self) {
        self.inner.begin_shutdown();
    }
}

impl ServerInner {
    fn begin_shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.drain();
        // the acceptor blocks in accept(): a throwaway self-connect is the
        // portable way to wake it so it can observe the flag
        TcpStream::connect(self.addr).ok();
    }
}

fn acceptor_loop(inner: &Arc<ServerInner>, listener: TcpListener) {
    loop {
        let Ok((stream, _)) = listener.accept() else { continue };
        if inner.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        let handler = {
            let inner = Arc::clone(inner);
            std::thread::spawn(move || connection_loop(&inner, stream))
        };
        inner.handlers.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).push(handler);
    }
}

/// What one [`read_bounded_line`] call produced.
enum LineRead {
    /// A complete line within the cap (newline stripped, lossy UTF-8).
    Line(String),
    /// The line blew past [`MAX_LINE_BYTES`]; the overflow is being (or
    /// has been) discarded up to the next newline.
    TooLong,
    /// Read timeout — the caller should poll the shutdown flag.
    Idle,
    /// EOF or a hard I/O error — the connection is done.
    Closed,
}

/// Read one newline-terminated line without ever buffering more than
/// [`MAX_LINE_BYTES`] of it. `partial` accumulates across `Idle` polls;
/// `discarding` carries the resync state after an oversize line.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    partial: &mut Vec<u8>,
    discarding: &mut bool,
) -> LineRead {
    enum Step {
        /// Consumed n bytes; keep reading.
        More(usize),
        /// Newline at offset n-1: a full line is in `partial`.
        Line(usize),
        /// Cap blown; consume n bytes and (maybe) keep discarding.
        TooLong(usize, bool),
    }
    loop {
        let step = match reader.fill_buf() {
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                return LineRead::Idle
            }
            Err(_) => return LineRead::Closed,
            Ok(chunk) if chunk.is_empty() => return LineRead::Closed,
            Ok(chunk) => match chunk.iter().position(|&b| b == b'\n') {
                Some(pos) if *discarding => {
                    // tail of an already-reported oversize line
                    *discarding = false;
                    Step::More(pos + 1)
                }
                Some(pos) if partial.len() + pos > MAX_LINE_BYTES => Step::TooLong(pos + 1, false),
                Some(pos) => {
                    partial.extend_from_slice(&chunk[..pos]);
                    Step::Line(pos + 1)
                }
                None if *discarding => Step::More(chunk.len()),
                None if partial.len() + chunk.len() > MAX_LINE_BYTES => {
                    Step::TooLong(chunk.len(), true)
                }
                None => {
                    partial.extend_from_slice(chunk);
                    Step::More(chunk.len())
                }
            },
        };
        match step {
            Step::More(n) => reader.consume(n),
            Step::Line(n) => {
                reader.consume(n);
                let line = String::from_utf8_lossy(partial).into_owned();
                partial.clear();
                return LineRead::Line(line);
            }
            Step::TooLong(n, keep_discarding) => {
                reader.consume(n);
                partial.clear();
                *discarding = keep_discarding;
                return LineRead::TooLong;
            }
        }
    }
}

/// One client connection: read request lines (bounded), write reply
/// lines, until the client hangs up or the daemon drains.
fn connection_loop(inner: &Arc<ServerInner>, stream: TcpStream) {
    stream.set_read_timeout(Some(READ_POLL)).ok();
    let Ok(mut writer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut partial = Vec::new();
    let mut discarding = false;
    loop {
        let reply = match read_bounded_line(&mut reader, &mut partial, &mut discarding) {
            LineRead::Closed => return,
            LineRead::Idle => {
                // idle poll: exit once the daemon is draining so a silent
                // client cannot hold shutdown open
                if inner.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            LineRead::TooLong => {
                inner.metrics.record_oversize_line();
                err_line(
                    "parse",
                    &format!("line-too-long: request lines are capped at {MAX_LINE_BYTES} bytes"),
                )
            }
            LineRead::Line(line) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                handle_line(inner, trimmed)
            }
        };
        if writeln!(writer, "{reply}").is_err() || writer.flush().is_err() {
            return;
        }
    }
}

fn handle_line(inner: &Arc<ServerInner>, line: &str) -> String {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(detail) => return err_line("parse", &detail),
    };
    match req {
        Request::Load { spec, sigma } => handle_load(inner, &spec, sigma),
        Request::Bfs { graph, root, deadline_ms } => handle_bfs(inner, &graph, root, deadline_ms),
        Request::Stats => {
            let snap = inner.metrics.snapshot(inner.coordinator().metrics().snapshot());
            format!("OK STATS {snap}")
        }
        Request::Health => handle_health(inner),
        Request::Shutdown => {
            inner.begin_shutdown();
            "OK SHUTDOWN draining".to_string()
        }
    }
}

/// The `HEALTH` reply: liveness/readiness in one greppable line —
/// accepting vs draining, queue depth, ledger pressure, supervision
/// counters, and every graph's breaker state (open breakers carry their
/// retry-after hint in ms).
fn handle_health(inner: &Arc<ServerInner>) -> String {
    let draining = inner.shutting_down.load(Ordering::SeqCst);
    let snap = inner.metrics.snapshot(inner.coordinator().metrics().snapshot());
    let now = Instant::now();
    let breakers = inner.breakers.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
    let mut ids: Vec<u64> = breakers.keys().copied().collect();
    ids.sort_unstable();
    let states = if ids.is_empty() {
        "none".to_string()
    } else {
        let frags: Vec<String> = ids
            .iter()
            .map(|id| {
                let b = &breakers[id];
                let name = b.state_name();
                match b.admit(now) {
                    Admission::FastFail { retry_after_ms } if name == "open" => {
                        format!("g{id}:open:{retry_after_ms}")
                    }
                    _ => format!("g{id}:{name}"),
                }
            })
            .collect();
        frags.join(",")
    };
    format!(
        "OK HEALTH status={} accepting={} graphs={} queue_depth={} pressure_events={} \
         watchdog_fires={} hung_waves={} workers_replaced={} breakers={}",
        if draining { "draining" } else { "ok" },
        !draining,
        snap.graphs_loaded,
        snap.queue_depth,
        snap.coordinator.pressure_events,
        snap.coordinator.watchdog_fires,
        snap.coordinator.hung_waves,
        snap.coordinator.workers_replaced,
        states,
    )
}

/// Load a graph from a `rmat:SCALE:EDGEFACTOR:SEED` spec or a file path
/// (binary CSR sniffed by magic, edge-list text otherwise) and register
/// it under a fresh `g{N}` id (with a fresh, closed circuit breaker).
fn handle_load(inner: &Arc<ServerInner>, spec: &str, sigma: Option<usize>) -> String {
    if inner.shutting_down.load(Ordering::SeqCst) {
        return err_line("shutting-down", "daemon is draining; not accepting new graphs");
    }
    if sigma.is_some() {
        // refuse eagerly: a sigma on an engine that cannot honor it would
        // otherwise silently serve un-sorted layouts
        let mut probe = inner.opts.engine.clone();
        if let Err(e) = apply_sigma(&mut probe, sigma) {
            return err_line("load", &e.to_string());
        }
    }
    let graph = match load_graph(spec) {
        Ok(g) => g,
        Err(e) => return err_line("load", &format!("{e:#}")),
    };
    if let Err(e) = graph.validate_structure() {
        return err_line("load", &format!("invalid graph structure: {e}"));
    }
    let id = inner.next_graph_id.fetch_add(1, Ordering::Relaxed);
    let (vertices, edges) = (graph.num_vertices(), graph.num_directed_edges());
    inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .insert(id, LoadedGraph { graph: Arc::new(graph), sigma });
    inner.breakers.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).insert(
        id,
        Arc::new(CircuitBreaker::new(BreakerPolicy {
            threshold: inner.opts.breaker_threshold,
            cooldown: inner.opts.breaker_cooldown,
        })),
    );
    inner.metrics.record_graph_loaded();
    format!("OK LOAD id=g{id} vertices={vertices} directed_edges={edges}")
}

fn breaker_for(inner: &ServerInner, id: u64) -> Option<Arc<CircuitBreaker>> {
    inner
        .breakers
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&id)
        .map(Arc::clone)
}

/// Enqueue one BFS request and block (on this connection's own thread)
/// until its wave runs and the dispatcher sends the reply line back.
fn handle_bfs(
    inner: &Arc<ServerInner>,
    graph: &str,
    root: Vertex,
    deadline_ms: Option<u64>,
) -> String {
    let Some(id) = graph.strip_prefix('g').and_then(|n| n.parse::<u64>().ok()) else {
        return err_line("unknown-graph", &format!("{graph:?} is not a g<N> id"));
    };
    let entry = inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&id)
        .cloned();
    let Some(entry) = entry else {
        return err_line("unknown-graph", &format!("no graph loaded as g{id}"));
    };
    // fast-fail at the door while the graph's breaker is open: the request
    // never touches the queue, and the leading token of the detail is the
    // retry-after hint in milliseconds
    if let Some(b) = breaker_for(inner, id) {
        if let Admission::FastFail { retry_after_ms } = b.admit(Instant::now()) {
            inner.metrics.record_breaker_fast_fail();
            return err_line(
                "unavailable",
                &format!(
                    "{retry_after_ms} circuit breaker open for g{id}; retry in \
                     {retry_after_ms} ms"
                ),
            );
        }
    }
    // per-request bounds check: the coordinator rejects a whole wave on
    // one bad root, so a bad request must never reach a shared wave
    let vertices = entry.graph.num_vertices();
    if root as usize >= vertices {
        return err_line(
            "root-out-of-bounds",
            &format!("root {root} out of bounds for a {vertices}-vertex graph"),
        );
    }
    let now = Instant::now();
    let deadline = deadline_ms.map(|ms| now + Duration::from_millis(ms.min(MAX_DEADLINE_MS)));
    // leave the queue with ≥ ¼ of the request's own budget still in hand
    // for the traversal itself
    let mut flush_by = now + inner.queue.batch_deadline();
    if let Some(ms) = deadline_ms {
        flush_by = flush_by.min(now + Duration::from_millis(ms.min(MAX_DEADLINE_MS)) * 3 / 4);
    }
    let (tx, rx) = mpsc::channel();
    let req = PendingBfs { root, deadline, enqueued: now, flush_by, reply: tx };
    if inner.queue.push(id, req).is_err() {
        return err_line("shutting-down", "daemon is draining; not accepting new requests");
    }
    inner.metrics.record_request();
    rx.recv()
        .unwrap_or_else(|_| err_line("internal", "reply channel closed before a reply was sent"))
}

fn dispatcher_loop(inner: &Arc<ServerInner>) {
    while let Some((graph_id, wave, trigger)) = inner.queue.pop_wave() {
        dispatch_wave(inner, graph_id, wave, trigger);
    }
}

/// The chaos fault (if any) for the next wave of `graph_id`: hang waves
/// first, then fail waves, then clean. Only [`CHAOS_TARGET_GRAPH`] is
/// ever poisoned, and the gate counter only advances while chaos is
/// armed, so production dispatch pays one branch.
fn chaos_fault(inner: &ServerInner, graph_id: u64) -> Option<FaultPlan> {
    let hang = inner.opts.fault_hang_waves;
    let fail = inner.opts.fault_fail_waves;
    if graph_id != CHAOS_TARGET_GRAPH || (hang == 0 && fail == 0) {
        return None;
    }
    let index = inner.chaos_waves.fetch_add(1, Ordering::Relaxed);
    if index < hang {
        Some(FaultPlan::hang_at(0))
    } else if index - hang < fail {
        Some(FaultPlan::fail_waves(fail))
    } else {
        None
    }
}

/// Run one wave through the supervisor and fan the outcome back to every
/// request's reply channel. `Rejected` sheds re-submit after the hint —
/// with each request's *remaining* deadline budget recomputed, and
/// already-expired requests answered `ERR expired` up front; every other
/// error is terminal for the wave. Wave outcomes feed the graph's
/// circuit breaker.
fn dispatch_wave(
    inner: &Arc<ServerInner>,
    graph_id: u64,
    wave: Vec<PendingBfs>,
    trigger: FlushTrigger,
) {
    inner.metrics.record_wave_popped(wave.len());
    let entry = inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&graph_id)
        .cloned();
    let Some(entry) = entry else {
        fail_wave(inner, &wave, &err_line("unknown-graph", "graph unloaded while queued"));
        return;
    };
    let mut engine = inner.opts.engine.clone();
    if apply_sigma(&mut engine, entry.sigma).is_err() {
        // LOAD pre-validated this; only reachable if the engine template
        // changed shape underneath us
        fail_wave(inner, &wave, &err_line("internal", "sigma no longer applies to the engine"));
        return;
    }
    let breaker = breaker_for(inner, graph_id);
    let wave_index = inner.waves_dispatched.fetch_add(1, Ordering::Relaxed);
    let job_id = inner.next_job_id.fetch_add(1, Ordering::Relaxed);
    let mut rng = Xoshiro256::seed_from_u64(job_id ^ 0x5345_5256);
    let max_submissions = inner.opts.max_attempts.max(1);
    let mut attempt = 0usize;
    let mut wave = wave;
    let (outcome, wave) = loop {
        // deadline sweep: a request whose own budget lapsed while it sat
        // in the queue (or while a rejected wave backed off) gets an
        // immediate structured reply instead of a doomed dispatch
        let now = Instant::now();
        let mut live = Vec::with_capacity(wave.len());
        for pending in wave {
            if pending.deadline.is_some_and(|d| now >= d) {
                inner.metrics.record_expired_request();
                inner.metrics.record_failed();
                let waited = now.saturating_duration_since(pending.enqueued);
                let line = err_line(
                    "expired",
                    &format!(
                        "deadline lapsed after {:.3} ms queued (never dispatched)",
                        waited.as_secs_f64() * 1e3
                    ),
                );
                pending.reply.send(line).ok();
            } else {
                live.push(pending);
            }
        }
        if live.is_empty() {
            // the whole wave expired before it could run
            return;
        }
        // each surviving request contributes what is *left* of its budget,
        // so a re-submitted wave never runs against a stale bound computed
        // at first dispatch
        let deadline = live
            .iter()
            .filter_map(|p| p.deadline)
            .map(|d| d.saturating_duration_since(now))
            .min();
        let control = Arc::new(RunControl::new());
        let roots: Vec<Vertex> = live.iter().map(|p| p.root).collect();
        let mut job = BfsJob::wave(
            job_id,
            Arc::clone(&entry.graph),
            roots,
            engine.clone(),
            deadline,
            Some(Arc::clone(&control)),
            inner.opts.max_attempts,
        );
        job.run.liveness = inner.opts.liveness;
        if attempt == 0 {
            if wave_index < inner.opts.fault_reject_waves {
                // chaos gate: synthetic ledger pressure makes a bounded
                // governor shed this wave as Rejected on first submission
                job.run.fault = Some(FaultPlan::memory_pressure(usize::MAX));
            } else if let Some(plan) = chaos_fault(inner, graph_id) {
                job.run.fault = Some(plan);
            }
        }
        match inner.supervisor.run_job(job) {
            Ok(outcome) => break (outcome, live),
            Err(CoordinatorError::Rejected { retry_after_hint })
                if attempt + 1 < max_submissions =>
            {
                attempt += 1;
                inner.metrics.record_rejected_wave();
                let pause = retry_after_hint.max(retry_backoff(attempt + 1, &mut rng, &control));
                eprintln!(
                    "phi-bfs serve: wave {job_id} on g{graph_id} rejected by admission \
                     control; retrying in {} ms (attempt {attempt}/{max_submissions})",
                    pause.as_millis()
                );
                std::thread::sleep(pause);
                inner.metrics.record_wave_retry();
                wave = live;
            }
            Err(e) => {
                if let Some(b) = &breaker {
                    if b.record_failure(Instant::now()) {
                        inner.metrics.record_breaker_open();
                    }
                }
                let kind = match &e {
                    CoordinatorError::Rejected { .. } => "rejected",
                    CoordinatorError::OverBudget { .. } => "over-budget",
                    CoordinatorError::RootOutOfBounds { .. } => "root-out-of-bounds",
                    _ => "failed",
                };
                fail_wave(inner, &live, &err_line(kind, &e.to_string()));
                return;
            }
        }
    };
    // breaker accounting: a wave where *every* root failed (including one
    // abandoned wholesale by the watchdog) is a wave failure; any root
    // succeeding counts as wave success and resets the streak
    if let Some(b) = &breaker {
        if outcome.outcomes.iter().all(|o| o.is_failed()) {
            if b.record_failure(Instant::now()) {
                inner.metrics.record_breaker_open();
            }
        } else {
            b.record_success();
        }
    }
    inner.metrics.record_wave(trigger, wave.len());
    let width = wave.len();
    for (pending, root_outcome) in wave.into_iter().zip(outcome.outcomes.iter()) {
        match root_outcome {
            RootOutcome::Ran(r) => {
                let latency = pending.enqueued.elapsed();
                inner.metrics.record_ok(latency);
                let (depth, checksum) =
                    r.depths.map(|d| (d.max_depth, d.checksum)).unwrap_or((0, 0));
                let status = match r.status() {
                    RunStatus::Complete => "complete",
                    RunStatus::TimedOut => "timed-out",
                    RunStatus::Cancelled => "cancelled",
                };
                let line = format!(
                    "OK BFS root={} reached={} edges={} depth={} checksum={:016x} \
                     status={} wave_width={} trigger={} latency_ms={:.3}",
                    r.root,
                    r.reached,
                    r.edges_traversed,
                    depth,
                    checksum,
                    status,
                    width,
                    trigger.as_str(),
                    latency.as_secs_f64() * 1e3,
                );
                pending.reply.send(line).ok();
            }
            RootOutcome::Failed { error, attempts, .. } => {
                inner.metrics.record_failed();
                let line = err_line("failed", &format!("after {attempts} attempts: {error}"));
                pending.reply.send(line).ok();
            }
        }
    }
}

/// The breaker prober: scans for open breakers whose cooldown lapsed and
/// dispatches their half-open probe wave (one root, one attempt) itself,
/// so recovery never waits for client traffic. Runs detached; exits once
/// shutdown begins.
fn prober_loop(inner: &Arc<ServerInner>) {
    loop {
        if inner.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        std::thread::sleep(PROBE_POLL);
        let mut due: Vec<(u64, Arc<CircuitBreaker>)> = Vec::new();
        {
            let now = Instant::now();
            let breakers = inner.breakers.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            for (id, b) in breakers.iter() {
                if b.probe(now) {
                    due.push((*id, Arc::clone(b)));
                }
            }
        }
        for (graph_id, b) in due {
            run_probe(inner, graph_id, &b);
        }
    }
}

/// Run one half-open probe wave for `graph_id` and settle its breaker:
/// close on success, re-open (for another cooldown) on failure.
fn run_probe(inner: &Arc<ServerInner>, graph_id: u64, breaker: &CircuitBreaker) {
    let entry = inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&graph_id)
        .cloned();
    let Some(entry) = entry else {
        // unreachable today (graphs are never unloaded); leave the breaker
        // half-open rather than invent an outcome for a missing graph
        return;
    };
    let mut engine = inner.opts.engine.clone();
    if apply_sigma(&mut engine, entry.sigma).is_err() {
        if breaker.record_failure(Instant::now()) {
            inner.metrics.record_breaker_open();
        }
        return;
    }
    inner.metrics.record_probe_wave();
    let job_id = inner.next_job_id.fetch_add(1, Ordering::Relaxed);
    // a bounded, single-attempt trial from root 0: the point is "does a
    // wave come back healthy", not throughput
    let deadline = inner.opts.breaker_cooldown.max(Duration::from_millis(100));
    let mut job =
        BfsJob::wave(job_id, Arc::clone(&entry.graph), vec![0], engine, Some(deadline), None, 1);
    job.run.liveness = inner.opts.liveness;
    if let Some(plan) = chaos_fault(inner, graph_id) {
        job.run.fault = Some(plan);
    }
    let healthy = match inner.supervisor.run_job(job) {
        Ok(outcome) => outcome.outcomes.iter().any(|o| !o.is_failed()),
        Err(_) => false,
    };
    if healthy {
        breaker.record_success();
    } else if breaker.record_failure(Instant::now()) {
        inner.metrics.record_breaker_open();
    }
}

/// Reply the same error line to every request in a wave.
fn fail_wave(inner: &Arc<ServerInner>, wave: &[PendingBfs], line: &str) {
    for pending in wave {
        inner.metrics.record_failed();
        pending.reply.send(line.to_string()).ok();
    }
}

/// Patch a per-graph sigma into sigma-bearing engine variants (mirrors
/// the `--sigma` handling in the CLI one-shot path). `None` is a no-op.
fn apply_sigma(engine: &mut EngineKind, sigma: Option<usize>) -> Result<()> {
    let Some(v) = sigma else { return Ok(()) };
    match engine {
        EngineKind::Sell { sigma, .. } | EngineKind::MultiSource { sigma, .. } => *sigma = v,
        EngineKind::Hybrid { sell, bu_sell, sigma, .. } if *sell || *bu_sell => *sigma = v,
        other => bail!("sigma {v} does not apply to engine {other:?}"),
    }
    Ok(())
}

/// Build a CSR from a `LOAD` spec: `rmat:SCALE:EDGEFACTOR:SEED`
/// generates a Graph500 R-MAT instance; anything else is a file path —
/// binary CSR when the magic matches, edge-list text otherwise.
fn load_graph(spec: &str) -> Result<Csr> {
    if let Some(rest) = spec.strip_prefix("rmat:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 3 {
            bail!("rmat spec must be rmat:SCALE:EDGEFACTOR:SEED, got {spec:?}");
        }
        let scale: u32 = parts[0].parse().with_context(|| format!("bad scale {:?}", parts[0]))?;
        let ef: usize =
            parts[1].parse().with_context(|| format!("bad edgefactor {:?}", parts[1]))?;
        let seed: u64 = parts[2].parse().with_context(|| format!("bad seed {:?}", parts[2]))?;
        if !(1..=26).contains(&scale) {
            bail!("rmat scale {scale} outside the served range 1..=26");
        }
        if ef == 0 {
            bail!("rmat edgefactor must be >= 1");
        }
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        return Ok(Csr::from_edge_list(scale, &el));
    }
    let bytes = std::fs::read(spec).with_context(|| format!("reading graph file {spec:?}"))?;
    if bytes.starts_with(b"PHIBFS01") {
        crate::graph::io::read_csr(&bytes[..])
    } else {
        let el = crate::graph::io::read_edge_list(&bytes[..])
            .with_context(|| format!("parsing {spec:?} as an edge list"))?;
        Ok(Csr::from_edge_list(0, &el))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_graph_parses_rmat_specs_and_rejects_bad_ones() {
        let g = load_graph("rmat:6:8:42").expect("valid spec");
        assert_eq!(g.num_vertices(), 64);
        assert!(load_graph("rmat:6:8").is_err(), "missing seed");
        assert!(load_graph("rmat:0:8:1").is_err(), "scale 0");
        assert!(load_graph("rmat:6:0:1").is_err(), "edgefactor 0");
        assert!(load_graph("/nonexistent/phi-bfs-graph").is_err(), "missing file");
    }

    #[test]
    fn load_graph_round_trips_both_file_formats() {
        let el = RmatConfig::graph500(5, 8).generate(7);
        let g = Csr::from_edge_list(5, &el);
        let dir = std::env::temp_dir().join(format!("phi-bfs-serve-load-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let csr_path = dir.join("g.csr");
        let el_path = dir.join("g.txt");
        crate::graph::io::save_csr(&csr_path, &g).unwrap();
        crate::graph::io::save_edge_list(&el_path, &el).unwrap();
        let from_csr = load_graph(csr_path.to_str().unwrap()).expect("binary CSR");
        let from_el = load_graph(el_path.to_str().unwrap()).expect("edge-list text");
        assert_eq!(from_csr.content_hash(), g.content_hash());
        assert_eq!(from_el.content_hash(), g.content_hash());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn apply_sigma_patches_sell_engines_and_refuses_serial() {
        let mut e = EngineKind::parse("sell", 2, "").unwrap();
        assert!(apply_sigma(&mut e, Some(4096)).is_ok());
        let mut serial = EngineKind::SerialQueue;
        assert!(apply_sigma(&mut serial, Some(4096)).is_err());
        assert!(apply_sigma(&mut serial, None).is_ok(), "no sigma is always fine");
    }

    #[test]
    fn hang_chaos_without_liveness_is_refused_at_bind() {
        let mut opts = ServeOptions::new(EngineKind::SerialLayered);
        opts.fault_hang_waves = 1;
        let err = match Server::bind(opts) {
            Err(e) => e,
            Ok(_) => panic!("a hang with no watchdog must not bind"),
        };
        assert!(err.to_string().contains("liveness"), "{err:#}");
    }

    #[test]
    fn chaos_faults_only_target_the_first_graph_in_order() {
        let mut opts = ServeOptions::new(EngineKind::SerialLayered);
        opts.liveness = Some(Duration::from_secs(1));
        opts.fault_hang_waves = 1;
        opts.fault_fail_waves = 2;
        let server = Server::bind(opts).expect("bind");
        let inner = Arc::clone(&server.inner);
        assert!(chaos_fault(&inner, 2).is_none(), "g2 is never poisoned");
        assert_eq!(chaos_fault(&inner, 1), Some(FaultPlan::hang_at(0)));
        assert_eq!(chaos_fault(&inner, 1), Some(FaultPlan::fail_waves(2)));
        assert_eq!(chaos_fault(&inner, 1), Some(FaultPlan::fail_waves(2)));
        assert!(chaos_fault(&inner, 1).is_none(), "chaos budget exhausted");
        server.begin_shutdown();
        server.wait();
    }
}
