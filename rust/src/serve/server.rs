//! The `phi-bfs serve` daemon: a thread-per-connection TCP acceptor over
//! the deadline-aware [`BatchQueue`], dispatching accumulated waves
//! through a resource-governed [`Coordinator`].
//!
//! Threads, from the socket inward:
//!
//! * **acceptor** — blocks in `TcpListener::accept`, spawns one
//!   connection handler per client, exits when shutdown begins (woken by
//!   a self-connect).
//! * **connection handlers** — parse one request line at a time.
//!   `LOAD`/`STATS` reply inline; `BFS` bounds-checks the root, enqueues
//!   a [`PendingBfs`] carrying a reply channel, and blocks on that
//!   channel (each connection is its own thread, so blocking here costs
//!   nothing); `SHUTDOWN` flips the daemon into drain mode.
//! * **dispatchers** — block in [`BatchQueue::pop_wave`], wrap each wave
//!   in a [`BfsJob::wave`], and submit it to the coordinator. A wave the
//!   coordinator sheds with [`CoordinatorError::Rejected`] is re-submitted
//!   after the shed's `retry_after_hint` (lower-bounded by the jittered
//!   [`retry_backoff`] curve) up to the job retry budget; every other
//!   error fans out to the wave's requests as structured `ERR` lines.
//!
//! Shutdown is *drain-then-exit*: the queue refuses new requests, every
//! accumulated wave still dispatches (trigger `drain`), and
//! [`Server::wait`] joins acceptor → dispatchers → handlers before
//! returning the final [`ServeSnapshot`].

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::metrics::{ServeMetrics, ServeSnapshot};
use super::protocol::{err_line, parse_request, Request, MAX_DEADLINE_MS};
use super::queue::{BatchQueue, FlushTrigger, PendingBfs};
use crate::bfs::{RunControl, RunStatus};
use crate::coordinator::{
    retry_backoff, AdmissionPolicy, BfsJob, Coordinator, CoordinatorError, EngineKind, FaultPlan,
    RootOutcome,
};
use crate::graph::{Csr, RmatConfig};
use crate::rng::Xoshiro256;
use crate::Vertex;

/// How often a blocked connection read wakes up to re-check the shutdown
/// flag, so idle clients cannot hold a draining daemon open.
const READ_POLL: Duration = Duration::from_millis(200);

/// Everything `phi-bfs serve` configures; [`Server::bind`] consumes it.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    pub host: String,
    /// TCP port; 0 asks the OS for an ephemeral one (tests, CI smoke).
    pub port: u16,
    /// Engine template for every wave (per-graph sigma is patched in at
    /// dispatch when the `LOAD` carried one).
    pub engine: EngineKind,
    /// Coordinator worker threads per wave.
    pub workers: usize,
    /// Dispatcher threads pulling waves off the queue — the number of
    /// waves traversing concurrently.
    pub dispatchers: usize,
    /// Roots per width-triggered wave (16 = the MS-BFS wave shape).
    pub batch_width: usize,
    /// Queue-wide accumulation bound for deadline-triggered flushes.
    pub batch_deadline: Duration,
    /// Coordinator memory budget (None = ungoverned).
    pub mem_budget_mb: Option<usize>,
    /// Admission cap on concurrently running coordinator jobs.
    pub max_inflight: usize,
    /// Per-root retry budget inside a wave, and the dispatcher's bound on
    /// whole-wave re-submissions after admission-control rejections.
    pub max_attempts: usize,
    /// Chaos knob: the first N waves carry a synthetic memory-pressure
    /// fault so they shed as `Rejected` and exercise the retry path
    /// (requires a bounded budget to have any effect).
    pub fault_reject_waves: u64,
}

impl ServeOptions {
    pub fn new(engine: EngineKind) -> Self {
        ServeOptions {
            host: "127.0.0.1".to_string(),
            port: 0,
            engine,
            workers: 2,
            dispatchers: 2,
            batch_width: 16,
            batch_deadline: Duration::from_millis(10),
            mem_budget_mb: None,
            max_inflight: AdmissionPolicy::default().max_inflight,
            max_attempts: 3,
            fault_reject_waves: 0,
        }
    }
}

/// A registry entry: the loaded CSR plus the sigma its `LOAD` requested
/// (applied to sigma-bearing engines at dispatch).
#[derive(Clone)]
struct LoadedGraph {
    graph: Arc<Csr>,
    sigma: Option<usize>,
}

/// State shared by the acceptor, every connection handler, and every
/// dispatcher.
struct ServerInner {
    opts: ServeOptions,
    addr: SocketAddr,
    coordinator: Coordinator,
    queue: BatchQueue,
    metrics: ServeMetrics,
    graphs: Mutex<HashMap<u64, LoadedGraph>>,
    next_graph_id: AtomicU64,
    next_job_id: AtomicU64,
    /// Waves handed to the coordinator so far — indexes the
    /// `fault_reject_waves` chaos gate deterministically.
    waves_dispatched: AtomicU64,
    shutting_down: AtomicBool,
    /// Connection handler threads, joined by [`Server::wait`].
    handlers: Mutex<Vec<JoinHandle<()>>>,
}

/// A bound, running daemon. Construct with [`Server::bind`]; block until
/// drained shutdown with [`Server::wait`].
pub struct Server {
    inner: Arc<ServerInner>,
    acceptor: Option<JoinHandle<()>>,
    dispatchers: Vec<JoinHandle<()>>,
}

impl Server {
    /// Bind the listener, start the dispatcher pool and the acceptor, and
    /// print the `listening on` line (flushed — CI greps it from a
    /// redirected pipe).
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind((opts.host.as_str(), opts.port))
            .with_context(|| format!("binding {}:{}", opts.host, opts.port))?;
        let addr = listener.local_addr().context("resolving the bound address")?;
        let coordinator = Coordinator::with_limits(
            opts.workers,
            opts.mem_budget_mb.map(|mb| mb.saturating_mul(1 << 20)),
            AdmissionPolicy { max_inflight: opts.max_inflight },
        );
        let queue = BatchQueue::new(opts.batch_width, opts.batch_deadline);
        let dispatchers_n = opts.dispatchers.max(1);
        let inner = Arc::new(ServerInner {
            opts,
            addr,
            coordinator,
            queue,
            metrics: ServeMetrics::default(),
            graphs: Mutex::new(HashMap::new()),
            next_graph_id: AtomicU64::new(1),
            next_job_id: AtomicU64::new(1),
            waves_dispatched: AtomicU64::new(0),
            shutting_down: AtomicBool::new(false),
            handlers: Mutex::new(Vec::new()),
        });
        println!("phi-bfs serve: listening on {addr}");
        std::io::stdout().flush().ok();
        let dispatchers = (0..dispatchers_n)
            .map(|_| {
                let inner = Arc::clone(&inner);
                std::thread::spawn(move || dispatcher_loop(&inner))
            })
            .collect();
        let acceptor = {
            let inner = Arc::clone(&inner);
            std::thread::spawn(move || acceptor_loop(&inner, listener))
        };
        Ok(Server { inner, acceptor: Some(acceptor), dispatchers })
    }

    /// The address actually bound (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr
    }

    /// Block until the daemon shuts down (a client sent `SHUTDOWN`, or
    /// [`Server::begin_shutdown`] was called), every pending wave has
    /// drained, and every thread has exited. Returns the final snapshot —
    /// the shutdown summary.
    pub fn wait(mut self) -> ServeSnapshot {
        if let Some(acceptor) = self.acceptor.take() {
            acceptor.join().ok();
        }
        for d in self.dispatchers.drain(..) {
            d.join().ok();
        }
        let handlers = {
            let mut guard =
                self.inner.handlers.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
            std::mem::take(&mut *guard)
        };
        for h in handlers {
            h.join().ok();
        }
        self.inner.metrics.snapshot(self.inner.coordinator.metrics().snapshot())
    }

    /// Start a drain-then-exit shutdown (idempotent): refuse new work,
    /// flush the queue, and wake the acceptor so it can exit.
    pub fn begin_shutdown(&self) {
        self.inner.begin_shutdown();
    }
}

impl ServerInner {
    fn begin_shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        self.queue.drain();
        // the acceptor blocks in accept(): a throwaway self-connect is the
        // portable way to wake it so it can observe the flag
        TcpStream::connect(self.addr).ok();
    }
}

fn acceptor_loop(inner: &Arc<ServerInner>, listener: TcpListener) {
    loop {
        let Ok((stream, _)) = listener.accept() else { continue };
        if inner.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        let handler = {
            let inner = Arc::clone(inner);
            std::thread::spawn(move || connection_loop(&inner, stream))
        };
        inner.handlers.lock().unwrap_or_else(|poisoned| poisoned.into_inner()).push(handler);
    }
}

/// One client connection: read request lines, write reply lines, until
/// the client hangs up or the daemon drains.
fn connection_loop(inner: &Arc<ServerInner>, stream: TcpStream) {
    stream.set_read_timeout(Some(READ_POLL)).ok();
    let Ok(mut writer) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return, // client closed
            Ok(_) => {
                let trimmed = line.trim();
                if trimmed.is_empty() {
                    continue;
                }
                let reply = handle_line(inner, trimmed);
                if writeln!(writer, "{reply}").is_err() || writer.flush().is_err() {
                    return;
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // idle poll: exit once the daemon is draining so a silent
                // client cannot hold shutdown open
                if inner.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

fn handle_line(inner: &Arc<ServerInner>, line: &str) -> String {
    let req = match parse_request(line) {
        Ok(req) => req,
        Err(detail) => return err_line("parse", &detail),
    };
    match req {
        Request::Load { spec, sigma } => handle_load(inner, &spec, sigma),
        Request::Bfs { graph, root, deadline_ms } => handle_bfs(inner, &graph, root, deadline_ms),
        Request::Stats => {
            let snap = inner.metrics.snapshot(inner.coordinator.metrics().snapshot());
            format!("OK STATS {snap}")
        }
        Request::Shutdown => {
            inner.begin_shutdown();
            "OK SHUTDOWN draining".to_string()
        }
    }
}

/// Load a graph from a `rmat:SCALE:EDGEFACTOR:SEED` spec or a file path
/// (binary CSR sniffed by magic, edge-list text otherwise) and register
/// it under a fresh `g{N}` id.
fn handle_load(inner: &Arc<ServerInner>, spec: &str, sigma: Option<usize>) -> String {
    if inner.shutting_down.load(Ordering::SeqCst) {
        return err_line("shutting-down", "daemon is draining; not accepting new graphs");
    }
    if sigma.is_some() {
        // refuse eagerly: a sigma on an engine that cannot honor it would
        // otherwise silently serve un-sorted layouts
        let mut probe = inner.opts.engine.clone();
        if let Err(e) = apply_sigma(&mut probe, sigma) {
            return err_line("load", &e.to_string());
        }
    }
    let graph = match load_graph(spec) {
        Ok(g) => g,
        Err(e) => return err_line("load", &format!("{e:#}")),
    };
    if let Err(e) = graph.validate_structure() {
        return err_line("load", &format!("invalid graph structure: {e}"));
    }
    let id = inner.next_graph_id.fetch_add(1, Ordering::Relaxed);
    let (vertices, edges) = (graph.num_vertices(), graph.num_directed_edges());
    inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .insert(id, LoadedGraph { graph: Arc::new(graph), sigma });
    inner.metrics.record_graph_loaded();
    format!("OK LOAD id=g{id} vertices={vertices} directed_edges={edges}")
}

/// Enqueue one BFS request and block (on this connection's own thread)
/// until its wave runs and the dispatcher sends the reply line back.
fn handle_bfs(
    inner: &Arc<ServerInner>,
    graph: &str,
    root: Vertex,
    deadline_ms: Option<u64>,
) -> String {
    let Some(id) = graph.strip_prefix('g').and_then(|n| n.parse::<u64>().ok()) else {
        return err_line("unknown-graph", &format!("{graph:?} is not a g<N> id"));
    };
    let entry = inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&id)
        .cloned();
    let Some(entry) = entry else {
        return err_line("unknown-graph", &format!("no graph loaded as g{id}"));
    };
    // per-request bounds check: the coordinator rejects a whole wave on
    // one bad root, so a bad request must never reach a shared wave
    let vertices = entry.graph.num_vertices();
    if root as usize >= vertices {
        return err_line(
            "root-out-of-bounds",
            &format!("root {root} out of bounds for a {vertices}-vertex graph"),
        );
    }
    let now = Instant::now();
    let deadline = deadline_ms.map(|ms| now + Duration::from_millis(ms.min(MAX_DEADLINE_MS)));
    // leave the queue with ≥ ¼ of the request's own budget still in hand
    // for the traversal itself
    let mut flush_by = now + inner.queue.batch_deadline();
    if let Some(ms) = deadline_ms {
        flush_by = flush_by.min(now + Duration::from_millis(ms.min(MAX_DEADLINE_MS)) * 3 / 4);
    }
    let (tx, rx) = mpsc::channel();
    let req = PendingBfs { root, deadline, enqueued: now, flush_by, reply: tx };
    if inner.queue.push(id, req).is_err() {
        return err_line("shutting-down", "daemon is draining; not accepting new requests");
    }
    inner.metrics.record_request();
    rx.recv()
        .unwrap_or_else(|_| err_line("internal", "reply channel closed before a reply was sent"))
}

fn dispatcher_loop(inner: &Arc<ServerInner>) {
    while let Some((graph_id, wave, trigger)) = inner.queue.pop_wave() {
        dispatch_wave(inner, graph_id, wave, trigger);
    }
}

/// Run one wave through the coordinator and fan the outcome back to every
/// request's reply channel. `Rejected` sheds re-submit after the hint;
/// every other error is terminal for the wave.
fn dispatch_wave(
    inner: &Arc<ServerInner>,
    graph_id: u64,
    wave: Vec<PendingBfs>,
    trigger: FlushTrigger,
) {
    inner.metrics.record_wave_popped(wave.len());
    let entry = inner
        .graphs
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner())
        .get(&graph_id)
        .cloned();
    let Some(entry) = entry else {
        fail_wave(inner, &wave, &err_line("unknown-graph", "graph unloaded while queued"));
        return;
    };
    let mut engine = inner.opts.engine.clone();
    if apply_sigma(&mut engine, entry.sigma).is_err() {
        // LOAD pre-validated this; only reachable if the engine template
        // changed shape underneath us
        fail_wave(inner, &wave, &err_line("internal", "sigma no longer applies to the engine"));
        return;
    }
    let now = Instant::now();
    let deadline = wave
        .iter()
        .filter_map(|p| p.deadline)
        .map(|d| d.saturating_duration_since(now))
        .min();
    let control = Arc::new(RunControl::new());
    let wave_index = inner.waves_dispatched.fetch_add(1, Ordering::Relaxed);
    let job_id = inner.next_job_id.fetch_add(1, Ordering::Relaxed);
    let roots: Vec<Vertex> = wave.iter().map(|p| p.root).collect();
    let mut job = BfsJob::wave(
        job_id,
        Arc::clone(&entry.graph),
        roots,
        engine,
        deadline,
        Some(Arc::clone(&control)),
        inner.opts.max_attempts,
    );
    if wave_index < inner.opts.fault_reject_waves {
        // chaos gate: synthetic ledger pressure makes a bounded governor
        // shed this wave as Rejected on its first submission
        job.run.fault = Some(FaultPlan::memory_pressure(usize::MAX));
    }
    let mut rng = Xoshiro256::seed_from_u64(job_id ^ 0x5345_5256);
    let max_submissions = inner.opts.max_attempts.max(1);
    let mut attempt = 0usize;
    let outcome = loop {
        match inner.coordinator.run_job(&job) {
            Ok(outcome) => break outcome,
            Err(CoordinatorError::Rejected { retry_after_hint })
                if attempt + 1 < max_submissions =>
            {
                attempt += 1;
                inner.metrics.record_rejected_wave();
                // the injected pressure made its point; retries run clean
                job.run.fault = None;
                let pause = retry_after_hint.max(retry_backoff(attempt + 1, &mut rng, &control));
                eprintln!(
                    "phi-bfs serve: wave {job_id} on g{graph_id} rejected by admission \
                     control; retrying in {} ms (attempt {attempt}/{max_submissions})",
                    pause.as_millis()
                );
                std::thread::sleep(pause);
                inner.metrics.record_wave_retry();
            }
            Err(e) => {
                let kind = match &e {
                    CoordinatorError::Rejected { .. } => "rejected",
                    CoordinatorError::OverBudget { .. } => "over-budget",
                    CoordinatorError::RootOutOfBounds { .. } => "root-out-of-bounds",
                    _ => "failed",
                };
                fail_wave(inner, &wave, &err_line(kind, &e.to_string()));
                return;
            }
        }
    };
    inner.metrics.record_wave(trigger, wave.len());
    let width = wave.len();
    for (pending, root_outcome) in wave.into_iter().zip(outcome.outcomes.iter()) {
        match root_outcome {
            RootOutcome::Ran(r) => {
                let latency = pending.enqueued.elapsed();
                inner.metrics.record_ok(latency);
                let (depth, checksum) =
                    r.depths.map(|d| (d.max_depth, d.checksum)).unwrap_or((0, 0));
                let status = match r.status() {
                    RunStatus::Complete => "complete",
                    RunStatus::TimedOut => "timed-out",
                    RunStatus::Cancelled => "cancelled",
                };
                let line = format!(
                    "OK BFS root={} reached={} edges={} depth={} checksum={:016x} \
                     status={} wave_width={} trigger={} latency_ms={:.3}",
                    r.root,
                    r.reached,
                    r.edges_traversed,
                    depth,
                    checksum,
                    status,
                    width,
                    trigger.as_str(),
                    latency.as_secs_f64() * 1e3,
                );
                pending.reply.send(line).ok();
            }
            RootOutcome::Failed { error, attempts, .. } => {
                inner.metrics.record_failed();
                let line = err_line("failed", &format!("after {attempts} attempts: {error}"));
                pending.reply.send(line).ok();
            }
        }
    }
}

/// Reply the same error line to every request in a wave.
fn fail_wave(inner: &Arc<ServerInner>, wave: &[PendingBfs], line: &str) {
    for pending in wave {
        inner.metrics.record_failed();
        pending.reply.send(line.to_string()).ok();
    }
}

/// Patch a per-graph sigma into sigma-bearing engine variants (mirrors
/// the `--sigma` handling in the CLI one-shot path). `None` is a no-op.
fn apply_sigma(engine: &mut EngineKind, sigma: Option<usize>) -> Result<()> {
    let Some(v) = sigma else { return Ok(()) };
    match engine {
        EngineKind::Sell { sigma, .. } | EngineKind::MultiSource { sigma, .. } => *sigma = v,
        EngineKind::Hybrid { sell, bu_sell, sigma, .. } if *sell || *bu_sell => *sigma = v,
        other => bail!("sigma {v} does not apply to engine {other:?}"),
    }
    Ok(())
}

/// Build a CSR from a `LOAD` spec: `rmat:SCALE:EDGEFACTOR:SEED`
/// generates a Graph500 R-MAT instance; anything else is a file path —
/// binary CSR when the magic matches, edge-list text otherwise.
fn load_graph(spec: &str) -> Result<Csr> {
    if let Some(rest) = spec.strip_prefix("rmat:") {
        let parts: Vec<&str> = rest.split(':').collect();
        if parts.len() != 3 {
            bail!("rmat spec must be rmat:SCALE:EDGEFACTOR:SEED, got {spec:?}");
        }
        let scale: u32 = parts[0].parse().with_context(|| format!("bad scale {:?}", parts[0]))?;
        let ef: usize =
            parts[1].parse().with_context(|| format!("bad edgefactor {:?}", parts[1]))?;
        let seed: u64 = parts[2].parse().with_context(|| format!("bad seed {:?}", parts[2]))?;
        if !(1..=26).contains(&scale) {
            bail!("rmat scale {scale} outside the served range 1..=26");
        }
        if ef == 0 {
            bail!("rmat edgefactor must be >= 1");
        }
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        return Ok(Csr::from_edge_list(scale, &el));
    }
    let bytes = std::fs::read(spec).with_context(|| format!("reading graph file {spec:?}"))?;
    if bytes.starts_with(b"PHIBFS01") {
        crate::graph::io::read_csr(&bytes[..])
    } else {
        let el = crate::graph::io::read_edge_list(&bytes[..])
            .with_context(|| format!("parsing {spec:?} as an edge list"))?;
        Ok(Csr::from_edge_list(0, &el))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_graph_parses_rmat_specs_and_rejects_bad_ones() {
        let g = load_graph("rmat:6:8:42").expect("valid spec");
        assert_eq!(g.num_vertices(), 64);
        assert!(load_graph("rmat:6:8").is_err(), "missing seed");
        assert!(load_graph("rmat:0:8:1").is_err(), "scale 0");
        assert!(load_graph("rmat:6:0:1").is_err(), "edgefactor 0");
        assert!(load_graph("/nonexistent/phi-bfs-graph").is_err(), "missing file");
    }

    #[test]
    fn load_graph_round_trips_both_file_formats() {
        let el = RmatConfig::graph500(5, 8).generate(7);
        let g = Csr::from_edge_list(5, &el);
        let dir = std::env::temp_dir().join(format!("phi-bfs-serve-load-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let csr_path = dir.join("g.csr");
        let el_path = dir.join("g.txt");
        crate::graph::io::save_csr(&csr_path, &g).unwrap();
        crate::graph::io::save_edge_list(&el_path, &el).unwrap();
        let from_csr = load_graph(csr_path.to_str().unwrap()).expect("binary CSR");
        let from_el = load_graph(el_path.to_str().unwrap()).expect("edge-list text");
        assert_eq!(from_csr.content_hash(), g.content_hash());
        assert_eq!(from_el.content_hash(), g.content_hash());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn apply_sigma_patches_sell_engines_and_refuses_serial() {
        let mut e = EngineKind::parse("sell", 2, "").unwrap();
        assert!(apply_sigma(&mut e, Some(4096)).is_ok());
        let mut serial = EngineKind::SerialQueue;
        assert!(apply_sigma(&mut serial, Some(4096)).is_err());
        assert!(apply_sigma(&mut serial, None).is_ok(), "no sigma is always fine");
    }
}
