//! Blocking line-protocol client for `phi-bfs serve`.
//!
//! Used by the integration tests, the ablation-11 closed-loop load
//! generator, and the `phi-bfs client` subcommand (the CI smoke leg's
//! driver). One request line out, one reply line back — the protocol has
//! no pipelining, which keeps the client a [`std::net::TcpStream`] and a
//! [`BufReader`].

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::Vertex;

/// One connection to a serve daemon.
pub struct ServeClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl ServeClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        let writer = stream.try_clone().context("cloning the connection")?;
        Ok(ServeClient { reader: BufReader::new(stream), writer })
    }

    /// Send one request line, wait for its reply line (trailing newline
    /// stripped). `Err` means the transport failed, not the request — a
    /// request-level failure is an `ERR ...` reply.
    pub fn send(&mut self, line: &str) -> Result<String> {
        writeln!(self.writer, "{line}").with_context(|| format!("sending {line:?}"))?;
        self.writer.flush().ok();
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply).context("reading reply")?;
        if n == 0 {
            bail!("server closed the connection");
        }
        Ok(reply.trim_end().to_string())
    }

    /// `LOAD` a graph; returns the assigned graph id (e.g. `"g1"`).
    pub fn load(&mut self, spec: &str, sigma: Option<usize>) -> Result<String> {
        let line = match sigma {
            Some(s) => format!("LOAD {spec} {s}"),
            None => format!("LOAD {spec}"),
        };
        let reply = self.send(&line)?;
        match kv(&reply, "id") {
            Some(id) if reply.starts_with("OK LOAD") => Ok(id),
            _ => bail!("LOAD failed: {reply}"),
        }
    }

    /// `BFS` — returns the raw reply line (`OK BFS ...` or `ERR ...`).
    pub fn bfs(&mut self, graph: &str, root: Vertex, deadline_ms: Option<u64>) -> Result<String> {
        let line = match deadline_ms {
            Some(ms) => format!("BFS {graph} {root} {ms}"),
            None => format!("BFS {graph} {root}"),
        };
        self.send(&line)
    }

    pub fn stats(&mut self) -> Result<String> {
        self.send("STATS")
    }

    /// `HEALTH` — the daemon's one-line liveness/readiness report
    /// (`OK HEALTH status=.. accepting=.. ... breakers=..`).
    pub fn health(&mut self) -> Result<String> {
        self.send("HEALTH")
    }

    pub fn shutdown(&mut self) -> Result<String> {
        self.send("SHUTDOWN")
    }
}

/// Look up `key=value` in a reply line (exact key, first match).
pub fn kv(line: &str, key: &str) -> Option<String> {
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(key)?.strip_prefix('=').map(str::to_string))
}

/// [`kv`], parsed as a decimal integer.
pub fn kv_u64(line: &str, key: &str) -> Option<u64> {
    kv(line, key)?.parse().ok()
}

/// [`kv`], parsed as a float (handles the `1.234e6` TEPS rendering).
pub fn kv_f64(line: &str, key: &str) -> Option<f64> {
    kv(line, key)?.parse().ok()
}

/// [`kv`], parsed as the 16-hex-digit checksum rendering.
pub fn kv_hex(line: &str, key: &str) -> Option<u64> {
    u64::from_str_radix(&kv(line, key)?, 16).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_matches_exact_keys_only() {
        let line = "OK BFS root=3 reached=512 checksum=00ff00ff00ff00ff p50_ms=1.024";
        assert_eq!(kv(line, "root").as_deref(), Some("3"));
        assert_eq!(kv_u64(line, "reached"), Some(512));
        assert_eq!(kv_hex(line, "checksum"), Some(0x00ff_00ff_00ff_00ff));
        assert_eq!(kv_f64(line, "p50_ms"), Some(1.024));
        assert_eq!(kv(line, "p50"), None, "prefix of a key must not match");
        assert_eq!(kv(line, "missing"), None);
    }

    #[test]
    fn kv_parses_scientific_floats() {
        assert_eq!(kv_f64("teps=1.250e6 x=1", "teps"), Some(1_250_000.0));
    }
}
