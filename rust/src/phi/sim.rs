//! The machine simulator: compose priced layers with a thread placement
//! into predicted wall time and TEPS.
//!
//! Per layer, per core:
//!
//! * **issue time** — the core's share of issue cycles divided by its
//!   effective issue rate `min(issue_per_core, issue_per_thread × t)`:
//!   one KNC thread can only use every other cycle, two saturate the pipe.
//! * **stall time** — the core's share of stall cycles shrunk by SMT
//!   overlap (`1 / (1 + smt_overlap × (t-1))`) and *grown* by cache
//!   contention (`1 + smt_cache_penalty × (t-1)`): more threads per core
//!   hide more latency but split the L2 — the tension Table 2 measures.
//! * **bandwidth floor** — bytes over the cores' aggregate share of the
//!   ring/GDDR bandwidth.
//! * **starvation** — a layer with fewer frontier vertices than scheduler
//!   grains leaves threads idle (the high-thread-count jitter of §6.1):
//!   utilization = min(1, input / (threads × grain)).
//! * **OS-core invasion** — any thread on the reserved core multiplies
//!   layer time by `os_core_penalty` (§6.2's cliff past 236 threads).

use super::affinity::{Affinity, CoreMap};
use super::config::KncParams;
use super::cost::{price_layer, CostParams, LayerCost};
use super::trace::WorkTrace;

/// Per-layer prediction detail.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerPrediction {
    pub layer: usize,
    pub seconds: f64,
    pub utilization: f64,
    pub bandwidth_bound: bool,
}

/// Whole-run prediction.
#[derive(Clone, Debug, Default)]
pub struct PhiPrediction {
    pub seconds: f64,
    /// Predicted TEPS (undirected traversed edges / seconds).
    pub teps: f64,
    pub layers: Vec<LayerPrediction>,
    pub cores_used: usize,
    pub max_threads_per_core: usize,
    pub invades_os_core: bool,
}

/// Predict the run time of `trace` on `knc` with `num_threads` placed by
/// `affinity`.
pub fn predict(
    knc: &KncParams,
    cp: &CostParams,
    trace: &WorkTrace,
    num_threads: usize,
    affinity: Affinity,
) -> PhiPrediction {
    let map = CoreMap::place(knc, num_threads, affinity);
    predict_with_map(knc, cp, trace, &map)
}

/// Predict with an explicit core map (for custom placements).
pub fn predict_with_map(
    knc: &KncParams,
    cp: &CostParams,
    trace: &WorkTrace,
    map: &CoreMap,
) -> PhiPrediction {
    let num_threads: usize = map.threads_on.iter().sum();
    let bitmap = trace.bitmap_bytes();
    let pred = trace.pred_bytes();
    let cores_used = map.cores_used();
    // aggregate bandwidth available to the active cores (each core's ring
    // stop sustains ~1/cores of the aggregate)
    let bw = knc.mem_bw_bytes_per_s * (cores_used as f64 / knc.cores as f64).min(1.0);

    let mut layers = Vec::with_capacity(trace.layers.len());
    let mut total = 0.0f64;
    for w in &trace.layers {
        let LayerCost { issue_cycles, stall_cycles, bytes } = price_layer(knc, cp, w, bitmap, pred);

        // scheduler starvation: small frontiers can't feed every thread
        let grains = (w.input_vertices as f64 / cp.sched_grain_vertices).max(1.0);
        let utilization = (grains / num_threads as f64).min(1.0);
        let active_threads = (num_threads as f64 * utilization).max(1.0);

        // Dynamic scheduling (the algorithms pull word-chunks from a shared
        // cursor) equalizes completion time across cores, so the machine
        // behaves like the SUM of per-core capacities rather than its worst
        // core: each core contributes issue throughput
        // min(issue_per_core, issue_per_thread × active contexts) and
        // stall-processing throughput overlap/cache_pen. Starvation scales
        // the active contexts per core (t_eff), which shrinks capacity on
        // small frontiers exactly where idle threads can't help.
        let _ = active_threads;
        let mut issue_capacity = 0.0f64;
        let mut stall_capacity = 0.0f64;
        for &t_on_core in &map.threads_on {
            if t_on_core == 0 {
                continue;
            }
            let t_eff = (t_on_core as f64 * utilization).min(t_on_core as f64).max(1e-9);
            issue_capacity += knc.issue_per_core.min(knc.issue_per_thread * t_eff);
            let overlap = 1.0 + cp.smt_overlap * (t_eff - 1.0).max(0.0);
            let cache_pen = 1.0 + cp.smt_cache_penalty * (t_eff - 1.0).max(0.0);
            stall_capacity += overlap / cache_pen;
        }
        let cycles = issue_cycles / issue_capacity.max(1e-12)
            + stall_cycles / stall_capacity.max(1e-12);
        let worst_core_seconds = cycles / knc.hz();

        let bw_floor = bytes / bw;
        let mut layer_seconds = worst_core_seconds.max(bw_floor);
        if map.invades_os_core {
            layer_seconds *= knc.os_core_penalty;
        }
        total += layer_seconds;
        layers.push(LayerPrediction {
            layer: w.layer,
            seconds: layer_seconds,
            utilization,
            bandwidth_bound: bw_floor > worst_core_seconds,
        });
    }

    PhiPrediction {
        seconds: total,
        teps: if total > 0.0 { trace.teps_edges() / total } else { 0.0 },
        layers,
        cores_used,
        max_threads_per_core: map.max_threads_per_core(),
        invades_os_core: map.invades_os_core,
    }
}

/// §6.2 future-work experiment: *helper threads*. Under-populate cores
/// with `workers` BFS threads and give each core `helpers_per_core` spare
/// thread contexts that only run prefetch streams (Kamruzzaman et al.,
/// the paper's [15]). Helpers contribute **no** issue or stall capacity,
/// but each one hides a further `helper_hide` fraction of the remaining
/// memory stalls (diminishing: capped at 2 effective helpers) while still
/// paying the L2-share cache penalty of an occupied context.
pub fn predict_with_helpers(
    knc: &KncParams,
    cp: &CostParams,
    trace: &WorkTrace,
    workers: usize,
    helpers_per_core: usize,
    affinity: Affinity,
) -> PhiPrediction {
    const HELPER_HIDE: f64 = 0.30;
    let map = CoreMap::place(knc, workers, affinity);
    let mut p = predict_with_map(knc, cp, trace, &map);
    if helpers_per_core == 0 {
        return p;
    }
    let eff_helpers = (helpers_per_core.min(2)) as f64;
    // helpers hide stalls but split the cache like any other context
    let stall_hide = 1.0 - HELPER_HIDE * eff_helpers / (1.0 + HELPER_HIDE * eff_helpers);
    let cache_pen = 1.0 + cp.smt_cache_penalty * helpers_per_core as f64 * 0.5;
    let mut total = 0.0;
    for l in &mut p.layers {
        // only the stall-dominated share of the layer shrinks; approximate
        // the stall share from the layer's bandwidth-bound flag heuristic
        let stall_share = 0.75; // BFS layers are stall-dominated on KNC
        l.seconds = l.seconds * (1.0 - stall_share)
            + l.seconds * stall_share * stall_hide * cache_pen;
        total += l.seconds;
    }
    p.seconds = total;
    p.teps = if total > 0.0 { trace.teps_edges() / total } else { 0.0 };
    p
}

/// Convenience: predicted TEPS for the paper's Table-1 SCALE-20 workload.
pub fn predict_scale20_simd(knc: &KncParams, cp: &CostParams, threads: usize, affinity: Affinity, aligned: bool, prefetch: bool) -> PhiPrediction {
    let trace = WorkTrace::synthesize_simd(1 << 20, super::trace::TABLE1_SCALE20, aligned, prefetch);
    predict(knc, cp, &trace, threads, affinity)
}

/// Convenience: the scalar (`non-simd`) counterpart.
pub fn predict_scale20_scalar(knc: &KncParams, cp: &CostParams, threads: usize, affinity: Affinity) -> PhiPrediction {
    let trace = WorkTrace::synthesize_scalar(1 << 20, super::trace::TABLE1_SCALE20);
    predict(knc, cp, &trace, threads, affinity)
}

#[cfg(test)]
mod calibration {
    //! The paper-anchored calibration bands. These tests are the contract
    //! that the model reproduces the *shape* of every evaluation artifact.

    use super::*;

    fn knc() -> KncParams {
        KncParams::default()
    }

    fn cp() -> CostParams {
        CostParams::default()
    }

    /// Table 2 row 1: 48 threads, 1T/C → 4.69E+08 TEPS (±35%).
    #[test]
    fn table2_anchor_48x1() {
        let p = predict_scale20_simd(&knc(), &cp(), 48, Affinity::Manual(1), true, true);
        assert!(
            p.teps > 3.0e8 && p.teps < 6.4e8,
            "48×1T/C predicted {:.3e}, paper 4.69e8",
            p.teps
        );
    }

    /// Table 2 ordering: 1T/C > 2T/C > 3T/C > 4T/C at fixed 48 threads,
    /// with the 4T/C value roughly a third of 1T/C (1.42/4.69 ≈ 0.30).
    #[test]
    fn table2_ordering_and_ratio() {
        let t: Vec<f64> = (1..=4)
            .map(|k| predict_scale20_simd(&knc(), &cp(), 48, Affinity::Manual(k), true, true).teps)
            .collect();
        assert!(t[0] > t[1] && t[1] > t[2] && t[2] > t[3], "{t:?}");
        let ratio = t[3] / t[0];
        assert!((0.18..=0.55).contains(&ratio), "4T/1T ratio {ratio}, paper 0.30");
    }

    /// Fig 10c headline: >1 GTEPS at 236 threads (±, we accept 0.8–1.6e9),
    /// beating Gao et al.'s 800 MTEPS.
    #[test]
    fn fig10c_gigateps_at_236() {
        let p = predict_scale20_simd(&knc(), &cp(), 236, Affinity::Balanced, true, true);
        assert!(p.teps > 0.8e9 && p.teps < 1.8e9, "236T predicted {:.3e}", p.teps);
    }

    /// Fig 10: simd beats non-simd at every thread count, by roughly
    /// 100–400 MTEPS at high thread counts (paper: ≈200).
    #[test]
    fn fig10_simd_gap() {
        for threads in [16usize, 48, 118, 236] {
            let s = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Balanced, true, true);
            let n = predict_scale20_scalar(&knc(), &cp(), threads, Affinity::Balanced);
            assert!(s.teps > n.teps, "simd {:.3e} !> nonsimd {:.3e} at {threads}", s.teps, n.teps);
            if threads >= 118 {
                let gap = s.teps - n.teps;
                assert!((0.5e8..6.0e8).contains(&gap), "gap {:.3e} at {threads}", gap);
            }
        }
    }

    /// Fig 10 shape: TEPS grows with thread count up to 236, with
    /// decreasing slope per T/C regime (60 → 120 → 180 → 236).
    #[test]
    fn fig10_scaling_slope_breaks() {
        let teps: Vec<f64> = [59usize, 118, 177, 236]
            .iter()
            .map(|&t| predict_scale20_simd(&knc(), &cp(), t, Affinity::Balanced, true, true).teps)
            .collect();
        assert!(teps.windows(2).all(|w| w[1] > w[0]), "monotone: {teps:?}");
        let slopes: Vec<f64> = teps.windows(2).map(|w| w[1] - w[0]).collect();
        assert!(
            slopes.windows(2).all(|s| s[1] < s[0] * 1.05),
            "decreasing slopes: {slopes:?}"
        );
    }

    /// §6.2: past 236 threads the OS core is invaded — performance falls
    /// off a cliff.
    #[test]
    fn os_core_cliff_past_236() {
        let ok = predict_scale20_simd(&knc(), &cp(), 236, Affinity::Balanced, true, true);
        let bad = predict_scale20_simd(&knc(), &cp(), 240, Affinity::Balanced, true, true);
        assert!(bad.teps < 0.5 * ok.teps, "236: {:.3e}, 240: {:.3e}", ok.teps, bad.teps);
    }

    /// Fig 9 ordering: no-opt < aligned+masks < aligned+masks+prefetch.
    #[test]
    fn fig9_optimization_ladder() {
        let threads = 118;
        let noopt = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Balanced, false, false);
        let amask = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Balanced, true, false);
        let full = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Balanced, true, true);
        assert!(noopt.teps < amask.teps, "align: {:.3e} !> {:.3e}", amask.teps, noopt.teps);
        assert!(amask.teps < full.teps, "prefetch: {:.3e} !> {:.3e}", full.teps, amask.teps);
    }

    /// Small frontiers starve threads: utilization < 1 on the tail layers
    /// at 236 threads (the §6.1 jitter mechanism).
    #[test]
    fn starvation_on_tiny_layers() {
        let p = predict_scale20_simd(&knc(), &cp(), 236, Affinity::Balanced, true, true);
        let last = p.layers.last().unwrap();
        assert!(last.utilization < 0.05, "layer 6 utilization {}", last.utilization);
        let peak = &p.layers[3];
        assert!(peak.utilization > 0.9, "peak layer utilization {}", peak.utilization);
    }

    /// Balanced ≥ scatter ≥(about) compact at partial populations (§4.2:
    /// "balanced affinity was generally better").
    #[test]
    fn balanced_generally_best() {
        for threads in [48usize, 100, 180] {
            let b = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Balanced, true, true);
            let c = predict_scale20_simd(&knc(), &cp(), threads, Affinity::Compact, true, true);
            assert!(b.teps >= c.teps * 0.98, "balanced {:.3e} vs compact {:.3e} at {threads}", b.teps, c.teps);
        }
    }

    /// §6.2 helper-thread hypothesis: at 2 workers/core, adding prefetch
    /// helpers on the spare contexts must beat leaving them idle, while
    /// staying below a (modelled) perfect 4-worker configuration — i.e.
    /// the paper's "use spare capacity to improve latency hiding" is
    /// directionally confirmed by the model.
    #[test]
    fn helper_threads_beat_idle_contexts() {
        let knc = knc();
        let cp = cp();
        let trace = WorkTrace::synthesize_simd(1 << 20, crate::phi::trace::TABLE1_SCALE20, true, true);
        let idle = predict_with_helpers(&knc, &cp, &trace, 118, 0, Affinity::Balanced);
        let helped = predict_with_helpers(&knc, &cp, &trace, 118, 2, Affinity::Balanced);
        assert!(helped.teps > idle.teps, "helpers {:.3e} !> idle {:.3e}", helped.teps, idle.teps);
        let full = predict_with_helpers(&knc, &cp, &trace, 236, 0, Affinity::Balanced);
        assert!(helped.teps < full.teps * 1.1, "helpers {:.3e} vs 236 workers {:.3e}", helped.teps, full.teps);
    }

    /// Single thread is far from the aggregate: sanity against absurd
    /// single-thread predictions.
    #[test]
    fn single_thread_sane() {
        let p = predict_scale20_simd(&knc(), &cp(), 1, Affinity::Balanced, true, true);
        assert!(p.teps > 1.0e6 && p.teps < 1.0e8, "1T predicted {:.3e}", p.teps);
    }
}
