//! Knights-Corner machine parameters (§2 of the paper + Intel's published
//! KNC documentation).

/// The modelled coprocessor. Defaults describe the paper's device: a
/// 60-core 4-way-SMT Xeon Phi with 8 GB GDDR5 at 320 GB/s.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KncParams {
    /// Physical cores (60 on the paper's card).
    pub cores: usize,
    /// Cores the OS reserves; user threads spilling onto them suffer
    /// [`Self::os_core_penalty`] (§6.2: "beyond 236 threads ... dramatic
    /// fall in performance").
    pub reserved_os_cores: usize,
    /// Hardware threads per core.
    pub smt: usize,
    /// Core clock in GHz.
    pub clock_ghz: f64,
    /// Per-core L2 capacity in bytes (512 KB).
    pub l2_bytes: usize,
    /// Per-core L1D capacity in bytes (32 KB).
    pub l1_bytes: usize,
    /// Aggregate GDDR bandwidth in bytes/second.
    pub mem_bw_bytes_per_s: f64,
    /// Average memory latency in core cycles (~250 on KNC).
    pub mem_latency_cycles: f64,
    /// L2 hit latency in cycles.
    pub l2_latency_cycles: f64,
    /// Peak instruction issue per core per cycle (KNC: 1 vector pipe).
    pub issue_per_core: f64,
    /// Peak issue per *thread* per cycle — the KNC u-arch cannot issue
    /// from the same thread context in back-to-back cycles, so a single
    /// thread tops out at 0.5/cycle; ≥2 threads/core saturate the pipe.
    pub issue_per_thread: f64,
    /// Slowdown multiplier for threads placed on the OS core.
    pub os_core_penalty: f64,
}

impl Default for KncParams {
    fn default() -> Self {
        KncParams {
            cores: 60,
            reserved_os_cores: 1,
            smt: 4,
            clock_ghz: 1.053,
            l2_bytes: 512 * 1024,
            l1_bytes: 32 * 1024,
            mem_bw_bytes_per_s: 320.0e9,
            mem_latency_cycles: 250.0,
            l2_latency_cycles: 24.0,
            issue_per_core: 1.0,
            issue_per_thread: 0.5,
            os_core_penalty: 8.0,
        }
    }
}

impl KncParams {
    /// Cores available to user threads without invading the OS core.
    pub fn user_cores(&self) -> usize {
        self.cores - self.reserved_os_cores
    }

    /// Max user threads with no OS-core invasion (236 on the paper's card).
    pub fn max_clean_threads(&self) -> usize {
        self.user_cores() * self.smt
    }

    /// Cycles per second.
    pub fn hz(&self) -> f64 {
        self.clock_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_numbers() {
        let p = KncParams::default();
        assert_eq!(p.cores, 60);
        assert_eq!(p.user_cores(), 59);
        assert_eq!(p.max_clean_threads(), 236); // §6.2's magic number
        assert_eq!(p.smt * p.cores, 240); // §1: up to 240 logical cores
    }
}
