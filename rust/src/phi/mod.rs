//! The Xeon Phi (Knights Corner) performance model.
//!
//! This container has one x86 core and no Phi; the paper's evaluation
//! (Figs 9–10, Table 2) is entirely about how *fixed algorithmic work*
//! scales across 60 in-order cores × 4-way SMT with a shared ring/GDDR
//! memory system. Per the substitution rule, we reproduce those results by
//! combining
//!
//! 1. **exact work counters** measured from the real algorithm
//!    implementations (edges scanned, 16-lane chunks, gather/scatter lanes,
//!    peel/remainder lanes, restoration words — see
//!    [`crate::bfs::RunTrace`] and [`crate::simd::VpuCounters`]), with
//! 2. **published machine parameters** of the Knights Corner generation
//!    ([`config::KncParams`]): 1.053 GHz in-order cores that cannot issue
//!    vector instructions from one thread in consecutive cycles (hence
//!    ≥2 threads/core to saturate the VPU), 32 KB L1 / 512 KB L2 per core,
//!    ~250-cycle memory latency, 320 GB/s aggregate GDDR bandwidth over a
//!    bidirectional ring, and the last core reserved for the OS.
//!
//! [`affinity`] maps a thread count + `KMP_AFFINITY` strategy to per-core
//! thread populations; [`cost`] prices one thread's share of a layer's
//! events in cycles; [`sim`] composes cores, SMT issue contention, cache
//! sharing, bandwidth caps and frontier-starvation imbalance into a layer
//! time, and sums layers into a predicted TEPS.
//!
//! Calibration: constants in [`cost`] are anchored to the paper's own
//! numbers (Table 2's 4.69E+08 at 48×1T/C; Fig 10c's >1 GTEPS at 236
//! threads; the ≈200 MTEPS SIMD/non-SIMD gap) — the calibration tests in
//! [`sim`] assert the model stays inside loose bands of those anchors, so
//! the *shape* claims of the paper remain enforced by CI rather than by
//! hand-tuned output.

pub mod affinity;
pub mod config;
pub mod cost;
pub mod sim;
pub mod trace;

pub use affinity::{Affinity, CoreMap};
pub use config::KncParams;
pub use sim::{predict, PhiPrediction};
pub use trace::WorkTrace;
