//! `KMP_AFFINITY` thread-placement strategies (§4.2 "Thread affinity").
//!
//! * **compact** — fill each core's 4 thread contexts before moving on.
//! * **scatter** — round-robin over physical cores, so thread ids far
//!   apart share a core.
//! * **balanced** — like scatter core-wise, but adjacent thread ids end up
//!   on the same core. For the *population counts* per core (what the
//!   performance model consumes) balanced and scatter are identical; they
//!   differ in which ids share a core, which we also record since the
//!   sharing pattern drives the cache-affinity term.
//! * **manual(k)** — exactly k threads per core, the paper's Table 2
//!   methodology (48 threads at 1T/C..4T/C).

use super::config::KncParams;

/// Placement strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Affinity {
    Compact,
    Scatter,
    Balanced,
    /// Fixed threads-per-core (Table 2's 1T/C..4T/C rows).
    Manual(usize),
}

impl Affinity {
    pub fn parse(s: &str) -> Option<Affinity> {
        Some(match s {
            "compact" => Affinity::Compact,
            "scatter" => Affinity::Scatter,
            "balanced" => Affinity::Balanced,
            _ => {
                let k = s.strip_suffix("t/c").or_else(|| s.strip_suffix("T/C"))?;
                Affinity::Manual(k.parse().ok()?)
            }
        })
    }
}

/// The result of placing `num_threads` threads: which core each thread
/// landed on, and the per-core populations.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreMap {
    /// `core_of[t]` = physical core of thread `t`.
    pub core_of: Vec<usize>,
    /// `threads_on[c]` = number of threads on core `c` (len = cores).
    pub threads_on: Vec<usize>,
    /// True if any thread landed on an OS-reserved core.
    pub invades_os_core: bool,
    /// True when adjacent thread ids tend to share a core (balanced /
    /// compact) — enables the shared-frontier cache-reuse credit.
    pub neighbors_share_core: bool,
}

impl CoreMap {
    /// Place threads according to the strategy.
    pub fn place(params: &KncParams, num_threads: usize, affinity: Affinity) -> CoreMap {
        let cores = params.cores;
        let user = params.user_cores();
        let mut core_of = vec![0usize; num_threads];
        match affinity {
            Affinity::Compact => {
                // fill thread contexts core by core (user cores first, the
                // OS core last — matching KMP behaviour where the OS core
                // is the highest-numbered)
                for (t, c) in core_of.iter_mut().enumerate() {
                    *c = (t / params.smt).min(cores - 1);
                }
            }
            Affinity::Scatter | Affinity::Balanced => {
                // both strategies spread threads as evenly as possible over
                // the user cores (per-core counts differ by at most one);
                // they differ in which *ids* share a core.
                let clean = num_threads.min(user * params.smt);
                if affinity == Affinity::Balanced {
                    // contiguous blocks: first `rem` cores take base+1
                    let base = clean / user;
                    let rem = clean % user;
                    let mut t = 0usize;
                    'outer: for core in 0..user {
                        let take = base + usize::from(core < rem);
                        for _ in 0..take {
                            if t >= clean {
                                break 'outer;
                            }
                            core_of[t] = core;
                            t += 1;
                        }
                    }
                } else {
                    // scatter: round-robin, adjacent ids on different cores
                    for (t, c) in core_of.iter_mut().enumerate().take(clean) {
                        *c = t % user;
                    }
                }
                // overflow beyond user×smt spills onto the OS core
                for c in core_of.iter_mut().skip(clean) {
                    *c = cores - 1;
                }
            }
            Affinity::Manual(k) => {
                let k = k.clamp(1, params.smt);
                for (t, c) in core_of.iter_mut().enumerate() {
                    *c = (t / k).min(cores - 1);
                }
            }
        }
        let mut threads_on = vec![0usize; cores];
        for &c in &core_of {
            threads_on[c] += 1;
        }
        let os_cores = cores - user;
        let invades_os_core =
            (cores - os_cores..cores).any(|c| threads_on[c] > 0) && os_cores > 0
            // compact fills cores in order, so the OS core is only reached
            // when every user context is taken
            ;
        // compact/balanced put adjacent ids together
        let neighbors_share_core = matches!(affinity, Affinity::Compact | Affinity::Balanced)
            && core_of.windows(2).any(|w| w[0] == w[1]);
        CoreMap { core_of, threads_on, invades_os_core, neighbors_share_core }
    }

    /// Number of cores with at least one thread.
    pub fn cores_used(&self) -> usize {
        self.threads_on.iter().filter(|&&t| t > 0).count()
    }

    /// Histogram entry: max threads on any used core.
    pub fn max_threads_per_core(&self) -> usize {
        self.threads_on.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> KncParams {
        KncParams::default()
    }

    #[test]
    fn manual_table2_rows() {
        // Table 2: 48 threads at 1/2/3/4 T per core → 48/24/16/12 cores.
        let p = params();
        for (k, cores) in [(1usize, 48usize), (2, 24), (3, 16), (4, 12)] {
            let m = CoreMap::place(&p, 48, Affinity::Manual(k));
            assert_eq!(m.cores_used(), cores, "{k}T/C");
            assert_eq!(m.max_threads_per_core(), k);
            assert!(!m.invades_os_core);
        }
    }

    #[test]
    fn scatter_spreads_wide() {
        let p = params();
        let m = CoreMap::place(&p, 59, Affinity::Scatter);
        assert_eq!(m.cores_used(), 59);
        assert_eq!(m.max_threads_per_core(), 1);
        let m = CoreMap::place(&p, 118, Affinity::Scatter);
        assert_eq!(m.cores_used(), 59);
        assert_eq!(m.max_threads_per_core(), 2);
        // scatter puts adjacent ids on different cores
        assert!(!m.neighbors_share_core);
    }

    #[test]
    fn balanced_shares_core_between_neighbors() {
        let p = params();
        let m = CoreMap::place(&p, 118, Affinity::Balanced);
        assert_eq!(m.cores_used(), 59);
        assert_eq!(m.max_threads_per_core(), 2);
        assert!(m.neighbors_share_core);
        assert_eq!(m.core_of[0], m.core_of[1]); // adjacent ids together
    }

    #[test]
    fn compact_fills_cores() {
        let p = params();
        let m = CoreMap::place(&p, 8, Affinity::Compact);
        assert_eq!(m.cores_used(), 2);
        assert_eq!(m.threads_on[0], 4);
        assert_eq!(m.threads_on[1], 4);
    }

    #[test]
    fn beyond_236_invades_os_core() {
        let p = params();
        let m236 = CoreMap::place(&p, 236, Affinity::Balanced);
        assert!(!m236.invades_os_core);
        let m240 = CoreMap::place(&p, 240, Affinity::Balanced);
        assert!(m240.invades_os_core, "{:?}", &m240.threads_on[55..]);
    }

    #[test]
    fn affinity_parse() {
        assert_eq!(Affinity::parse("balanced"), Some(Affinity::Balanced));
        assert_eq!(Affinity::parse("2t/c"), Some(Affinity::Manual(2)));
        assert_eq!(Affinity::parse("4T/C"), Some(Affinity::Manual(4)));
        assert_eq!(Affinity::parse("bogus"), None);
    }

    #[test]
    fn all_threads_mapped() {
        let p = params();
        for aff in [Affinity::Compact, Affinity::Scatter, Affinity::Balanced, Affinity::Manual(3)] {
            for t in [1usize, 7, 48, 100, 236, 240] {
                let m = CoreMap::place(&p, t, aff);
                assert_eq!(m.core_of.len(), t);
                assert_eq!(m.threads_on.iter().sum::<usize>(), t, "{aff:?} {t}");
            }
        }
    }
}
