//! The event cost model: prices one layer's work in core cycles and bytes.
//!
//! Two cost classes per layer:
//!
//! * **issue cycles** — instruction occupancy of the core's single vector
//!   pipe (or the scalar pipes for non-SIMD layers). Divided by the
//!   per-core issue capacity in [`super::sim`].
//! * **stall cycles** — memory latency a *thread* sits on: L2-latency for
//!   bitmap gathers (the bitmap fits L2 but not L1), full memory latency
//!   for predecessor-array writes (4 MB at SCALE 20, far beyond L2) and
//!   for streaming `rows` refills when prefetch is off. SMT overlaps
//!   stalls across a core's threads in [`super::sim`].
//!
//! Constants were calibrated against the paper's anchors (see
//! `sim::calibration` tests): Table 2's 4.69E+08 TEPS at 48×1T/C, Fig 10c's
//! >1 GTEPS at 236 threads, the ≈200 MTEPS SIMD gap, and Fig 9's
//! optimization deltas.

use super::config::KncParams;
use super::trace::LayerWork;

/// Tunable event costs (cycles unless noted).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Fixed instruction overhead per 16-lane chunk (address arithmetic,
    /// div/rem, shifts, mask logic ≈ Listing 1's non-memory ops).
    pub chunk_issue: f64,
    /// Extra issue cycles for a masked/unaligned chunk (§4.2: peel and
    /// remainder "imply an extra processing step").
    pub masked_chunk_penalty: f64,
    /// Issue occupancy per gathered lane (KNC gathers retire ~1 lane/cycle).
    pub gather_lane_issue: f64,
    /// Issue occupancy per scattered lane.
    pub scatter_lane_issue: f64,
    /// Stall fraction of L2 latency charged per gather lane (bitmap lives
    /// in L2; consecutive gathers pipeline partially).
    pub gather_l2_stall_frac: f64,
    /// Stall fraction of full memory latency charged per predecessor
    /// scatter lane (pred array ≫ L2; write-allocate miss).
    pub pred_miss_stall_frac: f64,
    /// Per-chunk stall for streaming `rows` refills when SW prefetch is
    /// OFF (one line miss per chunk, partially covered by the HW
    /// prefetcher).
    pub rows_stall_nopf: f64,
    /// Same with SW prefetch ON (§4.2: prefetch the next iteration's rows).
    pub rows_stall_pf: f64,
    /// Rows-stall multiplier when the chunking is UNALIGNED (the "SIMD -
    /// no opt" configuration: every load is masked and straddles cache
    /// lines; detected as full_chunks == 0 with masked chunks present).
    pub unaligned_stall_mult: f64,
    /// Issue cycles per scalar edge (Algorithm 2's test/set/store chain).
    pub scalar_edge_issue: f64,
    /// Stall cycles per scalar edge (serial dependent loads on an in-order
    /// core — this is what the vector unit amortizes 16-wide).
    pub scalar_edge_stall: f64,
    /// Issue cycles per restoration word scanned.
    pub restore_word_issue: f64,
    /// Bytes moved per edge scanned (rows read + share of bitmap/pred
    /// traffic) for the bandwidth floor.
    pub bytes_per_edge: f64,
    /// SMT stall-overlap efficiency: fraction of another thread's stalls a
    /// core can hide per extra thread context.
    pub smt_overlap: f64,
    /// L2-contention growth per extra thread on a core (cache splits;
    /// miss rates rise).
    pub smt_cache_penalty: f64,
    /// Dynamic-scheduling grain in frontier vertices (starvation model).
    pub sched_grain_vertices: f64,
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams {
            chunk_issue: 14.0,
            masked_chunk_penalty: 6.0,
            gather_lane_issue: 1.0,
            scatter_lane_issue: 1.0,
            gather_l2_stall_frac: 0.90,
            pred_miss_stall_frac: 0.50,
            rows_stall_nopf: 60.0,
            rows_stall_pf: 25.0,
            unaligned_stall_mult: 3.0,
            scalar_edge_issue: 12.0,
            scalar_edge_stall: 42.0,
            restore_word_issue: 10.0,
            bytes_per_edge: 9.0,
            smt_overlap: 0.55,
            smt_cache_penalty: 0.18,
            sched_grain_vertices: 2.0,
        }
    }
}

/// A layer's priced work (totals across all threads, before core mapping).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerCost {
    /// Total instruction-issue cycles.
    pub issue_cycles: f64,
    /// Total thread-stall cycles (before SMT overlap).
    pub stall_cycles: f64,
    /// Total bytes for the bandwidth floor.
    pub bytes: f64,
}

/// Price one layer.
pub fn price_layer(knc: &KncParams, cp: &CostParams, w: &LayerWork, bitmap_bytes: usize, pred_bytes: usize) -> LayerCost {
    let mut issue = 0.0;
    let mut stall = 0.0;

    if w.vectorized {
        // Gather-fed explorers (the SELL engine) issue rows without a
        // vector load, so the chunk count is the larger of the load tally
        // and the recorded explore issues; the extra issues are priced as
        // masked chunks (their lane masks vary per row). For load-fed
        // explorers the two tallies coincide and nothing changes.
        let masked = w.masked_chunks.max(w.explore_issues.saturating_sub(w.full_chunks));
        let chunks = (w.full_chunks + masked) as f64;
        issue += w.full_chunks as f64 * cp.chunk_issue;
        issue += masked as f64 * (cp.chunk_issue + cp.masked_chunk_penalty);
        issue += w.gather_lanes as f64 * cp.gather_lane_issue;
        issue += w.scatter_lanes as f64 * cp.scatter_lane_issue;
        issue += w.restore_words as f64 * cp.restore_word_issue;

        // bitmap gathers: L2-resident when the bitmap fits (it does for
        // every SCALE the paper runs), L1-resident fraction shrinks as the
        // bitmap outgrows L1.
        let l1_fit = (knc.l1_bytes as f64 / bitmap_bytes.max(1) as f64).min(1.0);
        let gather_lat = knc.l2_latency_cycles * (1.0 - l1_fit);
        stall += w.gather_lanes as f64 * gather_lat * cp.gather_l2_stall_frac;

        // predecessor scatters: miss probability grows with pred footprint
        // beyond L2.
        let pred_fit = (knc.l2_bytes as f64 / pred_bytes.max(1) as f64).min(1.0);
        let pred_miss = 1.0 - pred_fit;
        // half the scatter lanes hit `pred`, half the queue words (words
        // are bitmap-resident and cheap)
        stall += 0.5
            * w.scatter_lanes as f64
            * pred_miss
            * knc.mem_latency_cycles
            * cp.pred_miss_stall_frac;

        // streaming rows refills; unaligned (no-opt) chunking straddles
        // cache lines and defeats the streaming pattern
        let unaligned = w.full_chunks == 0 && w.masked_chunks > 0;
        let mut rows_stall = if w.prefetch_enabled() { cp.rows_stall_pf } else { cp.rows_stall_nopf };
        if unaligned {
            rows_stall *= cp.unaligned_stall_mult;
        }
        stall += chunks * rows_stall;
    } else {
        let edges = w.edges_scanned as f64;
        issue += edges * cp.scalar_edge_issue;
        let pred_fit = (knc.l2_bytes as f64 / pred_bytes.max(1) as f64).min(1.0);
        stall += edges * cp.scalar_edge_stall;
        stall += w.traversed as f64 * (1.0 - pred_fit) * knc.mem_latency_cycles * cp.pred_miss_stall_frac;
    }

    LayerCost { issue_cycles: issue, stall_cycles: stall, bytes: w.edges_scanned as f64 * cp.bytes_per_edge }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::phi::trace::WorkTrace;

    fn knc() -> KncParams {
        KncParams::default()
    }

    #[test]
    fn vector_layer_cheaper_per_edge_than_scalar() {
        let cp = CostParams::default();
        let profile = &[(1000, 100_000, 30_000)];
        let simd = WorkTrace::synthesize_simd(1 << 20, profile, true, true);
        let scalar = WorkTrace::synthesize_scalar(1 << 20, profile);
        let c_simd = price_layer(&knc(), &cp, &simd.layers[0], simd.bitmap_bytes(), simd.pred_bytes());
        let c_scalar =
            price_layer(&knc(), &cp, &scalar.layers[0], scalar.bitmap_bytes(), scalar.pred_bytes());
        let t_simd = c_simd.issue_cycles + c_simd.stall_cycles;
        let t_scalar = c_scalar.issue_cycles + c_scalar.stall_cycles;
        assert!(t_simd < t_scalar, "simd {t_simd} !< scalar {t_scalar}");
    }

    #[test]
    fn prefetch_reduces_stalls() {
        let cp = CostParams::default();
        let profile = &[(1000, 100_000, 30_000)];
        let pf = WorkTrace::synthesize_simd(1 << 20, profile, true, true);
        let nopf = WorkTrace::synthesize_simd(1 << 20, profile, true, false);
        let c_pf = price_layer(&knc(), &cp, &pf.layers[0], pf.bitmap_bytes(), pf.pred_bytes());
        let c_nopf = price_layer(&knc(), &cp, &nopf.layers[0], nopf.bitmap_bytes(), nopf.pred_bytes());
        assert!(c_pf.stall_cycles < c_nopf.stall_cycles);
    }

    #[test]
    fn unaligned_costs_more_issue() {
        let cp = CostParams::default();
        let profile = &[(1000, 100_000, 30_000)];
        let al = WorkTrace::synthesize_simd(1 << 20, profile, true, true);
        let un = WorkTrace::synthesize_simd(1 << 20, profile, false, true);
        let c_al = price_layer(&knc(), &cp, &al.layers[0], al.bitmap_bytes(), al.pred_bytes());
        let c_un = price_layer(&knc(), &cp, &un.layers[0], un.bitmap_bytes(), un.pred_bytes());
        assert!(c_un.issue_cycles > c_al.issue_cycles);
    }
}
