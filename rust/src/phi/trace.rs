//! Work traces: the bridge between a measured BFS run and the machine
//! model. A [`WorkTrace`] is algorithm- and graph-specific but
//! machine-independent; [`super::sim`] re-maps it onto any thread/affinity
//! configuration.

use crate::bfs::{LayerTrace, RunTrace};

/// One layer's machine-independent work description.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerWork {
    pub layer: usize,
    pub input_vertices: usize,
    pub edges_scanned: usize,
    pub traversed: usize,
    pub vectorized: bool,
    /// 16-lane chunk loads (aligned full vectors).
    pub full_chunks: u64,
    /// Masked (peel/remainder/unaligned) chunk loads.
    pub masked_chunks: u64,
    /// Explore issues pushed through the Listing-1 dataflow (≥ the load
    /// counts for gather-fed explorers like SELL, whose rows issue without
    /// a vector load).
    pub explore_issues: u64,
    /// Lanes carrying real adjacency work across those issues.
    pub lanes_active: u64,
    pub gather_lanes: u64,
    pub scatter_lanes: u64,
    pub alu_ops: u64,
    pub mask_ops: u64,
    pub prefetches: u64,
    pub restore_words: usize,
}

impl LayerWork {
    pub fn from_layer(l: &LayerTrace) -> Self {
        LayerWork {
            layer: l.layer,
            input_vertices: l.input_vertices,
            edges_scanned: l.edges_scanned,
            traversed: l.traversed,
            vectorized: l.vectorized,
            full_chunks: l.vpu.vector_loads,
            masked_chunks: l.vpu.masked_loads,
            explore_issues: l.vpu.explore_issues,
            lanes_active: l.vpu.lanes_active,
            gather_lanes: l.vpu.gather_lanes,
            scatter_lanes: l.vpu.scatter_lanes,
            alu_ops: l.vpu.alu_ops,
            mask_ops: l.vpu.mask_ops,
            prefetches: l.vpu.prefetch_l1 + l.vpu.prefetch_l2,
            restore_words: l.restore_words_scanned,
        }
    }

    /// Whether software prefetching was active during this layer.
    pub fn prefetch_enabled(&self) -> bool {
        self.prefetches > 0
    }
}

/// Machine-independent description of a whole run.
#[derive(Clone, Debug)]
pub struct WorkTrace {
    /// Vertices in the graph (bitmap geometry: `ceil(n/32)*4` bytes).
    pub num_vertices: usize,
    pub layers: Vec<LayerWork>,
}

impl WorkTrace {
    /// Extract from a measured run.
    pub fn from_run(num_vertices: usize, trace: &RunTrace) -> Self {
        WorkTrace {
            num_vertices,
            layers: trace.layers.iter().map(LayerWork::from_layer).collect(),
        }
    }

    /// Undirected edges traversed (Graph500 TEPS numerator).
    pub fn teps_edges(&self) -> f64 {
        self.layers.iter().map(|l| l.edges_scanned).sum::<usize>() as f64 / 2.0
    }

    /// Bitmap size in bytes (`visited` or the queues — same geometry).
    pub fn bitmap_bytes(&self) -> usize {
        self.num_vertices.div_ceil(32) * 4
    }

    /// Predecessor array footprint in bytes.
    pub fn pred_bytes(&self) -> usize {
        self.num_vertices * 4
    }

    /// Synthesize the trace of a *vectorized* run from per-layer
    /// (input, edges, traversed) profiles — used to model paper-scale
    /// graphs (SCALE 20) without holding them in this container's memory.
    /// Counter arithmetic mirrors what the emulated VPU would record:
    /// mean chunk occupancy from the degree distribution, 2 word-gathers +
    /// ≤2 scatters per discovered lane, restoration over the words the
    /// layer touched.
    pub fn synthesize_simd(
        num_vertices: usize,
        profile: &[(usize, usize, usize)], // (input, edges, traversed)
        aligned: bool,
        prefetch: bool,
    ) -> Self {
        let layers = profile
            .iter()
            .enumerate()
            .map(|(i, &(input, edges, traversed))| {
                let mean_degree = if input > 0 { edges / input.max(1) } else { 0 };
                // per vertex: one peel + one remainder chunk on average when
                // aligned; all-masked when not
                let full = if aligned { (edges / 16).saturating_sub(input) as u64 } else { 0 };
                let masked = if aligned {
                    (input * 2) as u64
                } else {
                    (edges.div_ceil(16).max(input)) as u64
                };
                let lanes = edges as u64;
                LayerWork {
                    layer: i,
                    input_vertices: input,
                    edges_scanned: edges,
                    traversed,
                    vectorized: mean_degree >= 16,
                    full_chunks: full,
                    masked_chunks: masked,
                    explore_issues: full + masked,
                    lanes_active: lanes,
                    gather_lanes: 2 * lanes,
                    scatter_lanes: 2 * traversed as u64,
                    alu_ops: (full + masked) * 8,
                    mask_ops: (full + masked) * 4,
                    prefetches: if prefetch { full + masked } else { 0 },
                    restore_words: (traversed / 8).max(1),
                }
            })
            .collect();
        WorkTrace { num_vertices, layers }
    }

    /// Synthesize a scalar (`non-simd`, Algorithm 2) run from the same
    /// profile shape.
    pub fn synthesize_scalar(num_vertices: usize, profile: &[(usize, usize, usize)]) -> Self {
        let layers = profile
            .iter()
            .enumerate()
            .map(|(i, &(input, edges, traversed))| LayerWork {
                layer: i,
                input_vertices: input,
                edges_scanned: edges,
                traversed,
                vectorized: false,
                ..Default::default()
            })
            .collect();
        WorkTrace { num_vertices, layers }
    }
}

/// The paper's Table 1 profile (SCALE 20, edgefactor 16): per layer
/// (input vertices, edges, traversed). Used by benches to model the exact
/// workload the paper measured.
pub const TABLE1_SCALE20: &[(usize, usize, usize)] = &[
    (1, 12, 12),
    (12, 21_892, 18_122),
    (18_122, 13_547_462, 540_575),
    (540_575, 17_626_910, 100_874),
    (100_874, 150_698, 486),
    (486, 490, 4),
    (2, 2, 0),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_totals() {
        let t = WorkTrace::synthesize_simd(1 << 20, TABLE1_SCALE20, true, true);
        assert_eq!(t.layers.len(), 7);
        // ~31.3M directed edge scans → ~15.7M undirected TEPS edges
        assert!((t.teps_edges() - 15_673_733.0).abs() < 1.0);
        assert_eq!(t.bitmap_bytes(), 131_072); // the paper's §3.3.1 number
        assert_eq!(t.pred_bytes(), 4 * 1024 * 1024);
    }

    #[test]
    fn from_run_roundtrip() {
        use crate::bfs::vectorized::VectorizedBfs;
        use crate::bfs::BfsEngine;
        use crate::graph::{Csr, RmatConfig};
        let el = RmatConfig::graph500(10, 8).generate(3);
        let g = Csr::from_edge_list(10, &el);
        let r = VectorizedBfs::default().run(&g, 0);
        let t = WorkTrace::from_run(g.num_vertices(), &r.trace);
        assert_eq!(t.layers.len(), r.trace.layers.len());
        assert_eq!(
            t.layers.iter().map(|l| l.edges_scanned).sum::<usize>(),
            r.trace.total_edges_scanned()
        );
    }

    #[test]
    fn synthesize_scalar_has_no_vpu_events() {
        let t = WorkTrace::synthesize_scalar(1024, &[(1, 10, 5), (5, 50, 20)]);
        assert!(t.layers.iter().all(|l| l.gather_lanes == 0 && !l.vectorized));
    }
}
