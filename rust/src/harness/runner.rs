//! End-to-end Graph500 experiment: kernel-0 graph construction, 64 random
//! roots, per-root traversal + soft validation, TEPS statistics.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use super::stats::TepsStats;
use crate::bfs::RunControl;
use crate::coordinator::engine::EngineKind;
use crate::coordinator::error::CoordinatorError;
use crate::coordinator::governor::{AdmissionPolicy, ResourcePressure};
use crate::coordinator::job::{BatchPolicy, BfsJob, RootOutcome, RootRun, RunPolicy};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::scheduler::{retry_backoff, Coordinator};
use crate::coordinator::watchdog::Supervisor;
use crate::graph::stats::LayerProfile;
use crate::graph::{Csr, RmatConfig};
use crate::rng::Xoshiro256;
use crate::Vertex;

/// Experiment configuration (§5's setup).
#[derive(Clone, Debug)]
pub struct Experiment {
    pub scale: u32,
    pub edgefactor: usize,
    pub seed: u64,
    /// Number of BFS executions; Graph500 and the paper use 64.
    pub num_roots: usize,
    pub engine: EngineKind,
    /// Coordinator worker threads (independent of the engine's threads).
    pub workers: usize,
    pub validate: bool,
    /// Roots per traversal batch (1 = the classic per-root schedule).
    /// Wider batches route through `PreparedBfs::run_batch`, which the
    /// MS engine (`hybrid-sell-ms`) turns into shared 16-root waves.
    pub batch_roots: usize,
    /// Traversal-phase deadline in milliseconds (`--deadline-ms`): engines
    /// stop at the next layer boundary once it passes and the interrupted
    /// roots are excluded from the TEPS statistics. `None` = unbounded.
    pub deadline_ms: Option<u64>,
    /// Attempts per root before it counts as failed (`--max-attempts`);
    /// retries walk the coordinator's degradation ladder.
    pub max_attempts: usize,
    /// Memory budget in MiB for the coordinator's resource governor
    /// (`--mem-budget-mb`): artifact builds and per-job working sets are
    /// byte-accounted against it, optional artifacts are skipped under
    /// pressure, and jobs whose footprint cannot fit are shed with a
    /// structured error. `None` = ungoverned.
    pub mem_budget_mb: Option<usize>,
    /// Admission cap on concurrently running jobs (`--max-inflight`);
    /// excess jobs are rejected with a retry hint instead of queueing.
    pub max_inflight: usize,
    /// Watchdog liveness budget in milliseconds (`--liveness-ms`): the
    /// job runs under a [`Supervisor`] that cancels it if its heartbeat
    /// stalls this long and abandons it (structured per-root failures)
    /// after a further grace window. `None` = unsupervised. The budget
    /// must also cover the one-time prepare phase, which does not tick.
    pub liveness_ms: Option<u64>,
}

impl Experiment {
    pub fn new(scale: u32, edgefactor: usize, engine: EngineKind) -> Self {
        Experiment {
            scale,
            edgefactor,
            seed: 1,
            num_roots: 64,
            engine,
            workers: 1,
            validate: true,
            batch_roots: 1,
            deadline_ms: None,
            max_attempts: RunPolicy::default().max_attempts,
            mem_budget_mb: None,
            max_inflight: AdmissionPolicy::default().max_inflight,
            liveness_ms: None,
        }
    }

    /// Build graph, sample roots, run all traversals, collect stats.
    pub fn run(&self) -> Result<ExperimentReport> {
        let t0 = Instant::now();
        let cfg = RmatConfig::graph500(self.scale, self.edgefactor);
        let edges = cfg.generate(self.seed);
        let graph = Arc::new(Csr::from_edge_list(self.scale, &edges));
        let construction_seconds = t0.elapsed().as_secs_f64();

        // Graph500 samples roots uniformly from the vertex space; the
        // paper explicitly does NOT filter unconnected ones (§5.3).
        let mut rng = Xoshiro256::seed_from_u64(self.seed ^ 0x524f_4f54); // "ROOT"
        let n = graph.num_vertices();
        let roots: Vec<Vertex> = rng
            .sample_distinct(n, self.num_roots.min(n))
            .into_iter()
            .map(|v| v as Vertex)
            .collect();

        let job = BfsJob {
            id: self.seed,
            graph: Arc::clone(&graph),
            roots,
            engine: self.engine.clone(),
            validate: self.validate,
            batch: if self.batch_roots > 1 {
                BatchPolicy::Fixed(self.batch_roots)
            } else {
                BatchPolicy::PerRoot
            },
            run: RunPolicy {
                deadline: self.deadline_ms.map(Duration::from_millis),
                max_attempts: self.max_attempts,
                liveness: self.liveness_ms.map(Duration::from_millis),
                ..RunPolicy::default()
            },
        };
        let coordinator = Arc::new(Coordinator::with_limits(
            self.workers,
            self.mem_budget_mb.map(|mb| mb.saturating_mul(1 << 20)),
            AdmissionPolicy { max_inflight: self.max_inflight },
        ));
        // a liveness budget routes the job through the watchdog's
        // supervised pool; without one the supervisor is never built and
        // the job runs inline exactly as before
        let supervisor =
            self.liveness_ms.map(|_| Supervisor::new(Arc::clone(&coordinator), 1));
        // a shed job is transient backpressure, not a failure: honor the
        // coordinator's retry hint (floored by the jittered backoff curve
        // so concurrent harnesses cannot re-collide in lockstep) for a
        // bounded number of re-submissions — the serve daemon's
        // dispatcher applies the same discipline per wave
        let mut backoff_rng = Xoshiro256::seed_from_u64(self.seed ^ 0x5245_5452); // "RETR"
        let max_submissions = self.max_attempts.max(1);
        let mut attempt = 0usize;
        let outcome = loop {
            let result = match &supervisor {
                Some(sup) => sup.run_job(job.clone()),
                None => coordinator.run_job(&job),
            };
            match result {
                Ok(outcome) => break outcome,
                Err(CoordinatorError::Rejected { retry_after_hint })
                    if attempt + 1 < max_submissions =>
                {
                    attempt += 1;
                    let pause = retry_after_hint.max(retry_backoff(
                        attempt + 1,
                        &mut backoff_rng,
                        RunControl::unbounded(),
                    ));
                    eprintln!(
                        "harness: job shed by admission control; retrying in {} ms \
                         (attempt {attempt}/{max_submissions})",
                        pause.as_millis()
                    );
                    std::thread::sleep(pause);
                }
                Err(e) => return Err(e.into()),
            }
        };

        // a benchmark's numbers are meaningless with holes in them: a
        // root that exhausted its retries fails the whole experiment
        if let Some(RootOutcome::Failed { root, error, attempts }) = outcome.failures().next()
        {
            anyhow::bail!(
                "{} of {} roots failed permanently (root {root} after {attempts} \
                 attempts: {error})",
                outcome.failures().count(),
                outcome.outcomes.len(),
            );
        }
        let preparation_seconds = outcome.preparation_seconds;
        let all_valid = outcome.all_valid;
        let pressure = outcome.pressure;
        let runs: Vec<RootRun> =
            outcome.outcomes.into_iter().filter_map(RootOutcome::into_run).collect();

        let stats = TepsStats::from_runs(&runs);
        let coordinator_metrics = coordinator.metrics().snapshot();
        Ok(ExperimentReport {
            scale: self.scale,
            edgefactor: self.edgefactor,
            num_vertices: n,
            num_directed_edges: graph.num_directed_edges(),
            construction_seconds,
            preparation_seconds,
            graph,
            runs,
            all_valid,
            pressure,
            stats,
            coordinator_metrics,
        })
    }
}

/// Everything a bench or example needs to print paper-style results.
pub struct ExperimentReport {
    pub scale: u32,
    pub edgefactor: usize,
    pub num_vertices: usize,
    pub num_directed_edges: usize,
    /// Kernel 0: RMAT generation + CSR build.
    pub construction_seconds: f64,
    /// One-time engine prepare (layouts, stats, compiled kernels) — paid
    /// once per experiment, amortized over all roots.
    pub preparation_seconds: f64,
    pub graph: Arc<Csr>,
    pub runs: Vec<RootRun>,
    pub all_valid: bool,
    /// Optional artifacts the governor skipped under memory pressure
    /// (empty when ungoverned or when everything fit); the experiment
    /// still completed on fallback paths.
    pub pressure: Vec<ResourcePressure>,
    pub stats: TepsStats,
    /// The coordinator's own counters for this experiment, rendered as
    /// one `key=value` line by its `Display` — the same line the serve
    /// daemon's `STATS` reply embeds.
    pub coordinator_metrics: MetricsSnapshot,
}

impl ExperimentReport {
    /// Table-1-style layer profile for the first *connected* root.
    pub fn layer_profile(&self) -> Option<LayerProfile> {
        let run = self.runs.iter().find(|r| r.reached > 1)?;
        Some(LayerProfile::compute(&self.graph, run.root))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_experiment_end_to_end() {
        let mut exp = Experiment::new(9, 8, EngineKind::SerialLayered);
        exp.num_roots = 8;
        exp.workers = 2;
        let report = exp.run().unwrap();
        assert_eq!(report.num_vertices, 512);
        assert_eq!(report.runs.len(), 8);
        assert!(report.all_valid, "validation failed");
        assert!(report.stats.max > 0.0);
        assert!(report.layer_profile().is_some());
    }

    #[test]
    fn experiment_deterministic_roots() {
        let mut exp = Experiment::new(8, 8, EngineKind::SerialQueue);
        exp.num_roots = 4;
        let a = exp.run().unwrap();
        let b = exp.run().unwrap();
        let ra: Vec<_> = a.runs.iter().map(|r| r.root).collect();
        let rb: Vec<_> = b.runs.iter().map(|r| r.root).collect();
        assert_eq!(ra, rb);
    }

    #[test]
    fn preparation_time_surfaced_separately() {
        // kernel-0 / prepare / traversal split: the sell engine's layout
        // build lands in preparation_seconds, not in any root's seconds,
        // and the stats' amortized sum equals the job's prepare time
        let mut exp =
            Experiment::new(9, 8, EngineKind::parse("sell", 2, "artifacts").unwrap());
        exp.num_roots = 6;
        exp.workers = 2;
        let report = exp.run().unwrap();
        assert!(report.preparation_seconds > 0.0);
        assert!(report.all_valid);
        assert!(
            (report.stats.preparation_seconds - report.preparation_seconds).abs() < 1e-9,
            "amortized prep shares must sum back to the job total"
        );
    }

    #[test]
    fn batched_experiment_through_harness() {
        // --batch-roots plumbing: the MS engine validates end to end in
        // 16-root waves and the TEPS stats stay well-formed
        let mut exp =
            Experiment::new(9, 8, EngineKind::parse("hybrid-sell-ms", 2, "artifacts").unwrap());
        exp.num_roots = 20;
        exp.workers = 2;
        exp.batch_roots = 16;
        let report = exp.run().unwrap();
        assert_eq!(report.runs.len(), 20);
        assert!(report.all_valid, "batched runs must validate");
        assert!(report.stats.max > 0.0);
        // batch timing: every root of a batch reports its equal share
        assert!(report.runs.iter().all(|r| r.seconds > 0.0));
    }

    #[test]
    fn governed_experiment_completes_under_a_real_budget() {
        // --mem-budget-mb plumbing end to end: a budget that comfortably
        // fits the scale-9 artifacts runs clean — validated trees, no
        // pressure events, no shedding
        let mut exp =
            Experiment::new(9, 8, EngineKind::parse("sell", 2, "artifacts").unwrap());
        exp.num_roots = 4;
        exp.mem_budget_mb = Some(64);
        let report = exp.run().unwrap();
        assert!(report.all_valid);
        assert!(report.pressure.is_empty(), "a 64 MiB budget fits a scale-9 graph");
    }

    #[test]
    fn rejected_one_shot_run_retries_boundedly_then_fails() {
        // --max-inflight 0 rejects every submission: the harness honors
        // the retry hint for max_attempts submissions, then surfaces the
        // structured rejection instead of hanging forever
        let mut exp = Experiment::new(7, 8, EngineKind::SerialLayered);
        exp.num_roots = 2;
        exp.max_inflight = 0;
        exp.max_attempts = 2;
        let t0 = Instant::now();
        let err = exp.run().expect_err("a zero-inflight cap admits nothing");
        assert!(
            err.to_string().contains("rejected by admission control"),
            "unexpected error: {err:#}"
        );
        // one retry happened, and it actually waited for the ~25 ms hint
        assert!(t0.elapsed() >= Duration::from_millis(20), "retry must back off");
    }

    #[test]
    fn supervised_experiment_runs_clean_with_a_generous_budget() {
        // --liveness-ms plumbing: the job routes through the watchdog's
        // supervised pool, completes normally, and a healthy run never
        // trips the watchdog
        let mut exp = Experiment::new(8, 8, EngineKind::SerialLayered);
        exp.num_roots = 4;
        exp.liveness_ms = Some(10_000);
        let report = exp.run().unwrap();
        assert_eq!(report.runs.len(), 4);
        assert!(report.all_valid);
        assert_eq!(report.coordinator_metrics.watchdog_fires, 0);
        assert_eq!(report.coordinator_metrics.hung_waves, 0);
    }

    #[test]
    fn report_carries_coordinator_metrics() {
        let mut exp = Experiment::new(8, 8, EngineKind::SerialLayered);
        exp.num_roots = 3;
        let report = exp.run().unwrap();
        let m = &report.coordinator_metrics;
        assert_eq!((m.jobs, m.roots), (1, 3));
        assert!(m.aggregate_teps > 0.0);
        let line = m.to_string();
        assert!(line.contains("jobs=1") && line.contains("roots=3"), "{line:?}");
    }

    #[test]
    fn simd_engine_through_harness() {
        use crate::bfs::policy::LayerPolicy;
        use crate::bfs::vectorized::SimdOpts;
        let mut exp = Experiment::new(9, 8, EngineKind::Simd {
            threads: 2,
            opts: SimdOpts::full(),
            policy: LayerPolicy::heavy(),
            vpu: crate::simd::VpuMode::default(),
        });
        exp.num_roots = 4;
        let report = exp.run().unwrap();
        assert!(report.all_valid);
    }

    #[test]
    fn auto_mode_flags_and_excludes_warmup_roots() {
        use crate::simd::{VpuMode, AUTO_WARMUP_ROOTS};
        // --vpu auto end to end: a single worker runs the first roots on
        // the counted emulator (flagged), the rest on hardware; TEPS
        // stats exclude exactly the warm-ups
        let mut engine = EngineKind::parse("sell", 2, "artifacts").unwrap();
        assert!(engine.set_vpu(VpuMode::Auto));
        let mut exp = Experiment::new(9, 8, engine);
        exp.num_roots = 6;
        exp.workers = 1;
        let report = exp.run().unwrap();
        assert!(report.all_valid, "auto-mode runs must validate");
        let warmups = report.runs.iter().filter(|r| r.counted_warmup).count();
        assert_eq!(warmups, AUTO_WARMUP_ROOTS, "sequential worker: exact warm-up count");
        assert!(report.runs[0].counted_warmup && !report.runs[5].counted_warmup);
        assert_eq!(report.stats.counted_warmup_excluded, warmups);
        assert_eq!(report.stats.runs, 6 - warmups);
        // steady-state roots ran uncounted — the hardware backend
        // records no VPU events at all
        let steady_issues: u64 = report
            .runs
            .iter()
            .filter(|r| !r.counted_warmup)
            .map(|r| r.trace.vpu_totals().explore_issues)
            .sum();
        assert_eq!(steady_issues, 0);
    }
}
