//! §5 — the Graph500-style experiment harness.
//!
//! "The experimental design comprises 64 BFS executions each with a
//! randomly chosen different starting vertex. ... After the completion of
//! the executions, statistics, including time and Traversed Edges Per
//! Second (TEPS), are collected."
//!
//! * [`stats`] — TEPS statistics including Graph500's harmonic mean with
//!   the zero-TEPS quirk the paper calls out (unconnected roots are *not*
//!   filtered, and inflate the harmonic mean above the max).
//! * [`runner`] — end-to-end experiment: generate graph → sample roots →
//!   run via the coordinator → validate → collect stats.
//! * [`report`] — fixed-width table / scientific-notation formatting for
//!   the bench outputs that mirror the paper's tables and figures.

pub mod report;
pub mod runner;
pub mod stats;

pub use runner::{Experiment, ExperimentReport};
pub use stats::TepsStats;
