//! Report formatting: fixed-width tables and the paper's scientific
//! notation (`4.69E+08`) so bench output reads like the original tables.

/// Format a TEPS value the way Table 2 prints it: `4.69E+08`.
pub fn sci(x: f64) -> String {
    if x == 0.0 {
        return "0.00E+00".to_string();
    }
    let exp = x.abs().log10().floor() as i32;
    let mant = x / 10f64.powi(exp);
    format!("{mant:.2}E{exp:+03}")
}

/// Format TEPS as the figures' MTEPS axis.
pub fn mteps(x: f64) -> String {
    format!("{:.1}", x / 1e6)
}

/// A simple fixed-width table builder.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Render with per-column width = max cell width + 2.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sci_matches_paper_format() {
        assert_eq!(sci(4.69e8), "4.69E+08");
        assert_eq!(sci(2.67e8), "2.67E+08");
        assert_eq!(sci(1.42e8), "1.42E+08");
        assert_eq!(sci(0.0), "0.00E+00");
        assert_eq!(sci(999.4), "9.99E+02");
    }

    #[test]
    fn mteps_format() {
        assert_eq!(mteps(1.05e9), "1050.0");
        assert_eq!(mteps(8.0e8), "800.0");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Layer", "Vertices", "Edges"]);
        t.row(&["0".into(), "1".into(), "12".into()]);
        t.row(&["1".into(), "12".into(), "21892".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("Layer"));
        assert!(lines[3].contains("21892"));
        // all data lines same width
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_bad_row() {
        Table::new(&["a", "b"]).row(&["1".into()]);
    }
}
