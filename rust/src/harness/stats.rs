//! TEPS statistics, Graph500 style.
//!
//! Graph500's collector works on *inverse* TEPS (seconds per edge): it
//! averages `1/TEPS_i` and reports the harmonic mean `n / Σ(1/TEPS_i)`.
//! An unconnected root traverses 0 edges, so its inverse is 0 — which
//! *removes* it from the denominator and inflates the harmonic mean, to
//! the point that it "can be higher than the maximum number of TEPS"
//! (§5.3). The paper deliberately keeps this quirk for comparability with
//! Gao et al. and Beamer et al.; we reproduce it and additionally report
//! the filtered value.

use crate::coordinator::job::RootRun;

/// Summary statistics over a set of per-root TEPS values.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TepsStats {
    pub runs: usize,
    /// Roots that traversed zero edges (unconnected starts).
    pub zero_runs: usize,
    pub min: f64,
    pub max: f64,
    pub arithmetic_mean: f64,
    /// Graph500's harmonic mean over inverse-TEPS, zeros contributing 0 to
    /// the denominator — the paper's headline statistic.
    pub harmonic_mean_graph500: f64,
    /// Harmonic mean over connected roots only.
    pub harmonic_mean_filtered: f64,
    /// One-time per-graph preparation seconds (engine prepare: layouts,
    /// stats, compiled kernels), amortized over all roots of the job and
    /// summed back here — the Graph500 kernel-1-style split. TEPS above
    /// are pure traversal; this is what prepare-once saves per root.
    pub preparation_seconds: f64,
}

impl TepsStats {
    pub fn from_teps(teps: &[f64]) -> Self {
        if teps.is_empty() {
            return TepsStats::default();
        }
        let zero_runs = teps.iter().filter(|&&t| t == 0.0).count();
        let nonzero: Vec<f64> = teps.iter().copied().filter(|&t| t > 0.0).collect();
        let min = teps.iter().copied().fold(f64::INFINITY, f64::min);
        let max = teps.iter().copied().fold(0.0, f64::max);
        let arithmetic_mean = teps.iter().sum::<f64>() / teps.len() as f64;
        // Graph500: inverse of a zero-TEPS run is *zero* (tm/m with m = 0
        // in the reference code), so the denominator only sees the
        // connected roots while n counts all of them.
        let inv_sum: f64 = nonzero.iter().map(|t| 1.0 / t).sum();
        let harmonic_mean_graph500 =
            if inv_sum > 0.0 { teps.len() as f64 / inv_sum } else { 0.0 };
        let harmonic_mean_filtered =
            if inv_sum > 0.0 { nonzero.len() as f64 / inv_sum } else { 0.0 };
        TepsStats {
            runs: teps.len(),
            zero_runs,
            min,
            max,
            arithmetic_mean,
            harmonic_mean_graph500,
            harmonic_mean_filtered,
            preparation_seconds: 0.0,
        }
    }

    pub fn from_runs(runs: &[RootRun]) -> Self {
        let teps: Vec<f64> = runs.iter().map(|r| r.teps()).collect();
        let mut stats = Self::from_teps(&teps);
        stats.preparation_seconds = runs.iter().map(|r| r.preparation_seconds).sum();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_zeros_equals_classic_harmonic() {
        let s = TepsStats::from_teps(&[100.0, 200.0, 400.0]);
        let classic = 3.0 / (1.0 / 100.0 + 1.0 / 200.0 + 1.0 / 400.0);
        assert!((s.harmonic_mean_graph500 - classic).abs() < 1e-9);
        assert_eq!(s.harmonic_mean_graph500, s.harmonic_mean_filtered);
        assert_eq!(s.zero_runs, 0);
    }

    #[test]
    fn paper_quirk_zeros_inflate_harmonic_mean() {
        // §5.3: with unconnected roots the Graph500 harmonic mean can
        // exceed the maximum TEPS.
        let teps = [100.0, 100.0, 0.0, 0.0, 0.0, 0.0];
        let s = TepsStats::from_teps(&teps);
        assert!(s.harmonic_mean_graph500 > s.max, "{s:?}");
        assert!((s.harmonic_mean_graph500 - 300.0).abs() < 1e-9); // 6 / (2/100)
        assert!((s.harmonic_mean_filtered - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_zero() {
        let s = TepsStats::from_teps(&[0.0, 0.0]);
        assert_eq!(s.harmonic_mean_graph500, 0.0);
        assert_eq!(s.zero_runs, 2);
    }

    #[test]
    fn empty() {
        assert_eq!(TepsStats::from_teps(&[]).runs, 0);
    }

    #[test]
    fn min_max_mean() {
        let s = TepsStats::from_teps(&[10.0, 20.0, 30.0]);
        assert_eq!(s.min, 10.0);
        assert_eq!(s.max, 30.0);
        assert_eq!(s.arithmetic_mean, 20.0);
    }
}
