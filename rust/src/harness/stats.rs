//! TEPS statistics, Graph500 style.
//!
//! Graph500's collector works on *inverse* TEPS (seconds per edge): it
//! averages `1/TEPS_i` and reports the harmonic mean `n / Σ(1/TEPS_i)`.
//! An unconnected root traverses 0 edges, so its inverse is 0 — which
//! *removes* it from the denominator and inflates the harmonic mean, to
//! the point that it "can be higher than the maximum number of TEPS"
//! (§5.3). The paper deliberately keeps this quirk for comparability with
//! Gao et al. and Beamer et al.; we reproduce it and additionally report
//! the filtered value.

use crate::coordinator::job::RootRun;

/// Summary statistics over a set of per-root TEPS values.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TepsStats {
    pub runs: usize,
    /// Roots that traversed zero edges (unconnected starts).
    pub zero_runs: usize,
    pub min: f64,
    pub max: f64,
    pub arithmetic_mean: f64,
    /// Graph500's harmonic mean over inverse-TEPS, zeros contributing 0 to
    /// the denominator — the paper's headline statistic.
    pub harmonic_mean_graph500: f64,
    /// Harmonic mean over connected roots only.
    pub harmonic_mean_filtered: f64,
    /// One-time per-graph preparation seconds (engine prepare: layouts,
    /// stats, compiled kernels), amortized over all roots of the job and
    /// summed back here — the Graph500 kernel-1-style split. TEPS above
    /// are pure traversal; this is what prepare-once saves per root.
    pub preparation_seconds: f64,
    /// Roots excluded from the TEPS statistics because they ran on the
    /// counted emulator as `--vpu auto` warm-ups
    /// ([`crate::coordinator::job::RootRun::counted_warmup`]): emulated
    /// timings would drag every aggregate, so only hardware-steady-state
    /// roots are measured. 0 unless auto mode ran (and 0 — with the
    /// warm-ups measured normally — in the degenerate case where *every*
    /// root was a warm-up, so small runs still report numbers).
    pub counted_warmup_excluded: usize,
    /// Roots excluded because their traversal was interrupted (deadline or
    /// cancellation, [`crate::bfs::RunStatus`]): their timings measure an
    /// aborted prefix, not BFS throughput.
    pub interrupted_excluded: usize,
}

impl TepsStats {
    pub fn from_teps(teps: &[f64]) -> Self {
        if teps.is_empty() {
            return TepsStats::default();
        }
        let zero_runs = teps.iter().filter(|&&t| t == 0.0).count();
        let nonzero: Vec<f64> = teps.iter().copied().filter(|&t| t > 0.0).collect();
        let min = teps.iter().copied().fold(f64::INFINITY, f64::min);
        let max = teps.iter().copied().fold(0.0, f64::max);
        let arithmetic_mean = teps.iter().sum::<f64>() / teps.len() as f64;
        // Graph500: inverse of a zero-TEPS run is *zero* (tm/m with m = 0
        // in the reference code), so the denominator only sees the
        // connected roots while n counts all of them.
        let inv_sum: f64 = nonzero.iter().map(|t| 1.0 / t).sum();
        let harmonic_mean_graph500 =
            if inv_sum > 0.0 { teps.len() as f64 / inv_sum } else { 0.0 };
        let harmonic_mean_filtered =
            if inv_sum > 0.0 { nonzero.len() as f64 / inv_sum } else { 0.0 };
        TepsStats {
            runs: teps.len(),
            zero_runs,
            min,
            max,
            arithmetic_mean,
            harmonic_mean_graph500,
            harmonic_mean_filtered,
            preparation_seconds: 0.0,
            counted_warmup_excluded: 0,
            interrupted_excluded: 0,
        }
    }

    pub fn from_runs(runs: &[RootRun]) -> Self {
        // interrupted roots (deadline/cancellation) traversed only a
        // prefix — their timings measure an abort, never throughput, so
        // they are excluded unconditionally
        let complete: Vec<&RootRun> =
            runs.iter().filter(|r| r.status().is_complete()).collect();
        let interrupted = runs.len() - complete.len();
        // exclude counted warm-up roots (auto mode) from the TEPS
        // aggregates — unless every root was a warm-up, in which case the
        // emulated numbers are all there is and excluding them would
        // yield an empty report
        let measured: Vec<f64> =
            complete.iter().filter(|r| !r.counted_warmup).map(|r| r.teps()).collect();
        let (teps, excluded) = if measured.is_empty() {
            (complete.iter().map(|r| r.teps()).collect::<Vec<f64>>(), 0)
        } else {
            let excluded = complete.len() - measured.len();
            (measured, excluded)
        };
        let mut stats = Self::from_teps(&teps);
        stats.counted_warmup_excluded = excluded;
        stats.interrupted_excluded = interrupted;
        // preparation was paid for every root, warm-up or not
        stats.preparation_seconds = runs.iter().map(|r| r.preparation_seconds).sum();
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_zeros_equals_classic_harmonic() {
        let s = TepsStats::from_teps(&[100.0, 200.0, 400.0]);
        let classic = 3.0 / (1.0 / 100.0 + 1.0 / 200.0 + 1.0 / 400.0);
        assert!((s.harmonic_mean_graph500 - classic).abs() < 1e-9);
        assert_eq!(s.harmonic_mean_graph500, s.harmonic_mean_filtered);
        assert_eq!(s.zero_runs, 0);
    }

    #[test]
    fn paper_quirk_zeros_inflate_harmonic_mean() {
        // §5.3: with unconnected roots the Graph500 harmonic mean can
        // exceed the maximum TEPS.
        let teps = [100.0, 100.0, 0.0, 0.0, 0.0, 0.0];
        let s = TepsStats::from_teps(&teps);
        assert!(s.harmonic_mean_graph500 > s.max, "{s:?}");
        assert!((s.harmonic_mean_graph500 - 300.0).abs() < 1e-9); // 6 / (2/100)
        assert!((s.harmonic_mean_filtered - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_zero() {
        let s = TepsStats::from_teps(&[0.0, 0.0]);
        assert_eq!(s.harmonic_mean_graph500, 0.0);
        assert_eq!(s.zero_runs, 2);
    }

    #[test]
    fn empty() {
        assert_eq!(TepsStats::from_teps(&[]).runs, 0);
    }

    #[test]
    fn warmup_runs_excluded_from_aggregates() {
        use crate::bfs::RunTrace;
        let mk = |teps_edges: usize, warm: bool| RootRun {
            root: 0,
            edges_traversed: teps_edges,
            reached: 10,
            seconds: 1.0,
            preparation_seconds: 0.5,
            trace: RunTrace::default(),
            counted_warmup: warm,
            validation: None,
            depths: None,
        };
        // two slow counted warm-ups, two fast hw roots
        let runs = vec![mk(10, true), mk(10, true), mk(1000, false), mk(1000, false)];
        let s = TepsStats::from_runs(&runs);
        assert_eq!(s.runs, 2, "only steady-state roots are measured");
        assert_eq!(s.counted_warmup_excluded, 2);
        assert_eq!(s.min, 1000.0, "warm-up timings must not drag the stats");
        assert!((s.preparation_seconds - 2.0).abs() < 1e-12, "prep sums over ALL roots");
        // all-warm-up degenerate case: measure everything, exclude nothing
        let all_warm = vec![mk(10, true), mk(20, true)];
        let s = TepsStats::from_runs(&all_warm);
        assert_eq!(s.runs, 2);
        assert_eq!(s.counted_warmup_excluded, 0);
        assert_eq!(s.max, 20.0);
    }

    #[test]
    fn interrupted_runs_excluded_from_aggregates() {
        use crate::bfs::{RunStatus, RunTrace};
        let mk = |edges: usize, status: RunStatus| RootRun {
            root: 0,
            edges_traversed: edges,
            reached: 10,
            seconds: 1.0,
            preparation_seconds: 0.25,
            trace: RunTrace { status, ..RunTrace::default() },
            counted_warmup: false,
            validation: None,
            depths: None,
        };
        let runs = vec![
            mk(1000, RunStatus::Complete),
            mk(10, RunStatus::TimedOut),
            mk(10, RunStatus::Cancelled),
            mk(1000, RunStatus::Complete),
        ];
        let s = TepsStats::from_runs(&runs);
        assert_eq!(s.runs, 2, "only complete roots are measured");
        assert_eq!(s.interrupted_excluded, 2);
        assert_eq!(s.min, 1000.0, "partial prefixes must not drag the stats");
        assert!((s.preparation_seconds - 1.0).abs() < 1e-12, "prep sums over ALL roots");
    }

    #[test]
    fn min_max_mean() {
        let s = TepsStats::from_teps(&[10.0, 20.0, 30.0]);
        assert_eq!(s.min, 10.0);
        assert_eq!(s.max, 30.0);
        assert_eq!(s.arithmetic_mean, 20.0);
    }
}
