//! # phi-bfs
//!
//! A reproduction of *"Breadth First Search Vectorization on the Intel Xeon
//! Phi"* (Paredes, Riley, Luján; 2016) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The paper's contribution is a top-down BFS that
//!
//! 1. represents the frontier/visited sets as **bitmap arrays** (§3.3.1),
//! 2. removes all atomic operations by tolerating bit-level races and
//!    repairing them afterwards with a **restoration process** (§3.3.2), and
//! 3. **vectorizes** the adjacency-list exploration and the restoration with
//!    512-bit vector intrinsics (gather/scatter + mask registers, §4), plus
//!    data-alignment / prefetching / thread-affinity tuning (§4.2, §6.2).
//!
//! This crate implements every substrate that work depends on:
//!
//! * [`graph`] — Graph500-style RMAT generator, CSR, bitmaps, statistics,
//!   and the SELL-16-σ sliced-ELLPACK layout ([`graph::sell`]).
//! * [`simd`] — a faithful 16-lane × 32-bit emulation of the Knights-Corner
//!   vector unit (the exact intrinsics of the paper's Listing 1, including
//!   the scatter write-conflict hazard the restoration process exists for),
//!   with per-issue lane-occupancy counters — and, behind the same
//!   [`simd::VpuBackend`] surface, zero-counter hardware tiers (AVX-512
//!   opt-in / AVX2 double-pump / portable unrolled) selected per run with
//!   `--vpu counted|hw|auto`.
//! * [`bfs`] — the paper's algorithm ladder: serial (Alg 1), parallel
//!   non-SIMD (Alg 2), bit-race-free with restoration (Alg 3), the
//!   vectorized version (Listing 1), and the SELL-16-σ lane-packed
//!   explorer ([`bfs::sell_vectorized`]) that fills all 16 VPU lanes from
//!   16 distinct frontier vertices on skewed RMAT frontiers — plus the
//!   layer policy of §4.1 and the Graph500 validator. Engines are
//!   two-phase ([`bfs::BfsEngine::prepare`] once per graph →
//!   [`bfs::PreparedBfs::run`] per root, or batch-first
//!   [`bfs::PreparedBfs::run_batch`] — the MS-BFS engine
//!   [`bfs::multi_source`] serves 16 roots per shared traversal) with
//!   per-graph state in [`bfs::GraphArtifacts`] and cross-root occupancy
//!   feedback in [`bfs::policy::PolicyFeedback`].
//! * [`threads`] — a small OpenMP-like scoped thread pool (no rayon offline).
//! * [`phi`] — an analytic Xeon Phi performance model (cores, SMT, affinity,
//!   caches, ring/GDDR bandwidth) that converts measured work traces into
//!   the TEPS figures of the paper's evaluation (Figs 9–10, Table 2).
//! * [`harness`] — the Graph500 experiment harness (64 roots, harmonic-mean
//!   TEPS with the paper's no-filtering quirk).
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas layer
//!   step (`artifacts/*.hlo.txt`) and executes it from Rust.
//! * [`coordinator`] — the L3 driver: BFS job queue, scheduler, engines.
//! * [`serve`] — BFS-as-a-service: the `phi-bfs serve` daemon with
//!   deadline-aware batching (independent clients accumulate into MS-BFS
//!   waves) and latency telemetry.
//! * [`benchkit`] / [`prop`] — offline stand-ins for criterion / proptest.
//!
//! ## Quickstart
//!
//! ```no_run
//! use phi_bfs::graph::{rmat::RmatConfig, csr::Csr};
//! use phi_bfs::bfs::{sell_vectorized::SellBfs, vectorized::VectorizedBfs, BfsEngine};
//!
//! let edges = RmatConfig::graph500(14, 16).generate(42);
//! let csr = Csr::from_edge_list(14, &edges);
//! let result = VectorizedBfs::default().run(&csr, 0);
//! println!("reached {} vertices", result.tree.reached_count());
//!
//! // the SELL-16-σ engine is two-phase: prepare once per graph (layout
//! // build), then run any number of roots against the shared state
//! let prepared = SellBfs::default().prepare(&csr).unwrap();
//! for root in [0, 1, 2] {
//!     let sell = prepared.run(root);
//!     println!("mean lanes/issue: {:.1}", sell.trace.vpu_totals().mean_lanes_active());
//! }
//! ```

pub mod apps;
pub mod benchkit;
pub mod bfs;
pub mod cli;
pub mod coordinator;
pub mod graph;
pub mod harness;
pub mod phi;
pub mod prop;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod simd;
pub mod threads;

/// Vertex identifier. The paper works with 32-bit integers throughout (the
/// vector unit processes 16 × 32-bit lanes), and Graph500 SCALE ≤ 26 fits.
pub type Vertex = u32;

/// Predecessor-array entry. Signed because the restoration protocol (§3.3.2)
/// marks freshly-written entries as `parent - nodes`, i.e. negative.
pub type Pred = i32;

/// "∞" initializer for the predecessor array: "an integer bigger than the
/// number of vertices" (§3.1). Kept positive so the `P[v] < 0` restoration
/// test cannot fire on untouched entries.
pub const PRED_INFINITY: Pred = i32::MAX;
