//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we carry our own small,
//! well-known generators: SplitMix64 for seeding and xoshiro256++ for the
//! main stream. Determinism matters here — the RMAT generator, the 64-root
//! Graph500 experiment design and every property test must be exactly
//! reproducible from a printed seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
/// (Steele, Lea & Flood, "Fast splittable pseudorandom number generators".)
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 256-bit-state generator
/// (Blackman & Vigna 2019). All randomness in the crate flows through this.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro authors.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Xoshiro256 { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform double in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's multiply-shift reduction;
    /// the tiny modulo bias is irrelevant for workload generation).
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn next_index(&mut self, bound: usize) -> usize {
        self.next_below(bound as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct values from `0..n` (partial Fisher–Yates on an
    /// index table; fine for the harness's 64-root draws).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.next_index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut b = Xoshiro256::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256::seed_from_u64(5);
        for bound in [1u64, 2, 3, 7, 100, 1 << 40] {
            for _ in 0..100 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_hits_all_small_values() {
        let mut r = Xoshiro256::seed_from_u64(6);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.next_below(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seed_from_u64(8);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Xoshiro256::seed_from_u64(9);
        let s = r.sample_distinct(100, 64);
        assert_eq!(s.len(), 64);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 64);
        assert!(sorted.iter().all(|&x| x < 100));
    }
}
