//! Extension (paper §8 future work): the bottom-up step and the
//! direction-optimizing **hybrid** BFS of Beamer, Asanović & Patterson
//! (the paper's [3]), with the bottom-up inner loop vectorized using the
//! same techniques as the top-down explorer — the paper's stated claim
//! being that "the same techniques can be applied to the bottom-up phase,
//! which can lead to speed up the hybrid BFS algorithm" (§3).
//!
//! Bottom-up inverts the traversal: every *unvisited* vertex scans its
//! own adjacency for a parent in the current frontier and claims the
//! first hit. There are no write races at all — each vertex writes only
//! its own predecessor entry and bitmap bit — so no restoration is
//! needed; the win is that a high-degree unvisited vertex stops at the
//! first frontier parent instead of being touched once per frontier edge.
//!
//! The hybrid controller is Beamer's: start top-down, switch to bottom-up
//! when the frontier's outgoing edge volume exceeds `alpha`-th of the
//! unexplored edge volume, switch back when the frontier shrinks below
//! `|V| / beta`.
//!
//! The bottom-up scan itself has three implementations, chosen per layer
//! by [`super::policy::BottomUpMode`]: the scalar first-hit walk, 16-wide
//! chunks of a single vertex's adjacency ([`bottom_up_layer_simd`]), and
//! the SELL-packed scan ([`super::sell_bottom_up`]) that gathers the k-th
//! neighbor of 16 *distinct* unvisited vertices per issue (see that
//! module's docs for the lane-refill protocol). With `bu_sell` enabled
//! (the `hybrid-sell-bu` engine) the choice is driven by the cross-root
//! [`PolicyFeedback`] occupancy tables, and **both** direction switches
//! compare predicted VPU issue counts (`edges ÷ measured lanes-per-issue`)
//! instead of raw volumes once the feedback channel holds a completed
//! root and both directions are measured — α via
//! [`PolicyFeedback::switch_to_bottom_up`], β via its symmetric
//! counterpart [`PolicyFeedback::switch_to_top_down`] (which replaces the
//! raw frontier-population test); a fresh channel's first root always
//! runs the classic raw tests.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::artifacts::HubBits;
use super::policy::{BottomUpMode, LayerPolicy, PolicyFeedback};
use super::sell_bottom_up::bottom_up_layer_sell;
use super::sell_vectorized::{SellStep, SIGMA_AUTO};
use super::state::{SharedBitmap, SharedPred};
use super::vectorized::SimdOpts;
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, RunControl, RunStatus,
    RunTrace, WORD_GRAIN,
};
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::sell::Sell16;
use crate::graph::{Bitmap, Csr, PaddedCsr};
use crate::simd::backend::{resolve, VpuBackend, VpuMode};
use crate::simd::vec512::{Mask16, LANES};
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// One bottom-up layer step (scalar): every unvisited vertex searches its
/// adjacency for a frontier parent. Returns (edges scanned, discovered).
pub fn bottom_up_layer_scalar(
    num_threads: usize,
    g: &Csr,
    frontier: &Bitmap,
    visited: &SharedBitmap,
    next: &SharedBitmap,
    pred: &SharedPred,
) -> (usize, usize) {
    let n = g.num_vertices();
    let num_words = n.div_ceil(BITS_PER_WORD as usize);
    let accs: Vec<(usize, usize)> = parallel_for_dynamic(
        num_threads,
        num_words,
        WORD_GRAIN,
        |_tid, range, acc: &mut (usize, usize)| {
            for w in range {
                for b in 0..BITS_PER_WORD {
                    let v = Bitmap::bit_to_vertex(w, b);
                    if v as usize >= n || visited.test_bit(v) {
                        continue;
                    }
                    for &u in g.neighbors(v) {
                        acc.0 += 1;
                        if frontier.test_bit(u) {
                            // claim: only v writes v's entries — race-free
                            pred.set(v, u as Pred);
                            next.set_bit_atomic(v);
                            visited.set_bit_atomic(v);
                            acc.1 += 1;
                            break; // first parent wins; stop scanning
                        }
                    }
                }
            }
        },
    );
    accs.into_iter().fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1))
}

/// Vectorized bottom-up layer step: the §4 techniques applied to the
/// bottom-up scan. For each unvisited vertex, adjacency chunks of 16 are
/// tested against the frontier bitmap with gather + bit-test exactly like
/// Listing 1's filter; the first enabled lane supplies the parent.
#[allow(clippy::too_many_arguments)]
pub fn bottom_up_layer_simd<V: VpuBackend>(
    num_threads: usize,
    g: &Csr,
    frontier_words: &[u32],
    visited: &SharedBitmap,
    next: &SharedBitmap,
    pred: &SharedPred,
) -> (usize, usize, crate::simd::VpuCounters) {
    struct Acc<V> {
        edges: usize,
        found: usize,
        vpu: Option<V>,
    }
    #[allow(clippy::derivable_impls)]
    impl<V> Default for Acc<V> {
        fn default() -> Self {
            Acc { edges: 0, found: 0, vpu: None }
        }
    }
    let n = g.num_vertices();
    let num_words = n.div_ceil(BITS_PER_WORD as usize);
    let frontier_i32: Vec<i32> = frontier_words.iter().map(|&w| w as i32).collect();
    let accs: Vec<Acc<V>> = parallel_for_dynamic(
        num_threads,
        num_words,
        WORD_GRAIN,
        // the per-thread scan runs inside the backend's #[target_feature]
        // envelope so the gather/bit-test filter fuses per tier
        |_tid, range, acc: &mut Acc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            for w in range {
                for b in 0..BITS_PER_WORD {
                    let v = Bitmap::bit_to_vertex(w, b);
                    if v as usize >= n || visited.test_bit(v) {
                        continue;
                    }
                    let (start, end) = g.adjacency_range(v);
                    let mut off = start;
                    'scan: while off < end {
                        let len = (end - off).min(LANES);
                        let chunk_mask = Mask16::first_n(len);
                        vpu.note_explore_issue(chunk_mask.count());
                        let vneig = vpu.mask_load_vertices(chunk_mask, &g.rows, off);
                        acc.edges += len;
                        // frontier membership test = Listing 1's filter
                        let bpw = vpu.set1_epi32(BITS_PER_WORD as i32);
                        let vword = vpu.div_epi32(vneig, bpw);
                        let vbits = vpu.rem_epi32(vneig, bpw);
                        let words = vpu.mask_i32gather_epi32(chunk_mask, vword, &frontier_i32);
                        let one = vpu.set1_epi32(1);
                        let bits = vpu.sllv_epi32(one, vbits);
                        let hit_all = vpu.test_epi32_mask(words, bits);
                        let hit = vpu.kand(hit_all, chunk_mask);
                        if !hit.is_empty() {
                            // first enabled lane supplies the parent
                            let lane = hit.0.trailing_zeros() as usize;
                            let u = vneig.lane(lane) as Vertex;
                            pred.set(v, u as Pred);
                            next.set_bit_atomic(v);
                            visited.set_bit_atomic(v);
                            acc.found += 1;
                            break 'scan;
                        }
                        off += len;
                    }
                }
            }
        }),
    );
    let mut edges = 0;
    let mut found = 0;
    let mut vpu = crate::simd::VpuCounters::default();
    for a in accs {
        edges += a.edges;
        found += a.found;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (edges, found, vpu)
}

/// Direction-optimizing hybrid BFS (paper [3]; the paper's §8 roadmap).
#[derive(Clone, Copy, Debug)]
pub struct HybridBfs {
    pub num_threads: usize,
    /// Switch top-down → bottom-up when frontier edge volume exceeds
    /// `unexplored edges / alpha` (Beamer's α, default 14).
    pub alpha: usize,
    /// Switch bottom-up → top-down when the frontier shrinks below
    /// `|V| / beta` (Beamer's β, default 24).
    pub beta: usize,
    /// Vectorize the bottom-up scan (the paper's §3 claim).
    pub simd: bool,
    /// Run top-down phases through the SELL-16-σ lane-packed explorer
    /// (plus restoration) instead of the scalar atomic step — the sequel
    /// paper's point that the SELL techniques carry to the hybrid.
    pub sell: bool,
    /// Lane-pack the bottom-up phase too (the `hybrid-sell-bu` engine):
    /// per layer, [`PolicyFeedback`] picks scalar vs per-vertex chunks vs
    /// SELL-packed from measured occupancy, and both direction switches
    /// (α and β) run in issue units instead of raw volumes.
    pub bu_sell: bool,
    /// σ sort window of the prepared [`Sell16`] layout (only read when
    /// `sell`/`bu_sell` need one); [`SIGMA_AUTO`] resolves to the
    /// per-scale default at prepare time.
    pub sigma: usize,
    /// Size of the packed hub-adjacency bitmap for the SELL bottom-up
    /// step (`--hub-bits`): prepare builds a [`HubBits`] for the top-k
    /// highest-degree vertices and bottom-up candidates adjacent to a
    /// frontier hub claim their parent from it without touching the SELL
    /// adjacency stream. `0` (the default) disables hub caching; values
    /// are clamped to 32. Only read when `bu_sell` is on.
    pub hub_bits: usize,
    pub opts: SimdOpts,
    /// VPU backend mode: counted emulation, hardware SIMD, or counted
    /// warm-up + hardware steady state.
    pub vpu: VpuMode,
}

impl HybridBfs {
    /// Beamer's α default (switch top-down → bottom-up).
    pub const DEFAULT_ALPHA: usize = 14;
    /// Beamer's β default (switch bottom-up → top-down).
    pub const DEFAULT_BETA: usize = 24;
}

impl Default for HybridBfs {
    fn default() -> Self {
        HybridBfs {
            num_threads: 4,
            alpha: Self::DEFAULT_ALPHA,
            beta: Self::DEFAULT_BETA,
            simd: true,
            sell: false,
            bu_sell: false,
            sigma: SIGMA_AUTO,
            hub_bits: 0,
            opts: SimdOpts::full(),
            vpu: VpuMode::default(),
        }
    }
}

impl HybridBfs {
    /// One traversal on VPU backend `V`. `sell_layout`/`padded`/`feedback`
    /// are the per-graph artifacts prepare built (all `None`/unused when
    /// `self.sell` is off).
    fn traverse<V: VpuBackend>(
        &self,
        g: &Csr,
        sell_layout: Option<&Sell16>,
        padded: Option<&PaddedCsr>,
        feedback: Option<&PolicyFeedback>,
        hub: Option<&HubBits>,
        root: Vertex,
        ctl: &RunControl,
    ) -> BfsResult {
        let n = g.num_vertices();
        let total_edges = g.num_directed_edges();
        let pred = SharedPred::new_infinity(n);
        let visited = SharedBitmap::new(n);
        let mut frontier = Bitmap::new(n);
        let next = SharedBitmap::new(n);

        frontier.set_bit(root);
        visited.set_bit_atomic(root);
        pred.set(root, root as Pred);

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut frontier_count = 1usize;
        let mut visited_count = 1usize;
        let mut edges_explored_total = 0usize;
        let mut bottom_up = false;
        let mut status = RunStatus::Complete;
        while frontier_count != 0 {
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let frontier_edges: usize = frontier.iter_set_bits().map(|u| g.degree(u)).sum();
            let unexplored = total_edges.saturating_sub(edges_explored_total);
            // Beamer's direction heuristic — with BU packing enabled the α
            // test runs in measured-issue units instead of raw edges from
            // the second root on (once the feedback channel has a full
            // root's data for both directions)
            let go_bottom_up = match feedback {
                Some(f) if self.bu_sell => {
                    f.switch_to_bottom_up(frontier_edges, unexplored, self.alpha)
                }
                _ => frontier_edges * self.alpha > unexplored,
            };
            if !bottom_up && go_bottom_up {
                bottom_up = true;
            } else if bottom_up {
                // the β side is symmetric to α: measured issue counts
                // replace the raw frontier-population test from the
                // second root on (PolicyFeedback::switch_to_top_down)
                let back_to_top_down = match feedback {
                    Some(f) if self.bu_sell => f.switch_to_top_down(
                        frontier_count,
                        frontier_edges,
                        unexplored,
                        n,
                        self.beta,
                    ),
                    _ => frontier_count * self.beta < n,
                };
                if back_to_top_down {
                    bottom_up = false;
                }
            }

            // the pool a bottom-up layer scans: everything still unvisited
            let unvisited = n - visited_count;
            let unvisited_edges =
                total_edges.saturating_sub(edges_explored_total + frontier_edges);
            let bu_mode = if !bottom_up {
                None
            } else if !self.simd {
                Some(BottomUpMode::Scalar)
            } else if self.bu_sell && sell_layout.is_some() {
                Some(match feedback {
                    // V::COUNTED gates the guided probe (see SellStep)
                    Some(f) => f.choose_bottom_up(unvisited, unvisited_edges, V::COUNTED),
                    None => LayerPolicy::bottom_up_chunking(unvisited, unvisited_edges),
                })
            } else {
                Some(BottomUpMode::PerVertexChunks)
            };

            let (edges_scanned, vpu, rstats) = if let Some(mode) = bu_mode {
                let (e, vpu) = match mode {
                    BottomUpMode::Scalar => {
                        let (e, _found) = bottom_up_layer_scalar(
                            self.num_threads,
                            g,
                            &frontier,
                            &visited,
                            &next,
                            &pred,
                        );
                        (e, Default::default())
                    }
                    BottomUpMode::PerVertexChunks => {
                        let (e, _found, vpu) = bottom_up_layer_simd::<V>(
                            self.num_threads,
                            g,
                            frontier.words(),
                            &visited,
                            &next,
                            &pred,
                        );
                        (e, vpu)
                    }
                    BottomUpMode::SellPacked => {
                        let sl = sell_layout.expect("SellPacked requires a prepared layout");
                        let (e, _found, vpu) = bottom_up_layer_sell::<V>(
                            self.num_threads,
                            sl,
                            frontier.words(),
                            &visited,
                            &next,
                            &pred,
                            self.opts,
                            hub,
                        );
                        (e, vpu)
                    }
                };
                if self.bu_sell {
                    if let Some(f) = feedback {
                        f.record_bottom_up_layer(mode, unvisited, unvisited_edges, &vpu);
                    }
                }
                (e, vpu, Default::default())
            } else if let (true, Some(sl)) = (self.sell, sell_layout) {
                // the shared SELL top-down step: chunking choice +
                // exploration + vectorized restoration
                let step = SellStep {
                    num_threads: self.num_threads,
                    g,
                    sell: sl,
                    padded,
                    feedback,
                    opts: self.opts,
                };
                let (e, rstats, vpu) = step.layer::<V>(
                    &frontier,
                    frontier_count,
                    frontier_edges,
                    &visited,
                    &next,
                    &pred,
                    n as Pred,
                );
                (e, vpu, rstats)
            } else {
                // scalar top-down step (Algorithm 2 with atomics)
                let in_words = frontier.words();
                let accs: Vec<usize> = parallel_for_dynamic(
                    self.num_threads,
                    in_words.len(),
                    WORD_GRAIN,
                    |_tid, range, acc: &mut usize| {
                        for w in range {
                            let mut word = in_words[w];
                            while word != 0 {
                                let bit = word.trailing_zeros();
                                word &= word - 1;
                                let u = Bitmap::bit_to_vertex(w, bit);
                                if (u as usize) >= n {
                                    continue;
                                }
                                for &v in g.neighbors(u) {
                                    *acc += 1;
                                    if !visited.test_bit(v) {
                                        visited.set_bit_atomic(v);
                                        next.set_bit_atomic(v);
                                        pred.set(v, u as Pred);
                                    }
                                }
                            }
                        }
                    },
                );
                (accs.iter().sum(), Default::default(), Default::default())
            };

            edges_explored_total += frontier_edges;
            let traversed = next.count_ones();
            visited_count += traversed;
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier_count,
                edges_scanned,
                traversed,
                restore_words_scanned: rstats.words_scanned,
                restore_fixed: rstats.lost_bits_fixed,
                vectorized: match bu_mode {
                    Some(mode) => mode != BottomUpMode::Scalar,
                    None => self.sell,
                },
                bottom_up,
                vpu,
                wall_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            });

            let snap = next.snapshot();
            frontier_count = snap.count_ones();
            frontier = snap;
            next.clear_all();
            layer += 1;
        }

        if let Some(f) = feedback {
            f.record_root();
        }

        BfsResult {
            tree: BfsTree::new(root, pred.into_vec()),
            trace: RunTrace { layers, num_threads: self.num_threads, status, ..Default::default() },
        }
    }
}

/// A [`HybridBfs`] bound to one graph. When the sell top-down step is
/// enabled the prepared state carries the σ-resolved [`Sell16`] layout and
/// the aligned per-vertex view, both built once per graph.
pub struct PreparedHybrid<'g> {
    g: &'g Csr,
    sell: Option<Arc<Sell16>>,
    padded: Option<Arc<PaddedCsr>>,
    /// Packed hub-adjacency bitmap for the SELL bottom-up step (built
    /// when `hub_bits > 0` and `bu_sell` is on).
    hub: Option<Arc<HubBits>>,
    engine: HybridBfs,
    artifacts: Arc<GraphArtifacts>,
}

impl PreparedBfs for PreparedHybrid<'_> {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        // backend dispatch, once per traversal (monomorphizes the whole
        // layer machinery under traverse)
        let fb = self.artifacts.feedback();
        let (select, warmup) = resolve(self.engine.vpu, fb.roots_done());
        let feedback = self.sell.is_some().then_some(fb);
        let mut engine = self.engine;
        let sampling = super::vectorized::plan_prefetch(&mut engine.opts, fb, select);
        let mut r = crate::with_vpu_backend!(select, V, engine.traverse::<V>(
            self.g,
            self.sell.as_deref(),
            self.padded.as_deref(),
            feedback,
            self.hub.as_deref(),
            root,
            ctl,
        ));
        if sampling {
            fb.record_prefetch_sample(
                engine.opts.prefetch_dist,
                r.trace.total_wall_ns(),
                r.trace.total_edges_scanned(),
            );
        }
        if feedback.is_none() && self.engine.vpu == VpuMode::Auto {
            // non-sell hybrids record no feedback of their own: advance
            // the auto warm-up count explicitly
            fb.record_root();
        }
        r.trace.counted_warmup = warmup;
        r
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

impl BfsEngine for HybridBfs {
    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        // fail fast on nonsense switch thresholds: α = 0 never leaves
        // top-down, β = 0 divides the frontier test by nothing sensible —
        // both silently degenerate the hybrid, so reject them here, before
        // any worker spawns
        if self.alpha == 0 || self.beta == 0 {
            anyhow::bail!(
                "hybrid switch thresholds must be >= 1 (alpha={}, beta={})",
                self.alpha,
                self.beta
            );
        }
        // the SELL layout serves the top-down step (`sell`), the
        // lane-packed bottom-up step (`bu_sell`), or both
        let sell = if self.sell || self.bu_sell {
            let sigma = if self.sigma == SIGMA_AUTO {
                artifacts.stats(g).suggested_sigma()
            } else {
                self.sigma
            };
            Some(artifacts.sell_layout(g, sigma)?)
        } else {
            None
        };
        // padded CSR and the hub bitmap are optional artifacts: under
        // governor memory pressure they come back `None` and the explorer
        // falls back to its unaligned / full-stream paths
        let padded =
            if self.sell && self.opts.aligned { artifacts.padded_csr(g) } else { None };
        // the hub bitmap only serves the SELL bottom-up step
        let hub = if self.bu_sell && self.hub_bits > 0 {
            artifacts.hub_bits(g, self.hub_bits)
        } else {
            None
        };
        Ok(Box::new(PreparedHybrid { g, sell, padded, hub, engine: *self, artifacts }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::bfs::validate::validate;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::simd::ops::Vpu;

    fn rmat(scale: u32, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, 16).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    #[test]
    fn hybrid_matches_serial_distances() {
        let g = rmat(11, 71);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
        for simd in [false, true] {
            let r = HybridBfs { num_threads: 2, simd, ..Default::default() }.run(&g, root);
            assert_eq!(r.tree.distances().unwrap(), expected, "simd={simd}");
        }
    }

    #[test]
    fn hybrid_actually_switches_direction() {
        // RMAT explosion layers must trigger bottom-up (vectorized marks it)
        let g = rmat(12, 72);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let r = HybridBfs { num_threads: 1, ..Default::default() }.run(&g, root);
        let bu_layers = r.trace.layers.iter().filter(|l| l.vectorized).count();
        assert!(bu_layers > 0, "no bottom-up layer on an RMAT explosion");
        assert!(bu_layers < r.trace.layers.len(), "never switched back / started bottom-up");
    }

    #[test]
    fn bottom_up_scans_fewer_edges_on_explosion_layers() {
        // the whole point of direction optimization (paper [3])
        let g = rmat(12, 73);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let td = SerialLayeredBfs.run(&g, root);
        let hy = HybridBfs { num_threads: 1, ..Default::default() }.run(&g, root);
        let td_edges: usize = td.trace.layers.iter().map(|l| l.edges_scanned).sum();
        let hy_edges: usize = hy.trace.layers.iter().map(|l| l.edges_scanned).sum();
        assert!(
            hy_edges < td_edges,
            "hybrid scanned {hy_edges}, top-down {td_edges}"
        );
    }

    #[test]
    fn hybrid_sell_top_down_matches_serial_and_validates() {
        let g = rmat(11, 76);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
        let alg =
            HybridBfs { num_threads: 2, sell: true, vpu: VpuMode::Counted, ..Default::default() };
        let r = alg.run(&g, root);
        assert_eq!(r.tree.distances().unwrap(), expected);
        let rep = validate(&g, &r.tree);
        assert!(rep.all_passed(), "{}", rep.summary());
        // the sell top-down step actually ran through the VPU: only the
        // sell top-down layers run restoration (bottom-up is race-free),
        // so filter on restore activity rather than the vectorized flag
        let td_vpu: u64 = r
            .trace
            .layers
            .iter()
            .filter(|l| l.restore_words_scanned > 0)
            .map(|l| l.vpu.explore_issues)
            .sum();
        assert!(td_vpu > 0, "no sell top-down issues recorded");
    }

    #[test]
    fn hybrid_sell_bu_matches_serial_and_validates() {
        let g = rmat(11, 77);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
        let alg = HybridBfs {
            num_threads: 2,
            sell: true,
            bu_sell: true,
            vpu: VpuMode::Counted,
            ..Default::default()
        };
        let r = alg.run(&g, root);
        assert_eq!(r.tree.distances().unwrap(), expected);
        let rep = validate(&g, &r.tree);
        assert!(rep.all_passed(), "{}", rep.summary());
        // at least one bottom-up layer actually ran through the VPU
        let bu_issues: u64 = r
            .trace
            .layers
            .iter()
            .filter(|l| l.bottom_up)
            .map(|l| l.vpu.explore_issues)
            .sum();
        assert!(bu_issues > 0, "no vectorized bottom-up issues recorded");
    }

    #[test]
    fn hybrid_sell_bu_scans_no_more_edges_than_hybrid_sell() {
        // the chunked bottom-up scan pays for post-hit chunk remainders;
        // the packed scan stops each lane at its hit — and a first root
        // always runs the raw Beamer α test (the occupancy-adjusted form
        // waits for a completed root), so both hybrids share identical
        // switch points and total scans can only shrink
        let g = rmat(12, 78);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let base = HybridBfs { num_threads: 1, sell: true, ..Default::default() }.run(&g, root);
        let bu = HybridBfs { num_threads: 1, sell: true, bu_sell: true, ..Default::default() }
            .run(&g, root);
        let base_edges = base.trace.total_edges_scanned();
        let bu_edges = bu.trace.total_edges_scanned();
        assert!(bu_edges <= base_edges, "packed BU scanned {bu_edges} > chunked {base_edges}");
        assert_eq!(
            base.tree.distances().unwrap(),
            bu.tree.distances().unwrap(),
            "both hybrids must agree"
        );
    }

    #[test]
    fn hybrid_sell_bu_occupancy_beats_chunked_on_bu_layers() {
        // the tentpole acceptance at the whole-traversal level: mean
        // lanes/issue over bottom-up layers, packed vs chunked
        let g = rmat(12, 79);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let bu_occ = |r: &crate::bfs::BfsResult| {
            let mut c = crate::simd::VpuCounters::default();
            for l in r.trace.layers.iter().filter(|l| l.bottom_up) {
                c.merge(&l.vpu);
            }
            c.mean_lanes_active()
        };
        let chunked = HybridBfs {
            num_threads: 1,
            sell: true,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, root);
        let packed = HybridBfs {
            num_threads: 1,
            sell: true,
            bu_sell: true,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, root);
        let occ_chunked = bu_occ(&chunked);
        let occ_packed = bu_occ(&packed);
        assert!(occ_chunked > 0.0, "no chunked BU layers measured");
        assert!(occ_packed > 0.0, "no packed BU layers measured");
        assert!(
            occ_packed > occ_chunked,
            "packed BU occupancy {occ_packed:.2} !> chunked {occ_chunked:.2}"
        );
    }

    #[test]
    fn sigma_override_is_honored_in_prepare() {
        let g = rmat(10, 80);
        // a global sort (σ = MAX) and the unsorted layout (σ = 16) must
        // produce layouts with the requested σ, not the per-scale default
        for sigma in [16usize, usize::MAX] {
            let alg = HybridBfs { num_threads: 1, sell: true, sigma, ..Default::default() };
            let prepared = alg.prepare(&g).unwrap();
            let built = prepared.artifacts().sell_builds();
            assert_eq!(built, 1);
            // traversals still agree with serial under the override
            let r = prepared.run(3);
            let s = SerialLayeredBfs.run(&g, 3);
            assert_eq!(r.tree.distances().unwrap(), s.tree.distances().unwrap());
        }
    }

    #[test]
    fn hub_bits_hybrid_matches_serial_and_builds_once() {
        let g = rmat(11, 82);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
        let alg = HybridBfs {
            num_threads: 2,
            sell: true,
            bu_sell: true,
            hub_bits: 16,
            vpu: VpuMode::Counted,
            ..Default::default()
        };
        let prepared = alg.prepare(&g).unwrap();
        assert_eq!(prepared.artifacts().hub_builds(), 1, "prepare builds the hub bitmap");
        let r = prepared.run(root);
        assert_eq!(r.tree.distances().unwrap(), expected, "hub caching must not change distances");
        // hub caching off by default: no bitmap is built
        let plain = HybridBfs { sell: true, bu_sell: true, ..Default::default() };
        let p2 = plain.prepare(&g).unwrap();
        assert_eq!(p2.artifacts().hub_builds(), 0);
    }

    #[test]
    fn zero_alpha_or_beta_fails_fast_in_prepare() {
        let g = rmat(9, 81);
        for (alpha, beta) in [(0usize, 24usize), (14, 0), (0, 0)] {
            let alg = HybridBfs { alpha, beta, ..Default::default() };
            let err = alg.prepare(&g).unwrap_err();
            assert!(
                err.to_string().contains("switch thresholds"),
                "unexpected error: {err}"
            );
        }
    }

    #[test]
    fn bottom_up_layers_are_marked_in_trace() {
        let g = rmat(12, 72);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let r = HybridBfs { num_threads: 1, ..Default::default() }.run(&g, root);
        let bu_layers = r.trace.layers.iter().filter(|l| l.bottom_up).count();
        assert!(bu_layers > 0, "explosion layers must run bottom-up");
        assert!(bu_layers < r.trace.layers.len());
        // for the plain hybrid the vectorized flag still tracks bottom-up
        for l in &r.trace.layers {
            assert_eq!(l.vectorized, l.bottom_up);
        }
    }

    #[test]
    fn hybrid_validates() {
        let g = rmat(10, 74);
        for root in [0u32, 5] {
            let r = HybridBfs::default().run(&g, root);
            let rep = validate(&g, &r.tree);
            assert!(rep.all_passed(), "{}", rep.summary());
        }
    }

    #[test]
    fn scalar_and_simd_bottom_up_agree() {
        let g = rmat(10, 75);
        let n = g.num_vertices();
        // frontier = all vertices at distance 1 from the hub
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);
        let mk = || {
            let vis = SharedBitmap::new(n);
            vis.set_bit_atomic(root);
            let next = SharedBitmap::new(n);
            let pred = SharedPred::new_infinity(n);
            pred.set(root, root as Pred);
            (vis, next, pred)
        };
        let (v1, n1, p1) = mk();
        bottom_up_layer_scalar(1, &g, &frontier, &v1, &n1, &p1);
        let (v2, n2, p2) = mk();
        bottom_up_layer_simd::<Vpu>(1, &g, frontier.words(), &v2, &n2, &p2);
        assert_eq!(n1.snapshot().words(), n2.snapshot().words());
        assert_eq!(v1.snapshot().words(), v2.snapshot().words());
        // parents may differ in *which* frontier vertex... with a single
        // frontier vertex they cannot:
        assert_eq!(p1.snapshot(), p2.snapshot());
    }

    #[test]
    fn bottom_up_no_frontier_discovers_nothing() {
        let el = EdgeList::with_edges(8, vec![(0, 1), (1, 2)]);
        let g = Csr::from_edge_list(0, &el);
        let frontier = Bitmap::new(8);
        let vis = SharedBitmap::new(8);
        let next = SharedBitmap::new(8);
        let pred = SharedPred::new_infinity(8);
        let (_e, found) = bottom_up_layer_scalar(1, &g, &frontier, &vis, &next, &pred);
        assert_eq!(found, 0);
        assert!(next.is_all_zero());
    }
}
