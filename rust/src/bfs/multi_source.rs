//! Multi-source (MS-BFS) traversal over the SELL layout — the batch-first
//! engine `hybrid-sell-ms`: up to [`MS_WAVE`] roots traverse the prepared
//! [`Sell16`] **concurrently, through one shared walk of the graph**.
//!
//! The single-root engines fill the VPU's 16 lanes *within* one search
//! (16 distinct frontier vertices per issue). This engine fills a second
//! dimension: 16 *searches* per memory access. Per vertex it keeps a
//! **visit mask** — one bit per root of the wave, stored one 32-bit word
//! per vertex so the mask array is directly gatherable — and advances the
//! traversal by **mask OR-propagation**: when the union frontier scans
//! edge `v → w`, every root whose bit is in `v`'s frontier mask but not
//! yet in `w`'s visit mask discovers `w` in this layer. One gather of 16
//! neighbor ids therefore serves every search of the wave at once, which
//! is exactly the amortization a Graph500-style 64-root batch (or a
//! serving deployment's request batch) wants.
//!
//! Layering is exact per root: all roots start at layer 0 together and
//! masks propagate one layer per iteration, so bit `r` walks precisely
//! root `r`'s standalone BFS — depths are identical to the single-root
//! engines' (the batch-equivalence property suite asserts this for every
//! engine, this one included).
//!
//! # Direction optimization, per root
//!
//! The wave is direction-optimizing (the hybrid-vectorization follow-up's
//! point that direction switching composes with lane packing) — but the
//! Beamer schedule runs **per root**, not on the union: each root hits
//! its explosion layers at its own depth, and a single union-wide switch
//! would force bottom-up layers to keep scanning until *every* root's
//! bits arrive (work-volume simulation showed a union-wide switch losing
//! to 16 per-root hybrids from SCALE 14 up, while per-root schedules
//! sharing the passes win ~2.3× at every scale). Every layer therefore
//! splits the live bits into a top-down group and a bottom-up group by
//! each root's own α/β test over its own frontier volumes, and runs up
//! to two shared passes:
//!
//! * **Top-down pass** — the frontier vertices carrying top-down bits
//!   are packed over the SELL layout exactly like
//!   [`super::sell_vectorized`] (aligned full-chunk rows + degree-sorted
//!   gather groups); each row gathers 16 neighbor ids, a second gather
//!   fetches those neighbors' visit masks, and a vector AND-NOT yields
//!   the per-lane candidate masks (restricted to the top-down bits).
//! * **Bottom-up pass** — vertices whose visit mask is still missing
//!   *bottom-up-live* bits stream through the [`super::sell_bottom_up`]
//!   lane-refill pack; a lane gathers its next neighbor's frontier mask
//!   and ORs the missing bits in (opportunistically including top-down
//!   bits — a frontier parent is a frontier parent), retiring once its
//!   mask covers the bottom-up live set. Exploding roots' frontiers are
//!   huge, so coverage — like the single-root first-hit exit — arrives
//!   within a few rows, and bits whose frontier has drained (an isolated
//!   root after layer 0) never pin lanes to exhaustion.
//!
//! Both per-root switches run through the cross-root [`PolicyFeedback`]
//! channel: classic raw-volume tests while the channel is fresh,
//! measured-issue units (`edges ÷ lanes-per-issue`) once a completed
//! root has measured both directions
//! ([`PolicyFeedback::switch_to_bottom_up`] /
//! [`PolicyFeedback::switch_to_top_down`]).
//!
//! # Claims and traces
//!
//! Discoveries are committed with the bottom-up claim discipline in
//! *both* directions: visit masks must **merge** (`fetch_or`), not
//! overwrite, so the paper's racy whole-word scatter + restoration pair
//! does not apply — the `fetch_or` return value arbitrates concurrent
//! claimants, giving every `pred[r][w]` cell a unique writer. Bit-
//! granularity atomic ORs are not in the vector ISA (§3.2), so claims are
//! scalar, at most 16 per issue and only on hit lanes.
//!
//! Each root of a batch gets its own [`BfsResult`]: its exact tree, and a
//! trace whose scalar columns (`input_vertices`, `edges_scanned` as
//! top-down degree sums — the Graph500-comparable volume — and
//! `traversed`) are per-root exact. The wave's *shared* work (VPU
//! counters, wall time) cannot be split per root, so it is attributed to
//! the wave's **lead result** (the first root), whose trace keeps a row
//! for every union layer; sums over a batch therefore stay additive, and
//! the attribution is direction-exact — a lead row carries one pass's
//! counters with a matching `bottom_up` flag, a mixed layer adding a
//! second zero-volume row for its bottom-up pass. Non-lead rows carry
//! their own root's per-layer direction (and no VPU counters).
//! [`PolicyFeedback`] additionally records each union layer's occupancy,
//! so later waves — and any engine sharing the artifacts — learn from
//! batch occupancy too.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::artifacts::ComponentMap;
use super::bottom_up::HybridBfs;
use super::policy::{BottomUpMode, ChunkingMode, PolicyFeedback};
use super::sell_bottom_up::LanePack;
use super::sell_vectorized::{pack_frontier, PackedItem, SIGMA_AUTO};
use super::state::{SharedBitmap, SharedPred};
use super::vectorized::SimdOpts;
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, RunControl, RunStatus,
    RunTrace,
};
use crate::graph::sell::{Sell16, SELL_C};
use crate::graph::{Bitmap, Csr};
use crate::simd::backend::{resolve, VpuBackend, VpuMode};
use crate::simd::ops::PrefetchHint;
use crate::simd::vec512::{Mask16, VecI32x16, LANES};
use crate::simd::VpuCounters;
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// Roots per MS wave — one bit of the per-vertex visit mask (and one VPU
/// mask-register bit) per root. Larger batches are split into waves.
pub const MS_WAVE: usize = LANES;

/// The shared discovery state of one MS wave: per-vertex visit masks,
/// next-frontier masks, the union next bitmap, and one predecessor array
/// per root bit. All cells are atomic — a wave is still parallelized
/// across `num_threads` workers like every other engine.
struct WaveState<'a> {
    seen: &'a [AtomicU32],
    next_mask: &'a [AtomicU32],
    next_union: &'a SharedBitmap,
    preds: &'a [SharedPred],
    /// Per-component reachable-mask bound (the ROADMAP lane-retirement
    /// item): `None` disables it.
    comp: Option<CompBound<'a>>,
}

/// The wave's per-component root masks: a vertex can only ever be reached
/// by the wave roots in its own connected component, so everything a
/// bottom-up lane *owes* is `live_mask & root_masks[label(v)]`. Bits of
/// roots in other components — which would otherwise pin the lane until
/// those roots drain — retire immediately.
struct CompBound<'a> {
    /// Component label per vertex ([`ComponentMap::labels`]).
    labels: &'a [u32],
    /// OR of `1 << r` over the wave roots in each component.
    root_masks: &'a [u32],
}

impl WaveState<'_> {
    /// The live bits vertex `v` can still be discovered by: `live_mask`
    /// restricted to `v`'s component's wave roots (or unrestricted when
    /// the bound is off).
    #[inline]
    fn live_for(&self, v: Vertex, live_mask: u32) -> u32 {
        match &self.comp {
            Some(c) => live_mask & c.root_masks[c.labels[v as usize] as usize],
            None => live_mask,
        }
    }
}

impl WaveState<'_> {
    /// Merge `cand`'s root bits into `w`'s visit mask, claiming `parent`
    /// for every bit that was genuinely new. `fetch_or` arbitrates
    /// concurrent claimants — exactly one claim observes each bit's 0→1
    /// transition, so every `preds[r]` cell has a unique writer (the
    /// race-free claim discipline of the SELL bottom-up scan, kept in
    /// both directions here). Returns the visit mask after the merge.
    fn claim(&self, w: Vertex, cand: u32, parent: Vertex) -> u32 {
        let old = self.seen[w as usize].fetch_or(cand, Ordering::Relaxed);
        let new = cand & !old;
        if new != 0 {
            self.next_mask[w as usize].fetch_or(new, Ordering::Relaxed);
            self.next_union.set_bit_atomic(w);
            let mut bits = new;
            while bits != 0 {
                let r = bits.trailing_zeros() as usize;
                bits &= bits - 1;
                self.preds[r].set(w, parent as Pred);
            }
        }
        old | cand
    }
}

/// Issue one packed row of the union frontier through the MS filter. The
/// visit-mask array holds one 32-bit word per vertex, so the second
/// gather's indices are the neighbor ids themselves; the per-lane
/// candidate masks come from a vector AND-NOT, and hit lanes commit
/// through [`WaveState::claim`].
fn ms_explore_row<V: VpuBackend>(
    vpu: &mut V,
    vneig: VecI32x16,
    active: Mask16,
    vsrc_mask: VecI32x16,
    src_vertices: &[Vertex; LANES],
    state: &WaveState<'_>,
    prefetch: bool,
) {
    if prefetch {
        vpu.prefetch_i32gather(vneig, PrefetchHint::T0);
    }
    let vseen = vpu.mask_gather_shared_words(active, vneig, state.seen);
    // bits of the source's frontier mask the neighbor has not seen yet
    let vcand = vpu.andnot_epi32(vseen, vsrc_mask);
    let hit = vpu.kand(vpu.test_epi32_mask(vcand, vcand), active);
    if hit.is_empty() {
        return;
    }
    for (lane, &src) in src_vertices.iter().enumerate() {
        if hit.test_lane(lane) {
            state.claim(vneig.lane(lane) as Vertex, vcand.lane(lane) as u32, src);
        }
    }
}

/// Per-thread accumulator shared by both passes: entries scanned, the
/// bottom-up pool tally (zero for the top-down pass), and the thread's
/// VPU (created lazily so idle threads stay free).
struct PassAcc<V> {
    edges: usize,
    pool_vertices: usize,
    pool_edges: usize,
    vpu: Option<V>,
}

#[allow(clippy::derivable_impls)]
impl<V> Default for PassAcc<V> {
    fn default() -> Self {
        PassAcc { edges: 0, pool_vertices: 0, pool_edges: 0, vpu: None }
    }
}

/// Merge the per-thread accumulators of one pass.
fn merge_accs<V: VpuBackend>(accs: Vec<PassAcc<V>>) -> (usize, usize, usize, VpuCounters) {
    let mut edges = 0usize;
    let mut pool_vertices = 0usize;
    let mut pool_edges = 0usize;
    let mut vpu = VpuCounters::default();
    for a in accs {
        edges += a.edges;
        pool_vertices += a.pool_vertices;
        pool_edges += a.pool_edges;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (edges, pool_vertices, pool_edges, vpu)
}

/// Explore one shared top-down pass: the frontier vertices carrying
/// top-down bits (`td_union`) are packed over the SELL layout exactly
/// like the single-root lane-packed explorer — aligned full-chunk rows
/// plus degree-sorted gather groups — but each row serves every top-down
/// root of the wave at once (source masks are restricted to `td_mask`).
/// Returns (adjacency entries scanned, merged VPU counters).
///
/// NOTE: the chunk/group iteration skeleton (active-mask construction,
/// issue accounting, aligned-vs-gather load choice, prefetching) mirrors
/// `sell_explore_layer` in [`super::sell_vectorized`] — only the per-lane
/// payload differs (source *mask* here vs marked parent there, and no
/// restoration since claims merge). A fix to the packing loop there
/// almost certainly applies here too.
fn ms_explore_layer<V: VpuBackend>(
    num_threads: usize,
    sell: &Sell16,
    td_union: &Bitmap,
    frontier_mask: &[u32],
    td_mask: u32,
    state: &WaveState<'_>,
    opts: SimdOpts,
) -> (usize, VpuCounters) {
    let (items, packed) = pack_frontier(sell, td_union, opts.aligned);
    let dist = opts.effective_dist();
    let accs: Vec<PassAcc<V>> = parallel_for_dynamic(
        num_threads,
        items.len(),
        2,
        |_tid, range, acc: &mut PassAcc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            for item in &items[range] {
                match *item {
                    PackedItem::FullChunk(c) => {
                        let start = sell.chunk_starts[c];
                        let lens = &sell.lane_len[c * SELL_C..(c + 1) * SELL_C];
                        let height = sell.chunk_lens[c] as usize;
                        let mut src: [Vertex; LANES] = [0; LANES];
                        let mut mask_arr = [0i32; LANES];
                        for (lane, (s, m)) in
                            src.iter_mut().zip(mask_arr.iter_mut()).enumerate()
                        {
                            *s = sell.perm[c * SELL_C + lane];
                            *m = (frontier_mask[*s as usize] & td_mask) as i32;
                        }
                        let vsrc_mask = VecI32x16(mask_arr);
                        for r in 0..height {
                            let mut m = 0u16;
                            for (lane, &len) in lens.iter().enumerate() {
                                if len as usize > r {
                                    m |= 1 << lane;
                                }
                            }
                            let active = Mask16(m);
                            vpu.note_explore_issue(active.count());
                            acc.edges += active.count() as usize;
                            let offset = start + r * SELL_C;
                            let vneig = if active == Mask16::ALL {
                                vpu.note_full_chunk();
                                vpu.load_vertices(&sell.cols, offset)
                            } else {
                                vpu.note_remainder(active.count() as usize);
                                vpu.mask_load_vertices(active, &sell.cols, offset)
                            };
                            if opts.prefetch {
                                if V::COUNTED {
                                    if r + 1 < height {
                                        vpu.prefetch_scalar(PrefetchHint::T1);
                                    }
                                } else if dist > 0 && r + dist < height {
                                    if let Some(c0) = sell.cols.get(start + (r + dist) * SELL_C) {
                                        vpu.prefetch_addr(
                                            (c0 as *const u32).cast(),
                                            PrefetchHint::T1,
                                        );
                                    }
                                }
                            }
                            ms_explore_row(
                                vpu, vneig, active, vsrc_mask, &src, state, opts.prefetch,
                            );
                        }
                    }
                    PackedItem::Group(gstart, gend) => {
                        let group = &packed[gstart..gend];
                        let mut base_arr = [0i32; LANES];
                        let mut len_arr = [0u32; LANES];
                        let mut src: [Vertex; LANES] = [0; LANES];
                        let mut mask_arr = [0i32; LANES];
                        for (lane, &slot) in group.iter().enumerate() {
                            let slot = slot as usize;
                            base_arr[lane] = sell.slot_base(slot) as i32;
                            len_arr[lane] = sell.lane_len[slot];
                            src[lane] = sell.perm[slot];
                            mask_arr[lane] =
                                (frontier_mask[src[lane] as usize] & td_mask) as i32;
                        }
                        let vbase = VecI32x16(base_arr);
                        let vsrc_mask = VecI32x16(mask_arr);
                        // groups are packed in descending length order
                        let height = len_arr[0] as usize;
                        for r in 0..height {
                            let mut m = 0u16;
                            for (lane, &len) in len_arr.iter().enumerate().take(group.len()) {
                                if len as usize > r {
                                    m |= 1 << lane;
                                }
                            }
                            let active = Mask16(m);
                            vpu.note_explore_issue(active.count());
                            acc.edges += active.count() as usize;
                            let roff = vpu.set1_epi32((r * SELL_C) as i32);
                            let vidx = vpu.add_epi32(vbase, roff);
                            if opts.prefetch {
                                if V::COUNTED {
                                    vpu.prefetch_i32gather(vidx, PrefetchHint::T1);
                                } else if dist > 0 && r + dist < height {
                                    // lane 0 is the longest lane of the
                                    // group — its stream is the one worth
                                    // staying ahead of
                                    if let Some(c0) = sell
                                        .cols
                                        .get(base_arr[0] as usize + (r + dist) * SELL_C)
                                    {
                                        vpu.prefetch_addr(
                                            (c0 as *const u32).cast(),
                                            PrefetchHint::T1,
                                        );
                                    }
                                }
                            }
                            let vneig = vpu.mask_i32gather_words(active, vidx, &sell.cols);
                            ms_explore_row(
                                vpu, vneig, active, vsrc_mask, &src, state, opts.prefetch,
                            );
                        }
                    }
                }
            }
        }),
    );

    let (edges, _, _, vpu) = merge_accs(accs);
    (edges, vpu)
}

/// SELL chunks per dynamic grab of the bottom-up scan — same granularity
/// tradeoff as the single-root packed scan.
const MS_BU_CHUNK_GRAIN: usize = 64;

/// One shared bottom-up pass: every vertex whose visit mask is still
/// missing a `live_mask` bit streams through a [`LanePack`] (16 distinct
/// incomplete vertices per issue); each lane gathers its next neighbor,
/// that neighbor's *frontier* mask, and its own visit mask, and ORs the
/// missing bits in — the claim takes the neighbor's whole frontier mask
/// (a frontier parent is a frontier parent, so top-down-scheduled bits
/// ride along opportunistically).
///
/// `live_mask` is the OR of the frontier-carried bits of the
/// bottom-up-scheduled roots. A lane retires as soon as its visit mask
/// covers it (nothing this pass owes it any more), or its adjacency
/// exhausts; vertices already covering `live_mask` are skipped outright.
/// Exploding roots' frontiers are huge, so coverage typically arrives
/// within a few rows — the multi-source analogue of the single-root
/// first-hit exit — and bits whose root frontier has drained (an
/// isolated root after layer 0) never pin lanes to exhaustion. Returns
/// (entries scanned, pool vertices streamed, pool adjacency entries,
/// merged counters) — the pool tally is counted in the candidate stream
/// itself, so no separate O(V) pool scan is needed.
fn ms_bottom_up_layer<V: VpuBackend>(
    num_threads: usize,
    sell: &Sell16,
    frontier_mask: &[u32],
    live_mask: u32,
    state: &WaveState<'_>,
    opts: SimdOpts,
) -> (usize, usize, usize, VpuCounters) {
    let dist = opts.effective_dist();
    let accs: Vec<PassAcc<V>> = parallel_for_dynamic(
        num_threads,
        sell.num_chunks(),
        MS_BU_CHUNK_GRAIN,
        |_tid, chunk_range, acc: &mut PassAcc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            let slots = chunk_range.start * SELL_C..chunk_range.end * SELL_C;
            // candidate lanes: occupied slots whose vertex some *live*
            // root has not seen yet. Within a layer only the lane
            // scanning a vertex grows its mask, so the filter is stable
            // across the refill stream; the stream doubles as the pool
            // tally for the feedback channel.
            let mut pool_vertices = 0usize;
            let mut pool_edges = 0usize;
            let mut stream = sell
                .slot_lanes(slots)
                .filter(|l| {
                    // everything this pass could still owe the vertex —
                    // restricted to its component's wave roots when the
                    // per-component bound is on
                    state.live_for(l.vertex, live_mask)
                        & !state.seen[l.vertex as usize].load(Ordering::Relaxed)
                        != 0
                })
                .inspect(|l| {
                    pool_vertices += 1;
                    pool_edges += l.len as usize;
                });
            let mut pack = LanePack::new();
            loop {
                let active = pack.refill(&mut stream);
                if active.is_empty() {
                    break;
                }
                vpu.note_explore_issue(active.count());
                acc.edges += active.count() as usize;

                // gather each lane's next neighbor from the SELL storage,
                // then that neighbor's frontier mask and the lane's own
                // visit mask (both one word per vertex)
                let vidx = pack.gather_indices(sell);
                if opts.prefetch {
                    if V::COUNTED {
                        vpu.prefetch_i32gather(vidx, PrefetchHint::T1);
                    } else if dist > 0 {
                        // stay `dist` rows ahead of lane 0's adjacency
                        // stream; `.get` bounds the lookahead
                        if let Some(c0) = sell.cols.get(vidx.0[0] as usize + dist * SELL_C) {
                            vpu.prefetch_addr((c0 as *const u32).cast(), PrefetchHint::T1);
                        }
                    }
                }
                let vneig = vpu.mask_i32gather_words(active, vidx, &sell.cols);
                let vfm = vpu.mask_i32gather_words(active, vneig, frontier_mask);
                let vself = pack.vertex_vec();
                let vseen = vpu.mask_gather_shared_words(active, vself, state.seen);
                let vwant = vpu.andnot_epi32(vseen, vfm);
                let hit = vpu.kand(vpu.test_epi32_mask(vwant, vwant), active);

                let mut retire = 0u16;
                if !hit.is_empty() {
                    for lane in 0..SELL_C {
                        if !hit.test_lane(lane) {
                            continue;
                        }
                        let v = pack.vertex(lane);
                        let u = vneig.lane(lane) as Vertex;
                        let now = state.claim(v, vwant.lane(lane) as u32, u);
                        if state.live_for(v, live_mask) & !now == 0 {
                            // converged: every live root that can ever
                            // reach v saw it — with the component bound,
                            // other components' live bits retire instantly
                            retire |= 1 << lane;
                        }
                    }
                }
                pack.advance(Mask16(retire));
            }
            drop(stream);
            acc.pool_vertices += pool_vertices;
            acc.pool_edges += pool_edges;
        }),
    );

    merge_accs(accs)
}

/// The batch-first multi-source engine (`hybrid-sell-ms`): up to
/// [`MS_WAVE`] roots per wave share one traversal of the prepared
/// [`Sell16`], each root running its own direction-optimizing schedule
/// (see the module docs). Single roots run as a one-bit wave, so the
/// engine plugs into the per-root API unchanged.
#[derive(Clone, Copy, Debug)]
pub struct MultiSourceSellBfs {
    pub num_threads: usize,
    /// σ sort window of the prepared layout ([`SIGMA_AUTO`] resolves to
    /// the per-scale default at prepare time).
    pub sigma: usize,
    /// Beamer's α (top-down → bottom-up), applied per root to that
    /// root's own frontier volumes.
    pub alpha: usize,
    /// Beamer's β (bottom-up → top-down), applied per root.
    pub beta: usize,
    pub opts: SimdOpts,
    /// Retire bottom-up lanes against the per-component reachable-mask
    /// bound (prepare runs a cheap components pass once): a lane owes a
    /// vertex only the live bits of roots in the vertex's own component,
    /// so bits of still-running roots elsewhere never pin it to adjacency
    /// exhaustion. Off reproduces the unbounded pre-PR scan.
    pub component_bound: bool,
    /// VPU backend mode: counted emulation, hardware SIMD, or counted
    /// warm-up + hardware steady state.
    pub vpu: VpuMode,
}

impl Default for MultiSourceSellBfs {
    fn default() -> Self {
        MultiSourceSellBfs {
            num_threads: 4,
            sigma: SIGMA_AUTO,
            alpha: HybridBfs::DEFAULT_ALPHA,
            beta: HybridBfs::DEFAULT_BETA,
            opts: SimdOpts::full(),
            component_bound: true,
            vpu: VpuMode::default(),
        }
    }
}

impl MultiSourceSellBfs {
    /// One MS wave on VPU backend `V`: traverse from up to [`MS_WAVE`]
    /// roots simultaneously, returning one result per root in root order.
    /// `components`, when present, supplies the per-component
    /// reachable-mask bound for bottom-up lane retirement.
    fn traverse_wave<V: VpuBackend>(
        &self,
        g: &Csr,
        sell: &Sell16,
        feedback: &PolicyFeedback,
        components: Option<&ComponentMap>,
        roots: &[Vertex],
        ctl: &RunControl,
    ) -> Vec<BfsResult> {
        let k = roots.len();
        debug_assert!((1..=MS_WAVE).contains(&k), "wave width {k} out of range");
        let n = g.num_vertices();
        let total_edges = g.num_directed_edges();

        let seen: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let next_mask: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
        let next_union = SharedBitmap::new(n);
        let preds: Vec<SharedPred> = (0..k).map(|_| SharedPred::new_infinity(n)).collect();
        let mut frontier_mask: Vec<u32> = vec![0; n];
        let mut union = Bitmap::new(n);

        for (r, &root) in roots.iter().enumerate() {
            seen[root as usize].fetch_or(1 << r, Ordering::Relaxed);
            frontier_mask[root as usize] |= 1 << r;
            union.set_bit(root);
            preds[r].set(root, root as Pred);
        }

        // per-component wave-root masks for the retirement bound
        let root_masks: Option<Vec<u32>> = components.map(|cm| {
            let mut masks = vec![0u32; cm.count.max(1)];
            for (r, &root) in roots.iter().enumerate() {
                masks[cm.label(root) as usize] |= 1 << r;
            }
            masks
        });
        let state = WaveState {
            seen: &seen,
            next_mask: &next_mask,
            next_union: &next_union,
            preds: &preds,
            comp: components.zip(root_masks.as_deref()).map(|(cm, masks)| CompBound {
                labels: &cm.labels,
                root_masks: masks,
            }),
        };

        let mut rows: Vec<Vec<LayerTrace>> = (0..k).map(|_| Vec::new()).collect();
        let mut layer = 0usize;
        let mut union_count = union.count_ones();
        // per-root Beamer state: direction flag and accumulated frontier
        // edge volume — exactly the bookkeeping 16 independent hybrids
        // would keep, one bit / cell per root
        let mut bu_flags = 0u32;
        let mut explored = [0usize; MS_WAVE];
        // a stop applies to the whole wave: every root of the wave gets the
        // same status and keeps its visited prefix
        let mut status = RunStatus::Complete;
        while union_count != 0 {
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();

            // per-root layer accounting from the union frontier: a root's
            // layer-ℓ frontier is exactly the vertices whose frontier mask
            // carries its bit, so per-root volumes (top-down degree sums,
            // the Graph500-comparable count) fall out of one pass
            let mut input_vertices = [0usize; MS_WAVE];
            let mut input_edges = [0usize; MS_WAVE];
            for v in union.iter_set_bits() {
                let deg = g.degree(v);
                let mut m = frontier_mask[v as usize];
                while m != 0 {
                    let r = m.trailing_zeros() as usize;
                    m &= m - 1;
                    input_vertices[r] += 1;
                    input_edges[r] += deg;
                }
            }

            // each live root runs its own Beamer schedule over its own
            // volumes — classic raw tests on a fresh channel, measured
            // issue units once a completed root measured both directions
            let mut td_mask = 0u32;
            let mut bu_mask = 0u32;
            for r in 0..k {
                if input_vertices[r] == 0 {
                    continue; // this root's traversal has drained
                }
                let unexplored = total_edges.saturating_sub(explored[r]);
                if bu_flags & (1 << r) == 0 {
                    if feedback.switch_to_bottom_up(input_edges[r], unexplored, self.alpha) {
                        bu_flags |= 1 << r;
                    }
                } else if feedback.switch_to_top_down(
                    input_vertices[r],
                    input_edges[r],
                    unexplored,
                    n,
                    self.beta,
                ) {
                    bu_flags &= !(1 << r);
                }
                explored[r] += input_edges[r];
                if bu_flags & (1 << r) != 0 {
                    bu_mask |= 1 << r;
                } else {
                    td_mask |= 1 << r;
                }
            }

            // split the frontier between the two shared passes: vertices
            // carrying top-down bits form the top-down pack; the union of
            // frontier-carried bottom-up bits bounds the bottom-up pool
            let mut td_union = Bitmap::new(n);
            let mut td_vertices = 0usize;
            let mut td_edges = 0usize;
            let mut bu_live = 0u32;
            for v in union.iter_set_bits() {
                let m = frontier_mask[v as usize];
                if m & td_mask != 0 {
                    td_union.set_bit(v);
                    td_vertices += 1;
                    td_edges += g.degree(v);
                }
                bu_live |= m & bu_mask;
            }

            let mut td_vpu = VpuCounters::default();
            let mut bu_vpu = VpuCounters::default();
            if td_vertices > 0 {
                let (_scanned, pass_vpu) = ms_explore_layer::<V>(
                    self.num_threads,
                    sell,
                    &td_union,
                    &frontier_mask,
                    td_mask,
                    &state,
                    self.opts,
                );
                // batch occupancy feeds the shared channel: later waves
                // (and any engine sharing the artifacts) learn from it
                feedback.record_layer(ChunkingMode::LanePacked, td_vertices, td_edges, &pass_vpu);
                td_vpu = pass_vpu;
            }
            if bu_live != 0 {
                // the pool the pass scans — every vertex still missing a
                // bottom-up-live bit — is tallied by the pass itself
                let (_scanned, pool_vertices, pool_edges, pass_vpu) = ms_bottom_up_layer::<V>(
                    self.num_threads,
                    sell,
                    &frontier_mask,
                    bu_live,
                    &state,
                    self.opts,
                );
                feedback.record_bottom_up_layer(
                    BottomUpMode::SellPacked,
                    pool_vertices,
                    pool_edges,
                    &pass_vpu,
                );
                bu_vpu = pass_vpu;
            }

            // advance: count per-root discoveries while installing the new
            // frontier masks (`swap(0)` also clears them for reuse)
            let mut traversed = [0usize; MS_WAVE];
            for v in union.iter_set_bits() {
                frontier_mask[v as usize] = 0;
            }
            let snap = next_union.snapshot();
            for v in snap.iter_set_bits() {
                let mask = next_mask[v as usize].swap(0, Ordering::Relaxed);
                frontier_mask[v as usize] = mask;
                let mut m = mask;
                while m != 0 {
                    let r = m.trailing_zeros() as usize;
                    m &= m - 1;
                    traversed[r] += 1;
                }
            }

            let wall_ns = t0.elapsed().as_nanos() as u64;
            let td_ran = td_vertices > 0;
            let bu_ran = bu_live != 0;
            for (r, root_rows) in rows.iter_mut().enumerate() {
                if r > 0 && input_vertices[r] == 0 {
                    // this root's own traversal already drained; only the
                    // wave lead keeps rows for trailing union layers
                    continue;
                }
                let mut row = LayerTrace {
                    layer,
                    input_vertices: input_vertices[r],
                    edges_scanned: input_edges[r],
                    traversed: traversed[r],
                    vectorized: true,
                    // per-root exact: the direction THIS root's bit ran
                    bottom_up: bu_flags & (1 << r) != 0,
                    ..Default::default()
                };
                if r == 0 {
                    // the wave's shared VPU events and wall time go to the
                    // lead result exactly once, so sums over a batch stay
                    // additive (see the module docs). Attribution is
                    // direction-exact: this row carries the top-down
                    // pass's counters (or the bottom-up pass's when only
                    // that ran) with a matching direction flag; a mixed
                    // layer appends an extra zero-volume row below for
                    // the bottom-up pass.
                    row.bottom_up = bu_ran && !td_ran;
                    row.vpu = if td_ran { td_vpu } else { bu_vpu };
                    row.wall_ns = wall_ns;
                }
                root_rows.push(row);
                if r == 0 && td_ran && bu_ran {
                    // the mixed layer's bottom-up pass, on its own row so
                    // per-direction aggregations over the lead trace stay
                    // exact (zero scalar volumes: those live on the
                    // primary row)
                    root_rows.push(LayerTrace {
                        layer,
                        vectorized: true,
                        bottom_up: true,
                        vpu: bu_vpu,
                        ..Default::default()
                    });
                }
            }

            union = snap;
            next_union.clear_all();
            union_count = union.count_ones();
            layer += 1;
        }

        for _ in 0..k {
            feedback.record_root();
        }

        preds
            .into_iter()
            .zip(roots.iter())
            .zip(rows)
            .map(|((pred, &root), layers)| BfsResult {
                tree: BfsTree::new(root, pred.into_vec()),
                trace: RunTrace {
                    layers,
                    num_threads: self.num_threads,
                    status,
                    ..Default::default()
                },
            })
            .collect()
    }

    /// Resolve [`SIGMA_AUTO`] against the graph's measured degree stats.
    fn resolved_sigma(&self, g: &Csr, artifacts: &GraphArtifacts) -> usize {
        if self.sigma == SIGMA_AUTO {
            artifacts.stats(g).suggested_sigma()
        } else {
            self.sigma
        }
    }
}

/// A [`MultiSourceSellBfs`] bound to one graph: the σ-resolved [`Sell16`]
/// layout built once by prepare and shared by every wave; the artifacts'
/// [`PolicyFeedback`] both steers the direction switches and accumulates
/// batch occupancy.
pub struct PreparedMultiSource<'g> {
    g: &'g Csr,
    sell: Arc<Sell16>,
    /// Component labels for the bottom-up retirement bound (`None` when
    /// [`MultiSourceSellBfs::component_bound`] is off).
    components: Option<Arc<ComponentMap>>,
    engine: MultiSourceSellBfs,
    artifacts: Arc<GraphArtifacts>,
}

impl PreparedBfs for PreparedMultiSource<'_> {
    fn name(&self) -> &'static str {
        "hybrid-sell-ms"
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        self.run_batch_with(std::slice::from_ref(&root), ctl)
            .pop()
            .expect("wave returned no result")
    }

    fn run_batch_with(&self, roots: &[Vertex], ctl: &RunControl) -> Vec<BfsResult> {
        let mut out = Vec::with_capacity(roots.len());
        let fb = self.artifacts.feedback();
        for wave in roots.chunks(MS_WAVE) {
            // backend dispatch per wave: Auto runs counted warm-up waves
            // until the feedback channel has seen enough roots
            let (select, warmup) = resolve(self.engine.vpu, fb.roots_done());
            let mut engine = self.engine;
            let sampling = super::vectorized::plan_prefetch(&mut engine.opts, fb, select);
            let mut results = crate::with_vpu_backend!(select, V, engine.traverse_wave::<V>(
                self.g,
                &self.sell,
                fb,
                self.components.as_deref(),
                wave,
                ctl,
            ));
            if sampling {
                if let Some(lead) = results.first() {
                    // the wave's shared wall time and VPU work live on the
                    // lead trace, so that is the sample
                    fb.record_prefetch_sample(
                        engine.opts.prefetch_dist,
                        lead.trace.total_wall_ns(),
                        lead.trace.total_edges_scanned(),
                    );
                }
            }
            if warmup {
                for r in &mut results {
                    r.trace.counted_warmup = true;
                }
            }
            out.append(&mut results);
        }
        out
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

impl BfsEngine for MultiSourceSellBfs {
    fn name(&self) -> &'static str {
        "hybrid-sell-ms"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        // same fail-fast contract as the hybrid: degenerate switch
        // thresholds are rejected before any worker spawns
        if self.alpha == 0 || self.beta == 0 {
            anyhow::bail!(
                "hybrid switch thresholds must be >= 1 (alpha={}, beta={})",
                self.alpha,
                self.beta
            );
        }
        let sigma = self.resolved_sigma(g, &artifacts);
        let sell = artifacts.sell_layout(g, sigma)?;
        // the cheap components pass for the lane-retirement bound runs
        // once per graph, in prepare, like every other artifact; it is
        // optional — under governor memory pressure the lanes simply
        // retire on the full live mask instead
        let components =
            if self.component_bound { artifacts.components(g) } else { None };
        Ok(Box::new(PreparedMultiSource { g, sell, components, engine: *self, artifacts }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::bfs::validate::validate;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::PRED_INFINITY;

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    /// A deterministic spread of roots: the hub plus stride-sampled ids.
    fn sample_roots(g: &Csr, k: usize) -> Vec<Vertex> {
        let n = g.num_vertices();
        let hub = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        std::iter::once(hub)
            .chain((0..k.saturating_sub(1)).map(|i| ((i * 97 + 13) % n) as Vertex))
            .collect()
    }

    #[test]
    fn wave_matches_serial_distances_all_widths() {
        let g = rmat(10, 8, 21);
        let engine = MultiSourceSellBfs { num_threads: 2, ..Default::default() };
        let prepared = engine.prepare(&g).unwrap();
        for k in [1usize, 2, 5, 16] {
            let roots = sample_roots(&g, k);
            let results = prepared.run_batch(&roots);
            assert_eq!(results.len(), k);
            for (r, &root) in results.iter().zip(roots.iter()) {
                assert_eq!(r.tree.root, root);
                let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
                assert_eq!(r.tree.distances().unwrap(), expected, "k={k} root={root}");
            }
        }
    }

    #[test]
    fn batch_larger_than_wave_chunks_into_waves() {
        // 19 roots = one full 16-wave plus a 3-wave
        let g = rmat(9, 8, 22);
        let roots = sample_roots(&g, 19);
        let engine = MultiSourceSellBfs { num_threads: 2, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&roots);
        assert_eq!(results.len(), 19);
        for (r, &root) in results.iter().zip(roots.iter()) {
            let expected = SerialLayeredBfs.run(&g, root).tree.distances().unwrap();
            assert_eq!(r.tree.distances().unwrap(), expected, "root={root}");
        }
    }

    #[test]
    fn wave_trees_validate_five_checks() {
        let g = rmat(11, 16, 23);
        let roots = sample_roots(&g, 16);
        let engine = MultiSourceSellBfs { num_threads: 4, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&roots);
        for r in &results {
            let report = validate(&g, &r.tree);
            assert!(report.all_passed(), "root {}: {}", r.tree.root, report.summary());
            for &p in &r.tree.pred {
                assert!(p == PRED_INFINITY || p >= 0, "marked pred survived: {p}");
            }
        }
    }

    #[test]
    fn per_root_trace_rows_match_serial_layers() {
        // the per-root scalar columns are exact: a non-lead root's rows
        // must equal the serial engine's layer profile entry for entry
        // (edges are top-down degree sums in both)
        let g = rmat(10, 16, 24);
        let roots = sample_roots(&g, 4);
        let engine =
            MultiSourceSellBfs { num_threads: 1, vpu: VpuMode::Counted, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&roots);
        for (i, &root) in roots.iter().enumerate().skip(1) {
            let serial = SerialLayeredBfs.run(&g, root);
            let ms = &results[i];
            assert_eq!(ms.trace.layers.len(), serial.trace.layers.len(), "root {root}");
            for (a, b) in ms.trace.layers.iter().zip(serial.trace.layers.iter()) {
                assert_eq!(a.input_vertices, b.input_vertices, "root {root} layer {}", a.layer);
                assert_eq!(a.edges_scanned, b.edges_scanned, "root {root} layer {}", a.layer);
                assert_eq!(a.traversed, b.traversed, "root {root} layer {}", a.layer);
                // shared VPU work lives on the lead result only
                assert_eq!(a.vpu.explore_issues, 0);
            }
        }
        // the lead result carries the wave's VPU counters
        assert!(results[0].trace.vpu_totals().explore_issues > 0);
    }

    #[test]
    fn wave_shares_issues_across_roots() {
        // the amortization claim: one 16-root wave issues far fewer VPU
        // explores than 16 single-root traversals of the same engine.
        // Connected roots only, so the sharing signal is about real
        // traversals (degree-0 roots add ~nothing to either side; the
        // isolated case has its own test).
        let g = rmat(10, 16, 25);
        let n = g.num_vertices();
        let hub = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let roots: Vec<Vertex> = std::iter::once(hub)
            .chain(
                (0usize..)
                    .map(|i| ((i * 97 + 13) % n) as Vertex)
                    .filter(|&v| g.degree(v) > 0)
                    .take(15),
            )
            .collect();
        let engine =
            MultiSourceSellBfs { num_threads: 1, vpu: VpuMode::Counted, ..Default::default() };
        let wave_issues: u64 = engine
            .prepare(&g)
            .unwrap()
            .run_batch(&roots)
            .iter()
            .map(|r| r.trace.vpu_totals().explore_issues)
            .sum();
        let single_issues: u64 = roots
            .iter()
            .map(|&r| {
                // fresh preparation per root: every root runs the same
                // raw-α first-root schedule the wave's roots share
                engine.prepare(&g).unwrap().run(r).trace.vpu_totals().explore_issues
            })
            .sum();
        assert!(wave_issues > 0 && single_issues > 0);
        assert!(
            wave_issues < single_issues,
            "wave issued {wave_issues} explores, singles {single_issues}"
        );
    }

    #[test]
    fn wave_runs_bottom_up_on_explosion_layers() {
        let g = rmat(12, 16, 26);
        let roots = sample_roots(&g, 16);
        let engine =
            MultiSourceSellBfs { num_threads: 1, vpu: VpuMode::Counted, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&roots);
        let lead = &results[0];
        let bu_layers = lead.trace.layers.iter().filter(|l| l.bottom_up).count();
        assert!(bu_layers > 0, "no bottom-up layer on an RMAT explosion");
        assert!(bu_layers < lead.trace.layers.len(), "never ran top-down");
        let bu_issues: u64 = lead
            .trace
            .layers
            .iter()
            .filter(|l| l.bottom_up)
            .map(|l| l.vpu.explore_issues)
            .sum();
        assert!(bu_issues > 0, "bottom-up layers issued nothing");
    }

    #[test]
    fn duplicate_roots_yield_identical_results() {
        let g = rmat(9, 8, 27);
        let engine = MultiSourceSellBfs { num_threads: 2, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&[7, 7]);
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].tree.distances().unwrap(),
            results[1].tree.distances().unwrap()
        );
    }

    #[test]
    fn isolated_root_in_wave_reaches_only_itself() {
        // 0–1–2 connected; 3 isolated
        let el = EdgeList::with_edges(4, vec![(0, 1), (1, 2)]);
        let g = Csr::from_edge_list(0, &el);
        let engine = MultiSourceSellBfs { num_threads: 1, ..Default::default() };
        let results = engine.prepare(&g).unwrap().run_batch(&[0, 3]);
        assert_eq!(results[0].tree.reached_count(), 3);
        assert_eq!(results[1].tree.reached_count(), 1);
        assert_eq!(results[1].tree.parent(3), Some(3));
        assert_eq!(results[1].tree.parent(0), None);
    }

    #[test]
    fn feedback_counts_every_root_of_a_batch() {
        let g = rmat(9, 8, 28);
        let engine =
            MultiSourceSellBfs { num_threads: 2, vpu: VpuMode::Counted, ..Default::default() };
        let prepared = engine.prepare(&g).unwrap();
        prepared.run_batch(&sample_roots(&g, 16));
        assert_eq!(prepared.artifacts().feedback().roots_done(), 16);
        // the batch's lane-packed occupancy landed in the shared channel
        assert!(prepared
            .artifacts()
            .feedback()
            .mean_lanes_active(ChunkingMode::LanePacked)
            .is_some());
    }

    #[test]
    fn component_bound_retires_lanes_and_preserves_results() {
        // the ROADMAP lane-retirement satellite: on a graph whose second
        // component finishes early (a clique), the unbounded bottom-up
        // scan keeps streaming that component's vertices through the pack
        // — they owe the other root's live bit forever — while the
        // per-component bound retires them immediately. Results must be
        // identical either way; issues must strictly drop.
        let base = rmat(12, 16, 41);
        let n_rmat = base.num_vertices();
        let clique = 64usize;
        let mut edges: Vec<(Vertex, Vertex)> = Vec::new();
        for u in 0..n_rmat as Vertex {
            for &v in base.neighbors(u) {
                if u < v {
                    edges.push((u, v));
                }
            }
        }
        for a in 0..clique {
            for b in (a + 1)..clique {
                edges.push(((n_rmat + a) as Vertex, (n_rmat + b) as Vertex));
            }
        }
        let g = Csr::from_edge_list(0, &EdgeList::with_edges(n_rmat + clique, edges));
        let hub = (0..n_rmat as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let roots = [hub, n_rmat as Vertex];

        let run = |component_bound: bool| {
            let engine = MultiSourceSellBfs {
                num_threads: 1,
                component_bound,
                vpu: VpuMode::Counted,
                ..Default::default()
            };
            engine.prepare(&g).unwrap().run_batch(&roots)
        };
        let bounded = run(true);
        let unbounded = run(false);
        for (a, b) in bounded.iter().zip(unbounded.iter()) {
            assert_eq!(a.tree.distances().unwrap(), b.tree.distances().unwrap());
            let report = validate(&g, &a.tree);
            assert!(report.all_passed(), "{}", report.summary());
        }
        // precondition: the wave actually ran bottom-up passes
        assert!(
            unbounded[0].trace.layers.iter().any(|l| l.bottom_up),
            "no bottom-up pass — the retirement bound was never exercised"
        );
        let issues = |rs: &[crate::bfs::BfsResult]| -> u64 {
            rs.iter().map(|r| r.trace.vpu_totals().explore_issues).sum()
        };
        let with = issues(&bounded);
        let without = issues(&unbounded);
        assert!(
            with < without,
            "component bound must retire lanes: {with} !< {without} issues"
        );
    }

    #[test]
    fn zero_alpha_or_beta_fails_fast_in_prepare() {
        let g = rmat(8, 8, 29);
        for (alpha, beta) in [(0usize, 24usize), (14, 0)] {
            let engine = MultiSourceSellBfs { alpha, beta, ..Default::default() };
            let err = engine.prepare(&g).unwrap_err();
            assert!(err.to_string().contains("switch thresholds"), "unexpected error: {err}");
        }
    }

    #[test]
    fn sigma_override_is_honored_in_prepare() {
        let g = rmat(9, 8, 30);
        for sigma in [16usize, usize::MAX] {
            let engine = MultiSourceSellBfs { num_threads: 1, sigma, ..Default::default() };
            let prepared = engine.prepare(&g).unwrap();
            assert_eq!(prepared.artifacts().sell_builds(), 1);
            let r = prepared.run(3);
            let s = SerialLayeredBfs.run(&g, 3);
            assert_eq!(r.tree.distances().unwrap(), s.tree.distances().unwrap());
        }
    }

    #[test]
    fn multithreaded_wave_agrees_with_single() {
        let g = rmat(11, 16, 31);
        let roots = sample_roots(&g, 16);
        let engine1 = MultiSourceSellBfs { num_threads: 1, ..Default::default() };
        let engine4 = MultiSourceSellBfs { num_threads: 4, ..Default::default() };
        let a = engine1.prepare(&g).unwrap().run_batch(&roots);
        let b = engine4.prepare(&g).unwrap().run_batch(&roots);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tree.distances().unwrap(), y.tree.distances().unwrap());
        }
    }
}
