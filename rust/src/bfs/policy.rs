//! §4.1 — *Which layers are vectorized?*
//!
//! The paper observes that RMAT graphs are small-world: per-layer input
//! vertices grow to a mid-traversal peak and collapse after it (Table 1),
//! and most of the edge volume is concentrated in a couple of layers. The
//! vector unit only pays off where adjacency lists are long enough to fill
//! 16-lane chunks, so the paper runs the SIMD explorer "only for the first
//! [heavy] layers and the parallel top-down ... for the rest".
//!
//! The policy is a parameter here so the ablation bench can compare the
//! paper's choice against alternatives.
//!
//! The SELL engine's per-layer *chunking* choice additionally learns from
//! measurement: [`PolicyFeedback`] accumulates the occupancy
//! (`lanes_active / explore_issues`) each chunking mode achieved on
//! earlier roots of the same job, bucketed by frontier mean degree, and
//! later roots pick whichever mode measured better — replacing the fixed
//! [`LayerPolicy::SELL_PER_VERTEX_DEGREE`] threshold once real data
//! exists.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::simd::vec512::LANES;
use crate::simd::VpuCounters;

/// Decides, per layer, whether to run the vectorized explorer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LayerPolicy {
    /// Vectorize every layer.
    All,
    /// Vectorize no layer (degenerates to the scalar parallel algorithm).
    None,
    /// Vectorize the first `k` layers *with non-trivial input* — the
    /// paper's literal "only for the first two layers" with `k = 2`
    /// (layer 0, the root's single vertex, never counts as non-trivial).
    FirstK(usize),
    /// Vectorize any layer whose expected edge volume is at least `0.01 ×
    /// usize` …no — see [`LayerPolicy::heavy`] constructor: layers whose
    /// mean frontier degree reaches the threshold (full 16-lane chunks are
    /// likely). This is the adaptive variant the evaluation uses by
    /// default: it picks exactly the explosion layers of Table 1.
    MinMeanDegree(usize),
}

impl Default for LayerPolicy {
    /// The paper's configuration: SIMD for the first two non-trivial
    /// layers. (§4.1)
    fn default() -> Self {
        LayerPolicy::FirstK(2)
    }
}

/// How a vectorized layer feeds the VPU — the SELL-engine extension of
/// §4.1's layer choice. Per-vertex chunking (Listing 1) streams one
/// vertex's adjacency through aligned loads; lane packing (SELL-16-σ)
/// gathers one neighbor from 16 *distinct* frontier vertices per issue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkingMode {
    /// Listing-1 chunking: ≤16 neighbors of a single vertex per issue.
    PerVertex,
    /// SELL-16-σ packing: 16 different frontier vertices per issue.
    LanePacked,
}

impl LayerPolicy {
    /// Adaptive policy: vectorize when the frontier's mean degree fills at
    /// least one 16-lane chunk per vertex.
    pub fn heavy() -> Self {
        LayerPolicy::MinMeanDegree(16)
    }

    /// Mean frontier degree at which per-vertex chunking overtakes lane
    /// packing. Above it, adjacency lists span ≥ 2 full vectors and the
    /// Listing-1 chunking already runs near-full lanes, while the skewed
    /// top of the degree distribution makes packed groups ragged (group
    /// occupancy is Σdeg/max-deg). Below it — the low-degree majority of
    /// an RMAT frontier — per-vertex chunks are mostly dead lanes and
    /// packing wins decisively (measured ~15 vs ~5 lanes/issue on RMAT
    /// tail layers).
    pub const SELL_PER_VERTEX_DEGREE: usize = 32;

    /// The SELL engine's per-layer chunking choice (an associated function:
    /// it depends only on the frontier's shape, not on which layer-selection
    /// variant is active). Hub-dominated layers (mean degree ≥
    /// [`Self::SELL_PER_VERTEX_DEGREE`]) keep Listing-1 per-vertex
    /// chunking; everything else — the low-degree majority of an RMAT
    /// traversal — is lane-packed to restore occupancy.
    pub fn sell_chunking(input_vertices: usize, input_edges: usize) -> ChunkingMode {
        if input_vertices > 0 && input_edges / input_vertices >= Self::SELL_PER_VERTEX_DEGREE {
            ChunkingMode::PerVertex
        } else {
            ChunkingMode::LanePacked
        }
    }

    /// Decide for a layer. `nontrivial_layers_so_far` counts previous
    /// layers whose input held more than one vertex; `input_vertices` and
    /// `input_edges` describe the layer about to be processed.
    pub fn vectorize(
        &self,
        nontrivial_layers_so_far: usize,
        input_vertices: usize,
        input_edges: usize,
    ) -> bool {
        match *self {
            LayerPolicy::All => true,
            LayerPolicy::None => false,
            LayerPolicy::FirstK(k) => input_vertices > 1 && nontrivial_layers_so_far < k,
            LayerPolicy::MinMeanDegree(d) => {
                input_vertices > 0 && input_edges / input_vertices >= d
            }
        }
    }
}

/// Mean-degree bands the feedback buckets layers into (log₂ bands:
/// 1, 2–3, 4–7, 8–15, 16–31, ≥32). A layer's chunking behaviour is a
/// function of its frontier's degree shape, so occupancy is only
/// comparable within a band.
pub const OCC_BANDS: usize = 6;

/// Issues a (band, mode) cell must accumulate before its measured
/// occupancy is trusted over the static threshold.
const MIN_FEEDBACK_ISSUES: u64 = 64;

#[derive(Default)]
struct ModeOcc {
    issues: AtomicU64,
    lanes: AtomicU64,
}

/// Cross-root occupancy feedback for the SELL engine's per-layer chunking
/// choice (a ROADMAP item: learn the choice from the measured
/// `lanes_active / explore_issues` of previous roots in a 64-root run).
///
/// Thread-safe by construction (atomic cells): the coordinator's workers
/// share one instance through [`crate::bfs::GraphArtifacts`] and record
/// concurrently. The protocol per layer is [`PolicyFeedback::choose`] →
/// explore → [`PolicyFeedback::record_layer`]; engines call
/// [`PolicyFeedback::record_root`] when a traversal completes.
///
/// Decision rule: once both modes have `MIN_FEEDBACK_ISSUES` measured
/// issues in the layer's degree band, pick the higher-occupancy mode.
/// While only lane packing is measured, probe per-vertex chunking **only
/// where it can plausibly win**: when its optimistic closed-form bound
/// ([`PolicyFeedback::per_vertex_occupancy_bound`]) exceeds the measured
/// packed occupancy. A blind probe would burn whole low-degree layers at
/// 1–3 lanes/issue just to confirm what the bound already rules out
/// (counter-simulation showed it costing ~2 lanes/issue of batch
/// occupancy); the guided probe is self-limiting — it supplies the
/// missing measurements, after which the argmax above governs. No probe
/// fires before the first root completes, so single-root runs behave
/// exactly like the static [`LayerPolicy::sell_chunking`] threshold.
#[derive(Default)]
pub struct PolicyFeedback {
    bands: [[ModeOcc; 2]; OCC_BANDS],
    roots_done: AtomicUsize,
}

/// log₂ band of a layer's mean frontier degree.
fn band_of(mean_degree: usize) -> usize {
    (usize::BITS - 1 - mean_degree.max(1).leading_zeros()).min(OCC_BANDS as u32 - 1) as usize
}

fn mode_index(mode: ChunkingMode) -> usize {
    match mode {
        ChunkingMode::LanePacked => 0,
        ChunkingMode::PerVertex => 1,
    }
}

impl PolicyFeedback {
    /// Pick the chunking mode for a layer of `input_vertices` frontier
    /// vertices carrying `input_edges` adjacency entries.
    pub fn choose(&self, input_vertices: usize, input_edges: usize) -> ChunkingMode {
        let fallback = LayerPolicy::sell_chunking(input_vertices, input_edges);
        if input_vertices == 0 {
            return fallback;
        }
        let mean_degree = input_edges / input_vertices;
        let b = band_of(mean_degree);
        let packed = self.occupancy_in_band(b, ChunkingMode::LanePacked);
        let per_vertex = self.occupancy_in_band(b, ChunkingMode::PerVertex);
        match (packed, per_vertex) {
            (Some(p), Some(v)) => {
                if v > p {
                    ChunkingMode::PerVertex
                } else {
                    ChunkingMode::LanePacked
                }
            }
            // guided probe: measure per-vertex chunking only in bands where
            // even its optimistic bound beats what packing measured
            (Some(p), None)
                if self.roots_done() > 0
                    && Self::per_vertex_occupancy_bound(mean_degree) > p =>
            {
                ChunkingMode::PerVertex
            }
            _ => fallback,
        }
    }

    /// Optimistic per-vertex occupancy bound for a layer of mean frontier
    /// degree `d`: if every vertex had exactly the mean degree, Listing-1
    /// chunking would issue `ceil(d / 16)` chunks per vertex holding
    /// `d / ceil(d / 16)` lanes each. Degree skew only lowers the real
    /// value (more ragged remainders), so the bound is a safe probe
    /// filter: where it cannot beat measured packing, per-vertex chunking
    /// is not worth measuring.
    pub fn per_vertex_occupancy_bound(mean_degree: usize) -> f64 {
        if mean_degree == 0 {
            return 0.0;
        }
        mean_degree as f64 / mean_degree.div_ceil(LANES) as f64
    }

    /// Record the exploration counters of one finished layer.
    pub fn record_layer(
        &self,
        mode: ChunkingMode,
        input_vertices: usize,
        input_edges: usize,
        vpu: &VpuCounters,
    ) {
        if input_vertices == 0 || vpu.explore_issues == 0 {
            return;
        }
        let cell = &self.bands[band_of(input_edges / input_vertices)][mode_index(mode)];
        cell.issues.fetch_add(vpu.explore_issues, Ordering::Relaxed);
        cell.lanes.fetch_add(vpu.lanes_active, Ordering::Relaxed);
    }

    /// Mark one root's traversal complete (enables probing).
    pub fn record_root(&self) {
        self.roots_done.fetch_add(1, Ordering::Relaxed);
    }

    /// Roots recorded so far.
    pub fn roots_done(&self) -> usize {
        self.roots_done.load(Ordering::Relaxed)
    }

    /// Measured mean occupancy of `mode` in degree band `band`, or `None`
    /// below the confidence floor.
    pub fn occupancy_in_band(&self, band: usize, mode: ChunkingMode) -> Option<f64> {
        let cell = &self.bands[band][mode_index(mode)];
        let issues = cell.issues.load(Ordering::Relaxed);
        if issues < MIN_FEEDBACK_ISSUES {
            return None;
        }
        Some(cell.lanes.load(Ordering::Relaxed) as f64 / issues as f64)
    }

    /// Overall measured occupancy of `mode` across all bands (`None` until
    /// anything was recorded) — the reporting/ablation view.
    pub fn mean_lanes_active(&self, mode: ChunkingMode) -> Option<f64> {
        let m = mode_index(mode);
        let mut issues = 0u64;
        let mut lanes = 0u64;
        for band in &self.bands {
            issues += band[m].issues.load(Ordering::Relaxed);
            lanes += band[m].lanes.load(Ordering::Relaxed);
        }
        if issues == 0 {
            None
        } else {
            Some(lanes as f64 / issues as f64)
        }
    }
}

impl std::fmt::Debug for PolicyFeedback {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PolicyFeedback")
            .field("roots_done", &self.roots_done())
            .field("packed_occ", &self.mean_lanes_active(ChunkingMode::LanePacked))
            .field("per_vertex_occ", &self.mean_lanes_active(ChunkingMode::PerVertex))
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_and_none() {
        assert!(LayerPolicy::All.vectorize(0, 1, 0));
        assert!(!LayerPolicy::None.vectorize(5, 1000, 100_000));
    }

    #[test]
    fn first_k_skips_trivial_root_layer() {
        let p = LayerPolicy::FirstK(2);
        // layer 0: single root vertex — not vectorized, doesn't consume k
        assert!(!p.vectorize(0, 1, 12));
        // first non-trivial layer
        assert!(p.vectorize(0, 12, 21_892));
        // second non-trivial layer
        assert!(p.vectorize(1, 18_122, 13_547_462));
        // third — back to scalar
        assert!(!p.vectorize(2, 540_575, 17_626_910));
    }

    #[test]
    fn min_mean_degree_targets_explosion_layers() {
        let p = LayerPolicy::heavy();
        // Table 1 rows: (input, edges)
        assert!(!p.vectorize(0, 1, 12)); // layer 0: degree 12 < 16
        assert!(p.vectorize(0, 12, 21_892)); // layer 1: ~1824
        assert!(p.vectorize(1, 18_122, 13_547_462)); // layer 2: ~747
        assert!(p.vectorize(2, 540_575, 17_626_910)); // layer 3: ~32
        assert!(!p.vectorize(3, 100_874, 150_698)); // layer 4: ~1.5
        assert!(!p.vectorize(4, 486, 490)); // layer 5: ~1
    }

    #[test]
    fn zero_inputs_never_vectorize_adaptive() {
        assert!(!LayerPolicy::heavy().vectorize(0, 0, 0));
    }

    #[test]
    fn sell_chunking_splits_on_mean_degree() {
        // Table 1 rows: the explosion layers (means ~1824, ~747, ~32.6)
        // stay per-vertex; the low-degree tail layers are lane-packed.
        assert_eq!(LayerPolicy::sell_chunking(12, 21_892), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(18_122, 13_547_462), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(540_575, 17_626_910), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(100_874, 150_698), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(486, 490), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(0, 0), ChunkingMode::LanePacked);
    }

    #[test]
    fn degree_bands() {
        assert_eq!(band_of(0), 0);
        assert_eq!(band_of(1), 0);
        assert_eq!(band_of(2), 1);
        assert_eq!(band_of(3), 1);
        assert_eq!(band_of(7), 2);
        assert_eq!(band_of(15), 3);
        assert_eq!(band_of(31), 4);
        assert_eq!(band_of(32), 5);
        assert_eq!(band_of(10_000), 5);
    }

    fn counters(issues: u64, lanes: u64) -> VpuCounters {
        VpuCounters { explore_issues: issues, lanes_active: lanes, ..Default::default() }
    }

    #[test]
    fn empty_feedback_falls_back_to_static_threshold() {
        let f = PolicyFeedback::default();
        assert_eq!(f.choose(100, 400), LayerPolicy::sell_chunking(100, 400));
        assert_eq!(f.choose(10, 1000), LayerPolicy::sell_chunking(10, 1000));
        assert_eq!(f.choose(0, 0), ChunkingMode::LanePacked);
    }

    #[test]
    fn measured_comparison_overrides_static_threshold() {
        // band of mean degree 4: static says LanePacked (4 < 32), but the
        // measured data says per-vertex held more lanes there
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 600));
        f.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(100, 900));
        assert_eq!(f.choose(100, 400), ChunkingMode::PerVertex);
        // ...and the reverse keeps lane packing
        let g = PolicyFeedback::default();
        g.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1500));
        g.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(100, 900));
        assert_eq!(g.choose(100, 400), ChunkingMode::LanePacked);
    }

    #[test]
    fn per_vertex_bound_matches_chunk_arithmetic() {
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(0), 0.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(4), 4.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(16), 16.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(17), 8.5);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(48), 16.0);
        assert!((PolicyFeedback::per_vertex_occupancy_bound(40) - 40.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn guided_probe_waits_for_first_root() {
        // mean degree 16: the per-vertex bound (16.0) beats the measured
        // packed occupancy (12.0), so the band is worth probing — but not
        // before a full root has landed
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 1600, &counters(100, 1200));
        assert_eq!(f.choose(100, 1600), ChunkingMode::LanePacked);
        f.record_root();
        assert_eq!(f.choose(100, 1600), ChunkingMode::PerVertex);
        // the probe's own measurements settle the comparison
        f.record_layer(ChunkingMode::PerVertex, 100, 1600, &counters(100, 900));
        assert_eq!(f.choose(100, 1600), ChunkingMode::LanePacked);
    }

    #[test]
    fn guided_probe_skips_hopeless_bands() {
        // mean degree 4: per-vertex can hold at most 4 lanes/issue, the
        // measured packing holds 10 — a blind probe would burn the layer,
        // the guided probe declines
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1000));
        f.record_root();
        assert_eq!(f.choose(100, 400), ChunkingMode::LanePacked);
    }

    #[test]
    fn low_sample_counts_are_not_trusted() {
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(8, 128));
        assert_eq!(f.occupancy_in_band(band_of(4), ChunkingMode::PerVertex), None);
        // under the floor the static threshold still decides
        assert_eq!(f.choose(100, 400), ChunkingMode::LanePacked);
        assert!(f.mean_lanes_active(ChunkingMode::PerVertex).is_some());
    }
}
