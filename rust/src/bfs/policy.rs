//! §4.1 — *Which layers are vectorized?*
//!
//! The paper observes that RMAT graphs are small-world: per-layer input
//! vertices grow to a mid-traversal peak and collapse after it (Table 1),
//! and most of the edge volume is concentrated in a couple of layers. The
//! vector unit only pays off where adjacency lists are long enough to fill
//! 16-lane chunks, so the paper runs the SIMD explorer "only for the first
//! [heavy] layers and the parallel top-down ... for the rest".
//!
//! The policy is a parameter here so the ablation bench can compare the
//! paper's choice against alternatives.

/// Decides, per layer, whether to run the vectorized explorer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LayerPolicy {
    /// Vectorize every layer.
    All,
    /// Vectorize no layer (degenerates to the scalar parallel algorithm).
    None,
    /// Vectorize the first `k` layers *with non-trivial input* — the
    /// paper's literal "only for the first two layers" with `k = 2`
    /// (layer 0, the root's single vertex, never counts as non-trivial).
    FirstK(usize),
    /// Vectorize any layer whose expected edge volume is at least `0.01 ×
    /// usize` …no — see [`LayerPolicy::heavy`] constructor: layers whose
    /// mean frontier degree reaches the threshold (full 16-lane chunks are
    /// likely). This is the adaptive variant the evaluation uses by
    /// default: it picks exactly the explosion layers of Table 1.
    MinMeanDegree(usize),
}

impl Default for LayerPolicy {
    /// The paper's configuration: SIMD for the first two non-trivial
    /// layers. (§4.1)
    fn default() -> Self {
        LayerPolicy::FirstK(2)
    }
}

/// How a vectorized layer feeds the VPU — the SELL-engine extension of
/// §4.1's layer choice. Per-vertex chunking (Listing 1) streams one
/// vertex's adjacency through aligned loads; lane packing (SELL-16-σ)
/// gathers one neighbor from 16 *distinct* frontier vertices per issue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkingMode {
    /// Listing-1 chunking: ≤16 neighbors of a single vertex per issue.
    PerVertex,
    /// SELL-16-σ packing: 16 different frontier vertices per issue.
    LanePacked,
}

impl LayerPolicy {
    /// Adaptive policy: vectorize when the frontier's mean degree fills at
    /// least one 16-lane chunk per vertex.
    pub fn heavy() -> Self {
        LayerPolicy::MinMeanDegree(16)
    }

    /// Mean frontier degree at which per-vertex chunking overtakes lane
    /// packing. Above it, adjacency lists span ≥ 2 full vectors and the
    /// Listing-1 chunking already runs near-full lanes, while the skewed
    /// top of the degree distribution makes packed groups ragged (group
    /// occupancy is Σdeg/max-deg). Below it — the low-degree majority of
    /// an RMAT frontier — per-vertex chunks are mostly dead lanes and
    /// packing wins decisively (measured ~15 vs ~5 lanes/issue on RMAT
    /// tail layers).
    pub const SELL_PER_VERTEX_DEGREE: usize = 32;

    /// The SELL engine's per-layer chunking choice (an associated function:
    /// it depends only on the frontier's shape, not on which layer-selection
    /// variant is active). Hub-dominated layers (mean degree ≥
    /// [`Self::SELL_PER_VERTEX_DEGREE`]) keep Listing-1 per-vertex
    /// chunking; everything else — the low-degree majority of an RMAT
    /// traversal — is lane-packed to restore occupancy.
    pub fn sell_chunking(input_vertices: usize, input_edges: usize) -> ChunkingMode {
        if input_vertices > 0 && input_edges / input_vertices >= Self::SELL_PER_VERTEX_DEGREE {
            ChunkingMode::PerVertex
        } else {
            ChunkingMode::LanePacked
        }
    }

    /// Decide for a layer. `nontrivial_layers_so_far` counts previous
    /// layers whose input held more than one vertex; `input_vertices` and
    /// `input_edges` describe the layer about to be processed.
    pub fn vectorize(
        &self,
        nontrivial_layers_so_far: usize,
        input_vertices: usize,
        input_edges: usize,
    ) -> bool {
        match *self {
            LayerPolicy::All => true,
            LayerPolicy::None => false,
            LayerPolicy::FirstK(k) => input_vertices > 1 && nontrivial_layers_so_far < k,
            LayerPolicy::MinMeanDegree(d) => {
                input_vertices > 0 && input_edges / input_vertices >= d
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_and_none() {
        assert!(LayerPolicy::All.vectorize(0, 1, 0));
        assert!(!LayerPolicy::None.vectorize(5, 1000, 100_000));
    }

    #[test]
    fn first_k_skips_trivial_root_layer() {
        let p = LayerPolicy::FirstK(2);
        // layer 0: single root vertex — not vectorized, doesn't consume k
        assert!(!p.vectorize(0, 1, 12));
        // first non-trivial layer
        assert!(p.vectorize(0, 12, 21_892));
        // second non-trivial layer
        assert!(p.vectorize(1, 18_122, 13_547_462));
        // third — back to scalar
        assert!(!p.vectorize(2, 540_575, 17_626_910));
    }

    #[test]
    fn min_mean_degree_targets_explosion_layers() {
        let p = LayerPolicy::heavy();
        // Table 1 rows: (input, edges)
        assert!(!p.vectorize(0, 1, 12)); // layer 0: degree 12 < 16
        assert!(p.vectorize(0, 12, 21_892)); // layer 1: ~1824
        assert!(p.vectorize(1, 18_122, 13_547_462)); // layer 2: ~747
        assert!(p.vectorize(2, 540_575, 17_626_910)); // layer 3: ~32
        assert!(!p.vectorize(3, 100_874, 150_698)); // layer 4: ~1.5
        assert!(!p.vectorize(4, 486, 490)); // layer 5: ~1
    }

    #[test]
    fn zero_inputs_never_vectorize_adaptive() {
        assert!(!LayerPolicy::heavy().vectorize(0, 0, 0));
    }

    #[test]
    fn sell_chunking_splits_on_mean_degree() {
        // Table 1 rows: the explosion layers (means ~1824, ~747, ~32.6)
        // stay per-vertex; the low-degree tail layers are lane-packed.
        assert_eq!(LayerPolicy::sell_chunking(12, 21_892), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(18_122, 13_547_462), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(540_575, 17_626_910), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(100_874, 150_698), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(486, 490), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(0, 0), ChunkingMode::LanePacked);
    }
}
