//! §4.1 — *Which layers are vectorized?*
//!
//! The paper observes that RMAT graphs are small-world: per-layer input
//! vertices grow to a mid-traversal peak and collapse after it (Table 1),
//! and most of the edge volume is concentrated in a couple of layers. The
//! vector unit only pays off where adjacency lists are long enough to fill
//! 16-lane chunks, so the paper runs the SIMD explorer "only for the first
//! [heavy] layers and the parallel top-down ... for the rest".
//!
//! The policy is a parameter here so the ablation bench can compare the
//! paper's choice against alternatives.
//!
//! The SELL engine's per-layer *chunking* choice additionally learns from
//! measurement: [`PolicyFeedback`] accumulates the occupancy
//! (`lanes_active / explore_issues`) each chunking mode achieved on
//! earlier roots of the same job, bucketed by frontier mean degree, and
//! later roots pick whichever mode measured better — replacing the fixed
//! [`LayerPolicy::SELL_PER_VERTEX_DEGREE`] threshold once real data
//! exists.
//!
//! The hybrid's **bottom-up** phase has the same three-way choice
//! ([`BottomUpMode`]): a scalar first-hit scan, 16-wide chunks of a single
//! unvisited vertex's adjacency, or the SELL-packed scan that gathers the
//! k-th neighbor of 16 *distinct* unvisited vertices per issue
//! ([`crate::bfs::sell_bottom_up`]). The feedback channel keeps a separate
//! (band, mode) occupancy table for it, bucketed by the *unvisited* pool's
//! mean degree, and the measured occupancy also feeds **both** Beamer
//! switches: [`PolicyFeedback::switch_to_bottom_up`] (α) and its
//! symmetric counterpart [`PolicyFeedback::switch_to_top_down`] (β)
//! compare predicted VPU *issues* (edges ÷ measured lanes-per-issue)
//! instead of raw edge counts / frontier population once a root has
//! completed and both directions have been measured.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::vectorized::DEFAULT_PREFETCH_DIST;
use crate::phi::config::KncParams;
use crate::phi::cost::{price_layer, CostParams};
use crate::phi::trace::LayerWork;
use crate::simd::vec512::LANES;
use crate::simd::VpuCounters;

/// Decides, per layer, whether to run the vectorized explorer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LayerPolicy {
    /// Vectorize every layer.
    All,
    /// Vectorize no layer (degenerates to the scalar parallel algorithm).
    None,
    /// Vectorize the first `k` layers *with non-trivial input* — the
    /// paper's literal "only for the first two layers" with `k = 2`
    /// (layer 0, the root's single vertex, never counts as non-trivial).
    FirstK(usize),
    /// Vectorize any layer whose expected edge volume is at least `0.01 ×
    /// usize` …no — see [`LayerPolicy::heavy`] constructor: layers whose
    /// mean frontier degree reaches the threshold (full 16-lane chunks are
    /// likely). This is the adaptive variant the evaluation uses by
    /// default: it picks exactly the explosion layers of Table 1.
    MinMeanDegree(usize),
}

impl Default for LayerPolicy {
    /// The paper's configuration: SIMD for the first two non-trivial
    /// layers. (§4.1)
    fn default() -> Self {
        LayerPolicy::FirstK(2)
    }
}

/// How a vectorized layer feeds the VPU — the SELL-engine extension of
/// §4.1's layer choice. Per-vertex chunking (Listing 1) streams one
/// vertex's adjacency through aligned loads; lane packing (SELL-16-σ)
/// gathers one neighbor from 16 *distinct* frontier vertices per issue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkingMode {
    /// Listing-1 chunking: ≤16 neighbors of a single vertex per issue.
    PerVertex,
    /// SELL-16-σ packing: 16 different frontier vertices per issue.
    LanePacked,
}

/// How a bottom-up layer scans the unvisited pool — the hybrid analogue of
/// [`ChunkingMode`]. Scalar walks one adjacency entry at a time;
/// per-vertex chunks push ≤16 neighbors of a *single* unvisited vertex
/// through the Listing-1 filter per issue; SELL packing gathers the k-th
/// neighbor of 16 *distinct* unvisited vertices per issue, refilling
/// retired lanes from the pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BottomUpMode {
    /// Scalar first-hit scan (no VPU) — worthwhile only when the unvisited
    /// pool is too small to keep lanes fed.
    Scalar,
    /// ≤16-neighbor chunks of one unvisited vertex per issue.
    PerVertexChunks,
    /// SELL-16-σ lane packing over the unvisited pool with dynamic refill.
    SellPacked,
}

impl LayerPolicy {
    /// Adaptive policy: vectorize when the frontier's mean degree fills at
    /// least one 16-lane chunk per vertex.
    pub fn heavy() -> Self {
        LayerPolicy::MinMeanDegree(16)
    }

    /// Mean frontier degree at which per-vertex chunking overtakes lane
    /// packing. Above it, adjacency lists span ≥ 2 full vectors and the
    /// Listing-1 chunking already runs near-full lanes, while the skewed
    /// top of the degree distribution makes packed groups ragged (group
    /// occupancy is Σdeg/max-deg). Below it — the low-degree majority of
    /// an RMAT frontier — per-vertex chunks are mostly dead lanes and
    /// packing wins decisively (measured ~15 vs ~5 lanes/issue on RMAT
    /// tail layers).
    pub const SELL_PER_VERTEX_DEGREE: usize = 32;

    /// The SELL engine's per-layer chunking choice (an associated function:
    /// it depends only on the frontier's shape, not on which layer-selection
    /// variant is active). Hub-dominated layers (mean degree ≥
    /// [`Self::SELL_PER_VERTEX_DEGREE`]) keep Listing-1 per-vertex
    /// chunking; everything else — the low-degree majority of an RMAT
    /// traversal — is lane-packed to restore occupancy.
    pub fn sell_chunking(input_vertices: usize, input_edges: usize) -> ChunkingMode {
        if input_vertices > 0 && input_edges / input_vertices >= Self::SELL_PER_VERTEX_DEGREE {
            ChunkingMode::PerVertex
        } else {
            ChunkingMode::LanePacked
        }
    }

    /// Unvisited-pool size below which the bottom-up scan stays scalar:
    /// with fewer than two groups' worth of candidate lanes the packed
    /// explorer cannot amortize its gather setup, and per-vertex chunks
    /// degenerate the same way.
    pub const BOTTOM_UP_SCALAR_VERTICES: usize = 2 * LANES;

    /// Static chunking rule for a bottom-up layer over `unvisited_vertices`
    /// carrying `unvisited_edges` adjacency entries (the analogue of
    /// [`Self::sell_chunking`], used until [`PolicyFeedback`] has measured
    /// data). Tiny pools stay scalar; hub-dominated pools (mean degree ≥
    /// [`Self::SELL_PER_VERTEX_DEGREE`]) keep per-vertex chunks, whose
    /// contiguous loads already run near-full lanes; the low-degree
    /// majority — where a first-hit scan retires after a handful of
    /// entries and per-vertex chunks are mostly dead lanes — is
    /// SELL-packed.
    pub fn bottom_up_chunking(unvisited_vertices: usize, unvisited_edges: usize) -> BottomUpMode {
        if unvisited_vertices < Self::BOTTOM_UP_SCALAR_VERTICES {
            BottomUpMode::Scalar
        } else if unvisited_edges / unvisited_vertices >= Self::SELL_PER_VERTEX_DEGREE {
            BottomUpMode::PerVertexChunks
        } else {
            BottomUpMode::SellPacked
        }
    }

    /// Decide for a layer. `nontrivial_layers_so_far` counts previous
    /// layers whose input held more than one vertex; `input_vertices` and
    /// `input_edges` describe the layer about to be processed.
    pub fn vectorize(
        &self,
        nontrivial_layers_so_far: usize,
        input_vertices: usize,
        input_edges: usize,
    ) -> bool {
        match *self {
            LayerPolicy::All => true,
            LayerPolicy::None => false,
            LayerPolicy::FirstK(k) => input_vertices > 1 && nontrivial_layers_so_far < k,
            LayerPolicy::MinMeanDegree(d) => {
                input_vertices > 0 && input_edges / input_vertices >= d
            }
        }
    }
}

/// Mean-degree bands the feedback buckets layers into (log₂ bands:
/// 1, 2–3, 4–7, 8–15, 16–31, ≥32). A layer's chunking behaviour is a
/// function of its frontier's degree shape, so occupancy is only
/// comparable within a band.
pub const OCC_BANDS: usize = 6;

/// Issues a (band, mode) cell must accumulate before its measured
/// occupancy is trusted over the static threshold.
const MIN_FEEDBACK_ISSUES: u64 = 64;

#[derive(Default)]
struct ModeOcc {
    issues: AtomicU64,
    lanes: AtomicU64,
    /// Aligned full-vector loads (the cheap chunk class of the cost model).
    full_chunks: AtomicU64,
    /// Masked/peel/remainder loads (pay the masked-chunk penalty).
    masked_chunks: AtomicU64,
    /// Gathered lanes — the per-lane issue occupancy a packed mode pays
    /// that contiguous per-vertex loads do not.
    gather_lanes: AtomicU64,
    /// Scattered lanes.
    scatter_lanes: AtomicU64,
}

impl ModeOcc {
    /// Accumulate one layer's exploration counters.
    fn record(&self, vpu: &VpuCounters) {
        self.issues.fetch_add(vpu.explore_issues, Ordering::Relaxed);
        self.lanes.fetch_add(vpu.lanes_active, Ordering::Relaxed);
        self.full_chunks.fetch_add(vpu.vector_loads, Ordering::Relaxed);
        self.masked_chunks.fetch_add(vpu.masked_loads, Ordering::Relaxed);
        self.gather_lanes.fetch_add(vpu.gather_lanes, Ordering::Relaxed);
        self.scatter_lanes.fetch_add(vpu.scatter_lanes, Ordering::Relaxed);
    }

    /// Measured mean occupancy, `None` below the confidence floor — the
    /// single definition of the trust rule, shared by the top-down and
    /// bottom-up tables.
    fn occupancy(&self) -> Option<f64> {
        let issues = self.issues.load(Ordering::Relaxed);
        if issues < MIN_FEEDBACK_ISSUES {
            return None;
        }
        Some(self.lanes.load(Ordering::Relaxed) as f64 / issues as f64)
    }

    /// Predicted Phi cycles per active lane: the cell's accumulated
    /// counters, priced by [`price_layer`] with the default KNC machine.
    /// This is what a mode's occupancy actually *buys* — a packed mode's
    /// extra lanes are worthless if each issue drags gather lanes and
    /// masked-chunk penalties behind it, which raw occupancy cannot see.
    /// Footprint arguments are zero: the model's cache-fit stalls depend
    /// on the graph, not the mode, so they would cancel in the comparison
    /// anyway. `None` below the same confidence floor as
    /// [`ModeOcc::occupancy`], or with no active lanes to normalize by.
    fn predicted_cycles_per_lane(&self) -> Option<f64> {
        let issues = self.issues.load(Ordering::Relaxed);
        if issues < MIN_FEEDBACK_ISSUES {
            return None;
        }
        let lanes = self.lanes.load(Ordering::Relaxed);
        if lanes == 0 {
            return None;
        }
        let w = LayerWork {
            vectorized: true,
            explore_issues: issues,
            lanes_active: lanes,
            full_chunks: self.full_chunks.load(Ordering::Relaxed),
            masked_chunks: self.masked_chunks.load(Ordering::Relaxed),
            gather_lanes: self.gather_lanes.load(Ordering::Relaxed),
            scatter_lanes: self.scatter_lanes.load(Ordering::Relaxed),
            ..Default::default()
        };
        let c = price_layer(&KncParams::default(), &CostParams::default(), &w, 0, 0);
        Some((c.issue_cycles + c.stall_cycles) / lanes as f64)
    }
}

/// Mean occupancy of mode-column `m` pooled across every band of `table`
/// (`None` until anything was recorded) — the reporting/ablation view.
fn table_mean(table: &[[ModeOcc; 2]; OCC_BANDS], m: usize) -> Option<f64> {
    let mut issues = 0u64;
    let mut lanes = 0u64;
    for band in table {
        issues += band[m].issues.load(Ordering::Relaxed);
        lanes += band[m].lanes.load(Ordering::Relaxed);
    }
    if issues == 0 {
        None
    } else {
        Some(lanes as f64 / issues as f64)
    }
}

/// Cross-root occupancy feedback for the SELL engine's per-layer chunking
/// choice (a ROADMAP item: learn the choice from the measured
/// `lanes_active / explore_issues` of previous roots in a 64-root run).
///
/// Thread-safe by construction (atomic cells): the coordinator's workers
/// share one instance through [`crate::bfs::GraphArtifacts`] and record
/// concurrently. The protocol per layer is [`PolicyFeedback::choose`] →
/// explore → [`PolicyFeedback::record_layer`]; engines call
/// [`PolicyFeedback::record_root`] when a traversal completes.
///
/// Decision rule: once both modes have `MIN_FEEDBACK_ISSUES` measured
/// issues in the layer's degree band, pick the higher-occupancy mode.
/// While only lane packing is measured, probe per-vertex chunking **only
/// where it can plausibly win**: when its optimistic closed-form bound
/// ([`PolicyFeedback::per_vertex_occupancy_bound`]) exceeds the measured
/// packed occupancy. A blind probe would burn whole low-degree layers at
/// 1–3 lanes/issue just to confirm what the bound already rules out
/// (counter-simulation showed it costing ~2 lanes/issue of batch
/// occupancy); the guided probe is self-limiting — it supplies the
/// missing measurements, after which the argmax above governs. No probe
/// fires before the first root completes, so single-root runs behave
/// exactly like the static [`LayerPolicy::sell_chunking`] threshold.
#[derive(Default)]
pub struct PolicyFeedback {
    bands: [[ModeOcc; 2]; OCC_BANDS],
    /// Bottom-up occupancy, bucketed by the *unvisited pool's* mean degree
    /// (the set a bottom-up layer actually scans). Index 0 = SellPacked,
    /// 1 = PerVertexChunks; the scalar mode issues nothing measurable.
    bu_bands: [[ModeOcc; 2]; OCC_BANDS],
    /// Per-candidate prefetch-distance samples of the `--prefetch-dist
    /// auto` warm-up sweep, indexed like [`PREFETCH_CANDIDATES`]: total
    /// wall ns and total edges scanned by the roots that ran at that
    /// distance. ns/edge is the figure of merit — roots differ in volume,
    /// so raw wall times are not comparable.
    prefetch: [PrefetchCell; PREFETCH_CANDIDATES.len()],
    roots_done: AtomicUsize,
}

/// Prefetch distances (SELL rows of lookahead) the auto-tune sweep
/// samples, one root each, before settling on the best measured ns/edge.
pub const PREFETCH_CANDIDATES: [usize; 4] = [1, 2, 4, 8];

#[derive(Default)]
struct PrefetchCell {
    wall_ns: AtomicU64,
    edges: AtomicU64,
}

/// log₂ band of a layer's mean frontier degree.
fn band_of(mean_degree: usize) -> usize {
    (usize::BITS - 1 - mean_degree.max(1).leading_zeros()).min(OCC_BANDS as u32 - 1) as usize
}

fn mode_index(mode: ChunkingMode) -> usize {
    match mode {
        ChunkingMode::LanePacked => 0,
        ChunkingMode::PerVertex => 1,
    }
}

/// Cell index of a vectorized bottom-up mode (`None` for the scalar scan,
/// which records no VPU occupancy).
fn bu_mode_index(mode: BottomUpMode) -> Option<usize> {
    match mode {
        BottomUpMode::SellPacked => Some(0),
        BottomUpMode::PerVertexChunks => Some(1),
        BottomUpMode::Scalar => None,
    }
}

impl PolicyFeedback {
    /// Pick the chunking mode for a layer of `input_vertices` frontier
    /// vertices carrying `input_edges` adjacency entries. `can_measure`
    /// says whether this layer's counters will actually be recorded (the
    /// counted backend) — an uncounted (hw) backend must not burn layers
    /// probing a mode whose measurement it can never supply, so the
    /// guided probe only fires when the probe can resolve itself.
    pub fn choose(
        &self,
        input_vertices: usize,
        input_edges: usize,
        can_measure: bool,
    ) -> ChunkingMode {
        let fallback = LayerPolicy::sell_chunking(input_vertices, input_edges);
        if input_vertices == 0 {
            return fallback;
        }
        let mean_degree = input_edges / input_vertices;
        let b = band_of(mean_degree);
        let packed = self.occupancy_in_band(b, ChunkingMode::LanePacked);
        let per_vertex = self.occupancy_in_band(b, ChunkingMode::PerVertex);
        match (packed, per_vertex) {
            (Some(p), Some(v)) => {
                // both modes measured: compare what the Phi cost model
                // says the counters *cost*, not what raw occupancy says
                // they filled — a packed issue drags gather-lane issue
                // cycles and masked-chunk penalties that a contiguous
                // per-vertex chunk does not, and the priced comparison
                // sees exactly that. With identical issue profiles the
                // prices cancel and the ordering degrades to occupancy.
                match (
                    self.predicted_cost_in_band(b, ChunkingMode::LanePacked),
                    self.predicted_cost_in_band(b, ChunkingMode::PerVertex),
                ) {
                    (Some(pc), Some(vc)) if pc != vc => {
                        if vc < pc {
                            ChunkingMode::PerVertex
                        } else {
                            ChunkingMode::LanePacked
                        }
                    }
                    _ if v > p => ChunkingMode::PerVertex,
                    _ => ChunkingMode::LanePacked,
                }
            }
            // guided probe: measure per-vertex chunking only in bands where
            // even its optimistic bound beats what packing measured
            (Some(p), None)
                if can_measure
                    && self.roots_done() > 0
                    && Self::per_vertex_occupancy_bound(mean_degree) > p =>
            {
                ChunkingMode::PerVertex
            }
            _ => fallback,
        }
    }

    /// Optimistic per-vertex occupancy bound for a layer of mean frontier
    /// degree `d`: if every vertex had exactly the mean degree, Listing-1
    /// chunking would issue `ceil(d / 16)` chunks per vertex holding
    /// `d / ceil(d / 16)` lanes each. Degree skew only lowers the real
    /// value (more ragged remainders), so the bound is a safe probe
    /// filter: where it cannot beat measured packing, per-vertex chunking
    /// is not worth measuring.
    pub fn per_vertex_occupancy_bound(mean_degree: usize) -> f64 {
        if mean_degree == 0 {
            return 0.0;
        }
        mean_degree as f64 / mean_degree.div_ceil(LANES) as f64
    }

    /// Record the exploration counters of one finished layer.
    pub fn record_layer(
        &self,
        mode: ChunkingMode,
        input_vertices: usize,
        input_edges: usize,
        vpu: &VpuCounters,
    ) {
        if input_vertices == 0 || vpu.explore_issues == 0 {
            return;
        }
        self.bands[band_of(input_edges / input_vertices)][mode_index(mode)].record(vpu);
    }

    /// Mark one root's traversal complete (enables probing).
    pub fn record_root(&self) {
        self.roots_done.fetch_add(1, Ordering::Relaxed);
    }

    /// Roots recorded so far.
    pub fn roots_done(&self) -> usize {
        self.roots_done.load(Ordering::Relaxed)
    }

    /// Measured mean occupancy of `mode` in degree band `band`, or `None`
    /// below the confidence floor.
    pub fn occupancy_in_band(&self, band: usize, mode: ChunkingMode) -> Option<f64> {
        self.bands[band][mode_index(mode)].occupancy()
    }

    /// Overall measured occupancy of `mode` across all bands (`None` until
    /// anything was recorded) — the reporting/ablation view.
    pub fn mean_lanes_active(&self, mode: ChunkingMode) -> Option<f64> {
        table_mean(&self.bands, mode_index(mode))
    }

    /// Predicted Phi cycles per active lane of `mode` in degree band
    /// `band` — the cost-model figure [`PolicyFeedback::choose`] compares
    /// (`None` below the confidence floor).
    pub fn predicted_cost_in_band(&self, band: usize, mode: ChunkingMode) -> Option<f64> {
        self.bands[band][mode_index(mode)].predicted_cycles_per_lane()
    }

    /// Bottom-up counterpart of [`Self::predicted_cost_in_band`] (`None`
    /// for the scalar mode, which records nothing).
    pub fn bu_predicted_cost_in_band(&self, band: usize, mode: BottomUpMode) -> Option<f64> {
        self.bu_bands[band][bu_mode_index(mode)?].predicted_cycles_per_lane()
    }

    // ---- prefetch distance: the `--prefetch-dist auto` warm-up sweep ----

    /// Plan the next run's prefetch distance. Returns `(distance,
    /// sampling)`: while any [`PREFETCH_CANDIDATES`] cell is still empty
    /// the first such candidate is returned with `sampling = true` (the
    /// caller must report the run back through
    /// [`Self::record_prefetch_sample`]); once every candidate has a
    /// sample the best measured distance is returned with `sampling =
    /// false` and the sweep is over.
    pub fn prefetch_plan(&self) -> (usize, bool) {
        for (i, cell) in self.prefetch.iter().enumerate() {
            if cell.edges.load(Ordering::Relaxed) == 0 {
                return (PREFETCH_CANDIDATES[i], true);
            }
        }
        (self.chosen_prefetch_dist(), false)
    }

    /// Report one sampling run back to the sweep: the whole-run wall time
    /// and edge volume measured at candidate distance `dist`. Samples at
    /// non-candidate distances or with no edge volume are discarded (a
    /// trivial root measures nothing).
    pub fn record_prefetch_sample(&self, dist: usize, wall_ns: u64, edges: usize) {
        if edges == 0 {
            return;
        }
        if let Some(i) = PREFETCH_CANDIDATES.iter().position(|&d| d == dist) {
            self.prefetch[i].wall_ns.fetch_add(wall_ns, Ordering::Relaxed);
            self.prefetch[i].edges.fetch_add(edges as u64, Ordering::Relaxed);
        }
    }

    /// The best prefetch distance measured so far — argmin of ns/edge over
    /// the sampled candidates, [`DEFAULT_PREFETCH_DIST`] while nothing has
    /// been sampled.
    pub fn chosen_prefetch_dist(&self) -> usize {
        let mut best: Option<(f64, usize)> = None;
        for (i, cell) in self.prefetch.iter().enumerate() {
            let edges = cell.edges.load(Ordering::Relaxed);
            if edges == 0 {
                continue;
            }
            let per_edge = cell.wall_ns.load(Ordering::Relaxed) as f64 / edges as f64;
            if best.map_or(true, |(b, _)| per_edge < b) {
                best = Some((per_edge, PREFETCH_CANDIDATES[i]));
            }
        }
        best.map_or(DEFAULT_PREFETCH_DIST, |(_, d)| d)
    }

    // ---- bottom-up: the hybrid's three-way scan choice ----

    /// Pick the bottom-up mode for a layer scanning `unvisited_vertices`
    /// carrying `unvisited_edges` adjacency entries. Same protocol as
    /// [`PolicyFeedback::choose`]: measured argmax once both vectorized
    /// modes have data in the pool's degree band, a bound-guided probe of
    /// per-vertex chunks after the first root, the static
    /// [`LayerPolicy::bottom_up_chunking`] threshold until then. Pools
    /// below [`LayerPolicy::BOTTOM_UP_SCALAR_VERTICES`] always stay scalar
    /// — occupancy cannot rescue a layer with too few lanes to fill.
    pub fn choose_bottom_up(
        &self,
        unvisited_vertices: usize,
        unvisited_edges: usize,
        can_measure: bool,
    ) -> BottomUpMode {
        let fallback = LayerPolicy::bottom_up_chunking(unvisited_vertices, unvisited_edges);
        if fallback == BottomUpMode::Scalar {
            return fallback;
        }
        let mean_degree = unvisited_edges / unvisited_vertices;
        let b = band_of(mean_degree);
        let packed = self.bu_occupancy_in_band(b, BottomUpMode::SellPacked);
        let chunks = self.bu_occupancy_in_band(b, BottomUpMode::PerVertexChunks);
        match (packed, chunks) {
            (Some(p), Some(c)) => {
                // same priced comparison as `choose`: predicted cycles per
                // active lane from the accumulated counters, occupancy as
                // the tie-break when the prices cancel
                match (
                    self.bu_predicted_cost_in_band(b, BottomUpMode::SellPacked),
                    self.bu_predicted_cost_in_band(b, BottomUpMode::PerVertexChunks),
                ) {
                    (Some(pc), Some(cc)) if pc != cc => {
                        if cc < pc {
                            BottomUpMode::PerVertexChunks
                        } else {
                            BottomUpMode::SellPacked
                        }
                    }
                    _ if c > p => BottomUpMode::PerVertexChunks,
                    _ => BottomUpMode::SellPacked,
                }
            }
            // the first-hit early exit only lowers per-vertex occupancy
            // further, so the top-down bound still filters probes safely
            // (same uncounted-backend guard as `choose`)
            (Some(p), None)
                if can_measure
                    && self.roots_done() > 0
                    && Self::per_vertex_occupancy_bound(mean_degree) > p =>
            {
                BottomUpMode::PerVertexChunks
            }
            _ => fallback,
        }
    }

    /// Record the exploration counters of one finished bottom-up layer
    /// (no-op for the scalar mode — nothing went through the VPU).
    pub fn record_bottom_up_layer(
        &self,
        mode: BottomUpMode,
        unvisited_vertices: usize,
        unvisited_edges: usize,
        vpu: &VpuCounters,
    ) {
        let Some(m) = bu_mode_index(mode) else { return };
        if unvisited_vertices == 0 || vpu.explore_issues == 0 {
            return;
        }
        self.bu_bands[band_of(unvisited_edges / unvisited_vertices)][m].record(vpu);
    }

    /// Measured mean bottom-up occupancy of `mode` in degree band `band`
    /// (`None` below the confidence floor, and always for the scalar mode).
    pub fn bu_occupancy_in_band(&self, band: usize, mode: BottomUpMode) -> Option<f64> {
        self.bu_bands[band][bu_mode_index(mode)?].occupancy()
    }

    /// Overall measured bottom-up occupancy of `mode` across all bands —
    /// the reporting/ablation view (`None` until recorded, and always for
    /// the scalar mode).
    pub fn mean_bottom_up_lanes_active(&self, mode: BottomUpMode) -> Option<f64> {
        table_mean(&self.bu_bands, bu_mode_index(mode)?)
    }

    /// Aggregate measured occupancy of one direction: all top-down chunking
    /// modes pooled (`bottom_up = false`) or all bottom-up modes pooled.
    fn direction_occupancy(&self, bottom_up: bool) -> Option<f64> {
        let table = if bottom_up { &self.bu_bands } else { &self.bands };
        let mut issues = 0u64;
        let mut lanes = 0u64;
        for band in table {
            for cell in band {
                issues += cell.issues.load(Ordering::Relaxed);
                lanes += cell.lanes.load(Ordering::Relaxed);
            }
        }
        if issues < MIN_FEEDBACK_ISSUES {
            None
        } else {
            Some(lanes as f64 / issues as f64)
        }
    }

    /// The Beamer α test, occupancy-adjusted. The classic heuristic
    /// compares raw edge volumes (`frontier_edges × α > unexplored`); on a
    /// VPU the real cost of a direction is its *issue* count, `edges ÷
    /// lanes-per-issue`. Once a full root has completed and both
    /// directions have measured occupancy, the comparison runs in issue
    /// units — a bottom-up scan that holds more lanes per issue than the
    /// top-down step is cheaper per edge, so the switch fires earlier (and
    /// vice versa). Like the guided probe, the adjustment waits for
    /// [`Self::record_root`]: mid-root measurements are partial (only the
    /// layers run so far), and holding a *fresh* channel's first root to
    /// the raw-edge test keeps its layer-by-layer switch points identical
    /// to classic Beamer — the property the cross-variant edges-scanned
    /// comparisons rely on. (A channel already carrying completed roots —
    /// e.g. reused through the coordinator's artifact cache — adjusts
    /// immediately; that is the point of reusing it.) With either side
    /// unmeasured the factors cancel back to the raw test, so single-root
    /// runs and non-SELL hybrids always behave exactly like classic
    /// Beamer.
    pub fn switch_to_bottom_up(
        &self,
        frontier_edges: usize,
        unexplored_edges: usize,
        alpha: usize,
    ) -> bool {
        if self.roots_done() == 0 {
            return frontier_edges * alpha > unexplored_edges;
        }
        match (self.direction_occupancy(false), self.direction_occupancy(true)) {
            (Some(td), Some(bu)) if td > 0.0 && bu > 0.0 => {
                (frontier_edges as f64 / td) * alpha as f64 > unexplored_edges as f64 / bu
            }
            _ => frontier_edges * alpha > unexplored_edges,
        }
    }

    /// The Beamer β test (bottom-up → top-down), made symmetric to the α
    /// side. Classic Beamer switches back when the frontier *population*
    /// shrinks below `|V| / β` — a vertex-count proxy for "top-down is
    /// cheap again". In issue units the comparison is direct: the next
    /// top-down layer costs about `frontier_edges ÷ td-lanes-per-issue`
    /// issues, staying bottom-up costs about `unexplored_edges ÷
    /// bu-lanes-per-issue`, so once a completed root has measured both
    /// directions the switch fires when the top-down cost times β is
    /// below the bottom-up cost. The same staging rules as the α side
    /// apply: a fresh channel's first root runs the classic population
    /// test (keeping its switch points identical to classic Beamer, which
    /// the cross-variant comparisons rely on), and with either direction
    /// unmeasured the test falls back to the classic form.
    pub fn switch_to_top_down(
        &self,
        frontier_vertices: usize,
        frontier_edges: usize,
        unexplored_edges: usize,
        num_vertices: usize,
        beta: usize,
    ) -> bool {
        if self.roots_done() == 0 {
            return frontier_vertices * beta < num_vertices;
        }
        match (self.direction_occupancy(false), self.direction_occupancy(true)) {
            (Some(td), Some(bu)) if td > 0.0 && bu > 0.0 => {
                (frontier_edges as f64 / td) * beta as f64 < unexplored_edges as f64 / bu
            }
            _ => frontier_vertices * beta < num_vertices,
        }
    }
}

impl std::fmt::Debug for PolicyFeedback {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PolicyFeedback")
            .field("roots_done", &self.roots_done())
            .field("packed_occ", &self.mean_lanes_active(ChunkingMode::LanePacked))
            .field("per_vertex_occ", &self.mean_lanes_active(ChunkingMode::PerVertex))
            .field("bu_packed_occ", &self.mean_bottom_up_lanes_active(BottomUpMode::SellPacked))
            .field(
                "bu_chunked_occ",
                &self.mean_bottom_up_lanes_active(BottomUpMode::PerVertexChunks),
            )
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_and_none() {
        assert!(LayerPolicy::All.vectorize(0, 1, 0));
        assert!(!LayerPolicy::None.vectorize(5, 1000, 100_000));
    }

    #[test]
    fn first_k_skips_trivial_root_layer() {
        let p = LayerPolicy::FirstK(2);
        // layer 0: single root vertex — not vectorized, doesn't consume k
        assert!(!p.vectorize(0, 1, 12));
        // first non-trivial layer
        assert!(p.vectorize(0, 12, 21_892));
        // second non-trivial layer
        assert!(p.vectorize(1, 18_122, 13_547_462));
        // third — back to scalar
        assert!(!p.vectorize(2, 540_575, 17_626_910));
    }

    #[test]
    fn min_mean_degree_targets_explosion_layers() {
        let p = LayerPolicy::heavy();
        // Table 1 rows: (input, edges)
        assert!(!p.vectorize(0, 1, 12)); // layer 0: degree 12 < 16
        assert!(p.vectorize(0, 12, 21_892)); // layer 1: ~1824
        assert!(p.vectorize(1, 18_122, 13_547_462)); // layer 2: ~747
        assert!(p.vectorize(2, 540_575, 17_626_910)); // layer 3: ~32
        assert!(!p.vectorize(3, 100_874, 150_698)); // layer 4: ~1.5
        assert!(!p.vectorize(4, 486, 490)); // layer 5: ~1
    }

    #[test]
    fn zero_inputs_never_vectorize_adaptive() {
        assert!(!LayerPolicy::heavy().vectorize(0, 0, 0));
    }

    #[test]
    fn sell_chunking_splits_on_mean_degree() {
        // Table 1 rows: the explosion layers (means ~1824, ~747, ~32.6)
        // stay per-vertex; the low-degree tail layers are lane-packed.
        assert_eq!(LayerPolicy::sell_chunking(12, 21_892), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(18_122, 13_547_462), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(540_575, 17_626_910), ChunkingMode::PerVertex);
        assert_eq!(LayerPolicy::sell_chunking(100_874, 150_698), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(486, 490), ChunkingMode::LanePacked);
        assert_eq!(LayerPolicy::sell_chunking(0, 0), ChunkingMode::LanePacked);
    }

    #[test]
    fn degree_bands() {
        assert_eq!(band_of(0), 0);
        assert_eq!(band_of(1), 0);
        assert_eq!(band_of(2), 1);
        assert_eq!(band_of(3), 1);
        assert_eq!(band_of(7), 2);
        assert_eq!(band_of(15), 3);
        assert_eq!(band_of(31), 4);
        assert_eq!(band_of(32), 5);
        assert_eq!(band_of(10_000), 5);
    }

    fn counters(issues: u64, lanes: u64) -> VpuCounters {
        VpuCounters { explore_issues: issues, lanes_active: lanes, ..Default::default() }
    }

    #[test]
    fn empty_feedback_falls_back_to_static_threshold() {
        let f = PolicyFeedback::default();
        assert_eq!(f.choose(100, 400, true), LayerPolicy::sell_chunking(100, 400));
        assert_eq!(f.choose(10, 1000, true), LayerPolicy::sell_chunking(10, 1000));
        assert_eq!(f.choose(0, 0, true), ChunkingMode::LanePacked);
    }

    #[test]
    fn measured_comparison_overrides_static_threshold() {
        // band of mean degree 4: static says LanePacked (4 < 32), but the
        // measured data says per-vertex held more lanes there
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 600));
        f.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(100, 900));
        assert_eq!(f.choose(100, 400, true), ChunkingMode::PerVertex);
        // ...and the reverse keeps lane packing
        let g = PolicyFeedback::default();
        g.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1500));
        g.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(100, 900));
        assert_eq!(g.choose(100, 400, true), ChunkingMode::LanePacked);
    }

    #[test]
    fn per_vertex_bound_matches_chunk_arithmetic() {
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(0), 0.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(4), 4.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(16), 16.0);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(17), 8.5);
        assert_eq!(PolicyFeedback::per_vertex_occupancy_bound(48), 16.0);
        assert!((PolicyFeedback::per_vertex_occupancy_bound(40) - 40.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn guided_probe_waits_for_first_root() {
        // mean degree 16: the per-vertex bound (16.0) beats the measured
        // packed occupancy (12.0), so the band is worth probing — but not
        // before a full root has landed
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 1600, &counters(100, 1200));
        assert_eq!(f.choose(100, 1600, true), ChunkingMode::LanePacked);
        f.record_root();
        assert_eq!(f.choose(100, 1600, true), ChunkingMode::PerVertex);
        // the probe's own measurements settle the comparison
        f.record_layer(ChunkingMode::PerVertex, 100, 1600, &counters(100, 900));
        assert_eq!(f.choose(100, 1600, true), ChunkingMode::LanePacked);
    }

    #[test]
    fn guided_probe_requires_a_measuring_backend() {
        // mean degree 16, bound 16.0 > measured 12.0, root complete: a
        // counted layer probes — an uncounted (hw) layer must not, since
        // its measurement would never land and the probe could never
        // resolve itself
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 1600, &counters(100, 1200));
        f.record_root();
        assert_eq!(f.choose(100, 1600, true), ChunkingMode::PerVertex);
        assert_eq!(f.choose(100, 1600, false), ChunkingMode::LanePacked);
        let g = PolicyFeedback::default();
        g.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 1600, &counters(100, 1200));
        g.record_root();
        assert_eq!(g.choose_bottom_up(100, 1600, true), BottomUpMode::PerVertexChunks);
        assert_eq!(g.choose_bottom_up(100, 1600, false), BottomUpMode::SellPacked);
    }

    #[test]
    fn guided_probe_skips_hopeless_bands() {
        // mean degree 4: per-vertex can hold at most 4 lanes/issue, the
        // measured packing holds 10 — a blind probe would burn the layer,
        // the guided probe declines
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1000));
        f.record_root();
        assert_eq!(f.choose(100, 400, true), ChunkingMode::LanePacked);
    }

    #[test]
    fn bottom_up_static_rule_three_ways() {
        // tiny pools stay scalar regardless of degree
        assert_eq!(LayerPolicy::bottom_up_chunking(8, 800), BottomUpMode::Scalar);
        assert_eq!(LayerPolicy::bottom_up_chunking(0, 0), BottomUpMode::Scalar);
        // hub-dominated pools keep per-vertex chunks
        assert_eq!(LayerPolicy::bottom_up_chunking(100, 3200), BottomUpMode::PerVertexChunks);
        // the low-degree majority is SELL-packed
        assert_eq!(LayerPolicy::bottom_up_chunking(1000, 4000), BottomUpMode::SellPacked);
        assert_eq!(LayerPolicy::bottom_up_chunking(100_874, 150_698), BottomUpMode::SellPacked);
    }

    #[test]
    fn bottom_up_measured_comparison_overrides_static() {
        // mean unvisited degree 4: static says SellPacked, but measurement
        // says per-vertex chunks held more lanes in that band
        let f = PolicyFeedback::default();
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 600));
        f.record_bottom_up_layer(BottomUpMode::PerVertexChunks, 100, 400, &counters(100, 900));
        assert_eq!(f.choose_bottom_up(100, 400, true), BottomUpMode::PerVertexChunks);
        // ...and the reverse keeps lane packing
        let g = PolicyFeedback::default();
        g.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 1500));
        g.record_bottom_up_layer(BottomUpMode::PerVertexChunks, 100, 400, &counters(100, 900));
        assert_eq!(g.choose_bottom_up(100, 400, true), BottomUpMode::SellPacked);
        // the scalar floor is not overridable by measurements
        assert_eq!(f.choose_bottom_up(8, 32, true), BottomUpMode::Scalar);
    }

    #[test]
    fn bottom_up_guided_probe_waits_for_first_root() {
        // mean degree 16: the per-vertex bound (16.0) beats measured
        // packing (12.0) — probe-worthy, but only after a root completes
        let f = PolicyFeedback::default();
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 1600, &counters(100, 1200));
        assert_eq!(f.choose_bottom_up(100, 1600, true), BottomUpMode::SellPacked);
        f.record_root();
        assert_eq!(f.choose_bottom_up(100, 1600, true), BottomUpMode::PerVertexChunks);
        // mean degree 4: the bound (4.0) cannot beat measured packing — no probe
        let g = PolicyFeedback::default();
        g.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 1000));
        g.record_root();
        assert_eq!(g.choose_bottom_up(100, 400, true), BottomUpMode::SellPacked);
    }

    #[test]
    fn scalar_mode_records_nothing() {
        let f = PolicyFeedback::default();
        f.record_bottom_up_layer(BottomUpMode::Scalar, 100, 400, &counters(100, 900));
        assert_eq!(f.mean_bottom_up_lanes_active(BottomUpMode::Scalar), None);
        assert_eq!(f.mean_bottom_up_lanes_active(BottomUpMode::SellPacked), None);
        assert_eq!(f.mean_bottom_up_lanes_active(BottomUpMode::PerVertexChunks), None);
    }

    #[test]
    fn switch_falls_back_to_raw_edges_unmeasured() {
        let f = PolicyFeedback::default();
        f.record_root();
        // classic Beamer: 100 × 14 > 1000 → switch; 10 × 14 < 1000 → stay
        assert!(f.switch_to_bottom_up(100, 1000, 14));
        assert!(!f.switch_to_bottom_up(10, 1000, 14));
        // one direction measured is not enough — still raw
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1200));
        assert!(!f.switch_to_bottom_up(10, 1000, 14));
    }

    #[test]
    fn switch_stays_raw_during_first_root() {
        // both directions measured mid-root, but no root has completed:
        // the first root must behave exactly like classic Beamer
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 400));
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 1600));
        // raw: 30 × 14 = 420 < 1000 → no switch, despite favorable BU occ
        assert!(!f.switch_to_bottom_up(30, 1000, 14));
        f.record_root();
        assert!(f.switch_to_bottom_up(30, 1000, 14));
    }

    #[test]
    fn switch_fires_earlier_when_bottom_up_occupancy_wins() {
        // top-down measures 4 lanes/issue, bottom-up 16. Raw test:
        // 30 × 14 = 420 < 1000 → no switch. Issue units:
        // (30/4) × 14 = 105 > 1000/16 = 62.5 → switch.
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 400));
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 1600));
        f.record_root();
        assert!(f.switch_to_bottom_up(30, 1000, 14), "adjusted test must fire earlier");
        // and with the occupancies reversed the switch is *later* than raw:
        // raw 100×14 = 1400 > 1000 would fire, issue units (100/16)×14 =
        // 87.5 < 1000/4 = 250 hold off
        let g = PolicyFeedback::default();
        g.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1600));
        g.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 400));
        g.record_root();
        assert!(!g.switch_to_bottom_up(100, 1000, 14), "adjusted test must hold off");
    }

    #[test]
    fn switch_back_falls_back_to_population_unmeasured() {
        let f = PolicyFeedback::default();
        f.record_root();
        // classic Beamer β: 100 × 24 = 2400 < 10000 → back to top-down;
        // 500 × 24 = 12000 > 10000 → stay bottom-up
        assert!(f.switch_to_top_down(100, 1000, 50_000, 10_000, 24));
        assert!(!f.switch_to_top_down(500, 1000, 50_000, 10_000, 24));
        // one direction measured is not enough — still the population test
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1200));
        assert!(!f.switch_to_top_down(500, 1000, 50_000, 10_000, 24));
    }

    #[test]
    fn switch_back_stays_classic_during_first_root() {
        // both directions measured mid-root, but no root completed: the
        // first root must behave exactly like classic Beamer
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1600));
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 400));
        // population test: 500 × 24 > 10000 → stay bottom-up, despite the
        // measured top-down occupancy advantage
        assert!(!f.switch_to_top_down(500, 1000, 50_000, 10_000, 24));
        f.record_root();
        assert!(f.switch_to_top_down(500, 1000, 50_000, 10_000, 24));
    }

    #[test]
    fn switch_back_runs_in_issue_units_once_measured() {
        // top-down measures 16 lanes/issue, bottom-up 4: the issue-unit
        // test fires back to top-down *earlier* than the population test.
        // population: 500 × 24 = 12000 > 10000 → classic stays bottom-up;
        // issues: (1000/16) × 24 = 1500 < 50000/4 = 12500 → switch back.
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 1600));
        f.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 400));
        f.record_root();
        assert!(f.switch_to_top_down(500, 1000, 50_000, 10_000, 24));
        // reversed occupancies hold bottom-up longer than the population
        // test would: population 100 × 24 = 2400 < 10000 → classic fires,
        // issues (1000/4) × 24 = 6000 > 50000/16 = 3125 → stay
        let g = PolicyFeedback::default();
        g.record_layer(ChunkingMode::LanePacked, 100, 400, &counters(100, 400));
        g.record_bottom_up_layer(BottomUpMode::SellPacked, 100, 400, &counters(100, 1600));
        g.record_root();
        assert!(!g.switch_to_top_down(100, 1000, 50_000, 10_000, 24));
    }

    #[test]
    fn low_sample_counts_are_not_trusted() {
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::PerVertex, 100, 400, &counters(8, 128));
        assert_eq!(f.occupancy_in_band(band_of(4), ChunkingMode::PerVertex), None);
        // under the floor the static threshold still decides
        assert_eq!(f.choose(100, 400, true), ChunkingMode::LanePacked);
        assert!(f.mean_lanes_active(ChunkingMode::PerVertex).is_some());
    }

    /// Counters with a chunk/gather profile, for the cost-model tests.
    fn rich_counters(issues: u64, lanes: u64, full: u64, gather: u64) -> VpuCounters {
        VpuCounters {
            explore_issues: issues,
            lanes_active: lanes,
            vector_loads: full,
            gather_lanes: gather,
            ..Default::default()
        }
    }

    #[test]
    fn priced_comparison_overrides_raw_occupancy() {
        // band of mean degree 4. Lane packing measures MORE lanes per
        // issue (10 vs 9), but every one of its issues is a gather-fed
        // masked chunk dragging 32 gathered lanes behind it, while
        // per-vertex chunking ran aligned full-vector loads. Priced:
        // packing (100×(14+6) + 3200×1 + 100×60) / 1000 = 11.2 cycles per
        // active lane vs chunking (100×14 + 100×60) / 900 ≈ 8.2 — the
        // occupancy argmax points the wrong way and the cost model must
        // override it.
        let f = PolicyFeedback::default();
        f.record_layer(ChunkingMode::LanePacked, 100, 400, &rich_counters(100, 1000, 0, 3200));
        f.record_layer(ChunkingMode::PerVertex, 100, 400, &rich_counters(100, 900, 100, 0));
        let b = band_of(4);
        let packed_cost = f.predicted_cost_in_band(b, ChunkingMode::LanePacked).unwrap();
        let chunk_cost = f.predicted_cost_in_band(b, ChunkingMode::PerVertex).unwrap();
        assert!(chunk_cost < packed_cost, "{chunk_cost} !< {packed_cost}");
        assert!(
            f.occupancy_in_band(b, ChunkingMode::LanePacked).unwrap()
                > f.occupancy_in_band(b, ChunkingMode::PerVertex).unwrap(),
            "precondition: occupancy must point the other way"
        );
        assert_eq!(f.choose(100, 400, true), ChunkingMode::PerVertex);
    }

    #[test]
    fn bottom_up_priced_comparison_overrides_raw_occupancy() {
        // the same synthetic band, on the bottom-up three-way choice
        let f = PolicyFeedback::default();
        f.record_bottom_up_layer(
            BottomUpMode::SellPacked,
            100,
            400,
            &rich_counters(100, 1000, 0, 3200),
        );
        f.record_bottom_up_layer(
            BottomUpMode::PerVertexChunks,
            100,
            400,
            &rich_counters(100, 900, 100, 0),
        );
        assert_eq!(f.choose_bottom_up(100, 400, true), BottomUpMode::PerVertexChunks);
        // the scalar floor still cannot be overridden by measurements
        assert_eq!(f.choose_bottom_up(8, 32, true), BottomUpMode::Scalar);
    }

    #[test]
    fn prefetch_sweep_samples_each_candidate_then_settles() {
        let f = PolicyFeedback::default();
        for &d in PREFETCH_CANDIDATES.iter() {
            let (dist, sampling) = f.prefetch_plan();
            assert_eq!(dist, d, "candidates must be sampled in order");
            assert!(sampling);
            // candidate 4 measures fastest per edge
            let ns = if d == 4 { 1_000 } else { 10_000 };
            f.record_prefetch_sample(d, ns, 1_000);
        }
        assert_eq!(f.prefetch_plan(), (4, false));
        assert_eq!(f.chosen_prefetch_dist(), 4);
    }

    #[test]
    fn prefetch_sweep_ignores_empty_and_foreign_samples() {
        let f = PolicyFeedback::default();
        assert_eq!(f.chosen_prefetch_dist(), DEFAULT_PREFETCH_DIST);
        // a zero-edge sample measures nothing: the candidate stays open
        f.record_prefetch_sample(1, 999, 0);
        assert_eq!(f.prefetch_plan(), (1, true));
        // a sample at a non-candidate distance (a CLI-pinned run) is
        // discarded rather than polluting a cell
        f.record_prefetch_sample(3, 999, 1_000);
        assert_eq!(f.prefetch_plan(), (1, true));
        // ns/edge, not raw ns, decides: dist 1 is slower per edge despite
        // the smaller total
        f.record_prefetch_sample(1, 4_000, 1_000);
        f.record_prefetch_sample(2, 8_000, 4_000);
        f.record_prefetch_sample(4, 30_000, 10_000);
        f.record_prefetch_sample(8, 50_000, 10_000);
        assert_eq!(f.chosen_prefetch_dist(), 2);
        assert_eq!(f.prefetch_plan(), (2, false));
    }
}
