//! Byte accounting for prepared graph structures.
//!
//! The SELL-16-σ layout, the padded-CSR view, and the per-vertex bitmaps
//! are memory-hungry by design — on a Graph500 RMAT graph the prepared
//! artifacts together retain a small multiple of the CSR itself. Before
//! the runtime can bound its footprint (the
//! [`crate::coordinator::governor::ResourceGovernor`] ledger), every
//! retained structure has to be able to say exactly how many bytes it
//! holds: that is the [`HeapFootprint`] trait.
//!
//! Two flavors live here:
//!
//! - **`heap_bytes()`** — the exact payload bytes a *built* structure
//!   retains, computed from its element counts. Capacity slack is not
//!   counted: every constructor in `graph/` sizes its vectors exactly
//!   (`with_capacity`/`vec![]`/`resize`), so length-based accounting is
//!   the allocation truth, and the property suite pins the planners below
//!   to it.
//! - **`planned_*_bytes(g, ..)`** — the same number computed *before*
//!   building, from the CSR alone. The governor charges its ledger with
//!   these planned sizes **before** any allocation happens, which is what
//!   makes "the ledger never exceeds the budget at any observation point"
//!   an invariant rather than an aspiration. Each planner mirrors its
//!   constructor's sizing logic exactly (`planned_sell_bytes` replays the
//!   σ-window sort on degrees only — chunk heights depend only on each
//!   chunk's degree multiset, so ties in the sort cannot change the
//!   answer).

use crate::bfs::artifacts::{ComponentMap, GraphArtifacts, HubBits};
use crate::graph::sell::SELL_C;
use crate::graph::{Adjacency, Csr, PaddedCsr, Sell16};
use crate::Vertex;

/// Exact retained heap bytes of a prepared structure.
///
/// Implementations count the payload bytes of owned allocations
/// (`len * size_of::<Element>()`); inline fields are free and capacity
/// slack is not counted (see the module docs for why that is exact here).
pub trait HeapFootprint {
    /// Retained heap bytes.
    fn heap_bytes(&self) -> usize;
}

/// Payload bytes of a slice-backed allocation.
#[inline]
fn slice_bytes<T>(s: &[T]) -> usize {
    std::mem::size_of_val(s)
}

impl HeapFootprint for Csr {
    fn heap_bytes(&self) -> usize {
        slice_bytes(&self.colstarts) + slice_bytes(&self.rows)
    }
}

impl HeapFootprint for PaddedCsr {
    fn heap_bytes(&self) -> usize {
        // starts: usize per vertex, lens: u32 per vertex, rows: padded cells.
        let n = Adjacency::num_vertices(self);
        n * std::mem::size_of::<usize>()
            + n * std::mem::size_of::<u32>()
            + self.padded_len() * std::mem::size_of::<Vertex>()
    }
}

impl HeapFootprint for Sell16 {
    fn heap_bytes(&self) -> usize {
        slice_bytes(&self.perm)
            + slice_bytes(&self.rank)
            + slice_bytes(&self.chunk_starts)
            + slice_bytes(&self.chunk_lens)
            + slice_bytes(&self.lane_len)
            + slice_bytes(&self.cols)
    }
}

impl HeapFootprint for HubBits {
    fn heap_bytes(&self) -> usize {
        slice_bytes(&self.hubs) + slice_bytes(&self.masks)
    }
}

impl HeapFootprint for ComponentMap {
    fn heap_bytes(&self) -> usize {
        slice_bytes(&self.labels)
    }
}

impl HeapFootprint for GraphArtifacts {
    /// Sum of the graph-scale members built so far. The
    /// [`crate::bfs::policy::PolicyFeedback`] tables and the build
    /// counters are O(1) and not counted.
    fn heap_bytes(&self) -> usize {
        self.built_sell().map_or(0, |s| s.heap_bytes())
            + self.built_padded().map_or(0, |p| p.heap_bytes())
            + self.built_components().map_or(0, |c| c.heap_bytes())
            + self.built_hub().map_or(0, |h| h.heap_bytes())
    }
}

/// Bytes a [`PaddedCsr`] built from `g` will retain. O(V); mirrors
/// [`PaddedCsr::from_csr`]'s sizing exactly.
pub fn planned_padded_bytes(g: &Csr) -> usize {
    let n = g.num_vertices();
    let padded_cells: usize =
        (0..n as Vertex).map(|v| g.degree(v).next_multiple_of(SELL_C)).sum();
    n * std::mem::size_of::<usize>()
        + n * std::mem::size_of::<u32>()
        + padded_cells * std::mem::size_of::<Vertex>()
}

/// Bytes a [`Sell16`] built from `g` with window `sigma` will retain.
/// O(V log σ): replays the σ-window degree sort on degrees alone. Chunk
/// heights depend only on the sorted degree multiset of each 16-slot
/// chunk, so this matches [`Sell16::from_csr`]'s storage exactly whatever
/// order the stable sort leaves equal-degree vertices in.
pub fn planned_sell_bytes(g: &Csr, sigma: usize) -> usize {
    let n = g.num_vertices();
    let sigma = sigma.max(SELL_C);
    let num_chunks = n.div_ceil(SELL_C);
    let num_slots = num_chunks * SELL_C;

    let mut degrees: Vec<u32> = (0..n as Vertex).map(|v| g.degree(v) as u32).collect();
    let mut start = 0usize;
    while start < n {
        let end = start.saturating_add(sigma).min(n);
        degrees[start..end].sort_unstable_by_key(|&d| std::cmp::Reverse(d));
        start = end;
    }
    let cols_cells: usize = degrees
        .chunks(SELL_C)
        .map(|c| c.iter().copied().max().unwrap_or(0) as usize * SELL_C)
        .sum();

    n * std::mem::size_of::<Vertex>()                         // perm
        + n * std::mem::size_of::<u32>()                      // rank
        + (num_chunks + 1) * std::mem::size_of::<usize>()     // chunk_starts
        + num_chunks * std::mem::size_of::<u32>()             // chunk_lens
        + num_slots * std::mem::size_of::<u32>()              // lane_len
        + cols_cells * std::mem::size_of::<Vertex>() // cols
}

/// Bytes a [`ComponentMap`] over `g` will retain.
pub fn planned_component_bytes(g: &Csr) -> usize {
    g.num_vertices() * std::mem::size_of::<u32>()
}

/// Bytes a [`HubBits`] bitmap over `g` with `k` hubs will retain.
pub fn planned_hub_bytes(g: &Csr, k: usize) -> usize {
    let n = g.num_vertices();
    let k = k.min(32).min(n);
    k * std::mem::size_of::<Vertex>() + n * std::mem::size_of::<u32>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeList, RmatConfig};

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    #[test]
    fn csr_footprint_counts_offsets_and_rows() {
        let g = rmat(9, 8, 1);
        let expect = (g.num_vertices() + 1) * std::mem::size_of::<usize>()
            + g.num_directed_edges() * std::mem::size_of::<Vertex>();
        assert_eq!(g.heap_bytes(), expect);
    }

    #[test]
    fn planners_match_built_structures_exactly() {
        for (scale, seed) in [(7u32, 11u64), (9, 12), (10, 13)] {
            let g = rmat(scale, 8, seed);
            assert_eq!(planned_padded_bytes(&g), PaddedCsr::from_csr(&g).heap_bytes());
            for sigma in [16usize, 256, usize::MAX] {
                assert_eq!(
                    planned_sell_bytes(&g, sigma),
                    Sell16::from_csr(&g, sigma).heap_bytes(),
                    "scale {scale} sigma {sigma}"
                );
            }
            assert_eq!(
                planned_component_bytes(&g),
                ComponentMap::compute(&g).heap_bytes()
            );
            for k in [1usize, 16, 32, 1000] {
                assert_eq!(
                    planned_hub_bytes(&g, k),
                    HubBits::build(&g, k).heap_bytes(),
                    "k {k}"
                );
            }
        }
    }

    #[test]
    fn planners_handle_degenerate_graphs() {
        let g = Csr::from_edge_list(0, &EdgeList::with_edges(1, vec![]));
        assert_eq!(planned_padded_bytes(&g), PaddedCsr::from_csr(&g).heap_bytes());
        assert_eq!(planned_sell_bytes(&g, 16), Sell16::from_csr(&g, 16).heap_bytes());
        assert_eq!(planned_hub_bytes(&g, 4), HubBits::build(&g, 4).heap_bytes());
    }

    #[test]
    fn artifacts_footprint_sums_built_members() {
        let g = rmat(8, 8, 2);
        let a = GraphArtifacts::for_graph(&g);
        assert_eq!(a.heap_bytes(), 0, "nothing built yet");
        let sell = a.sell_layout(&g, 256).unwrap();
        assert_eq!(a.heap_bytes(), sell.heap_bytes());
        let padded = a.padded_csr(&g).unwrap();
        assert_eq!(a.heap_bytes(), sell.heap_bytes() + padded.heap_bytes());
        let comp = a.components(&g).unwrap();
        let hub = a.hub_bits(&g, 16).unwrap();
        assert_eq!(
            a.heap_bytes(),
            sell.heap_bytes() + padded.heap_bytes() + comp.heap_bytes() + hub.heap_bytes()
        );
    }
}
