//! The SELL-16-σ lane-packed explorer — the `sell` engine of the ladder.
//!
//! Listing 1 (the `simd` engine) vectorizes *within* one vertex's
//! adjacency list, so a frontier vertex of degree d < 16 wastes 16 − d
//! lanes per issue — and the skewed RMAT degree distribution (§6.1) makes
//! that the common case. This engine instead gathers **one neighbor from
//! 16 distinct frontier vertices per VPU issue**, following the SlimSell
//! Sell-C-σ idea over the [`Sell16`] layout:
//!
//! * the frontier's occupied slots are collected each layer and packed in
//!   **descending lane-length order** (the dynamic analogue of the layout's
//!   σ sort), so the 16 lanes of a group run out of neighbors together and
//!   rows stay dense;
//! * a group row `r` is one gather over `cols` at per-lane indices
//!   `slot_base + r*16`, followed by exactly the Listing-1 filter/scatter
//!   dataflow — including the word-granularity bit race, which the same
//!   vectorized restoration repairs;
//! * when a whole 16-lane chunk of the static layout is frontier-active
//!   and [`SimdOpts::aligned`] is on, its rows are issued as aligned full
//!   vector loads instead of gathers (the fast path that makes dense
//!   frontiers as cheap as Listing 1's best case);
//! * the [`LayerPolicy::sell_chunking`] extension keeps hub-dominated
//!   layers (mean degree ≥ 32) on the per-vertex explorer, where long
//!   adjacency lists already fill whole vectors; low-degree layers — the
//!   ones §4.1's heavy-layer policy had to leave scalar because per-vertex
//!   chunking wasted their lanes — are exactly where packing wins, so the
//!   engine defaults to [`LayerPolicy::All`] and vectorizes every layer.
//!
//! Occupancy is observable: every explore issue records its active lanes
//! in [`VpuCounters::lanes_active`] / `explore_issues`, so the ablation
//! bench can show `sell` holding strictly more lanes per issue than
//! `simd` on the same graph.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::bitrace_free::RestoreStats;
use super::policy::{ChunkingMode, LayerPolicy, PolicyFeedback};
use super::state::{SharedBitmap, SharedPred};
use super::vectorized::{
    explore_layer_per_vertex, restore_layer_simd, scalar_fallback_layer, SimdOpts,
};
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, RunControl, RunStatus,
    RunTrace,
};
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::sell::{Sell16, SELL_C};
use crate::graph::{Adjacency, Bitmap, Csr, PaddedCsr};
use crate::simd::backend::{resolve, VpuBackend, VpuMode};
use crate::simd::ops::PrefetchHint;
use crate::simd::vec512::{Mask16, VecI32x16, LANES};
use crate::simd::VpuCounters;
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// Default σ window (16 chunks per sorting window — enough to keep RMAT
/// chunk lanes degree-uniform without a global sort).
pub const DEFAULT_SIGMA: usize = 256;

/// Sentinel σ: let [`BfsEngine::prepare`] pick the per-scale default from
/// the graph's [`super::DegreeStats::suggested_sigma`] (σ-sweep result).
pub const SIGMA_AUTO: usize = 0;

/// The SELL-16-σ lane-packed BFS engine.
///
/// The [`Sell16`] layout is a *per-graph* artifact: [`BfsEngine::prepare`]
/// builds it once (an O(V log σ + E) step) and every root's
/// [`PreparedBfs::run`] reuses it — a 64-root Graph500 experiment pays the
/// layout exactly once. The one-shot [`BfsEngine::run`] convenience still
/// works but prepares per call.
#[derive(Clone, Copy, Debug)]
pub struct SellBfs {
    pub num_threads: usize,
    pub opts: SimdOpts,
    pub policy: LayerPolicy,
    /// Degree-sort window of the prepared [`Sell16`] layout.
    /// [`SIGMA_AUTO`] resolves to the per-scale default at prepare time.
    pub sigma: usize,
    /// VPU backend mode: counted emulation, hardware SIMD, or counted
    /// warm-up + hardware steady state.
    pub vpu: VpuMode,
}

impl Default for SellBfs {
    fn default() -> Self {
        SellBfs {
            num_threads: 4,
            opts: SimdOpts::full(),
            // Lane packing keeps low-degree layers lane-efficient, so the
            // sell engine retires the §4.1 scalar fallback by default —
            // every layer runs through the VPU.
            policy: LayerPolicy::All,
            sigma: SIGMA_AUTO,
            vpu: VpuMode::default(),
        }
    }
}

/// One unit of lane-packed work: either all 16 lanes of a static chunk
/// (aligned loads) or a dynamically packed group of frontier slots
/// (gathers). Shared with the MS-BFS engine ([`super::multi_source`]),
/// which packs the *union* frontier of a whole root batch the same way.
pub(crate) enum PackedItem {
    FullChunk(usize),
    /// `[start, end)` range into the packed slot list.
    Group(usize, usize),
}

/// Collect the frontier's occupied slots (degree-0 vertices carry no work)
/// and split them into aligned full-chunk items and degree-sorted gather
/// groups.
pub(crate) fn pack_frontier(
    sell: &Sell16,
    frontier: &Bitmap,
    aligned: bool,
) -> (Vec<PackedItem>, Vec<u32>) {
    let slots: Vec<u32> = frontier
        .iter_set_bits()
        .map(|v| sell.rank[v as usize])
        .filter(|&s| sell.lane_len[s as usize] > 0)
        .collect();

    let mut items = Vec::new();
    let mut rest: Vec<u32>;
    if aligned {
        // A chunk whose 16 lanes are all frontier-active runs on aligned
        // full loads; everything else joins the gather pool. Full-chunk
        // detection needs the slots in ascending order.
        let mut slots = slots;
        slots.sort_unstable();
        rest = Vec::with_capacity(slots.len());
        let mut i = 0usize;
        while i < slots.len() {
            let first = slots[i] as usize;
            if first % SELL_C == 0
                && i + SELL_C <= slots.len()
                && slots[i + SELL_C - 1] as usize == first + SELL_C - 1
            {
                items.push(PackedItem::FullChunk(first / SELL_C));
                i += SELL_C;
            } else {
                rest.push(slots[i]);
                i += 1;
            }
        }
    } else {
        rest = slots;
    }

    // Dynamic σ analogue: pack leftover slots in descending length order so
    // group lanes exhaust together (ties broken by slot for determinism).
    rest.sort_unstable_by_key(|&s| (std::cmp::Reverse(sell.lane_len[s as usize]), s));
    let mut start = 0usize;
    while start < rest.len() {
        let end = (start + LANES).min(rest.len());
        items.push(PackedItem::Group(start, end));
        start = end;
    }
    (items, rest)
}

/// Issue one packed row through the Listing-1 filter/scatter dataflow.
/// `vparent_marked` carries each lane's parent as `u − nodes` (the
/// restoration journal marker) — the key difference from the per-vertex
/// explorer, where one scalar parent covers the whole chunk.
#[allow(clippy::too_many_arguments)]
fn explore_packed_row<V: VpuBackend>(
    vpu: &mut V,
    vneig: VecI32x16,
    active: Mask16,
    vparent_marked: VecI32x16,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
    prefetch: bool,
) {
    // word/bit decomposition of the gathered neighbor ids
    let bits_per_word = vpu.set1_epi32(BITS_PER_WORD as i32);
    let vword = vpu.div_epi32(vneig, bits_per_word);
    let vbits = vpu.rem_epi32(vneig, bits_per_word);

    if prefetch {
        vpu.prefetch_i32gather(vword, PrefetchHint::T0);
    }
    let vis_words = vpu.mask_gather_shared_words(active, vword, visited.atomic_words());
    let out_words = vpu.mask_gather_shared_words(active, vword, out.atomic_words());

    let one = vpu.set1_epi32(1);
    let bits = vpu.sllv_epi32(one, vbits);

    let m_vis = vpu.test_epi32_mask(vis_words, bits);
    let m_out = vpu.test_epi32_mask(out_words, bits);
    let m_seen = vpu.kor(m_vis, m_out);
    let m_new_all = vpu.knot(m_seen);
    let mask = vpu.kand(m_new_all, active);
    if mask.is_empty() {
        return;
    }

    if prefetch {
        vpu.mask_prefetch_i32scatter(mask, vneig, PrefetchHint::T0);
    }
    // P[v] = u − nodes, a different u per lane
    vpu.mask_scatter_shared_i32(pred.atomic_cells(), mask, vneig, vparent_marked);

    let zero = vpu.set1_epi32(0);
    let new_values = vpu.mask_or_epi32(zero, mask, out_words, bits);
    if prefetch {
        vpu.mask_prefetch_i32scatter(mask, vword, PrefetchHint::T0);
    }
    // same word-granularity racy scatter as Listing 1 — restoration repairs
    vpu.mask_scatter_shared_words(out.atomic_words(), mask, vword, new_values);
}

/// Explore one layer with lane packing. Returns (edges scanned, merged VPU
/// counters); the caller runs restoration afterwards.
///
/// NOTE: the MS-BFS top-down pass (`ms_explore_layer` in
/// [`super::multi_source`]) mirrors this chunk/group iteration skeleton
/// with a different per-lane payload — keep fixes to the packing loop in
/// sync.
#[allow(clippy::too_many_arguments)]
pub fn sell_explore_layer<V: VpuBackend>(
    num_threads: usize,
    sell: &Sell16,
    frontier: &Bitmap,
    nodes: Pred,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
    opts: SimdOpts,
) -> (usize, VpuCounters) {
    struct Acc<V> {
        edges: usize,
        vpu: Option<V>,
    }
    #[allow(clippy::derivable_impls)]
    impl<V> Default for Acc<V> {
        fn default() -> Self {
            Acc { edges: 0, vpu: None }
        }
    }

    let (items, packed) = pack_frontier(sell, frontier, opts.aligned);
    let dist = opts.effective_dist();
    // the per-thread item loop runs inside the backend's #[target_feature]
    // envelope so the whole gather → filter → scatter dataflow fuses
    let accs: Vec<Acc<V>> = parallel_for_dynamic(
        num_threads,
        items.len(),
        2,
        |_tid, range, acc: &mut Acc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            for item in &items[range] {
                match *item {
                    PackedItem::FullChunk(c) => {
                        let start = sell.chunk_starts[c];
                        let lens = &sell.lane_len[c * SELL_C..(c + 1) * SELL_C];
                        let height = sell.chunk_lens[c] as usize;
                        let mut parent_arr = [0i32; LANES];
                        for (lane, p) in parent_arr.iter_mut().enumerate() {
                            *p = sell.perm[c * SELL_C + lane] as Pred - nodes;
                        }
                        let vparent = VecI32x16(parent_arr);
                        for r in 0..height {
                            let mut m = 0u16;
                            for (lane, &len) in lens.iter().enumerate() {
                                if len as usize > r {
                                    m |= 1 << lane;
                                }
                            }
                            let active = Mask16(m);
                            vpu.note_explore_issue(active.count());
                            acc.edges += active.count() as usize;
                            let offset = start + r * SELL_C;
                            let vneig = if active == Mask16::ALL {
                                vpu.note_full_chunk();
                                vpu.load_vertices(&sell.cols, offset)
                            } else {
                                vpu.note_remainder(active.count() as usize);
                                vpu.mask_load_vertices(active, &sell.cols, offset)
                            };
                            if opts.prefetch {
                                if V::COUNTED {
                                    if r + 1 < height {
                                        // next row of this chunk streams in
                                        vpu.prefetch_scalar(PrefetchHint::T1);
                                    }
                                } else if dist > 0 && r + dist < height {
                                    // hardware: keep the cols line `dist`
                                    // rows out in flight
                                    if let Some(c) =
                                        sell.cols.get(start + (r + dist) * SELL_C)
                                    {
                                        vpu.prefetch_addr(
                                            (c as *const u32).cast(),
                                            PrefetchHint::T1,
                                        );
                                    }
                                }
                            }
                            explore_packed_row(
                                vpu, vneig, active, vparent, visited, out, pred, opts.prefetch,
                            );
                        }
                    }
                    PackedItem::Group(gstart, gend) => {
                        let group = &packed[gstart..gend];
                        let mut base_arr = [0i32; LANES];
                        let mut len_arr = [0u32; LANES];
                        let mut parent_arr = [0i32; LANES];
                        for (lane, &slot) in group.iter().enumerate() {
                            let slot = slot as usize;
                            base_arr[lane] = sell.slot_base(slot) as i32;
                            len_arr[lane] = sell.lane_len[slot];
                            parent_arr[lane] = sell.perm[slot] as Pred - nodes;
                        }
                        let vbase = VecI32x16(base_arr);
                        let vparent = VecI32x16(parent_arr);
                        // groups are packed in descending length order
                        let height = len_arr[0] as usize;
                        for r in 0..height {
                            let mut m = 0u16;
                            for (lane, &len) in len_arr.iter().enumerate().take(group.len()) {
                                if len as usize > r {
                                    m |= 1 << lane;
                                }
                            }
                            let active = Mask16(m);
                            vpu.note_explore_issue(active.count());
                            acc.edges += active.count() as usize;
                            let roff = vpu.set1_epi32((r * SELL_C) as i32);
                            let vidx = vpu.add_epi32(vbase, roff);
                            if opts.prefetch {
                                if V::COUNTED {
                                    vpu.prefetch_i32gather(vidx, PrefetchHint::T1);
                                } else if dist > 0 && r + dist < height {
                                    // representative-lane prefetch `dist`
                                    // rows ahead of lane 0 (the longest —
                                    // groups pack by descending length)
                                    if let Some(c) = sell
                                        .cols
                                        .get(base_arr[0] as usize + (r + dist) * SELL_C)
                                    {
                                        vpu.prefetch_addr(
                                            (c as *const u32).cast(),
                                            PrefetchHint::T1,
                                        );
                                    }
                                }
                            }
                            let vneig = vpu.mask_i32gather_words(active, vidx, &sell.cols);
                            explore_packed_row(
                                vpu, vneig, active, vparent, visited, out, pred, opts.prefetch,
                            );
                        }
                    }
                }
            }
        }),
    );

    let mut edges = 0usize;
    let mut vpu = VpuCounters::default();
    for a in accs {
        edges += a.edges;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (edges, vpu)
}

/// One complete SELL top-down layer step, bound to its per-graph inputs:
/// the [`Sell16`] layout, the optional aligned [`PaddedCsr`] view for the
/// per-vertex mode, and the cross-root [`PolicyFeedback`] channel.
/// [`SellStep::layer`] picks lane packing or per-vertex chunking — the
/// measured-occupancy comparison once feedback has data, the static
/// [`LayerPolicy::sell_chunking`] threshold until then — runs the chosen
/// explorer, records what it measured, then the vectorized restoration
/// repairs the bit races. The single definition of the sell step protocol
/// — shared by [`SellBfs`] and [`super::bottom_up::HybridBfs`].
pub struct SellStep<'a> {
    pub num_threads: usize,
    pub g: &'a Csr,
    pub sell: &'a Sell16,
    /// Aligned per-vertex view; `None` falls back to the raw CSR.
    pub padded: Option<&'a PaddedCsr>,
    /// Cross-root occupancy feedback; `None` keeps the static threshold.
    pub feedback: Option<&'a PolicyFeedback>,
    pub opts: SimdOpts,
}

impl SellStep<'_> {
    #[allow(clippy::too_many_arguments)]
    pub fn layer<V: VpuBackend>(
        &self,
        frontier: &Bitmap,
        input_vertices: usize,
        input_edges: usize,
        visited: &SharedBitmap,
        next: &SharedBitmap,
        pred: &SharedPred,
        nodes: Pred,
    ) -> (usize, RestoreStats, VpuCounters) {
        let mode = match self.feedback {
            // V::COUNTED gates the guided probe: an uncounted backend
            // cannot supply the measurement a probe exists to collect
            Some(f) => f.choose(input_vertices, input_edges, V::COUNTED),
            None => LayerPolicy::sell_chunking(input_vertices, input_edges),
        };
        let (edges, explore_vpu) = match mode {
            ChunkingMode::LanePacked => sell_explore_layer::<V>(
                self.num_threads,
                self.sell,
                frontier,
                nodes,
                visited,
                next,
                pred,
                self.opts,
            ),
            // hub layers: Listing-1 chunking already fills lanes
            ChunkingMode::PerVertex => {
                let adj: &dyn Adjacency = match self.padded {
                    Some(p) => p,
                    None => self.g,
                };
                explore_layer_per_vertex::<dyn Adjacency, V>(
                    self.num_threads,
                    adj,
                    frontier,
                    nodes,
                    visited,
                    next,
                    pred,
                    self.opts,
                )
            }
        };
        if let Some(f) = self.feedback {
            f.record_layer(mode, input_vertices, input_edges, &explore_vpu);
        }
        let (rstats, restore_vpu) =
            restore_layer_simd::<V>(self.num_threads, next, visited, pred, nodes);
        let mut vpu = explore_vpu;
        vpu.merge(&restore_vpu);
        (edges, rstats, vpu)
    }
}

impl SellBfs {
    /// One traversal over a prepared layout, on VPU backend `V`.
    /// `feedback`, when present, is both consulted (chunking choice) and
    /// fed (measured occupancy — zeros on uncounted backends, which the
    /// channel ignores).
    fn traverse<V: VpuBackend>(
        &self,
        g: &Csr,
        sell: &Sell16,
        padded: Option<&PaddedCsr>,
        feedback: Option<&PolicyFeedback>,
        root: Vertex,
        ctl: &RunControl,
    ) -> BfsResult {
        let step = SellStep {
            num_threads: self.num_threads,
            g,
            sell,
            padded,
            feedback,
            opts: self.opts,
        };
        let n = g.num_vertices();
        let nodes = n as Pred;
        let pred = SharedPred::new_infinity(n);
        let visited = SharedBitmap::new(n);
        let mut input = Bitmap::new(n);
        let output = SharedBitmap::new(n);

        input.set_bit(root);
        visited.set_bit_atomic(root);
        pred.set(root, root as Pred);

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut frontier_count = 1usize;
        let mut nontrivial_seen = 0usize;
        let mut status = RunStatus::Complete;
        while frontier_count != 0 {
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let input_edges: usize = input.iter_set_bits().map(|u| g.degree(u)).sum();
            let vectorize = self.policy.vectorize(nontrivial_seen, frontier_count, input_edges);
            if frontier_count > 1 {
                nontrivial_seen += 1;
            }

            let (edges_scanned, rstats, vpu_counters) = if vectorize {
                step.layer::<V>(
                    &input,
                    frontier_count,
                    input_edges,
                    &visited,
                    &output,
                    &pred,
                    nodes,
                )
            } else {
                // scalar parallel fallback (Algorithm 2, §4.1)
                let edges =
                    scalar_fallback_layer(self.num_threads, g, &input, &visited, &output, &pred);
                (edges, RestoreStats::default(), VpuCounters::default())
            };

            let traversed = output.count_ones();
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier_count,
                edges_scanned,
                traversed,
                restore_words_scanned: rstats.words_scanned,
                restore_fixed: rstats.lost_bits_fixed,
                vectorized: vectorize,
                bottom_up: false,
                vpu: vpu_counters,
                wall_ns: t0.elapsed().as_nanos() as u64,
            });

            let snap = output.snapshot();
            frontier_count = snap.count_ones();
            input = snap;
            output.clear_all();
            layer += 1;
        }

        if let Some(f) = feedback {
            f.record_root();
        }

        BfsResult {
            tree: BfsTree::new(root, pred.into_vec()),
            trace: RunTrace { layers, num_threads: self.num_threads, status, ..Default::default() },
        }
    }

    /// Resolve [`SIGMA_AUTO`] against the graph's measured degree stats.
    pub fn resolved_sigma(&self, g: &Csr, artifacts: &GraphArtifacts) -> usize {
        if self.sigma == SIGMA_AUTO {
            artifacts.stats(g).suggested_sigma()
        } else {
            self.sigma
        }
    }
}

/// A [`SellBfs`] bound to one graph: the σ-resolved [`Sell16`] layout and
/// the aligned per-vertex view, built once by prepare and shared by every
/// root; the artifacts' [`PolicyFeedback`] carries occupancy across roots.
pub struct PreparedSell<'g> {
    g: &'g Csr,
    sell: Arc<Sell16>,
    padded: Option<Arc<PaddedCsr>>,
    engine: SellBfs,
    artifacts: Arc<GraphArtifacts>,
}

impl PreparedBfs for PreparedSell<'_> {
    fn name(&self) -> &'static str {
        "sell"
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        // backend dispatch, once per traversal; the traverse (and every
        // layer helper under it) monomorphizes per backend
        let fb = self.artifacts.feedback();
        let (select, warmup) = resolve(self.engine.vpu, fb.roots_done());
        let mut engine = self.engine;
        let sampling = super::vectorized::plan_prefetch(&mut engine.opts, fb, select);
        let mut r = crate::with_vpu_backend!(select, V, engine.traverse::<V>(
            self.g,
            &self.sell,
            self.padded.as_deref(),
            Some(self.artifacts.feedback()),
            root,
            ctl,
        ));
        if sampling {
            fb.record_prefetch_sample(
                engine.opts.prefetch_dist,
                r.trace.total_wall_ns(),
                r.trace.total_edges_scanned(),
            );
        }
        r.trace.counted_warmup = warmup;
        r
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

impl BfsEngine for SellBfs {
    fn name(&self) -> &'static str {
        "sell"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        let sigma = self.resolved_sigma(g, &artifacts);
        let sell = artifacts.sell_layout(g, sigma)?;
        // optional under governor pressure: `None` re-enables the peel loop
        let padded = if self.opts.aligned { artifacts.padded_csr(g) } else { None };
        Ok(Box::new(PreparedSell { g, sell, padded, engine: *self, artifacts }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::bfs::validate::validate;
    use crate::bfs::vectorized::VectorizedBfs;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::PRED_INFINITY;

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    fn assert_matches_serial(g: &Csr, root: Vertex, alg: SellBfs) {
        let s = SerialLayeredBfs.run(g, root);
        let v = alg.run(g, root);
        assert_eq!(
            v.tree.distances().unwrap(),
            s.tree.distances().unwrap(),
            "distances differ for {alg:?}"
        );
    }

    #[test]
    fn matches_serial_all_policies() {
        let g = rmat(10, 8, 91);
        for policy in [
            LayerPolicy::All,
            LayerPolicy::None,
            LayerPolicy::FirstK(2),
            LayerPolicy::heavy(),
        ] {
            assert_matches_serial(
                &g,
                0,
                SellBfs { num_threads: 2, policy, ..Default::default() },
            );
        }
    }

    #[test]
    fn matches_serial_all_opt_levels_and_sigmas() {
        let g = rmat(10, 16, 92);
        for opts in [SimdOpts::none(), SimdOpts::aligned_masks(), SimdOpts::full()] {
            for sigma in [SELL_C, 256, usize::MAX] {
                assert_matches_serial(
                    &g,
                    5,
                    SellBfs { num_threads: 4, opts, policy: LayerPolicy::All, sigma, ..Default::default() },
                );
            }
        }
    }

    #[test]
    fn validates_on_rmat_scale_14() {
        // acceptance bar: the sell engine must validate (Graph500 five
        // checks + serial distance agreement) at SCALE ≥ 14.
        let g = rmat(14, 16, 93);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let r = SellBfs { num_threads: 4, ..Default::default() }.run(&g, root);
        let report = validate(&g, &r.tree);
        assert!(report.all_passed(), "{}", report.summary());
        let s = SerialLayeredBfs.run(&g, root);
        assert_eq!(r.tree.distances().unwrap(), s.tree.distances().unwrap());
    }

    #[test]
    fn lane_occupancy_beats_per_vertex_on_rmat() {
        // the tentpole claim: on the same layers (policy All for both, so
        // chunking is the only variable), lane packing holds strictly more
        // active lanes per VPU issue than per-vertex chunking.
        let g = rmat(12, 16, 94);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let simd = VectorizedBfs {
            num_threads: 1,
            opts: SimdOpts::full(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, root);
        let sell =
            SellBfs { num_threads: 1, vpu: VpuMode::Counted, ..Default::default() }.run(&g, root);
        let occ_simd = simd.trace.vpu_totals().mean_lanes_active();
        let occ_sell = sell.trace.vpu_totals().mean_lanes_active();
        assert!(occ_simd > 0.0 && occ_sell > 0.0);
        // the prepared padded-CSR view removes the simd engine's peel
        // issues and narrows the gap, but per-vertex chunking still wastes
        // lanes on every low-degree frontier vertex — demand a real gap,
        // not a rounding artifact
        assert!(
            occ_sell > occ_simd + 0.3,
            "sell occupancy {occ_sell:.2} !> simd {occ_simd:.2} + 0.3"
        );
        // lane packing also needs fewer issues to scan the same edges
        assert!(
            sell.trace.vpu_totals().explore_issues < simd.trace.vpu_totals().explore_issues,
            "sell should issue fewer explores"
        );
    }

    #[test]
    fn aligned_mode_full_loads_on_dense_frontier() {
        // a star's leaf layer activates whole chunks → aligned full loads
        let el = EdgeList::with_edges(65, (1..65).map(|i| (0u32, i as Vertex)).collect());
        let g = Csr::from_edge_list(0, &el);
        let full = SellBfs {
            num_threads: 1,
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, 0);
        assert!(full.trace.vpu_totals().full_chunks > 0, "no aligned full loads");
        let noopt = SellBfs {
            num_threads: 1,
            opts: SimdOpts::none(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, 0);
        let c = noopt.trace.vpu_totals();
        assert_eq!(c.full_chunks, 0);
        assert_eq!(c.vector_loads, 0);
        assert_eq!(full.tree.reached_count(), 65);
        assert_eq!(noopt.tree.reached_count(), 65);
    }

    #[test]
    fn prefetch_counters_follow_opts() {
        let g = rmat(9, 8, 95);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let with = SellBfs {
            num_threads: 1,
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, root);
        assert!(with.trace.vpu_totals().prefetch_l1 + with.trace.vpu_totals().prefetch_l2 > 0);
        let without = SellBfs {
            num_threads: 1,
            opts: SimdOpts::aligned_masks(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, root);
        let c = without.trace.vpu_totals();
        assert_eq!(c.prefetch_l1 + c.prefetch_l2, 0);
    }

    #[test]
    fn bit_races_occur_and_are_repaired() {
        // packing 16 distinct parents per issue makes same-word scatters
        // even likelier than Listing 1 — restoration must still repair all
        let el = EdgeList::with_edges(64, (1..64).map(|i| (0u32, i as Vertex)).collect());
        let g = Csr::from_edge_list(0, &el);
        let r = SellBfs {
            num_threads: 1,
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
            ..Default::default()
        }
        .run(&g, 0);
        let vpu = r.trace.vpu_totals();
        assert!(vpu.scatter_conflicts > 0, "dense children must collide in words");
        assert_eq!(r.tree.reached_count(), 64);
        for &p in &r.tree.pred {
            assert!(p == PRED_INFINITY || p >= 0, "negative pred survived: {p}");
        }
    }

    #[test]
    fn multithreaded_agrees_with_single() {
        let g = rmat(11, 16, 96);
        let a = SellBfs { num_threads: 1, policy: LayerPolicy::All, ..Default::default() }
            .run(&g, 3);
        let b = SellBfs { num_threads: 4, policy: LayerPolicy::All, ..Default::default() }
            .run(&g, 3);
        assert_eq!(a.tree.distances().unwrap(), b.tree.distances().unwrap());
    }

    #[test]
    fn single_vertex_graph() {
        let el = EdgeList::with_edges(1, vec![]);
        let g = Csr::from_edge_list(0, &el);
        let r = SellBfs::default().run(&g, 0);
        assert_eq!(r.tree.reached_count(), 1);
    }

    #[test]
    fn edges_scanned_matches_serial_layers() {
        // lane packing must scan exactly the frontier's degree sum, like
        // every top-down engine
        let g = rmat(10, 16, 97);
        let s = SerialLayeredBfs.run(&g, 2);
        let r = SellBfs { num_threads: 2, policy: LayerPolicy::All, ..Default::default() }
            .run(&g, 2);
        assert_eq!(r.trace.layers.len(), s.trace.layers.len());
        for (a, b) in r.trace.layers.iter().zip(s.trace.layers.iter()) {
            assert_eq!(a.edges_scanned, b.edges_scanned, "layer {}", a.layer);
            assert_eq!(a.traversed, b.traversed, "layer {}", a.layer);
        }
    }
}
