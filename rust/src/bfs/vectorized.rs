//! §4 — the vectorized BFS: Listing 1's adjacency-list exploration on the
//! emulated 512-bit VPU, the vectorized restoration process, and the layer
//! policy of §4.1.
//!
//! Per adjacency chunk of ≤16 vertices the explorer issues the exact
//! Listing-1 sequence:
//!
//! ```text
//! 1. vneig     = load(rows[chunk])                       // _mm512_load_epi32
//! 2. vword     = vneig / 32 ; vbits = vneig % 32         // div/rem_epi32
//!    prefetch gather (out words, hint T0)                // §4.2 prefetching
//!    vis_words = gather(visited, vword)                  // i32gather
//!    out_words = gather(out, vword)
//!    bits      = 1 << vbits                              // sllv
//!    mask      = knot(kor(test(vis_words, bits),
//!                         test(out_words, bits)))        // filter unvisited
//! 3. prefetch scatter (bfs_tree, masked, hint T0)
//!    scatter(bfs_tree, mask, vneig, u - nodes)           // benign race
//!    new_values = mask_or(0, mask, out_words, bits)
//!    prefetch scatter (out, masked, hint T0)
//!    scatter(out, mask, vword, new_values)               // BIT RACE here
//! ```
//!
//! The word-granularity scatter in step 3 loses bits whenever two lanes (or
//! two threads) hit the same word — deliberately unrepaired until the
//! vectorized restoration sweeps the non-zero `out` words in 16-lane halves
//! (low/high, §4 ¶"On the other hand…") and repairs every vertex whose
//! predecessor entry is still negative.
//!
//! §4.2's three optimization stages are selectable via [`SimdOpts`] so the
//! Fig 9 ablation can measure them: `aligned` enables the peel/full/
//! remainder chunk structure (otherwise every chunk issues unaligned masked
//! loads), `prefetch` enables the software-prefetch intrinsics.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::policy::{LayerPolicy, PolicyFeedback};
use super::state::{SharedBitmap, SharedPred};
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, RunControl, RunStatus,
    RunTrace, WORD_GRAIN,
};
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::{Adjacency, Bitmap, Csr, PaddedCsr};
use crate::simd::backend::{resolve, VpuBackend, VpuMode, VpuSelect};
use crate::simd::ops::PrefetchHint;
use crate::simd::vec512::{Mask16, VecI32x16, LANES};
use crate::simd::VpuCounters;
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// `--prefetch-dist auto`: sweep [`crate::bfs::policy::PREFETCH_CANDIDATES`]
/// on the first hardware roots, then lock the distance with the best
/// measured ns/edge (see `PolicyFeedback::prefetch_plan`).
pub const PREFETCH_DIST_AUTO: usize = usize::MAX;

/// The distance layer kernels fall back to when asked to run with the
/// [`PREFETCH_DIST_AUTO`] sentinel still unresolved (direct layer-function
/// calls in tests, or prepared engines whose sweep has not produced a
/// sample yet). Chunks (SELL rows / adjacency chunks) ahead of the one
/// being explored.
pub const DEFAULT_PREFETCH_DIST: usize = 4;

/// §4.2 optimization toggles (the Fig 9 ablation axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SimdOpts {
    /// 64-byte-aligned chunking: peel to the 16-element boundary, full
    /// vector loads in the middle, masked remainder (§4.2 "Data alignment"
    /// / "Peel and remainder loops"). When false, every chunk is an
    /// unaligned masked load ("SIMD - no opt").
    pub aligned: bool,
    /// Software prefetching of gathers/scatters plus next-iteration rows
    /// (§4.2 "Prefetching").
    pub prefetch: bool,
    /// How many chunks ahead the **hardware** tiers issue their address
    /// prefetches (`--prefetch-dist`). [`PREFETCH_DIST_AUTO`] lets the
    /// prepared engine sweep for the best value; `0` disables the
    /// distance-tuned prefetches (the counted emulator's §4.2 prefetch
    /// *counters* are governed solely by `prefetch` and never see this
    /// knob, so event counts stay bit-identical across distances).
    pub prefetch_dist: usize,
}

impl SimdOpts {
    /// "SIMD - no opt" in Fig 9.
    pub fn none() -> Self {
        SimdOpts { aligned: false, prefetch: false, prefetch_dist: PREFETCH_DIST_AUTO }
    }

    /// "SIMD + parallel + alignment and masks" in Fig 9.
    pub fn aligned_masks() -> Self {
        SimdOpts { aligned: true, prefetch: false, prefetch_dist: PREFETCH_DIST_AUTO }
    }

    /// Full optimization set (alignment + masks + prefetching) — the
    /// configuration the headline results use.
    pub fn full() -> Self {
        SimdOpts { aligned: true, prefetch: true, prefetch_dist: PREFETCH_DIST_AUTO }
    }

    /// The concrete prefetch distance a layer kernel should use: the
    /// configured value, or [`DEFAULT_PREFETCH_DIST`] while the auto
    /// sentinel is still unresolved.
    pub fn effective_dist(&self) -> usize {
        if self.prefetch_dist == PREFETCH_DIST_AUTO {
            DEFAULT_PREFETCH_DIST
        } else {
            self.prefetch_dist
        }
    }
}

impl Default for SimdOpts {
    fn default() -> Self {
        SimdOpts::full()
    }
}

/// The paper's `simd` algorithm.
#[derive(Clone, Copy, Debug)]
pub struct VectorizedBfs {
    pub num_threads: usize,
    pub opts: SimdOpts,
    pub policy: LayerPolicy,
    /// VPU backend mode: counted emulation, hardware SIMD, or counted
    /// warm-up + hardware steady state ([`VpuMode::Auto`]).
    pub vpu: VpuMode,
}

impl Default for VectorizedBfs {
    fn default() -> Self {
        VectorizedBfs {
            num_threads: 4,
            opts: SimdOpts::full(),
            policy: LayerPolicy::default(),
            vpu: VpuMode::default(),
        }
    }
}

/// Per-thread accumulator for an explored layer.
struct ExploreAcc<V> {
    edges_scanned: usize,
    vpu: Option<V>,
}

// manual impl: `V` need not be `Default` for `Option<V>` to default
#[allow(clippy::derivable_impls)]
impl<V> Default for ExploreAcc<V> {
    fn default() -> Self {
        ExploreAcc { edges_scanned: 0, vpu: None }
    }
}

/// Explore one vertex's adjacency chunk `[offset, offset+n)` (n ≤ 16) with
/// the Listing-1 instruction sequence. `chunk_mask` filters peel/remainder
/// lanes (§4.2).
#[allow(clippy::too_many_arguments)]
fn explore_chunk<V: VpuBackend>(
    vpu: &mut V,
    rows: &[u32],
    offset: usize,
    chunk_mask: Mask16,
    full: bool,
    u: Vertex,
    nodes: Pred,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
    prefetch: bool,
) {
    vpu.note_explore_issue(chunk_mask.count());
    // 1.- Load adjacency list to the register
    let vneig = if full {
        vpu.load_vertices(rows, offset)
    } else {
        vpu.mask_load_vertices(chunk_mask, rows, offset)
    };

    // 2.- Getting word and bit offset
    let bits_per_word = vpu.set1_epi32(BITS_PER_WORD as i32);
    let vword = vpu.div_epi32(vneig, bits_per_word);
    let vbits = vpu.rem_epi32(vneig, bits_per_word);

    // Gathering words from visited / output bitmap arrays
    if prefetch {
        vpu.prefetch_i32gather(vword, PrefetchHint::T0);
    }
    let vis_words = vpu.mask_gather_shared_words(chunk_mask, vword, visited.atomic_words());
    let out_words = vpu.mask_gather_shared_words(chunk_mask, vword, out.atomic_words());

    // Shifting 1 to the left by the bit offsets
    let one = vpu.set1_epi32(1);
    let bits = vpu.sllv_epi32(one, vbits);

    // mask = knot(kor(test(vis, bits), test(out, bits))) ∧ chunk_mask
    let m_vis = vpu.test_epi32_mask(vis_words, bits);
    let m_out = vpu.test_epi32_mask(out_words, bits);
    let m_seen = vpu.kor(m_vis, m_out);
    let m_new_all = vpu.knot(m_seen);
    let mask = vpu.kand(m_new_all, chunk_mask);
    if mask.is_empty() {
        return;
    }

    // 3.- Scattering P (bfs_tree) and output queue
    if prefetch {
        vpu.mask_prefetch_i32scatter(mask, vneig, PrefetchHint::T0);
    }
    // P[v] = u - nodes  (negative marker — the restoration journal)
    let parent_marked = vpu.set1_epi32(u as Pred - nodes);
    vpu.mask_scatter_shared_i32(pred.atomic_cells(), mask, vneig, parent_marked);

    // Setting the output queue: out_word | bit for the surviving lanes.
    let zero = vpu.set1_epi32(0);
    let new_values = vpu.mask_or_epi32(zero, mask, out_words, bits);
    if prefetch {
        vpu.mask_prefetch_i32scatter(mask, vword, PrefetchHint::T0);
    }
    // Word-granularity racy scatter: intra-vector duplicates lose bits
    // (highest lane wins) — the §3.3.2 hazard, repaired by restoration.
    vpu.mask_scatter_shared_words(out.atomic_words(), mask, vword, new_values);
}

/// Explore one vertex's whole adjacency list, chunked per §4.2, over any
/// [`Adjacency`] layout — the raw [`Csr`] (peel/full/remainder) or the
/// prepared [`PaddedCsr`] view whose aligned starts make the peel loop
/// vanish. Shared with the SELL engine's per-vertex chunking mode.
#[allow(clippy::too_many_arguments)]
pub(crate) fn explore_vertex<A: Adjacency + ?Sized, V: VpuBackend>(
    vpu: &mut V,
    g: &A,
    u: Vertex,
    nodes: Pred,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
    opts: SimdOpts,
) -> usize {
    let (start, end) = g.adjacency_range(u);
    let degree = end - start;
    if degree == 0 {
        return 0;
    }
    let rows = g.rows();
    let dist = opts.effective_dist();

    if opts.prefetch {
        if V::COUNTED {
            // Prefetch the rows array for the vertices processed next
            // iteration (§4.2, after Jha et al. [14]). The counted
            // emulator models this through the index-based hint so the
            // event counters never depend on the tuned distance.
            vpu.prefetch_scalar(PrefetchHint::T1);
        } else if dist > 0 {
            // Hardware tiers issue a real address prefetch `dist` chunks
            // into the adjacency segment.
            if let Some(r) = rows.get(start + dist * LANES) {
                vpu.prefetch_addr((r as *const u32).cast(), PrefetchHint::T1);
            }
        }
    }

    if !opts.aligned {
        // "SIMD - no opt": no peel/remainder structure; every chunk is an
        // unaligned masked load.
        let mut off = start;
        while off < end {
            let n = (end - off).min(LANES);
            let m = Mask16::first_n(n);
            vpu.note_remainder(n);
            explore_chunk(vpu, rows, off, m, false, u, nodes, visited, out, pred, opts.prefetch);
            off += n;
        }
        return degree;
    }

    // Aligned mode: peel up to the 16-element boundary of `rows`, full
    // vectors through the middle, masked remainder at the tail.
    let aligned_start = start.next_multiple_of(LANES);
    let peel_end = aligned_start.min(end);
    if peel_end > start {
        let n = peel_end - start;
        vpu.note_peel(n);
        explore_chunk(
            vpu,
            rows,
            start,
            Mask16::first_n(n),
            false,
            u,
            nodes,
            visited,
            out,
            pred,
            opts.prefetch,
        );
    }
    let mut off = peel_end;
    while off + LANES <= end {
        if !V::COUNTED && opts.prefetch && dist > 0 {
            // stream-ahead: keep the rows line `dist` chunks out in flight
            if let Some(r) = rows.get(off + dist * LANES) {
                vpu.prefetch_addr((r as *const u32).cast(), PrefetchHint::T1);
            }
        }
        vpu.note_full_chunk();
        explore_chunk(vpu, rows, off, Mask16::ALL, true, u, nodes, visited, out, pred, opts.prefetch);
        off += LANES;
    }
    if off < end {
        let n = end - off;
        vpu.note_remainder(n);
        explore_chunk(
            vpu,
            rows,
            off,
            Mask16::first_n(n),
            false,
            u,
            nodes,
            visited,
            out,
            pred,
            opts.prefetch,
        );
    }
    degree
}

/// Per-vertex (Listing 1) exploration of one whole layer, parallel over
/// the frontier's bitmap words. Returns (edges scanned, merged VPU
/// counters). Shared by the `simd` engine and the sell engine's
/// per-vertex chunking mode; generic over the [`Adjacency`] layout so a
/// prepared engine can traverse the aligned padded view.
#[allow(clippy::too_many_arguments)]
pub(crate) fn explore_layer_per_vertex<A: Adjacency + ?Sized, V: VpuBackend>(
    num_threads: usize,
    g: &A,
    input: &Bitmap,
    nodes: Pred,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
    opts: SimdOpts,
) -> (usize, VpuCounters) {
    let n = g.num_vertices();
    let in_words = input.words();
    let accs: Vec<ExploreAcc<V>> = parallel_for_dynamic(
        num_threads,
        in_words.len(),
        WORD_GRAIN,
        // the whole per-thread chunk runs inside the backend's
        // #[target_feature] envelope so Listing 1 fuses per tier
        |_tid, range, acc: &mut ExploreAcc<V>| {
            crate::simd::fused::fuse::<V, _, _>(|| {
                for w in range {
                    let mut word = in_words[w];
                    while word != 0 {
                        let bit = word.trailing_zeros();
                        word &= word - 1;
                        let u = Bitmap::bit_to_vertex(w, bit);
                        if (u as usize) >= n {
                            continue;
                        }
                        let vpu = acc.vpu.get_or_insert_with(V::new);
                        acc.edges_scanned +=
                            explore_vertex(vpu, g, u, nodes, visited, out, pred, opts);
                    }
                }
            })
        },
    );
    let mut edges = 0usize;
    let mut vpu = VpuCounters::default();
    for a in accs {
        edges += a.edges_scanned;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (edges, vpu)
}

/// Scalar parallel top-down step over a bitmap frontier (Algorithm 2 with
/// atomics — the §4.1 fallback for layers not worth vectorizing). Returns
/// edges scanned. Shared by the `simd` and `sell` engines.
pub(crate) fn scalar_fallback_layer(
    num_threads: usize,
    g: &Csr,
    input: &Bitmap,
    visited: &SharedBitmap,
    out: &SharedBitmap,
    pred: &SharedPred,
) -> usize {
    let n = g.num_vertices();
    let in_words = input.words();
    let accs: Vec<usize> = parallel_for_dynamic(
        num_threads,
        in_words.len(),
        WORD_GRAIN,
        |_tid, range, acc: &mut usize| {
            for w in range {
                let mut word = in_words[w];
                while word != 0 {
                    let bit = word.trailing_zeros();
                    word &= word - 1;
                    let u = Bitmap::bit_to_vertex(w, bit);
                    if (u as usize) >= n {
                        continue;
                    }
                    for &v in g.neighbors(u) {
                        *acc += 1;
                        if !visited.test_bit(v) && !out.test_bit(v) {
                            out.set_bit_atomic(v);
                            visited.set_bit_atomic(v);
                            pred.set(v, u as Pred);
                        }
                    }
                }
            }
        },
    );
    accs.iter().sum()
}

/// Vectorized restoration (§4, closing paragraphs): for every non-zero
/// `out` word, process its low and high 16-bit halves as 16-lane vectors —
/// gather the predecessors, select `P < 0`, rebuild the word's bit pattern
/// with a horizontal OR, commit to `out` and `visited`, and add `nodes`
/// back to the repaired predecessor entries.
pub fn restore_layer_simd<V: VpuBackend>(
    num_threads: usize,
    out: &SharedBitmap,
    visited: &SharedBitmap,
    pred: &SharedPred,
    nodes: Pred,
) -> (super::bitrace_free::RestoreStats, VpuCounters) {
    struct Acc<V> {
        stats: super::bitrace_free::RestoreStats,
        vpu: Option<V>,
    }
    #[allow(clippy::derivable_impls)]
    impl<V> Default for Acc<V> {
        fn default() -> Self {
            Acc { stats: Default::default(), vpu: None }
        }
    }
    let n = out.len();
    let num_words = out.num_words();
    let accs: Vec<Acc<V>> = parallel_for_dynamic(
        num_threads,
        num_words,
        WORD_GRAIN,
        |_tid, range, acc: &mut Acc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            for w in range {
                let word = out.word(w);
                if word == 0 {
                    continue;
                }
                acc.stats.words_scanned += 1;
                // The word covers 32 vertices but the VPU holds 16 lanes:
                // split into the low and the high half (§4).
                for half in 0..2usize {
                    let base_bit = half as i32 * 16;
                    let first_vertex = Bitmap::bit_to_vertex(w, base_bit as u32);
                    // lanes beyond the bitmap length are masked off
                    let valid = (n as i64 - first_vertex as i64).clamp(0, 16) as usize;
                    if valid == 0 {
                        continue;
                    }
                    let lane_mask = Mask16::first_n(valid);
                    // vvertex = w*32 + base_bit + lane
                    let mut vertex_arr = [0i32; LANES];
                    for (lane, x) in vertex_arr.iter_mut().enumerate() {
                        *x = first_vertex as i32 + lane as i32;
                    }
                    let vvertex = VecI32x16(vertex_arr);
                    let pvals = vpu.mask_gather_shared_i32(lane_mask, vvertex, pred.atomic_cells());
                    let zero = vpu.set1_epi32(0);
                    let m_neg_all = vpu.cmplt_epi32_mask(pvals, zero);
                    let m_neg = vpu.kand(m_neg_all, lane_mask);
                    if m_neg.is_empty() {
                        continue;
                    }
                    // track genuine lost bits for the trace
                    for lane in 0..LANES {
                        if m_neg.test_lane(lane) {
                            let bit = base_bit as u32 + lane as u32;
                            if (word >> bit) & 1 == 0 {
                                acc.stats.lost_bits_fixed += 1;
                            }
                            acc.stats.repaired += 1;
                        }
                    }
                    // rebuild the half-word bit pattern: 1 << (base_bit+lane)
                    let mut shift_arr = [0i32; LANES];
                    for (lane, x) in shift_arr.iter_mut().enumerate() {
                        *x = base_bit + lane as i32;
                    }
                    let one = vpu.set1_epi32(1);
                    let bits = vpu.sllv_epi32(one, VecI32x16(shift_arr));
                    let patch = vpu.mask_reduce_or_epi32(m_neg, bits) as u32;
                    out.or_word_atomic(w, patch);
                    visited.or_word_atomic(w, patch);
                    // P[vertex] += nodes for the repaired lanes
                    let vnodes = vpu.set1_epi32(nodes);
                    let restored = vpu.add_epi32(pvals, vnodes);
                    vpu.mask_scatter_shared_i32(pred.atomic_cells(), m_neg, vvertex, restored);
                }
            }
        }),
    );
    let mut stats = super::bitrace_free::RestoreStats::default();
    let mut vpu = VpuCounters::default();
    for a in accs {
        stats.words_scanned += a.stats.words_scanned;
        stats.repaired += a.stats.repaired;
        stats.lost_bits_fixed += a.stats.lost_bits_fixed;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (stats, vpu)
}

/// Resolve the [`PREFETCH_DIST_AUTO`] sentinel for one traversal: on a
/// hardware backend, pick the next unsampled sweep candidate (or the
/// locked winner once the sweep is done) from the graph's shared
/// [`PolicyFeedback`]. Returns whether this run is a sweep **sample**
/// whose wall time should be recorded afterwards via
/// [`PolicyFeedback::record_prefetch_sample`]. Counted traversals keep
/// the sentinel (the emulator never reads the distance), so the sweep
/// spends hardware roots only.
pub(crate) fn plan_prefetch(opts: &mut SimdOpts, fb: &PolicyFeedback, select: VpuSelect) -> bool {
    if opts.prefetch_dist != PREFETCH_DIST_AUTO || !opts.prefetch || select == VpuSelect::Counted {
        return false;
    }
    let (dist, sampling) = fb.prefetch_plan();
    opts.prefetch_dist = dist;
    sampling
}

/// A [`VectorizedBfs`] bound to one graph: carries the aligned
/// [`PaddedCsr`] view (when `opts.aligned` is on) so every root's
/// traversal reuses it instead of peeling unaligned segment heads.
pub struct PreparedSimd<'g> {
    g: &'g Csr,
    padded: Option<Arc<PaddedCsr>>,
    engine: VectorizedBfs,
    artifacts: Arc<GraphArtifacts>,
}

impl PreparedBfs for PreparedSimd<'_> {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        // backend dispatch, once per traversal: the layer loops below
        // monomorphize per backend (crate::with_vpu_backend)
        let fb = self.artifacts.feedback();
        let (select, warmup) = resolve(self.engine.vpu, fb.roots_done());
        let mut engine = self.engine;
        let sampling = plan_prefetch(&mut engine.opts, fb, select);
        let mut r = crate::with_vpu_backend!(select, V, engine.traverse::<V>(
            self.g,
            self.padded.as_deref(),
            root,
            ctl
        ));
        if sampling {
            fb.record_prefetch_sample(
                engine.opts.prefetch_dist,
                r.trace.total_wall_ns(),
                r.trace.total_edges_scanned(),
            );
        }
        if self.engine.vpu == VpuMode::Auto {
            // the simd engine records no policy feedback of its own, so
            // advance the auto warm-up count explicitly
            fb.record_root();
        }
        r.trace.counted_warmup = warmup;
        r
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

impl BfsEngine for VectorizedBfs {
    fn name(&self) -> &'static str {
        "simd"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        // the padded view only pays off when aligned chunking is on —
        // unaligned mode issues masked loads regardless; under governor
        // memory pressure it comes back `None` and the peel loop returns
        let padded = if self.opts.aligned { artifacts.padded_csr(g) } else { None };
        Ok(Box::new(PreparedSimd { g, padded, engine: *self, artifacts }))
    }
}

impl VectorizedBfs {
    /// One traversal over `g`, exploring through `padded` when present,
    /// on VPU backend `V` (monomorphized per backend by the dispatch in
    /// [`PreparedSimd::run`]).
    fn traverse<V: VpuBackend>(
        &self,
        g: &Csr,
        padded: Option<&PaddedCsr>,
        root: Vertex,
        ctl: &RunControl,
    ) -> BfsResult {
        let n = g.num_vertices();
        let nodes = n as Pred;
        let pred = SharedPred::new_infinity(n);
        let visited = SharedBitmap::new(n);
        let mut input = Bitmap::new(n);
        let output = SharedBitmap::new(n);

        input.set_bit(root);
        visited.set_bit_atomic(root);
        pred.set(root, root as Pred);

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut frontier_count = 1usize;
        let mut nontrivial_seen = 0usize;
        let mut status = RunStatus::Complete;
        while frontier_count != 0 {
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            // estimate the layer's edge volume for the policy decision
            let input_edges: usize =
                input.iter_set_bits().map(|u| g.degree(u)).sum();
            let vectorize = self.policy.vectorize(nontrivial_seen, frontier_count, input_edges);
            if frontier_count > 1 {
                nontrivial_seen += 1;
            }

            let (edges_scanned, rstats, vpu_counters) = if vectorize {
                // ---- SIMD exploration (Listing 1) ----
                let adj: &dyn Adjacency = match padded {
                    Some(p) => p,
                    None => g,
                };
                let (edges, mut vpu_total) = explore_layer_per_vertex::<dyn Adjacency, V>(
                    self.num_threads,
                    adj,
                    &input,
                    nodes,
                    &visited,
                    &output,
                    &pred,
                    self.opts,
                );
                // ---- vectorized restoration ----
                let (rstats, restore_vpu) =
                    restore_layer_simd::<V>(self.num_threads, &output, &visited, &pred, nodes);
                vpu_total.merge(&restore_vpu);
                (edges, rstats, vpu_total)
            } else {
                // ---- scalar parallel fallback (Algorithm 2, §4.1) ----
                let edges =
                    scalar_fallback_layer(self.num_threads, g, &input, &visited, &output, &pred);
                (edges, Default::default(), VpuCounters::default())
            };

            let traversed = output.count_ones();
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier_count,
                edges_scanned,
                traversed,
                restore_words_scanned: rstats.words_scanned,
                restore_fixed: rstats.lost_bits_fixed,
                vectorized: vectorize,
                bottom_up: false,
                vpu: vpu_counters,
                wall_ns: t0.elapsed().as_nanos() as u64,
            });

            let snap = output.snapshot();
            frontier_count = snap.count_ones();
            input = snap;
            output.clear_all();
            layer += 1;
        }

        BfsResult {
            tree: BfsTree::new(root, pred.into_vec()),
            trace: RunTrace { layers, num_threads: self.num_threads, status, ..Default::default() },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::simd::ops::Vpu;
    use crate::PRED_INFINITY;

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    fn assert_matches_serial(g: &Csr, root: Vertex, alg: VectorizedBfs) {
        let s = SerialLayeredBfs.run(g, root);
        let v = alg.run(g, root);
        assert_eq!(
            v.tree.distances().unwrap(),
            s.tree.distances().unwrap(),
            "distances differ for {:?}",
            alg
        );
    }

    #[test]
    fn matches_serial_all_policies() {
        let g = rmat(10, 8, 31);
        for policy in [LayerPolicy::All, LayerPolicy::None, LayerPolicy::FirstK(2), LayerPolicy::heavy()] {
            assert_matches_serial(&g, 0, VectorizedBfs { num_threads: 2, opts: SimdOpts::full(), policy, ..Default::default() });
        }
    }

    #[test]
    fn matches_serial_all_opt_levels() {
        let g = rmat(10, 16, 32);
        for opts in [SimdOpts::none(), SimdOpts::aligned_masks(), SimdOpts::full()] {
            assert_matches_serial(
                &g,
                5,
                VectorizedBfs { num_threads: 4, opts, policy: LayerPolicy::All, ..Default::default() },
            );
        }
    }

    #[test]
    fn scatter_conflicts_occur_and_get_repaired() {
        // A hub whose children are packed into few bitmap words forces
        // intra-vector scatter conflicts.
        let el = EdgeList::with_edges(64, (1..64).map(|i| (0u32, i as Vertex)).collect());
        let g = Csr::from_edge_list(0, &el);
        let r = VectorizedBfs {
            num_threads: 1,
            opts: SimdOpts::full(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        let vpu = r.trace.vpu_totals();
        assert!(vpu.scatter_conflicts > 0, "dense children must collide in words");
        let fixed: usize = r.trace.layers.iter().map(|l| l.restore_fixed).sum();
        assert!(fixed > 0, "restoration must repair genuinely lost bits");
        // and the final tree is still complete
        assert_eq!(r.tree.reached_count(), 64);
    }

    #[test]
    fn aligned_mode_uses_full_chunks() {
        let g = rmat(11, 16, 33);
        let full = VectorizedBfs {
            num_threads: 2,
            opts: SimdOpts::full(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        let c = full.trace.vpu_totals();
        assert!(c.full_chunks > 0);
        assert!(c.vector_loads > 0);
        // unaligned mode must not use full loads
        let noopt = VectorizedBfs {
            num_threads: 2,
            opts: SimdOpts::none(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        let c2 = noopt.trace.vpu_totals();
        assert_eq!(c2.vector_loads, 0);
        assert_eq!(c2.full_chunks, 0);
        assert!(c2.masked_loads > 0);
    }

    #[test]
    fn prefetch_counters_only_with_prefetch() {
        let g = rmat(9, 8, 34);
        let with = VectorizedBfs {
            num_threads: 1,
            opts: SimdOpts::full(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        assert!(with.trace.vpu_totals().prefetch_l1 > 0);
        let without = VectorizedBfs {
            num_threads: 1,
            opts: SimdOpts::aligned_masks(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        let c = without.trace.vpu_totals();
        assert_eq!(c.prefetch_l1 + c.prefetch_l2, 0);
    }

    #[test]
    fn policy_mix_marks_layers() {
        let g = rmat(11, 16, 35);
        let r = VectorizedBfs {
            num_threads: 2,
            opts: SimdOpts::full(),
            policy: LayerPolicy::FirstK(2),
            ..Default::default()
        }
        .run(&g, 0);
        let vec_layers: Vec<bool> = r.trace.layers.iter().map(|l| l.vectorized).collect();
        assert!(vec_layers.iter().any(|&b| b), "some layer vectorized");
        assert!(vec_layers.iter().any(|&b| !b), "some layer scalar");
        // vectorized layers come before scalar ones under FirstK
        let first_scalar_after_vec = vec_layers
            .iter()
            .skip_while(|&&b| !b) // leading trivial scalar layers (root)
            .skip_while(|&&b| b)
            .all(|&b| !b);
        assert!(first_scalar_after_vec);
    }

    #[test]
    fn predecessors_normalized_after_run() {
        let g = rmat(10, 16, 36);
        let r = VectorizedBfs::default().run(&g, 1);
        for &p in &r.tree.pred {
            assert!(p == PRED_INFINITY || p >= 0, "negative pred survived: {p}");
        }
    }

    #[test]
    fn vector_efficiency_reported() {
        let g = rmat(11, 16, 37);
        let r = VectorizedBfs {
            num_threads: 1,
            opts: SimdOpts::full(),
            policy: LayerPolicy::All,
            vpu: VpuMode::Counted,
        }
        .run(&g, 0);
        let eff = r.trace.vpu_totals().vector_efficiency();
        assert!(eff > 0.0 && eff <= 1.0);
    }

    #[test]
    fn single_vertex_graph() {
        let el = EdgeList::with_edges(1, vec![]);
        let g = Csr::from_edge_list(0, &el);
        let r = VectorizedBfs::default().run(&g, 0);
        assert_eq!(r.tree.reached_count(), 1);
    }

    #[test]
    fn restore_layer_simd_equals_scalar_restore() {
        use crate::bfs::bitrace_free::restore_layer;
        // Build identical corrupted states and repair with both paths.
        let n = 256usize;
        let nodes = n as Pred;
        let mk = || {
            let out = SharedBitmap::new(n);
            let vis = SharedBitmap::new(n);
            let pred = SharedPred::new_infinity(n);
            // journal entries across several words, some bits lost
            for (v, parent, bit_present) in
                [(5u32, 2, false), (9, 3, true), (40, 3, true), (41, 7, false), (200, 9, false), (255, 1, true)]
            {
                pred.set(v, parent - nodes);
                if bit_present {
                    out.or_word_atomic((v / 32) as usize, 1 << (v % 32));
                } else {
                    // ensure the word is non-zero so restoration scans it
                    out.or_word_atomic((v / 32) as usize, 1 << ((v + 1) % 32));
                }
            }
            (out, vis, pred)
        };
        let (o1, v1, p1) = mk();
        let s1 = restore_layer(1, &o1, &v1, &p1, nodes);
        let (o2, v2, p2) = mk();
        let (s2, _) = restore_layer_simd::<Vpu>(1, &o2, &v2, &p2, nodes);
        assert_eq!(s1.repaired, s2.repaired);
        assert_eq!(s1.lost_bits_fixed, s2.lost_bits_fixed);
        assert_eq!(o1.snapshot().words(), o2.snapshot().words());
        assert_eq!(v1.snapshot().words(), v2.snapshot().words());
        assert_eq!(p1.snapshot(), p2.snapshot());
    }
}
