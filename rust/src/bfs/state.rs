//! Shared traversal state for the threaded algorithms.
//!
//! Rust won't let several threads mutate a plain `Vec<u32>` (that's UB), so
//! the shared bitmap and predecessor arrays are `AtomicU32`/`AtomicI32`
//! cells accessed with `Relaxed` ordering. Two update disciplines exist,
//! mirroring the paper:
//!
//! * [`SharedBitmap::set_bit_atomic`] — `__sync_fetch_and_or`, the atomic
//!   escape hatch the paper *rejects* for the vector path (§3.2: atomic bit
//!   operations are not in the vector ISA) but which is the natural
//!   implementation for the scalar parallel baseline (Algorithm 2).
//! * [`SharedBitmap::set_bit_racy`] — plain read-modify-write on the whole
//!   word (load, OR, store). Concurrent writers to the same word can lose
//!   each other's bits — the §3.3.2 bit race, deliberately preserved. The
//!   restoration process repairs the damage afterwards.

use std::sync::atomic::{AtomicI32, AtomicU32, Ordering};

use crate::graph::bitmap::{Bitmap, BITS_PER_WORD};
use crate::{Pred, Vertex, PRED_INFINITY};

/// A bitmap whose words are atomic cells (safe to share across threads; the
/// *algorithmic* races are chosen by the caller via the two set methods).
pub struct SharedBitmap {
    words: Vec<AtomicU32>,
    len: usize,
}

impl SharedBitmap {
    pub fn new(len: usize) -> Self {
        let nwords = len.div_ceil(BITS_PER_WORD as usize);
        SharedBitmap { words: (0..nwords).map(|_| AtomicU32::new(0)).collect(), len }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn num_words(&self) -> usize {
        self.words.len()
    }

    /// Atomic OR — race-free bit set (`__sync_fetch_and_or`).
    #[inline]
    pub fn set_bit_atomic(&self, v: Vertex) {
        self.words[(v / BITS_PER_WORD) as usize]
            .fetch_or(1 << (v % BITS_PER_WORD), Ordering::Relaxed);
    }

    /// Racy bit set: plain load / OR / store on the containing word.
    /// Concurrent writers to the same word can lose updates — the paper's
    /// bit race (Fig 6), kept on purpose.
    #[inline]
    pub fn set_bit_racy(&self, v: Vertex) {
        let w = (v / BITS_PER_WORD) as usize;
        let bit = 1u32 << (v % BITS_PER_WORD);
        let old = self.words[w].load(Ordering::Relaxed);
        self.words[w].store(old | bit, Ordering::Relaxed);
    }

    #[inline]
    pub fn test_bit(&self, v: Vertex) -> bool {
        (self.words[(v / BITS_PER_WORD) as usize].load(Ordering::Relaxed) >> (v % BITS_PER_WORD))
            & 1
            == 1
    }

    /// Read a whole word.
    #[inline]
    pub fn word(&self, w: usize) -> u32 {
        self.words[w].load(Ordering::Relaxed)
    }

    /// Plain (racy) whole-word store — what a vector scatter does.
    #[inline]
    pub fn store_word_racy(&self, w: usize, value: u32) {
        self.words[w].store(value, Ordering::Relaxed);
    }

    /// Atomic whole-word OR (used by restoration, which may itself run
    /// multi-threaded but partitions words disjointly; OR keeps it safe
    /// even if partitions ever overlap).
    #[inline]
    pub fn or_word_atomic(&self, w: usize, value: u32) {
        self.words[w].fetch_or(value, Ordering::Relaxed);
    }

    pub fn clear_all(&self) {
        for w in &self.words {
            w.store(0, Ordering::Relaxed);
        }
    }

    pub fn is_all_zero(&self) -> bool {
        self.words.iter().all(|w| w.load(Ordering::Relaxed) == 0)
    }

    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.load(Ordering::Relaxed).count_ones() as usize).sum()
    }

    /// The raw atomic word cells — what the vector unit's shared
    /// gather/scatter instructions operate on.
    #[inline]
    pub fn atomic_words(&self) -> &[AtomicU32] {
        &self.words
    }

    /// Snapshot into a plain [`Bitmap`].
    pub fn snapshot(&self) -> Bitmap {
        let mut b = Bitmap::new(self.len);
        for (i, w) in self.words.iter().enumerate() {
            b.set_word(i, w.load(Ordering::Relaxed));
        }
        b
    }

    /// Copy a plain bitmap's contents in.
    pub fn load_from(&self, src: &Bitmap) {
        assert_eq!(src.num_words(), self.words.len());
        for (i, w) in self.words.iter().enumerate() {
            w.store(src.word(i), Ordering::Relaxed);
        }
    }

    /// Collect set bits as vertices (test/reporting helper).
    pub fn to_vertices(&self) -> Vec<Vertex> {
        self.snapshot().to_vertices()
    }
}

/// Shared predecessor array. Plain 32-bit stores are atomic on every target
/// we run on; the benign race of §3.2 (two parents writing the same child)
/// maps to relaxed stores where either value may land — exactly the paper's
/// "different correct BFS spanning trees" outcome.
pub struct SharedPred {
    p: Vec<AtomicI32>,
}

impl SharedPred {
    /// All entries initialized to ∞ (§3.1 lines 1–3).
    pub fn new_infinity(n: usize) -> Self {
        SharedPred { p: (0..n).map(|_| AtomicI32::new(PRED_INFINITY)).collect() }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.p.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.p.is_empty()
    }

    #[inline]
    pub fn get(&self, v: Vertex) -> Pred {
        self.p[v as usize].load(Ordering::Relaxed)
    }

    #[inline]
    pub fn set(&self, v: Vertex, value: Pred) {
        self.p[v as usize].store(value, Ordering::Relaxed);
    }

    /// Compare-free add used by restoration (`P[vertex] += nodes`); safe
    /// because restoration partitions vertices disjointly across threads.
    #[inline]
    pub fn add(&self, v: Vertex, delta: Pred) {
        self.p[v as usize].fetch_add(delta, Ordering::Relaxed);
    }

    /// The raw atomic cells — target of the vector unit's predecessor
    /// scatter.
    #[inline]
    pub fn atomic_cells(&self) -> &[AtomicI32] {
        &self.p
    }

    pub fn into_vec(self) -> Vec<Pred> {
        self.p.into_iter().map(|a| a.into_inner()).collect()
    }

    pub fn snapshot(&self) -> Vec<Pred> {
        self.p.iter().map(|a| a.load(Ordering::Relaxed)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_and_racy_agree_single_threaded() {
        let a = SharedBitmap::new(100);
        let b = SharedBitmap::new(100);
        for v in [0u32, 31, 32, 63, 99] {
            a.set_bit_atomic(v);
            b.set_bit_racy(v);
        }
        assert_eq!(a.snapshot().words(), b.snapshot().words());
    }

    #[test]
    fn racy_store_word_loses_updates_by_design() {
        // Deterministic demonstration of the §3.3.2 lost update: two
        // "threads" read the same word, each ORs its own bit, stores —
        // second store wins, first bit lost.
        let bm = SharedBitmap::new(64);
        let w0_a = bm.word(0) | (1 << 5); // thread A prepares vertex 5
        let w0_b = bm.word(0) | (1 << 9); // thread B prepares vertex 9
        bm.store_word_racy(0, w0_a);
        bm.store_word_racy(0, w0_b); // clobbers A
        assert!(!bm.test_bit(5), "bit 5 must be lost");
        assert!(bm.test_bit(9));
    }

    #[test]
    fn snapshot_roundtrip() {
        let bm = SharedBitmap::new(70);
        bm.set_bit_atomic(3);
        bm.set_bit_atomic(69);
        let snap = bm.snapshot();
        let bm2 = SharedBitmap::new(70);
        bm2.load_from(&snap);
        assert_eq!(bm2.to_vertices(), vec![3, 69]);
    }

    #[test]
    fn shared_pred_infinity_and_restore_add() {
        let p = SharedPred::new_infinity(10);
        assert_eq!(p.get(4), PRED_INFINITY);
        // restoration protocol: P[v] = u - nodes, later += nodes
        p.set(4, 7 - 10);
        assert!(p.get(4) < 0);
        p.add(4, 10);
        assert_eq!(p.get(4), 7);
    }

    #[test]
    fn clear_and_count() {
        let bm = SharedBitmap::new(128);
        for v in 0..10 {
            bm.set_bit_atomic(v);
        }
        assert_eq!(bm.count_ones(), 10);
        bm.clear_all();
        assert!(bm.is_all_zero());
    }
}
