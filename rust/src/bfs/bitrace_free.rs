//! Algorithm 3 — parallel top-down BFS *without bit-race conditions*:
//! bitmap frontiers, **no atomic operations**, and the restoration process.
//!
//! §3.3: bitmap word updates are plain read-modify-write, so concurrent
//! writers to the same 32-bit word can lose each other's bits (Fig 6). The
//! predecessor array is an `i32` array — element stores don't race at bit
//! level — so it stays consistent and doubles as the repair journal:
//! during exploration a discovery writes `P[v] = u - nodes` (negative).
//! The **restoration process** (§3.3.2, Alg 3 lines 15–29) then scans the
//! non-zero words of `out`, and every vertex in them with `P[vertex] < 0`
//! gets its `out` and `visited` bits (re)set and `nodes` added back to its
//! predecessor entry.
//!
//! Note the phase structure: `visited` is updated **only** by restoration —
//! that is what keeps `visited` consistent without atomics (Alg 3 line 24).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::state::{SharedBitmap, SharedPred};
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, PreparedStateless,
    RunControl, RunStatus, RunTrace, StatelessBfs, WORD_GRAIN,
};
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::{Bitmap, Csr};
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// Parallel BFS with bitmaps, no atomics, and the restoration pass.
#[derive(Clone, Copy, Debug)]
pub struct BitRaceFreeBfs {
    pub num_threads: usize,
}

impl Default for BitRaceFreeBfs {
    fn default() -> Self {
        BitRaceFreeBfs { num_threads: 4 }
    }
}

#[derive(Default)]
struct ExploreAcc {
    edges_scanned: usize,
}

/// Statistics returned by one restoration sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RestoreStats {
    /// Non-zero `out` words scanned (Alg 3 line 18).
    pub words_scanned: usize,
    /// Vertices with `P < 0` that were normalized (bit set + P += nodes).
    pub repaired: usize,
    /// Subset of `repaired` whose `out` bit was actually missing — i.e.
    /// genuine lost updates, the Fig 6 corruption.
    pub lost_bits_fixed: usize,
}

/// The scalar restoration process (Alg 3 lines 15–29), exposed standalone so
/// the vectorized algorithm and the corruption-injection tests can reuse it.
///
/// Scans `out` at word granularity; for every vertex in a non-zero word
/// whose predecessor entry is negative: set its `out` bit, set its
/// `visited` bit, and add `nodes` back to the predecessor entry.
pub fn restore_layer(
    num_threads: usize,
    out: &SharedBitmap,
    visited: &SharedBitmap,
    pred: &SharedPred,
    nodes: Pred,
) -> RestoreStats {
    let n = out.len();
    let num_words = out.num_words();
    let stats: Vec<RestoreStats> = parallel_for_dynamic(
        num_threads,
        num_words,
        WORD_GRAIN,
        |_tid, range, acc: &mut RestoreStats| {
            for w in range {
                let word = out.word(w);
                if word == 0 {
                    continue; // line 18
                }
                acc.words_scanned += 1;
                // lines 20-27: step through every bit position of the word
                for b in 0..BITS_PER_WORD {
                    let vertex = Bitmap::bit_to_vertex(w, b);
                    if vertex as usize >= n {
                        break;
                    }
                    if pred.get(vertex) < 0 {
                        // line 22
                        if (word >> b) & 1 == 0 {
                            acc.lost_bits_fixed += 1;
                        }
                        out.or_word_atomic(w, 1 << b); // line 23
                        visited.set_bit_atomic(vertex); // line 24
                        pred.add(vertex, nodes); // line 25
                        acc.repaired += 1;
                    }
                }
            }
        },
    );
    let mut total = RestoreStats::default();
    for s in stats {
        total.words_scanned += s.words_scanned;
        total.repaired += s.repaired;
        total.lost_bits_fixed += s.lost_bits_fixed;
    }
    total
}

impl StatelessBfs for BitRaceFreeBfs {
    fn name(&self) -> &'static str {
        "bitrace-free"
    }

    fn traverse(&self, g: &Csr, root: Vertex, ctl: &RunControl) -> BfsResult {
        let n = g.num_vertices();
        let nodes = n as Pred;
        let pred = SharedPred::new_infinity(n);
        let visited = SharedBitmap::new(n);
        let mut input = Bitmap::new(n);
        let output = SharedBitmap::new(n);

        input.set_bit(root); // line 4
        visited.set_bit_atomic(root); // line 5
        pred.set(root, root as Pred); // line 6

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut frontier_count = 1usize;
        let mut status = RunStatus::Complete;
        while frontier_count != 0 {
            // Checked only between layers: a stop can never land between
            // exploration and restoration, so no negative journal entries
            // survive in the returned tree.
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let in_words = input.words();
            // --- exploration (lines 8-14): racy word updates, no atomics ---
            let accs: Vec<ExploreAcc> = parallel_for_dynamic(
                self.num_threads,
                in_words.len(),
                WORD_GRAIN,
                |_tid, range, acc: &mut ExploreAcc| {
                    for w in range {
                        let mut word = in_words[w];
                        while word != 0 {
                            let bit = word.trailing_zeros();
                            word &= word - 1;
                            let u = Bitmap::bit_to_vertex(w, bit);
                            if (u as usize) >= n {
                                continue;
                            }
                            for &v in g.neighbors(u) {
                                acc.edges_scanned += 1;
                                // line 10: filter on visited OR out
                                if !visited.test_bit(v) && !output.test_bit(v) {
                                    output.set_bit_racy(v); // line 11 (racy!)
                                    pred.set(v, u as Pred - nodes); // line 12
                                }
                            }
                        }
                    }
                },
            );
            // --- restoration (lines 15-29) ---
            let rstats = restore_layer(self.num_threads, &output, &visited, &pred, nodes);

            let edges_scanned: usize = accs.iter().map(|a| a.edges_scanned).sum();
            let traversed = output.count_ones();
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier_count,
                edges_scanned,
                traversed,
                restore_words_scanned: rstats.words_scanned,
                restore_fixed: rstats.lost_bits_fixed,
                wall_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            });

            // line 31: swap(in, out); out ← 0
            let snap = output.snapshot();
            frontier_count = snap.count_ones();
            input = snap;
            output.clear_all();
            layer += 1;
        }

        BfsResult {
            tree: BfsTree::new(root, pred.into_vec()),
            trace: RunTrace { layers, num_threads: self.num_threads, status, ..Default::default() },
        }
    }
}

impl BfsEngine for BitRaceFreeBfs {
    fn name(&self) -> &'static str {
        "bitrace-free"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        Ok(Box::new(PreparedStateless::new(g, *self, artifacts)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::PRED_INFINITY;

    #[test]
    fn matches_serial_on_rmat() {
        let el = RmatConfig::graph500(11, 8).generate(21);
        let g = Csr::from_edge_list(11, &el);
        let s = SerialLayeredBfs.run(&g, 3);
        for t in [1, 4] {
            let r = BitRaceFreeBfs { num_threads: t }.run(&g, 3);
            assert_eq!(r.tree.distances().unwrap(), s.tree.distances().unwrap());
        }
    }

    #[test]
    fn predecessors_all_normalized() {
        // After the run no negative predecessor entries may survive.
        let el = RmatConfig::graph500(10, 8).generate(2);
        let g = Csr::from_edge_list(10, &el);
        let r = BitRaceFreeBfs::default().run(&g, 0);
        for &p in &r.tree.pred {
            assert!(p == PRED_INFINITY || p >= 0);
        }
    }

    #[test]
    fn restoration_repairs_injected_corruption() {
        // Simulate Fig 6 exactly: vertices 5 and 9 share word 0; thread B's
        // store clobbered thread A's bit for vertex 5. P carries both
        // journal entries.
        let n = 64usize;
        let nodes = n as Pred;
        let out = SharedBitmap::new(n);
        let visited = SharedBitmap::new(n);
        let pred = SharedPred::new_infinity(n);
        // journal: both discovered, parents 2 and 3
        pred.set(5, 2 - nodes);
        pred.set(9, 3 - nodes);
        // corrupted word: only vertex 9's bit survived
        out.store_word_racy(0, 1 << 9);

        let stats = restore_layer(2, &out, &visited, &pred, nodes);
        assert_eq!(stats.repaired, 2);
        assert_eq!(stats.lost_bits_fixed, 1); // vertex 5's bit was missing
        assert!(out.test_bit(5), "lost bit must be restored");
        assert!(out.test_bit(9));
        assert!(visited.test_bit(5) && visited.test_bit(9));
        assert_eq!(pred.get(5), 2);
        assert_eq!(pred.get(9), 3);
    }

    #[test]
    fn restoration_ignores_clean_words() {
        let n = 96usize;
        let nodes = n as Pred;
        let out = SharedBitmap::new(n);
        let visited = SharedBitmap::new(n);
        let pred = SharedPred::new_infinity(n);
        // a word with a set bit but non-negative pred (already restored)
        out.store_word_racy(1, 1 << 0); // vertex 32
        pred.set(32, 7);
        let stats = restore_layer(1, &out, &visited, &pred, nodes);
        assert_eq!(stats.repaired, 0);
        assert_eq!(stats.words_scanned, 1);
        assert_eq!(pred.get(32), 7);
    }

    #[test]
    fn restoration_is_idempotent() {
        let n = 64usize;
        let nodes = n as Pred;
        let out = SharedBitmap::new(n);
        let visited = SharedBitmap::new(n);
        let pred = SharedPred::new_infinity(n);
        pred.set(10, 4 - nodes);
        out.store_word_racy(0, 1 << 12); // vertex 10's bit lost, 12 present
        pred.set(12, 4 - nodes);
        restore_layer(1, &out, &visited, &pred, nodes);
        let snap1 = out.snapshot();
        let p1 = pred.snapshot();
        restore_layer(1, &out, &visited, &pred, nodes);
        assert_eq!(out.snapshot().words(), snap1.words());
        assert_eq!(pred.snapshot(), p1);
    }

    #[test]
    fn trace_counts_restoration_work() {
        let el = RmatConfig::graph500(10, 16).generate(4);
        let g = Csr::from_edge_list(10, &el);
        // root at the highest-degree vertex so the traversal covers the
        // giant component (vertex 0 may be isolated after permutation)
        let root = (0..g.num_vertices() as Vertex).max_by_key(|&v| g.degree(v)).unwrap();
        let r = BitRaceFreeBfs { num_threads: 2 }.run(&g, root);
        // restoration scans at least the words holding discoveries
        let scanned: usize = r.trace.layers.iter().map(|l| l.restore_words_scanned).sum();
        assert!(scanned > 0);
    }

    #[test]
    fn star_graph_heavy_collision_layer() {
        // A hub exploding into 200 children exercises many same-word writes
        // within one layer.
        let el = EdgeList::with_edges(201, (1..=200).map(|i| (0u32, i as Vertex)).collect());
        let g = Csr::from_edge_list(0, &el);
        let s = SerialLayeredBfs.run(&g, 0);
        let r = BitRaceFreeBfs { num_threads: 8 }.run(&g, 0);
        assert_eq!(r.tree.distances().unwrap(), s.tree.distances().unwrap());
        assert_eq!(r.tree.reached_count(), 201);
    }
}
