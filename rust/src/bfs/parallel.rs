//! Algorithm 2 — the parallel top-down BFS (the paper's `non-simd` version).
//!
//! §3.2: the outer (input-list) loop is parallelized across OpenMP threads;
//! the inner (adjacency) loop stays scalar here — exploiting it is the job
//! of the vector unit in §4. Bit updates use the atomic
//! `__sync_fetch_and_or` escape hatch the paper mentions, so no restoration
//! is needed; the predecessor write keeps the *benign* race (either parent
//! may win, both give a correct spanning tree).
//!
//! Scheduling is OpenMP `schedule(dynamic)` over bitmap words of the input
//! frontier — the skewed RMAT degrees make static partitions badly
//! imbalanced (§6.1 attributes the TEPS jitter at high thread counts to
//! exactly this imbalance).

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::state::{SharedBitmap, SharedPred};
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, PreparedStateless,
    RunControl, RunStatus, RunTrace, StatelessBfs, WORD_GRAIN,
};
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::{Bitmap, Csr};
use crate::threads::parallel_for_dynamic;
use crate::{Pred, Vertex};

/// Parallel non-SIMD top-down BFS.
#[derive(Clone, Copy, Debug)]
pub struct ParallelBfs {
    /// Worker threads (the paper sweeps 1..240).
    pub num_threads: usize,
}

impl Default for ParallelBfs {
    fn default() -> Self {
        ParallelBfs { num_threads: 4 }
    }
}

/// Per-thread accumulator for one layer.
#[derive(Default)]
struct LayerAcc {
    edges_scanned: usize,
    traversed: usize,
}

impl StatelessBfs for ParallelBfs {
    fn name(&self) -> &'static str {
        "non-simd"
    }

    fn traverse(&self, g: &Csr, root: Vertex, ctl: &RunControl) -> BfsResult {
        let n = g.num_vertices();
        let pred = SharedPred::new_infinity(n);
        let visited = SharedBitmap::new(n);
        let mut input = Bitmap::new(n);
        let output = SharedBitmap::new(n);

        input.set_bit(root); // line 4
        visited.set_bit_atomic(root); // line 5
        pred.set(root, root as Pred); // line 6

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut frontier_count = 1usize;
        let mut status = RunStatus::Complete;
        while frontier_count != 0 {
            // line 7
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let in_words = input.words();
            let accs: Vec<LayerAcc> = parallel_for_dynamic(
                self.num_threads,
                in_words.len(),
                WORD_GRAIN,
                |_tid, range, acc: &mut LayerAcc| {
                    for w in range {
                        let mut word = in_words[w];
                        while word != 0 {
                            let bit = word.trailing_zeros();
                            word &= word - 1;
                            let u = Bitmap::bit_to_vertex(w, bit);
                            if (u as usize) >= n {
                                continue;
                            }
                            // lines 9-14: scalar adjacency exploration
                            for &v in g.neighbors(u) {
                                acc.edges_scanned += 1;
                                if !visited.test_bit(v) && !output.test_bit(v) {
                                    // atomic variant: no bit race, no
                                    // restoration; benign pred race remains.
                                    output.set_bit_atomic(v);
                                    visited.set_bit_atomic(v);
                                    pred.set(v, u as Pred);
                                    acc.traversed += 1;
                                }
                            }
                        }
                    }
                },
            );

            let edges_scanned: usize = accs.iter().map(|a| a.edges_scanned).sum();
            // `traversed` from per-thread counters can double-count under the
            // benign race (two threads passing the test before either sets
            // the bit); report the exact popcount instead.
            let traversed = output.count_ones();
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier_count,
                edges_scanned,
                traversed,
                wall_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            });

            // line 16: swap(in, out); out ← 0
            let snap = output.snapshot();
            frontier_count = snap.count_ones();
            input = snap;
            output.clear_all();
            layer += 1;
        }

        BfsResult {
            tree: BfsTree::new(root, pred.into_vec()),
            trace: RunTrace { layers, num_threads: self.num_threads, status, ..Default::default() },
        }
    }
}

impl BfsEngine for ParallelBfs {
    fn name(&self) -> &'static str {
        "non-simd"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        Ok(Box::new(PreparedStateless::new(g, *self, artifacts)))
    }
}

/// Sanity helper shared by tests: number of words a frontier of `n` vertices
/// occupies.
#[allow(dead_code)]
fn words_for(n: usize) -> usize {
    n.div_ceil(BITS_PER_WORD as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::graph::{EdgeList, RmatConfig};

    fn agree_with_serial(g: &Csr, root: Vertex, threads: usize) {
        let serial = SerialLayeredBfs.run(g, root);
        let par = ParallelBfs { num_threads: threads }.run(g, root);
        assert_eq!(
            par.tree.distances().unwrap(),
            serial.tree.distances().unwrap(),
            "distance maps differ (threads={threads})"
        );
    }

    #[test]
    fn matches_serial_small() {
        let el = EdgeList::with_edges(7, vec![(1, 2), (1, 3), (2, 4), (3, 4), (3, 5), (4, 6)]);
        let g = Csr::from_edge_list(0, &el);
        for t in [1, 2, 4, 8] {
            agree_with_serial(&g, 1, t);
        }
    }

    #[test]
    fn matches_serial_rmat() {
        let el = RmatConfig::graph500(11, 8).generate(3);
        let g = Csr::from_edge_list(11, &el);
        for root in [0u32, 7, 100] {
            agree_with_serial(&g, root, 4);
        }
    }

    #[test]
    fn layer_structure_matches_serial() {
        let el = RmatConfig::graph500(10, 8).generate(9);
        let g = Csr::from_edge_list(10, &el);
        let s = SerialLayeredBfs.run(&g, 2);
        let p = ParallelBfs { num_threads: 3 }.run(&g, 2);
        assert_eq!(p.trace.layers.len(), s.trace.layers.len());
        for (pl, sl) in p.trace.layers.iter().zip(s.trace.layers.iter()) {
            assert_eq!(pl.input_vertices, sl.input_vertices);
            assert_eq!(pl.edges_scanned, sl.edges_scanned);
            assert_eq!(pl.traversed, sl.traversed);
        }
    }

    #[test]
    fn single_thread_equals_multi() {
        let el = RmatConfig::graph500(9, 8).generate(5);
        let g = Csr::from_edge_list(9, &el);
        let a = ParallelBfs { num_threads: 1 }.run(&g, 0);
        let b = ParallelBfs { num_threads: 6 }.run(&g, 0);
        assert_eq!(a.tree.distances().unwrap(), b.tree.distances().unwrap());
    }

    #[test]
    fn unreached_vertices_stay_infinity() {
        let el = EdgeList::with_edges(10, vec![(0, 1), (1, 2)]);
        let g = Csr::from_edge_list(0, &el);
        let r = ParallelBfs { num_threads: 2 }.run(&g, 0);
        assert_eq!(r.tree.reached_count(), 3);
        for v in 3..10u32 {
            assert!(!r.tree.reached(v));
        }
    }
}
