//! Algorithm 1 — the serial top-down BFS.
//!
//! §3.1: two lists (`in`, `out`) processed layer by layer, a `visited`
//! array, and the predecessor array `P` that *is* the output spanning tree.
//! The classic single-queue variant is also provided ([`SerialQueueBfs`]) —
//! it is the O(V+E) baseline the paper starts from, and its tree is the
//! reference everything else is property-tested against.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use super::control::SERIAL_CHECK_GRAIN;
use super::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, PreparedStateless,
    RunControl, RunStatus, RunTrace, StatelessBfs,
};
use crate::graph::{Bitmap, Csr};
use crate::{Pred, Vertex, PRED_INFINITY};

/// Classic FIFO-queue serial BFS (the Θ(1) enqueue/dequeue formulation the
/// paper's §3 opens with). No layer structure — one trace entry total.
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialQueueBfs;

impl StatelessBfs for SerialQueueBfs {
    fn name(&self) -> &'static str {
        "serial-queue"
    }

    fn traverse(&self, g: &Csr, root: Vertex, ctl: &RunControl) -> BfsResult {
        let start = Instant::now();
        let n = g.num_vertices();
        let mut pred: Vec<Pred> = vec![PRED_INFINITY; n];
        let mut visited = Bitmap::new(n);
        let mut queue = std::collections::VecDeque::with_capacity(1024);
        pred[root as usize] = root as Pred;
        visited.set_bit(root);
        queue.push_back(root);
        let mut edges_scanned = 0usize;
        let mut traversed = 0usize;
        let mut status = RunStatus::Complete;
        // No layer boundaries to piggyback the control check on: check
        // every SERIAL_CHECK_GRAIN dequeues instead. A vertex already
        // queued when the run stops keeps its pred, so the partial tree
        // still assigns every reached vertex its true BFS depth.
        let mut since_check = 0usize;
        while let Some(u) = queue.pop_front() {
            since_check += 1;
            if since_check >= SERIAL_CHECK_GRAIN {
                since_check = 0;
                if let Some(s) = ctl.stop_reason() {
                    status = s;
                    break;
                }
            }
            for &v in g.neighbors(u) {
                edges_scanned += 1;
                if !visited.test_bit(v) {
                    visited.set_bit(v);
                    pred[v as usize] = u as Pred;
                    queue.push_back(v);
                    traversed += 1;
                }
            }
        }
        let trace = RunTrace {
            layers: vec![LayerTrace {
                layer: 0,
                input_vertices: 1,
                edges_scanned,
                traversed,
                wall_ns: start.elapsed().as_nanos() as u64,
                ..Default::default()
            }],
            num_threads: 1,
            status,
            ..Default::default()
        };
        BfsResult { tree: BfsTree::new(root, pred), trace }
    }
}

impl BfsEngine for SerialQueueBfs {
    fn name(&self) -> &'static str {
        "serial-queue"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        Ok(Box::new(PreparedStateless::new(g, *self, artifacts)))
    }
}

/// Algorithm 1 proper: layer-synchronous serial top-down with `in`/`out`
/// lists swapped each layer (§3.1 lines 7–17).
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialLayeredBfs;

impl StatelessBfs for SerialLayeredBfs {
    fn name(&self) -> &'static str {
        "serial-layered"
    }

    fn traverse(&self, g: &Csr, root: Vertex, ctl: &RunControl) -> BfsResult {
        let n = g.num_vertices();
        let mut pred: Vec<Pred> = vec![PRED_INFINITY; n];
        let mut visited = Bitmap::new(n);
        // The serial algorithm's lists are plain vertex vectors; bitmaps
        // arrive with Algorithm 3.
        let mut input: Vec<Vertex> = Vec::new();
        let mut output: Vec<Vertex> = Vec::new();

        pred[root as usize] = root as Pred; // line 6
        visited.set_bit(root); // line 5
        input.push(root); // line 4

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut status = RunStatus::Complete;
        while !input.is_empty() {
            // line 7
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let mut edges_scanned = 0usize;
            for &u in &input {
                // line 8
                for &v in g.neighbors(u) {
                    // line 9
                    edges_scanned += 1;
                    if !visited.test_bit(v) {
                        // line 10
                        visited.set_bit(v); // line 11
                        output.push(v); // line 12
                        pred[v as usize] = u as Pred; // line 13
                    }
                }
            }
            layers.push(LayerTrace {
                layer,
                input_vertices: input.len(),
                edges_scanned,
                traversed: output.len(),
                wall_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            });
            std::mem::swap(&mut input, &mut output); // line 16 (swap)
            output.clear(); // line 16 (out ← 0)
            layer += 1;
        }
        BfsResult {
            tree: BfsTree::new(root, pred),
            trace: RunTrace { layers, num_threads: 1, status, ..Default::default() },
        }
    }
}

impl BfsEngine for SerialLayeredBfs {
    fn name(&self) -> &'static str {
        "serial-layered"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        Ok(Box::new(PreparedStateless::new(g, *self, artifacts)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeList, RmatConfig};

    fn paper_fig2_graph() -> Csr {
        // The Fig 2 example: root 1 reaches three layers.
        //     1 -> {2, 3}; 2 -> {4}; 3 -> {4, 5}; 4 -> {6}; 5 -> {}
        let el = EdgeList::with_edges(7, vec![(1, 2), (1, 3), (2, 4), (3, 4), (3, 5), (4, 6)]);
        Csr::from_edge_list(0, &el)
    }

    #[test]
    fn queue_and_layered_agree_on_distances() {
        let g = paper_fig2_graph();
        let a = SerialQueueBfs.run(&g, 1);
        let b = SerialLayeredBfs.run(&g, 1);
        assert_eq!(a.tree.distances().unwrap(), b.tree.distances().unwrap());
    }

    #[test]
    fn fig2_distances() {
        let g = paper_fig2_graph();
        let r = SerialLayeredBfs.run(&g, 1);
        let d = r.tree.distances().unwrap();
        assert_eq!(d[1], 0);
        assert_eq!(d[2], 1);
        assert_eq!(d[3], 1);
        assert_eq!(d[4], 2);
        assert_eq!(d[5], 2);
        assert_eq!(d[6], 3);
        assert_eq!(d[0], u32::MAX); // vertex 0 unreachable
    }

    #[test]
    fn root_is_own_parent() {
        let g = paper_fig2_graph();
        for alg in [&SerialQueueBfs as &dyn BfsEngine, &SerialLayeredBfs] {
            let r = alg.run(&g, 1);
            assert_eq!(r.tree.parent(1), Some(1));
        }
    }

    #[test]
    fn tree_edges_exist_in_graph() {
        let el = RmatConfig::graph500(10, 8).generate(1);
        let g = Csr::from_edge_list(10, &el);
        let r = SerialLayeredBfs.run(&g, 0);
        for v in 0..g.num_vertices() as Vertex {
            if let Some(p) = r.tree.parent(v) {
                if p != v {
                    assert!(g.has_edge(p, v), "tree edge {p}->{v} not in graph");
                }
            }
        }
    }

    #[test]
    fn layer_trace_matches_profile() {
        let el = RmatConfig::graph500(10, 8).generate(2);
        let g = Csr::from_edge_list(10, &el);
        let r = SerialLayeredBfs.run(&g, 5);
        let profile = crate::graph::stats::LayerProfile::compute(&g, 5);
        assert_eq!(r.trace.layers.len(), profile.num_layers());
        for (t, p) in r.trace.layers.iter().zip(profile.rows.iter()) {
            assert_eq!(t.input_vertices, p.input_vertices);
            assert_eq!(t.edges_scanned, p.edges);
            assert_eq!(t.traversed, p.traversed);
        }
    }

    #[test]
    fn isolated_root_reaches_only_itself() {
        let el = EdgeList::with_edges(4, vec![(0, 1)]);
        let g = Csr::from_edge_list(0, &el);
        let r = SerialQueueBfs.run(&g, 3);
        assert_eq!(r.tree.reached_count(), 1);
        assert!(r.tree.reached(3));
    }
}
