//! The paper's algorithm ladder.
//!
//! | Module | Paper artifact |
//! |---|---|
//! | [`serial`] | Algorithm 1 — serial top-down (queue and layered forms) |
//! | [`parallel`] | Algorithm 2 — OpenMP-style parallel top-down (the `non-simd` curve of Fig 10) |
//! | [`bitrace_free`] | Algorithm 3 — bitmaps, no atomics, restoration process |
//! | [`vectorized`] | §4 / Listing 1 — the SIMD explorer + vectorized restoration (the `simd` curve) |
//! | [`sell_vectorized`] | extension — SELL-16-σ lane-packed explorer (the `sell` engine): 16 distinct frontier vertices per VPU issue |
//! | [`bottom_up`] | extension (§8) — direction-optimizing hybrid with vectorized (and optionally SELL) steps |
//! | [`sell_bottom_up`] | extension — SELL-packed bottom-up scan: 16 distinct *unvisited* vertices per VPU issue, dynamic lane refill |
//! | [`multi_source`] | extension — 16-root MS-BFS over the SELL layout (the `hybrid-sell-ms` engine): one traversal serves a whole root batch |
//! | [`policy`] | §4.1 — which layers run vectorized, and how the sell engine chunks them |
//! | [`validate`] | §5.3 — the Graph500 five-check soft validator |
//! | [`state`] | shared frontier/visited/predecessor state for the threaded versions |
//! | [`artifacts`] | per-graph prepared state ([`GraphArtifacts`]) shared across roots |
//!
//! # The two-phase engine API
//!
//! The paper's experimental unit is the Graph500 run: **64 traversals over
//! one read-only graph**. Per-graph work (the SELL-16-σ layout, the
//! aligned padded-CSR view, degree statistics) must therefore be paid once
//! per graph, not once per root, so every engine implements [`BfsEngine`]
//! in two phases:
//!
//! 1. [`BfsEngine::prepare`] — expensive, once per graph. Builds the
//!    engine's [`GraphArtifacts`] and returns a [`PreparedBfs`] bound to
//!    the graph.
//! 2. [`PreparedBfs::run`] — cheap, once per root. `PreparedBfs` is
//!    `Sync`, so the coordinator's workers share one prepared instance by
//!    reference instead of constructing a private engine per root.
//!
//! The prepared instance also carries the cross-root
//! [`policy::PolicyFeedback`] channel: occupancy measured on earlier roots
//! of a job steers the per-layer chunking choice of later roots.
//!
//! # The batch entry point
//!
//! The run phase is **batch-first**: [`PreparedBfs::run_batch`] takes a
//! whole slice of roots and returns one [`BfsResult`] per root, in order.
//! The provided implementation loops [`PreparedBfs::run`], so every
//! engine accepts batches of any size unchanged; engines with a genuinely
//! batched traversal override it — [`multi_source`]'s `hybrid-sell-ms`
//! runs 16 concurrent roots through one shared SELL traversal, so a
//! single VPU gather serves all 16 searches at once. The coordinator's
//! `BatchPolicy` decides how a job's sampled roots are grouped into
//! `run_batch` calls.
//!
//! [`BfsEngine::run`] is the provided one-shot convenience (prepare +
//! run); benchmarks and multi-root callers should prepare once and reuse.
//!
//! All traversals return a [`BfsResult`]: the spanning tree (predecessor
//! array, §3.1) plus a [`RunTrace`] of per-layer work counters that the
//! Xeon Phi performance model prices.

pub mod artifacts;
pub mod bitrace_free;
pub mod control;
pub mod bottom_up;
pub mod footprint;
pub mod multi_source;
pub mod parallel;
pub mod policy;
pub mod sell_bottom_up;
pub mod sell_vectorized;
pub mod serial;
pub mod state;
pub mod validate;
pub mod vectorized;

use std::sync::Arc;

use anyhow::Result;

pub use artifacts::{ComponentMap, DegreeStats, GraphArtifacts, HubBits, DEFAULT_HUB_BITS};
pub use footprint::HeapFootprint;
pub use control::{RunControl, RunStatus};

use crate::graph::Csr;
use crate::simd::VpuCounters;
use crate::{Pred, Vertex, PRED_INFINITY};

/// Bitmap words each dynamic-schedule grab claims in the threaded
/// algorithms (OpenMP `schedule(dynamic, 16)` over frontier words). One
/// shared definition — every engine's scheduling granularity moves
/// together.
pub(crate) const WORD_GRAIN: usize = 16;

/// The BFS spanning tree: `pred[v]` is the parent of `v`, `pred[root] ==
/// root`, and unreached vertices hold [`PRED_INFINITY`] (§3.1's "∞").
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BfsTree {
    pub root: Vertex,
    pub pred: Vec<Pred>,
}

impl BfsTree {
    pub fn new(root: Vertex, pred: Vec<Pred>) -> Self {
        BfsTree { root, pred }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.pred.len()
    }

    /// Parent of `v`, or `None` if `v` was not reached.
    pub fn parent(&self, v: Vertex) -> Option<Vertex> {
        let p = self.pred[v as usize];
        if p == PRED_INFINITY {
            None
        } else {
            Some(p as Vertex)
        }
    }

    /// True if `v` is in the tree.
    #[inline]
    pub fn reached(&self, v: Vertex) -> bool {
        self.pred[v as usize] != PRED_INFINITY
    }

    /// Number of vertices in the tree (root included).
    pub fn reached_count(&self) -> usize {
        self.pred.iter().filter(|&&p| p != PRED_INFINITY).count()
    }

    /// Distance-from-root map computed from the predecessor chain, with
    /// memoization; `u32::MAX` marks unreached vertices. Returns `None` if
    /// the parent pointers contain a cycle. Chains that dangle (a "reached"
    /// vertex whose ancestor line never hits the root) are classified as
    /// unreached rather than panicking — the validator turns both defects
    /// into check failures.
    pub fn distances(&self) -> Option<Vec<u32>> {
        const UNSEEN: u32 = u32::MAX - 1;
        const ON_STACK: u32 = u32::MAX - 2;
        let n = self.pred.len();
        let mut dist = vec![UNSEEN; n];
        if self.reached(self.root) {
            dist[self.root as usize] = 0;
        }
        let mut stack: Vec<usize> = Vec::new();
        for v0 in 0..n {
            if dist[v0] != UNSEEN {
                continue;
            }
            if !self.reached(v0 as Vertex) {
                dist[v0] = u32::MAX;
                continue;
            }
            let mut v = v0;
            loop {
                match dist[v] {
                    UNSEEN => {
                        dist[v] = ON_STACK;
                        stack.push(v);
                        let p = self.pred[v];
                        if p == crate::PRED_INFINITY || p < 0 || p as usize >= n {
                            // dangling chain — everything on it is unreached
                            for &u in &stack {
                                dist[u] = u32::MAX;
                            }
                            stack.clear();
                            break;
                        }
                        v = p as usize;
                    }
                    ON_STACK => return None, // cycle
                    u32::MAX => {
                        // anchored on an unreached vertex — dangling chain
                        for &u in &stack {
                            dist[u] = u32::MAX;
                        }
                        stack.clear();
                        break;
                    }
                    d => {
                        let mut dd = d;
                        while let Some(u) = stack.pop() {
                            dd += 1;
                            dist[u] = dd;
                        }
                        break;
                    }
                }
            }
        }
        Some(dist)
    }
}

/// Per-layer work trace (one entry per `while in ≠ 0` iteration).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LayerTrace {
    pub layer: usize,
    /// Vertices in the input list.
    pub input_vertices: usize,
    /// Adjacency entries inspected.
    pub edges_scanned: usize,
    /// Vertices newly discovered into the output list.
    pub traversed: usize,
    /// Bitmap words scanned by the restoration pass (0 when not applicable).
    pub restore_words_scanned: usize,
    /// Vertices actually repaired by restoration.
    pub restore_fixed: usize,
    /// Whether this layer ran through the vector unit.
    pub vectorized: bool,
    /// Whether this layer ran bottom-up (hybrid engines only) — lets the
    /// ablation separate bottom-up occupancy from top-down occupancy.
    pub bottom_up: bool,
    /// VPU events for this layer (zero for scalar layers).
    pub vpu: VpuCounters,
    /// Wall-clock nanoseconds actually spent on this layer (host machine).
    pub wall_ns: u64,
}

/// Whole-run trace: the input to [`crate::phi::sim`].
#[derive(Clone, Debug, Default)]
pub struct RunTrace {
    pub layers: Vec<LayerTrace>,
    /// Threads the algorithm was configured with (the Phi model re-maps
    /// work onto its own core topology, but keeps this for reporting).
    pub num_threads: usize,
    /// This traversal was a counted **warm-up** root of
    /// [`crate::simd::VpuMode::Auto`]: it ran on the counted emulator to
    /// feed the policy feedback while steady-state roots run the hardware
    /// backend. Warm-up timings are emulation timings, so TEPS aggregates
    /// exclude flagged runs ([`crate::harness::stats::TepsStats`]).
    pub counted_warmup: bool,
    /// How the traversal ended ([`RunStatus::Complete`] unless the run's
    /// [`RunControl`] stopped it early — then `layers` and the tree cover
    /// only the visited prefix).
    pub status: RunStatus,
    /// Nanoseconds this run spent *waiting for a device lock* before any
    /// traversal work started (the PJRT-backed runtime serializes runs on
    /// one device). Zero for engines with no device lock. Reported
    /// separately so per-root seconds measure execution, not queueing.
    pub lock_wait_ns: u64,
}

impl RunTrace {
    pub fn total_edges_scanned(&self) -> usize {
        self.layers.iter().map(|l| l.edges_scanned).sum()
    }

    pub fn total_traversed(&self) -> usize {
        self.layers.iter().map(|l| l.traversed).sum()
    }

    pub fn total_wall_ns(&self) -> u64 {
        self.layers.iter().map(|l| l.wall_ns).sum()
    }

    /// Merged VPU counters across layers.
    pub fn vpu_totals(&self) -> VpuCounters {
        let mut c = VpuCounters::default();
        for l in &self.layers {
            c.merge(&l.vpu);
        }
        c
    }
}

/// Result of one BFS execution.
#[derive(Clone, Debug)]
pub struct BfsResult {
    pub tree: BfsTree,
    pub trace: RunTrace,
}

/// Common interface over the algorithm ladder — the *configuration* half
/// of the two-phase API (see the module docs). An engine value is a cheap,
/// copyable description (thread count, SIMD options, policy); all
/// per-graph state lives in the [`PreparedBfs`] returned by
/// [`BfsEngine::prepare`].
pub trait BfsEngine {
    /// Short name for reports ("serial", "non-simd", "simd", ...).
    fn name(&self) -> &'static str;

    /// Phase 1 with caller-supplied artifacts: bind the engine to `g`,
    /// building (or reusing, when `artifacts` already carries them) every
    /// per-graph structure the traversals need. The coordinator calls this
    /// once per job with artifacts it shares across worker threads.
    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>>;

    /// Phase 1: bind the engine to `g` with fresh artifacts. The graph's
    /// structure is validated first ([`Csr::validate_structure`]) so a
    /// corrupt CSR surfaces as a structured error here, never as
    /// out-of-bounds indexing deep inside a layout build or a lane gather.
    fn prepare<'g>(&self, g: &'g Csr) -> Result<Box<dyn PreparedBfs + 'g>> {
        g.validate_structure()?;
        self.prepare_with(g, Arc::new(GraphArtifacts::for_graph(g)))
    }

    /// One-shot convenience: prepare for `g` and traverse from `root`.
    /// Multi-root callers should [`BfsEngine::prepare`] once instead —
    /// this pays the per-graph phase on every call.
    fn run(&self, g: &Csr, root: Vertex) -> BfsResult {
        self.prepare(g).expect("engine preparation failed").run(root)
    }
}

/// Phase 2 of the engine API: an engine bound to one graph. `Sync` by
/// contract — the coordinator's worker threads share one instance and pull
/// root batches from a common cursor, so `run`/`run_batch` must be
/// callable concurrently.
pub trait PreparedBfs: Sync {
    /// Short name of the underlying engine.
    fn name(&self) -> &'static str;

    /// Traverse the prepared graph from `root` under `ctl` — the required
    /// primitive. Engines check the control at layer boundaries and, when
    /// it trips, return the visited prefix with the matching
    /// [`RunStatus`] in the trace instead of the full tree.
    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult;

    /// Traverse the prepared graph from `root`, uncontrolled (no deadline,
    /// no cancellation).
    fn run(&self, root: Vertex) -> BfsResult {
        self.run_with(root, RunControl::unbounded())
    }

    /// Traverse the prepared graph from every root of `roots` under `ctl`,
    /// returning one result per root **in root order**. The provided
    /// implementation loops [`PreparedBfs::run_with`], so every engine
    /// accepts batches of any size; engines with a genuinely batched
    /// traversal (the MS-BFS [`multi_source`] engine) override it to share
    /// one traversal across the batch. Duplicate roots are allowed and
    /// yield independent results.
    fn run_batch_with(&self, roots: &[Vertex], ctl: &RunControl) -> Vec<BfsResult> {
        roots.iter().map(|&r| self.run_with(r, ctl)).collect()
    }

    /// Uncontrolled batch entry point (see [`PreparedBfs::run_batch_with`]).
    fn run_batch(&self, roots: &[Vertex]) -> Vec<BfsResult> {
        self.run_batch_with(roots, RunControl::unbounded())
    }

    /// The per-graph artifacts this instance was prepared with.
    fn artifacts(&self) -> &GraphArtifacts;
}

/// Engines whose traversal uses no per-graph artifacts beyond the graph
/// itself (the serial/scalar rungs of the ladder). Implementing this is
/// enough to plug into the two-phase API through [`PreparedStateless`].
pub(crate) trait StatelessBfs: Sync {
    fn name(&self) -> &'static str;
    fn traverse(&self, g: &Csr, root: Vertex, ctl: &RunControl) -> BfsResult;
}

/// A [`PreparedBfs`] for [`StatelessBfs`] engines: just the engine config,
/// the graph reference, and the (unused but carried) artifacts.
pub(crate) struct PreparedStateless<'g, E> {
    g: &'g Csr,
    engine: E,
    artifacts: Arc<GraphArtifacts>,
}

impl<'g, E> PreparedStateless<'g, E> {
    pub(crate) fn new(g: &'g Csr, engine: E, artifacts: Arc<GraphArtifacts>) -> Self {
        PreparedStateless { g, engine, artifacts }
    }
}

impl<E: StatelessBfs> PreparedBfs for PreparedStateless<'_, E> {
    fn name(&self) -> &'static str {
        self.engine.name()
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        self.engine.traverse(self.g, root, ctl)
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_basics() {
        // 0 -> 1 -> 2, vertex 3 unreached
        let t = BfsTree::new(0, vec![0, 0, 1, PRED_INFINITY]);
        assert_eq!(t.parent(0), Some(0));
        assert_eq!(t.parent(2), Some(1));
        assert_eq!(t.parent(3), None);
        assert_eq!(t.reached_count(), 3);
        assert_eq!(t.distances().unwrap(), vec![0, 1, 2, u32::MAX]);
    }

    #[test]
    fn distances_detect_cycles() {
        // 1 and 2 point at each other — corrupt tree.
        let t = BfsTree::new(0, vec![0, 2, 1, PRED_INFINITY]);
        assert!(t.distances().is_none());
    }

    #[test]
    fn distances_long_chain_no_recursion() {
        let n = 100_000;
        let mut pred: Vec<Pred> = (0..n as Pred).map(|v| v - 1).collect();
        pred[0] = 0;
        let t = BfsTree::new(0, pred);
        let d = t.distances().unwrap();
        assert_eq!(d[n - 1], (n - 1) as u32);
    }

    #[test]
    fn trace_totals() {
        let trace = RunTrace {
            layers: vec![
                LayerTrace { layer: 0, edges_scanned: 10, traversed: 5, wall_ns: 100, ..Default::default() },
                LayerTrace { layer: 1, edges_scanned: 20, traversed: 7, wall_ns: 200, ..Default::default() },
            ],
            num_threads: 4,
            ..Default::default()
        };
        assert_eq!(trace.total_edges_scanned(), 30);
        assert_eq!(trace.total_traversed(), 12);
        assert_eq!(trace.total_wall_ns(), 300);
    }
}
