//! Per-graph artifacts for the two-phase engine API.
//!
//! A Graph500 experiment is 64 traversals over one read-only graph, so
//! anything derived from the graph alone — degree statistics, the
//! SELL-16-σ layout, the aligned padded-CSR view — is *graph-level* state:
//! built once by [`crate::bfs::BfsEngine::prepare`], then shared by every
//! root's [`crate::bfs::PreparedBfs::run`] (and across the coordinator's
//! worker threads via `Arc`). [`GraphArtifacts`] is the typed home for
//! that state; the expensive members are built lazily so an engine only
//! pays for the layouts it actually traverses.
//!
//! The artifacts also carry the cross-root [`PolicyFeedback`] channel:
//! occupancy measured while running earlier roots of a job accumulates
//! here and steers the per-layer chunking choice of later roots (see
//! [`crate::bfs::policy`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use super::footprint::{
    planned_component_bytes, planned_hub_bytes, planned_padded_bytes, planned_sell_bytes,
};
use super::policy::PolicyFeedback;
use crate::coordinator::governor::ResourceGovernor;
use crate::graph::{Csr, PaddedCsr, Sell16};

pub use crate::graph::stats::DegreeStats;

use crate::Vertex;

/// Connected-component labels of a graph — the cheap per-graph pass behind
/// the MS-BFS bottom-up **per-component reachable-mask bound**
/// ([`crate::bfs::multi_source`]): a vertex can only ever be discovered by
/// wave roots in its own component, so a lane retires the moment it covers
/// that subset of the live mask instead of waiting on unreachable bits.
/// One scalar O(V + E) sweep, built lazily like every other artifact.
#[derive(Clone, Debug)]
pub struct ComponentMap {
    /// Component label per vertex, dense in `0..count`.
    pub labels: Vec<u32>,
    /// Number of connected components (isolated vertices included).
    pub count: usize,
}

impl ComponentMap {
    /// Label every vertex with an iterative scalar BFS sweep.
    pub fn compute(g: &Csr) -> Self {
        let n = g.num_vertices();
        let mut labels = vec![u32::MAX; n];
        let mut count = 0usize;
        let mut stack: Vec<Vertex> = Vec::new();
        for v0 in 0..n {
            if labels[v0] != u32::MAX {
                continue;
            }
            let label = count as u32;
            count += 1;
            labels[v0] = label;
            stack.push(v0 as Vertex);
            while let Some(u) = stack.pop() {
                for &w in g.neighbors(u) {
                    if labels[w as usize] == u32::MAX {
                        labels[w as usize] = label;
                        stack.push(w);
                    }
                }
            }
        }
        ComponentMap { labels, count }
    }

    /// Component label of `v`.
    #[inline]
    pub fn label(&self, v: Vertex) -> u32 {
        self.labels[v as usize]
    }
}

/// Default number of hub lanes in the packed hub-adjacency bitmap
/// ([`HubBits`]) — one `u32` mask word per vertex covers up to 32 hubs.
pub const DEFAULT_HUB_BITS: usize = 32;

/// Packed hub-adjacency bitmap: for the `k ≤ 32` highest-degree vertices
/// ("hubs"), one mask word per vertex records which hubs it is adjacent
/// to. An RMAT graph's hubs appear in almost every adjacency list, so
/// during a bottom-up layer most unvisited vertices have a frontier
/// neighbor among them: testing `masks[v] & frontier_hub_mask` answers
/// "does v have a frontier hub parent?" from one L1-resident word,
/// without touching the SELL adjacency stream at all
/// ([`crate::bfs::sell_bottom_up::bottom_up_layer_sell`]).
#[derive(Clone, Debug)]
pub struct HubBits {
    /// How many hubs were requested (clamped to 32 and the vertex count).
    pub k: usize,
    /// The hub vertices, highest degree first — bit `j` of a mask word
    /// refers to `hubs[j]`.
    pub hubs: Vec<Vertex>,
    /// Per-vertex adjacency mask: bit `j` set ⇔ the vertex is adjacent to
    /// `hubs[j]`.
    pub masks: Vec<u32>,
}

impl HubBits {
    /// Select the `k` highest-degree vertices of `g` (ties broken by id
    /// for determinism) and mark their neighbors. O(V + Σ deg(hub)).
    pub fn build(g: &Csr, k: usize) -> Self {
        let n = g.num_vertices();
        let k = k.min(32).min(n);
        let mut by_degree: Vec<Vertex> = (0..n as Vertex).collect();
        if k > 0 && k < n {
            by_degree
                .select_nth_unstable_by_key(k - 1, |&v| (std::cmp::Reverse(g.degree(v)), v));
        }
        let mut hubs: Vec<Vertex> = by_degree[..k].to_vec();
        hubs.sort_unstable_by_key(|&v| (std::cmp::Reverse(g.degree(v)), v));
        let mut masks = vec![0u32; n];
        for (j, &h) in hubs.iter().enumerate() {
            let bit = 1u32 << j;
            for &w in g.neighbors(h) {
                masks[w as usize] |= bit;
            }
        }
        HubBits { k, hubs, masks }
    }

    /// Which hubs are set in `frontier_words` — the one mask word a
    /// bottom-up layer tests every candidate against.
    pub fn frontier_mask(&self, frontier_words: &[u32]) -> u32 {
        let mut m = 0u32;
        for (j, &h) in self.hubs.iter().enumerate() {
            let w = (h / 32) as usize;
            if let Some(&word) = frontier_words.get(w) {
                if word >> (h % 32) & 1 != 0 {
                    m |= 1 << j;
                }
            }
        }
        m
    }
}

/// Typed per-graph state shared across all roots of a job.
///
/// Only the [`PolicyFeedback`] channel exists up front; everything
/// derived from the graph — [`DegreeStats`], the layouts — is built on
/// first request and cached, so an engine only pays for the artifacts it
/// actually reads and "build exactly once per job" holds by construction.
/// The build counters exist so tests can assert it.
pub struct GraphArtifacts {
    stats: OnceLock<DegreeStats>,
    feedback: PolicyFeedback,
    /// Byte-budget authority the lazy builders consult; absent (the
    /// default, and every direct [`crate::bfs::BfsEngine::prepare`] call)
    /// means ungoverned — every build proceeds and charges nothing.
    governor: OnceLock<Arc<ResourceGovernor>>,
    sell: OnceLock<Arc<Sell16>>,
    padded: OnceLock<Arc<PaddedCsr>>,
    components: OnceLock<Arc<ComponentMap>>,
    hub: OnceLock<Arc<HubBits>>,
    sell_builds: AtomicUsize,
    padded_builds: AtomicUsize,
    component_builds: AtomicUsize,
    hub_builds: AtomicUsize,
}

impl GraphArtifacts {
    /// Create empty artifacts for `g`. Construction is free; the caller
    /// must pass the same graph to the lazy accessors below.
    pub fn for_graph(_g: &Csr) -> Self {
        GraphArtifacts {
            stats: OnceLock::new(),
            feedback: PolicyFeedback::default(),
            governor: OnceLock::new(),
            sell: OnceLock::new(),
            padded: OnceLock::new(),
            components: OnceLock::new(),
            hub: OnceLock::new(),
            sell_builds: AtomicUsize::new(0),
            padded_builds: AtomicUsize::new(0),
            component_builds: AtomicUsize::new(0),
            hub_builds: AtomicUsize::new(0),
        }
    }

    /// Install the byte-budget authority the lazy builders consult.
    /// Set once per artifacts (the coordinator does this right after the
    /// cache lookup); later installs are ignored, so cached artifacts
    /// keep the governor whose ledger their builds were charged to.
    pub fn install_governor(&self, governor: Arc<ResourceGovernor>) {
        let _ = self.governor.set(governor);
    }

    /// The installed governor, if any.
    pub fn governor(&self) -> Option<&Arc<ResourceGovernor>> {
        self.governor.get()
    }

    /// Degree statistics of `g`, computed on first call and cached.
    pub fn stats(&self, g: &Csr) -> &DegreeStats {
        self.stats.get_or_init(|| DegreeStats::compute(g))
    }

    /// The cached SELL layout, if one was built.
    pub fn built_sell(&self) -> Option<&Arc<Sell16>> {
        self.sell.get()
    }

    /// The cached padded-CSR view, if one was built.
    pub fn built_padded(&self) -> Option<&Arc<PaddedCsr>> {
        self.padded.get()
    }

    /// The cached component map, if one was built.
    pub fn built_components(&self) -> Option<&Arc<ComponentMap>> {
        self.components.get()
    }

    /// The cached hub bitmap, if one was built.
    pub fn built_hub(&self) -> Option<&Arc<HubBits>> {
        self.hub.get()
    }

    /// The cross-root occupancy feedback channel of this job.
    pub fn feedback(&self) -> &PolicyFeedback {
        &self.feedback
    }

    /// The SELL-16-σ layout of `g`, built on first call and cached. A call
    /// with a different σ than the cached layout builds a fresh layout
    /// (uncached) — within one job the engine's σ is fixed, so this path
    /// only triggers when artifacts are deliberately shared across
    /// differently-configured engines.
    ///
    /// The SELL layout is **mandatory** for the engines that request it
    /// (no fallback), so under an installed governor the build charges the
    /// full budget and a charge that does not fit is an error carrying
    /// [`crate::coordinator::governor::OVER_BUDGET_MARKER`] — the
    /// coordinator surfaces it as
    /// [`crate::coordinator::CoordinatorError::OverBudget`]. σ-mismatch
    /// rebuilds are transient per-prepare copies and are not charged.
    pub fn sell_layout(&self, g: &Csr, sigma: usize) -> anyhow::Result<Arc<Sell16>> {
        if self.sell.get().is_none() {
            let planned =
                self.governor.get().map(|gov| (gov, planned_sell_bytes(g, sigma)));
            if let Some((gov, bytes)) = &planned {
                gov.charge_mandatory(*bytes, "SELL-16-sigma layout")?;
            }
            let mut built = false;
            let _ = self.sell.get_or_init(|| {
                built = true;
                self.sell_builds.fetch_add(1, Ordering::Relaxed);
                Arc::new(Sell16::from_csr(g, sigma))
            });
            if !built {
                // Lost the init race: another thread's charge covers the
                // cached layout, refund ours.
                if let Some((gov, bytes)) = planned {
                    gov.release(bytes);
                }
            }
        }
        let cached = self.sell.get().expect("initialized above");
        Ok(if cached.sigma == sigma.max(crate::graph::sell::SELL_C) {
            Arc::clone(cached)
        } else {
            self.sell_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(Sell16::from_csr(g, sigma))
        })
    }

    /// The aligned padded-CSR view of `g`, built on first call and cached.
    ///
    /// **Optional artifact**: under an installed governor, a build whose
    /// planned bytes would push the ledger over the high watermark is
    /// skipped — `None`, with a structured
    /// [`crate::coordinator::governor::ResourcePressure`] event — and the
    /// explorers run their unaligned-CSR peel-loop path instead.
    pub fn padded_csr(&self, g: &Csr) -> Option<Arc<PaddedCsr>> {
        if let Some(p) = self.padded.get() {
            return Some(Arc::clone(p));
        }
        let planned = self.governor.get().map(|gov| (gov, planned_padded_bytes(g)));
        if let Some((gov, bytes)) = &planned {
            if !gov.optional_build_allowed(*bytes, "padded-csr") {
                return None;
            }
        }
        let mut built = false;
        let p = self.padded.get_or_init(|| {
            built = true;
            self.padded_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(PaddedCsr::from_csr(g))
        });
        if !built {
            if let Some((gov, bytes)) = planned {
                gov.release(bytes);
            }
        }
        Some(Arc::clone(p))
    }

    /// The connected-component labels of `g`, built on first call and
    /// cached — the MS-BFS per-component lane-retirement bound reads them.
    ///
    /// **Optional artifact**: skipped (`None`, with a
    /// [`crate::coordinator::governor::ResourcePressure`] event) under
    /// governor pressure; MS-BFS then retires lanes on the full live mask.
    pub fn components(&self, g: &Csr) -> Option<Arc<ComponentMap>> {
        if let Some(c) = self.components.get() {
            return Some(Arc::clone(c));
        }
        let planned = self.governor.get().map(|gov| (gov, planned_component_bytes(g)));
        if let Some((gov, bytes)) = &planned {
            if !gov.optional_build_allowed(*bytes, "component-map") {
                return None;
            }
        }
        let mut built = false;
        let c = self.components.get_or_init(|| {
            built = true;
            self.component_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(ComponentMap::compute(g))
        });
        if !built {
            if let Some((gov, bytes)) = planned {
                gov.release(bytes);
            }
        }
        Some(Arc::clone(c))
    }

    /// The packed hub-adjacency bitmap of `g` for the top-`k` hubs, built
    /// on first call and cached. Like [`Self::sell_layout`], a call with a
    /// different `k` than the cached bitmap builds fresh (uncached) — one
    /// job runs one hub configuration.
    ///
    /// **Optional artifact**: skipped (`None`, with a
    /// [`crate::coordinator::governor::ResourcePressure`] event) under
    /// governor pressure; the bottom-up scan then reads the SELL adjacency
    /// stream for every candidate.
    pub fn hub_bits(&self, g: &Csr, k: usize) -> Option<Arc<HubBits>> {
        let clamped = k.min(32).min(g.num_vertices());
        if self.hub.get().is_none() {
            let planned = self.governor.get().map(|gov| (gov, planned_hub_bytes(g, k)));
            if let Some((gov, bytes)) = &planned {
                if !gov.optional_build_allowed(*bytes, "hub-bits") {
                    return None;
                }
            }
            let mut built = false;
            let _ = self.hub.get_or_init(|| {
                built = true;
                self.hub_builds.fetch_add(1, Ordering::Relaxed);
                Arc::new(HubBits::build(g, k))
            });
            if !built {
                if let Some((gov, bytes)) = planned {
                    gov.release(bytes);
                }
            }
        }
        let cached = self.hub.get().expect("initialized above");
        Some(if cached.k == clamped {
            Arc::clone(cached)
        } else {
            self.hub_builds.fetch_add(1, Ordering::Relaxed);
            Arc::new(HubBits::build(g, k))
        })
    }

    /// How many times a [`HubBits`] bitmap was constructed through these
    /// artifacts.
    pub fn hub_builds(&self) -> usize {
        self.hub_builds.load(Ordering::Relaxed)
    }

    /// How many times a [`ComponentMap`] was constructed through these
    /// artifacts.
    pub fn component_builds(&self) -> usize {
        self.component_builds.load(Ordering::Relaxed)
    }

    /// How many times a [`Sell16`] layout was constructed through these
    /// artifacts (the "built exactly once per job" test hook).
    pub fn sell_builds(&self) -> usize {
        self.sell_builds.load(Ordering::Relaxed)
    }

    /// How many times a [`PaddedCsr`] was constructed through these
    /// artifacts.
    pub fn padded_builds(&self) -> usize {
        self.padded_builds.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for GraphArtifacts {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GraphArtifacts")
            .field("stats", &self.stats.get())
            .field("sell_builds", &self.sell_builds())
            .field("padded_builds", &self.padded_builds())
            .finish_non_exhaustive()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeList, RmatConfig};

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    #[test]
    fn stats_match_graph() {
        let g = rmat(10, 8, 3);
        let s = DegreeStats::compute(&g);
        assert_eq!(s.num_vertices, g.num_vertices());
        assert_eq!(s.num_directed_edges, g.num_directed_edges());
        let max =
            (0..g.num_vertices() as crate::Vertex).map(|v| g.degree(v)).max().unwrap();
        assert_eq!(s.max, max);
        assert!(s.max as f64 > s.mean, "RMAT graphs are skewed");
    }

    #[test]
    fn stats_empty_graph_no_nan() {
        let g = Csr::from_edge_list(0, &EdgeList::with_edges(1, vec![]));
        let s = DegreeStats::compute(&g);
        assert_eq!(s.mean, 0.0);
        assert!(s.suggested_sigma() >= 16);
    }

    #[test]
    fn layouts_build_once_and_are_shared() {
        let g = rmat(9, 8, 4);
        let a = GraphArtifacts::for_graph(&g);
        assert_eq!(a.sell_builds(), 0);
        let s1 = a.sell_layout(&g, 256).unwrap();
        let s2 = a.sell_layout(&g, 256).unwrap();
        assert!(Arc::ptr_eq(&s1, &s2));
        assert_eq!(a.sell_builds(), 1);
        let p1 = a.padded_csr(&g).unwrap();
        let p2 = a.padded_csr(&g).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(a.padded_builds(), 1);
    }

    #[test]
    fn sigma_mismatch_builds_fresh_without_evicting() {
        let g = rmat(9, 8, 5);
        let a = GraphArtifacts::for_graph(&g);
        let s1 = a.sell_layout(&g, 256).unwrap();
        let s3 = a.sell_layout(&g, usize::MAX).unwrap();
        assert!(!Arc::ptr_eq(&s1, &s3));
        assert_eq!(a.sell_builds(), 2);
        // the original σ stays cached
        let s4 = a.sell_layout(&g, 256).unwrap();
        assert!(Arc::ptr_eq(&s1, &s4));
        assert_eq!(a.sell_builds(), 2);
    }

    #[test]
    fn component_map_labels_components() {
        // 0-1-2 connected; 3-4 a second component; 5 isolated
        let el = EdgeList::with_edges(6, vec![(0, 1), (1, 2), (3, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let cm = ComponentMap::compute(&g);
        assert_eq!(cm.count, 3);
        assert_eq!(cm.label(0), cm.label(1));
        assert_eq!(cm.label(0), cm.label(2));
        assert_eq!(cm.label(3), cm.label(4));
        assert_ne!(cm.label(0), cm.label(3));
        assert_ne!(cm.label(5), cm.label(0));
        assert_ne!(cm.label(5), cm.label(3));
        // built once through the artifacts, then cached
        let a = GraphArtifacts::for_graph(&g);
        let c1 = a.components(&g).unwrap();
        let c2 = a.components(&g).unwrap();
        assert!(Arc::ptr_eq(&c1, &c2));
        assert_eq!(a.component_builds(), 1);
        assert_eq!(c1.count, cm.count);
    }

    #[test]
    fn hub_bits_mark_exactly_the_hub_neighbors() {
        // star around 0 plus a 3-4 edge: hubs by degree are 0 then 3/4
        let el = EdgeList::with_edges(6, vec![(0, 1), (0, 2), (0, 5), (3, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let h = HubBits::build(&g, 2);
        assert_eq!(h.k, 2);
        assert_eq!(h.hubs[0], 0, "highest degree first");
        assert_eq!(h.hubs[1], 3, "ties broken by id");
        // bit 0 = adjacency to vertex 0, bit 1 = adjacency to vertex 3
        assert_eq!(h.masks[1] & 1, 1);
        assert_eq!(h.masks[2] & 1, 1);
        assert_eq!(h.masks[5] & 1, 1);
        assert_eq!(h.masks[4], 2);
        assert_eq!(h.masks[0], 0, "a hub is not its own neighbor here");
        // frontier containing only vertex 3 activates hub bit 1
        let mut frontier = crate::graph::Bitmap::new(6);
        frontier.set_bit(3);
        assert_eq!(h.frontier_mask(frontier.words()), 0b10);
        frontier.set_bit(0);
        assert_eq!(h.frontier_mask(frontier.words()), 0b11);
    }

    #[test]
    fn hub_bits_build_once_and_k_mismatch_builds_fresh() {
        let g = rmat(9, 8, 7);
        let a = GraphArtifacts::for_graph(&g);
        assert_eq!(a.hub_builds(), 0);
        let h1 = a.hub_bits(&g, 16).unwrap();
        let h2 = a.hub_bits(&g, 16).unwrap();
        assert!(Arc::ptr_eq(&h1, &h2));
        assert_eq!(a.hub_builds(), 1);
        let h3 = a.hub_bits(&g, 8).unwrap();
        assert!(!Arc::ptr_eq(&h1, &h3));
        assert_eq!(h3.k, 8);
        assert_eq!(a.hub_builds(), 2);
        // the original k stays cached
        let h4 = a.hub_bits(&g, 16).unwrap();
        assert!(Arc::ptr_eq(&h1, &h4));
        assert_eq!(a.hub_builds(), 2);
        // oversized k clamps to 32
        let h5 = HubBits::build(&g, 1000);
        assert_eq!(h5.k, 32);
        assert_eq!(h5.hubs.len(), 32);
    }

    #[test]
    fn governed_optional_builds_skip_with_pressure_events() {
        use crate::bfs::footprint::HeapFootprint;

        let g = rmat(9, 8, 21);
        let a = GraphArtifacts::for_graph(&g);
        // A 1-byte budget: the high watermark is 0, so every optional
        // build is refused before allocating anything.
        a.install_governor(Arc::new(ResourceGovernor::with_budget(1)));
        let gov = a.governor().unwrap();
        assert!(a.padded_csr(&g).is_none());
        assert!(a.components(&g).is_none());
        assert!(a.hub_bits(&g, 16).is_none());
        assert_eq!(a.padded_builds() + a.component_builds() + a.hub_builds(), 0);
        assert_eq!(gov.pressure_events(), 3);
        assert_eq!(gov.used(), 0, "refused builds charge nothing");
        assert_eq!(a.heap_bytes(), 0);
        let events = gov.drain_events();
        let names: Vec<_> = events.iter().map(|e| e.artifact).collect();
        assert_eq!(names, ["padded-csr", "component-map", "hub-bits"]);
        // mandatory SELL layout: structured over-budget error
        let err = a.sell_layout(&g, 256).unwrap_err();
        assert!(format!("{err:#}")
            .contains(crate::coordinator::governor::OVER_BUDGET_MARKER));
        assert_eq!(a.sell_builds(), 0);
    }

    #[test]
    fn governed_builds_charge_exact_planned_bytes() {
        use crate::bfs::footprint::HeapFootprint;

        let g = rmat(9, 8, 22);
        let a = GraphArtifacts::for_graph(&g);
        a.install_governor(Arc::new(ResourceGovernor::with_budget(64 << 20)));
        let gov = Arc::clone(a.governor().unwrap());
        let sell = a.sell_layout(&g, 256).unwrap();
        assert_eq!(gov.used(), sell.heap_bytes());
        let padded = a.padded_csr(&g).unwrap();
        assert_eq!(gov.used(), sell.heap_bytes() + padded.heap_bytes());
        // repeat calls hit the cache and charge nothing more
        let _ = a.sell_layout(&g, 256).unwrap();
        let _ = a.padded_csr(&g).unwrap();
        assert_eq!(gov.used(), sell.heap_bytes() + padded.heap_bytes());
        assert_eq!(gov.used(), a.heap_bytes());
        assert_eq!(gov.pressure_events(), 0);
    }

    #[test]
    fn already_built_artifacts_survive_later_pressure() {
        let g = rmat(8, 8, 23);
        let a = GraphArtifacts::for_graph(&g);
        a.install_governor(Arc::new(ResourceGovernor::with_budget(64 << 20)));
        let gov = Arc::clone(a.governor().unwrap());
        let p1 = a.padded_csr(&g).unwrap();
        // fill the ledger to the brim: new builds would be refused…
        assert!(gov.try_charge(gov.remaining()));
        assert!(a.components(&g).is_none());
        // …but the cached padded view is still served
        let p2 = a.padded_csr(&g).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn suggested_sigma_per_scale() {
        assert_eq!(
            DegreeStats { num_vertices: 1 << 12, ..DegreeStats::compute(&rmat(8, 8, 6)) }
                .suggested_sigma(),
            usize::MAX
        );
        assert_eq!(
            DegreeStats { num_vertices: 1 << 20, ..DegreeStats::compute(&rmat(8, 8, 6)) }
                .suggested_sigma(),
            crate::bfs::sell_vectorized::DEFAULT_SIGMA
        );
    }
}
