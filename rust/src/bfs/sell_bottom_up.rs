//! SELL-packed bottom-up exploration — the tentpole of the hybrid's
//! vectorization story.
//!
//! The chunked bottom-up scan ([`super::bottom_up::bottom_up_layer_simd`])
//! vectorizes *within* one unvisited vertex's adjacency: a vertex of
//! degree d < 16 issues a chunk with 16 − d dead lanes, and the first-hit
//! early exit makes the effective scanned degree even smaller than d — on
//! the low-degree majority of an RMAT graph most lanes idle. This module
//! applies the SELL-16-σ lane-packing idea to the *unvisited pool*
//! instead: every VPU issue gathers the k-th neighbor of **16 distinct
//! unvisited vertices**, one per lane.
//!
//! # The lane-refill protocol
//!
//! Each worker thread owns a contiguous range of SELL chunks and streams
//! their occupied, still-unvisited lanes ([`crate::graph::SellLane`], in
//! rank order — degree-sorted within the σ window, so co-resident lanes
//! have similar lengths) through a `LanePack` (this module's per-lane
//! cursor state):
//!
//! 1. **Refill** — every inactive lane takes the next candidate from the
//!    stream; the pack runs 16-wide until the pool drains.
//! 2. **Issue** — one gather over `Sell16::cols` at per-lane indices
//!    `slot_base + row·16` fetches each lane's next neighbor; a second
//!    gather fetches the frontier-bitmap words those neighbors live in,
//!    and a bit-test mask marks the lanes whose neighbor is in the
//!    frontier (Listing 1's filter, aimed at the frontier instead of the
//!    visited map).
//! 3. **Claim** — hit lanes scatter the found parent into their own
//!    vertex's predecessor entry. Every active lane scans a *distinct*
//!    vertex, so the scatter indices never collide: the claim is race-free
//!    by construction, needs no negative-marker journal and no
//!    restoration pass (the bottom-up property the paper's §3 points out,
//!    kept intact under lane packing). The `next`/`visited` bits are set
//!    with the scalar atomic-OR — bit-granularity updates the vector ISA
//!    lacks (§3.2), at most 16 per issue and only on hits.
//! 4. **Retire + advance** — hit lanes (converged) and lanes whose row
//!    reached their length (exhausted: no parent this layer) leave the
//!    pack; everyone else steps one row. Loop to 1.
//!
//! Parent choice is deterministic and identical to the scalar scan: a
//! lane's rows visit its adjacency in CSR order, so the first hit is the
//! first frontier neighbor in adjacency order. Edge accounting is also
//! identical — one adjacency entry per active lane per issue — which the
//! equivalence tests assert; the chunked scan by contrast pays for every
//! entry of a 16-chunk even when lane 0 already hit.

use super::artifacts::HubBits;
use super::state::{SharedBitmap, SharedPred};
use super::vectorized::SimdOpts;
use crate::graph::bitmap::BITS_PER_WORD;
use crate::graph::sell::{Sell16, SELL_C};
use crate::graph::SellLane;
use crate::simd::backend::VpuBackend;
use crate::simd::ops::PrefetchHint;
use crate::simd::vec512::{Mask16, VecI32x16, LANES};
use crate::simd::VpuCounters;
use crate::threads::parallel_for_dynamic;
use crate::Vertex;

/// Per-lane cursor state for packed exploration with **dynamic refill**.
/// The top-down packer (`pack_frontier` in [`super::sell_vectorized`]) is
/// the static analogue: it pre-sorts frontier slots by length so a group's
/// lanes exhaust together and never need refilling mid-group. The
/// bottom-up explorer cannot pre-sort — lanes retire unpredictably the
/// moment they find a parent — so it streams candidate lanes
/// ([`SellLane`]) through this pack instead: every issue runs all
/// currently-active lanes one row forward, and retired lanes (converged
/// or exhausted) are refilled from the stream before the next issue,
/// keeping occupancy at 16 until the pool drains. Shared with the MS-BFS
/// bottom-up scan ([`super::multi_source`]), where a lane retires once
/// its vertex's visit mask covers the layer's live root set.
pub(crate) struct LanePack {
    /// SELL slot each lane is scanning.
    slot: [u32; LANES],
    /// Adjacency length of each lane.
    len: [u32; LANES],
    /// Next row (k-th neighbor) each lane will scan.
    row: [u32; LANES],
    /// Original vertex id each lane is scanning for.
    vertex: [Vertex; LANES],
    active: u16,
}

impl LanePack {
    pub(crate) fn new() -> Self {
        LanePack {
            slot: [0; LANES],
            len: [0; LANES],
            row: [0; LANES],
            vertex: [0; LANES],
            active: 0,
        }
    }

    /// Fill every inactive lane from `stream` (stops early when the stream
    /// runs dry). Returns the active-lane mask after refilling.
    pub(crate) fn refill(&mut self, stream: &mut impl Iterator<Item = SellLane>) -> Mask16 {
        for lane in 0..LANES {
            let bit = 1u16 << lane;
            if self.active & bit != 0 {
                continue;
            }
            let Some(l) = stream.next() else { break };
            self.slot[lane] = l.slot;
            self.len[lane] = l.len;
            self.row[lane] = 0;
            self.vertex[lane] = l.vertex;
            self.active |= bit;
        }
        Mask16(self.active)
    }

    /// Per-lane gather indices into `Sell16::cols` for each active lane's
    /// current row ([`Sell16::lane_index`] — the one definition of the
    /// SELL gather address); inactive lanes hold 0 and are masked off by
    /// the caller.
    pub(crate) fn gather_indices(&self, sell: &Sell16) -> VecI32x16 {
        let mut idx = [0i32; LANES];
        for lane in 0..LANES {
            if self.active & (1 << lane) != 0 {
                idx[lane] =
                    sell.lane_index(self.slot[lane] as usize, self.row[lane] as usize) as i32;
            }
        }
        VecI32x16(idx)
    }

    /// Each lane's own vertex id as a vector — the scatter index for
    /// race-free per-lane claims (all active lanes are distinct vertices).
    pub(crate) fn vertex_vec(&self) -> VecI32x16 {
        let mut v = [0i32; LANES];
        for lane in 0..LANES {
            if self.active & (1 << lane) != 0 {
                v[lane] = self.vertex[lane] as i32;
            }
        }
        VecI32x16(v)
    }

    /// Vertex id in `lane` (only meaningful for active lanes).
    #[inline]
    pub(crate) fn vertex(&self, lane: usize) -> Vertex {
        self.vertex[lane]
    }

    /// Advance every active lane one row; lanes in `retire` (converged) and
    /// lanes that ran out of adjacency (exhausted) leave the pack.
    pub(crate) fn advance(&mut self, retire: Mask16) {
        for lane in 0..LANES {
            let bit = 1u16 << lane;
            if self.active & bit == 0 {
                continue;
            }
            if retire.0 & bit != 0 {
                self.active &= !bit;
                continue;
            }
            self.row[lane] += 1;
            if self.row[lane] >= self.len[lane] {
                self.active &= !bit;
            }
        }
    }
}

/// SELL chunks per dynamic-schedule grab. The refill pool lives inside one
/// grab, and every grab pays a lane-drain tail (the last ≤16 candidates
/// retire without replacement), so the grain trades load balancing against
/// occupancy: 64 chunks (1024 slots) keeps the drain below ~2% of a grab's
/// issues while still giving the dynamic scheduler dozens of grabs at
/// Graph500 scales.
const BU_CHUNK_GRAIN: usize = 64;

/// One SELL-packed bottom-up layer step: every unvisited vertex searches
/// its adjacency for a frontier parent, 16 distinct vertices per VPU
/// issue. Returns (edges scanned, vertices discovered, merged counters).
///
/// `frontier_words` is the read-only frontier bitmap of the current layer;
/// `visited`/`next`/`pred` follow the same discipline as the scalar scan —
/// a vertex's entries are written only by the lane scanning that vertex.
///
/// `hub`, when present, is the packed hub-adjacency bitmap
/// ([`HubBits`]): candidates adjacent to a frontier hub are claimed from
/// one L1-resident mask word and never enter the [`LanePack`], so the
/// SELL adjacency stream is read strictly less on hub-heavy layers.
/// Hub-claimed lanes scan zero adjacency entries (that is the point), so
/// edge counts shrink versus `hub = None`; distances are unchanged — the
/// claimed parent is a frontier neighbor either way.
pub fn bottom_up_layer_sell<V: VpuBackend>(
    num_threads: usize,
    sell: &Sell16,
    frontier_words: &[u32],
    visited: &SharedBitmap,
    next: &SharedBitmap,
    pred: &SharedPred,
    opts: SimdOpts,
    hub: Option<&HubBits>,
) -> (usize, usize, VpuCounters) {
    struct Acc<V> {
        edges: usize,
        found: usize,
        vpu: Option<V>,
    }
    #[allow(clippy::derivable_impls)]
    impl<V> Default for Acc<V> {
        fn default() -> Self {
            Acc { edges: 0, found: 0, vpu: None }
        }
    }

    // which hubs are in this layer's frontier — one mask word for the
    // whole layer, reused by every candidate test
    let hub_mask = hub.map_or(0u32, |h| h.frontier_mask(frontier_words));
    let dist = opts.effective_dist();
    let accs: Vec<Acc<V>> = parallel_for_dynamic(
        num_threads,
        sell.num_chunks(),
        BU_CHUNK_GRAIN,
        |_tid, chunk_range, acc: &mut Acc<V>| crate::simd::fused::fuse::<V, _, _>(|| {
            let vpu = acc.vpu.get_or_insert_with(V::new);
            let slots = chunk_range.start * SELL_C..chunk_range.end * SELL_C;
            // candidate lanes: occupied slots whose vertex is still
            // unvisited. Within a layer only this thread can visit them
            // (each vertex is claimed by its own lane), so the filter is
            // stable across the refill stream. Candidates adjacent to a
            // frontier hub are claimed right here, from the bitmap, and
            // never reach the pack.
            let mut hub_found = 0usize;
            let mut stream = sell.slot_lanes(slots).filter(|l| {
                if visited.test_bit(l.vertex) {
                    return false;
                }
                if hub_mask != 0 {
                    if let Some(h) = hub {
                        let m = h.masks[l.vertex as usize] & hub_mask;
                        if m != 0 {
                            // claim the lowest-indexed (highest-degree)
                            // frontier hub as parent — race-free, same
                            // per-vertex ownership as the lane claim
                            let parent = h.hubs[m.trailing_zeros() as usize];
                            pred.set(l.vertex, parent as crate::Pred);
                            next.set_bit_atomic(l.vertex);
                            visited.set_bit_atomic(l.vertex);
                            hub_found += 1;
                            return false;
                        }
                    }
                }
                true
            });
            let mut pack = LanePack::new();
            loop {
                let active = pack.refill(&mut stream);
                if active.is_empty() {
                    break;
                }
                vpu.note_explore_issue(active.count());
                acc.edges += active.count() as usize;

                // gather each lane's next neighbor from the SELL storage
                let vidx = pack.gather_indices(sell);
                if opts.prefetch {
                    if V::COUNTED {
                        vpu.prefetch_i32gather(vidx, PrefetchHint::T1);
                    } else if dist > 0 {
                        // hardware: representative-lane stream prefetch —
                        // lane 0's SELL column line `dist` rows ahead
                        if let Some(c) = sell.cols.get(vidx.0[0] as usize + dist * SELL_C) {
                            vpu.prefetch_addr((c as *const u32).cast(), PrefetchHint::T1);
                        }
                    }
                }
                let vneig = vpu.mask_i32gather_words(active, vidx, &sell.cols);

                // frontier membership = Listing 1's filter aimed at the
                // frontier bitmap
                let bpw = vpu.set1_epi32(BITS_PER_WORD as i32);
                let vword = vpu.div_epi32(vneig, bpw);
                let vbits = vpu.rem_epi32(vneig, bpw);
                if opts.prefetch {
                    vpu.prefetch_i32gather(vword, PrefetchHint::T0);
                }
                let fwords = vpu.mask_i32gather_words(active, vword, frontier_words);
                let one = vpu.set1_epi32(1);
                let bits = vpu.sllv_epi32(one, vbits);
                let hit = vpu.kand(vpu.test_epi32_mask(fwords, bits), active);

                if !hit.is_empty() {
                    // claim: P[v] = u for each hit lane's own vertex — all
                    // scatter targets distinct, so no race and no marker
                    let vself = pack.vertex_vec();
                    vpu.mask_scatter_shared_i32(pred.atomic_cells(), hit, vself, vneig);
                    for lane in 0..SELL_C {
                        if hit.test_lane(lane) {
                            let v = pack.vertex(lane);
                            next.set_bit_atomic(v);
                            visited.set_bit_atomic(v);
                            acc.found += 1;
                        }
                    }
                }
                pack.advance(hit);
            }
            acc.found += hub_found;
        }),
    );

    let mut edges = 0usize;
    let mut found = 0usize;
    let mut vpu = VpuCounters::default();
    for a in accs {
        edges += a.edges;
        found += a.found;
        if let Some(v) = a.vpu {
            vpu.merge(&v.counters());
        }
    }
    (edges, found, vpu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::bottom_up::{bottom_up_layer_scalar, bottom_up_layer_simd};
    use crate::graph::{Bitmap, Csr, EdgeList, RmatConfig};
    use crate::simd::hw::HwPortable;
    use crate::simd::ops::Vpu;
    use crate::{Pred, Vertex};

    fn rmat(scale: u32, ef: usize, seed: u64) -> Csr {
        let el = RmatConfig::graph500(scale, ef).generate(seed);
        Csr::from_edge_list(scale, &el)
    }

    fn fresh_state(n: usize, root: Vertex) -> (SharedBitmap, SharedBitmap, SharedPred) {
        let vis = SharedBitmap::new(n);
        vis.set_bit_atomic(root);
        let next = SharedBitmap::new(n);
        let pred = SharedPred::new_infinity(n);
        pred.set(root, root as Pred);
        (vis, next, pred)
    }

    #[test]
    fn agrees_with_scalar_bottom_up() {
        // one layer from a hub frontier: identical discoveries, parents,
        // and — unlike the chunked scan — identical edge counts
        let g = rmat(10, 16, 75);
        let n = g.num_vertices();
        let sell = Sell16::from_csr(&g, 256);
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);

        let (v1, n1, p1) = fresh_state(n, root);
        let (e1, f1) = bottom_up_layer_scalar(1, &g, &frontier, &v1, &n1, &p1);
        for threads in [1usize, 4] {
            let (v2, n2, p2) = fresh_state(n, root);
            let (e2, f2, vpu) = bottom_up_layer_sell::<Vpu>(
                threads,
                &sell,
                frontier.words(),
                &v2,
                &n2,
                &p2,
                SimdOpts::full(),
                None,
            );
            assert_eq!(e1, e2, "lane-packed must scan exactly the scalar entry count");
            assert_eq!(f1, f2);
            assert_eq!(n1.snapshot().words(), n2.snapshot().words());
            assert_eq!(v1.snapshot().words(), v2.snapshot().words());
            assert_eq!(p1.snapshot(), p2.snapshot(), "threads={threads}");
            assert!(vpu.explore_issues > 0);
            assert!(vpu.gathers > 0);
        }
    }

    #[test]
    fn agrees_with_chunked_bottom_up_on_discoveries() {
        // discoveries/parents match the chunked scan too; the chunked scan
        // may only ever scan *more* entries (post-hit chunk remainders)
        let g = rmat(10, 8, 77);
        let n = g.num_vertices();
        let sell = Sell16::from_csr(&g, 256);
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);

        let (v1, n1, p1) = fresh_state(n, root);
        let (e_chunked, _f, _) =
            bottom_up_layer_simd::<Vpu>(1, &g, frontier.words(), &v1, &n1, &p1);
        let (v2, n2, p2) = fresh_state(n, root);
        let (e_packed, _f2, _) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &v2,
            &n2,
            &p2,
            SimdOpts::full(),
            None,
        );
        assert_eq!(n1.snapshot().words(), n2.snapshot().words());
        assert_eq!(v1.snapshot().words(), v2.snapshot().words());
        assert_eq!(p1.snapshot(), p2.snapshot());
        assert!(e_packed <= e_chunked, "packed {e_packed} > chunked {e_chunked}");
    }

    #[test]
    fn occupancy_beats_chunked_on_skewed_frontier() {
        // the tentpole claim at the layer level: scanning the same
        // unvisited pool against the same frontier, lane packing holds
        // strictly more active lanes per issue than per-vertex chunks
        let g = rmat(12, 16, 94);
        let n = g.num_vertices();
        let sell = Sell16::from_csr(&g, 256);
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        // frontier = the hub's neighborhood (a realistic explosion-layer
        // frontier), unvisited = everything else
        let (vis, next, pred) = fresh_state(n, root);
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);
        bottom_up_layer_scalar(1, &g, &frontier, &vis, &next, &pred);
        let frontier = next.snapshot();
        let vis_words = vis.snapshot();

        let mk = || {
            let v = SharedBitmap::new(n);
            for (w, &bits) in vis_words.words().iter().enumerate() {
                v.or_word_atomic(w, bits);
            }
            (v, SharedBitmap::new(n), SharedPred::new_infinity(n))
        };
        let (v1, n1, p1) = mk();
        let (_, _, chunked) = bottom_up_layer_simd::<Vpu>(1, &g, frontier.words(), &v1, &n1, &p1);
        let (v2, n2, p2) = mk();
        let (_, _, packed) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &v2,
            &n2,
            &p2,
            SimdOpts::full(),
            None,
        );
        let occ_chunked = chunked.mean_lanes_active();
        let occ_packed = packed.mean_lanes_active();
        assert!(occ_chunked > 0.0 && occ_packed > 0.0);
        assert!(
            occ_packed > occ_chunked + 1.0,
            "packed occupancy {occ_packed:.2} !> chunked {occ_chunked:.2} + 1.0"
        );
        // same discoveries either way
        assert_eq!(n1.snapshot().words(), n2.snapshot().words());
    }

    #[test]
    fn hw_backend_layer_matches_counted() {
        // backend equivalence at the layer level: the portable hardware
        // tier must produce the identical discoveries, parents and edge
        // count as the counted emulator — and record nothing
        let g = rmat(10, 16, 76);
        let n = g.num_vertices();
        let sell = Sell16::from_csr(&g, 256);
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);

        let (v1, n1, p1) = fresh_state(n, root);
        let (e1, f1, counted) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &v1,
            &n1,
            &p1,
            SimdOpts::full(),
            None,
        );
        let (v2, n2, p2) = fresh_state(n, root);
        let (e2, f2, hw) = bottom_up_layer_sell::<HwPortable>(
            1,
            &sell,
            frontier.words(),
            &v2,
            &n2,
            &p2,
            SimdOpts::full(),
            None,
        );
        assert_eq!(e1, e2);
        assert_eq!(f1, f2);
        assert_eq!(n1.snapshot().words(), n2.snapshot().words());
        assert_eq!(v1.snapshot().words(), v2.snapshot().words());
        assert_eq!(p1.snapshot(), p2.snapshot());
        assert!(counted.explore_issues > 0, "counted backend must record");
        assert_eq!(hw, crate::simd::VpuCounters::default(), "hw backend must not record");
    }

    #[test]
    fn empty_frontier_discovers_nothing() {
        let el = EdgeList::with_edges(8, vec![(0, 1), (1, 2)]);
        let g = Csr::from_edge_list(0, &el);
        let sell = Sell16::from_csr(&g, 16);
        let frontier = Bitmap::new(8);
        let vis = SharedBitmap::new(8);
        let next = SharedBitmap::new(8);
        let pred = SharedPred::new_infinity(8);
        let (edges, found, _) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &vis,
            &next,
            &pred,
            SimdOpts::full(),
            None,
        );
        // every unvisited lane scans to exhaustion, finds nothing
        assert_eq!(found, 0);
        assert!(next.is_all_zero());
        assert_eq!(edges, g.num_directed_edges());
    }

    #[test]
    fn disconnected_vertices_never_claimed() {
        // 0–1 connected; 2–3 form a separate component; 4 isolated
        let el = EdgeList::with_edges(5, vec![(0, 1), (2, 3)]);
        let g = Csr::from_edge_list(0, &el);
        let sell = Sell16::from_csr(&g, 16);
        let mut frontier = Bitmap::new(5);
        frontier.set_bit(0);
        let (vis, next, pred) = fresh_state(5, 0);
        let (_, found, _) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &vis,
            &next,
            &pred,
            SimdOpts::none(),
            None,
        );
        assert_eq!(found, 1);
        assert!(next.test_bit(1));
        assert_eq!(pred.get(1), 0);
        assert_eq!(pred.get(2), crate::PRED_INFINITY);
        assert_eq!(pred.get(4), crate::PRED_INFINITY);
    }

    #[test]
    fn hub_bitmap_claims_match_and_scan_less() {
        // the frontier is the top-degree hub, so every candidate adjacent
        // to it resolves from the bitmap: identical discoveries and
        // parents, strictly fewer adjacency-stream reads
        let g = rmat(10, 16, 78);
        let n = g.num_vertices();
        let sell = Sell16::from_csr(&g, 256);
        let root = (0..n as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let hub = HubBits::build(&g, 16);
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);

        let (v1, n1, p1) = fresh_state(n, root);
        let (e_off, f_off, _) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &v1,
            &n1,
            &p1,
            SimdOpts::full(),
            None,
        );
        let (v2, n2, p2) = fresh_state(n, root);
        let (e_on, f_on, _) = bottom_up_layer_sell::<Vpu>(
            1,
            &sell,
            frontier.words(),
            &v2,
            &n2,
            &p2,
            SimdOpts::full(),
            Some(&hub),
        );
        assert_eq!(f_off, f_on, "hub claims must find the same vertices");
        assert_eq!(n1.snapshot().words(), n2.snapshot().words());
        assert_eq!(v1.snapshot().words(), v2.snapshot().words());
        // the only frontier hub is the root, so claimed parents agree too
        assert_eq!(p1.snapshot(), p2.snapshot());
        assert!(e_on < e_off, "hub path must skip adjacency reads ({e_on} !< {e_off})");
    }
}
