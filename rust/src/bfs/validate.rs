//! §5.3 — the Graph500-style soft validator.
//!
//! "The validation method ... consists of five check results that do not
//! intend to get a full check of the generated output ... but just provide
//! a 'soft' check." We implement the five checks of the Graph500
//! specification's `validate` kernel:
//!
//! 1. the root is its own parent and is marked reached;
//! 2. the predecessor structure is a tree: every reached vertex's parent
//!    chain terminates at the root (no cycles, no dangling parents);
//! 3. every tree edge `(parent(v), v)` exists in the graph;
//! 4. levels are consistent: `dist(v) == dist(parent(v)) + 1` for every
//!    reached non-root vertex;
//! 5. edge-cut consistency: every graph edge `{a, b}` has both endpoints
//!    reached or both unreached, and if reached their levels differ by at
//!    most 1 (this is what catches "missed" vertices without recomputing a
//!    reference BFS).

use super::BfsTree;
use crate::graph::Csr;
use crate::Vertex;

/// Outcome of one check.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Check {
    pub name: &'static str,
    pub passed: bool,
    /// First violation found (empty when passed).
    pub detail: String,
}

/// The five-check report.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub checks: Vec<Check>,
}

impl ValidationReport {
    pub fn all_passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    pub fn summary(&self) -> String {
        self.checks
            .iter()
            .map(|c| format!("[{}] {}{}", if c.passed { "ok" } else { "FAIL" }, c.name, if c.detail.is_empty() { String::new() } else { format!(": {}", c.detail) }))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Run the five checks of a BFS tree against its graph.
pub fn validate(g: &Csr, tree: &BfsTree) -> ValidationReport {
    let mut checks = Vec::with_capacity(5);
    let n = g.num_vertices();
    let root = tree.root;

    // Check 1: root parent.
    let c1 = tree.reached(root) && tree.parent(root) == Some(root);
    checks.push(Check {
        name: "root is its own parent",
        passed: c1,
        detail: if c1 { String::new() } else { format!("pred[root]={:?}", tree.parent(root)) },
    });

    // Check 2: tree-ness (distances computable = acyclic parent chains that
    // terminate at the root).
    let dist = tree.distances();
    let c2_detail = match &dist {
        Some(d) => {
            // parent of a reached vertex must itself be reached
            let mut bad = String::new();
            for v in 0..n as Vertex {
                if let Some(p) = tree.parent(v) {
                    if d[p as usize] == u32::MAX {
                        bad = format!("vertex {v} has unreached parent {p}");
                        break;
                    }
                }
            }
            bad
        }
        None => "cycle in predecessor chains".to_string(),
    };
    checks.push(Check { name: "predecessors form a tree", passed: c2_detail.is_empty(), detail: c2_detail });

    let dist = dist.unwrap_or_else(|| vec![u32::MAX; n]);

    // Check 3: tree edges exist in the graph.
    let mut c3_detail = String::new();
    for v in 0..n as Vertex {
        if let Some(p) = tree.parent(v) {
            if p != v && !g.has_edge(p, v) {
                c3_detail = format!("tree edge {p}->{v} not in graph");
                break;
            }
        }
    }
    checks.push(Check { name: "tree edges exist in graph", passed: c3_detail.is_empty(), detail: c3_detail });

    // Check 4: levels differ by exactly one along tree edges.
    let mut c4_detail = String::new();
    for v in 0..n as Vertex {
        if let Some(p) = tree.parent(v) {
            if v != root && dist[v as usize] != dist[p as usize].saturating_add(1) {
                c4_detail =
                    format!("level({v})={} but level(parent {p})={}", dist[v as usize], dist[p as usize]);
                break;
            }
        }
    }
    checks.push(Check { name: "levels increase by one", passed: c4_detail.is_empty(), detail: c4_detail });

    // Check 5: graph-edge consistency (both endpoints reached or neither;
    // reached endpoints within one level).
    let mut c5_detail = String::new();
    'outer: for a in 0..n as Vertex {
        for &b in g.neighbors(a) {
            let (da, db) = (dist[a as usize], dist[b as usize]);
            match (da == u32::MAX, db == u32::MAX) {
                (false, true) | (true, false) => {
                    c5_detail = format!("edge {{{a},{b}}} crosses the reached boundary");
                    break 'outer;
                }
                (false, false) => {
                    if da.abs_diff(db) > 1 {
                        c5_detail = format!("edge {{{a},{b}}} spans levels {da} and {db}");
                        break 'outer;
                    }
                }
                (true, true) => {}
            }
        }
    }
    checks.push(Check { name: "graph edges within one level", passed: c5_detail.is_empty(), detail: c5_detail });

    ValidationReport { checks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialLayeredBfs;
    use crate::bfs::BfsEngine;
    use crate::graph::{EdgeList, RmatConfig};
    use crate::{Pred, PRED_INFINITY};

    fn good_tree() -> (Csr, BfsTree) {
        let el = RmatConfig::graph500(9, 8).generate(41);
        let g = Csr::from_edge_list(9, &el);
        let tree = SerialLayeredBfs.run(&g, 0).tree;
        (g, tree)
    }

    #[test]
    fn valid_tree_passes_all_five() {
        let (g, tree) = good_tree();
        let report = validate(&g, &tree);
        assert_eq!(report.checks.len(), 5);
        assert!(report.all_passed(), "{}", report.summary());
    }

    #[test]
    fn detects_wrong_root_parent() {
        let (g, mut tree) = good_tree();
        tree.pred[tree.root as usize] = PRED_INFINITY;
        let r = validate(&g, &tree);
        assert!(!r.checks[0].passed);
    }

    #[test]
    fn detects_cycle() {
        let (g, mut tree) = good_tree();
        // find two reached non-root vertices and point them at each other
        let vs: Vec<Vertex> = (0..g.num_vertices() as Vertex)
            .filter(|&v| tree.reached(v) && v != tree.root)
            .take(2)
            .collect();
        tree.pred[vs[0] as usize] = vs[1] as Pred;
        tree.pred[vs[1] as usize] = vs[0] as Pred;
        let r = validate(&g, &tree);
        assert!(!r.all_passed());
        assert!(!r.checks[1].passed, "{}", r.summary());
    }

    #[test]
    fn detects_phantom_tree_edge() {
        // connect two vertices that are NOT adjacent in the graph
        let el = EdgeList::with_edges(5, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let mut tree = SerialLayeredBfs.run(&g, 0).tree;
        tree.pred[4] = 0; // 0-4 is not an edge
        let r = validate(&g, &tree);
        assert!(!r.checks[2].passed || !r.checks[3].passed, "{}", r.summary());
    }

    #[test]
    fn detects_level_skip() {
        let el = EdgeList::with_edges(5, vec![(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let mut tree = SerialLayeredBfs.run(&g, 0).tree;
        // make 3's parent 0: edge (0,3) doesn't exist → check 3; even if it
        // did, levels would skip → craft with existing edge instead:
        // set 2's parent to 4 (edge 4-? no). Use vertex 4: parent currently 0
        // (edge 0-4 exists, dist 1). Set 3's parent to 4 and 4's to 0:
        tree.pred[3] = 4;
        // now dist(3) = 2 via 4, but graph edge (2,3) spans levels... still
        // consistent. Force a skip: claim 2's parent is 0 (no edge 0-2).
        tree.pred[2] = 0;
        let r = validate(&g, &tree);
        assert!(!r.all_passed());
    }

    #[test]
    fn detects_missed_vertex() {
        // a reachable vertex left out of the tree must trip check 5
        let el = EdgeList::with_edges(4, vec![(0, 1), (1, 2), (2, 3)]);
        let g = Csr::from_edge_list(0, &el);
        let mut tree = SerialLayeredBfs.run(&g, 0).tree;
        tree.pred[3] = PRED_INFINITY; // pretend BFS missed vertex 3
        let r = validate(&g, &tree);
        assert!(!r.checks[4].passed, "{}", r.summary());
    }

    #[test]
    fn all_algorithms_validate() {
        use crate::bfs::bitrace_free::BitRaceFreeBfs;
        use crate::bfs::parallel::ParallelBfs;
        use crate::bfs::serial::SerialQueueBfs;
        use crate::bfs::vectorized::VectorizedBfs;
        let el = RmatConfig::graph500(10, 16).generate(42);
        let g = Csr::from_edge_list(10, &el);
        let algs: Vec<Box<dyn BfsEngine>> = vec![
            Box::new(SerialQueueBfs),
            Box::new(SerialLayeredBfs),
            Box::new(ParallelBfs { num_threads: 3 }),
            Box::new(BitRaceFreeBfs { num_threads: 3 }),
            Box::new(VectorizedBfs::default()),
        ];
        for alg in algs {
            let r = alg.run(&g, 7);
            let report = validate(&g, &r.tree);
            assert!(report.all_passed(), "{} failed:\n{}", alg.name(), report.summary());
        }
    }
}
