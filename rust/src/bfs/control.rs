//! Cooperative run control: cancellation flags and deadlines for the
//! traversal runtime.
//!
//! A BFS-as-a-service coordinator must be able to bound a traversal (a
//! request deadline) or abandon it (a dropped client) without tearing down
//! the worker pool. [`RunControl`] is the shared signal: a cancel flag plus
//! an optional deadline, checked **at layer boundaries** by every engine of
//! the ladder. Layer granularity is deliberate — the monomorphized VPU hot
//! loops never see the control, so uninterrupted runs pay one atomic load
//! (and, only when a deadline is armed, one `Instant::now`) per layer,
//! which is noise next to a layer's edge volume. The serial queue engine
//! has no layers, so it checks every [`SERIAL_CHECK_GRAIN`] dequeues.
//!
//! An interrupted traversal is not an error: it returns the **partial**
//! result built so far, tagged with a [`RunStatus`]. Because every engine
//! stops only at a layer boundary (or, for the queue form, between vertex
//! expansions), the visited prefix is always internally consistent: every
//! reached vertex carries its true BFS depth, so partial results validate
//! against the serial oracle as a prefix (the chaos suite asserts this for
//! every registered engine).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// How many vertices the queue-form serial engine expands between control
/// checks (it has no layer boundaries to piggyback on).
pub const SERIAL_CHECK_GRAIN: usize = 1024;

/// How a traversal ended.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RunStatus {
    /// The frontier drained — the result is the full BFS tree.
    #[default]
    Complete,
    /// The run's deadline passed; the result is the visited prefix.
    TimedOut,
    /// The run was cancelled; the result is the visited prefix.
    Cancelled,
}

impl RunStatus {
    /// True when the traversal ran to completion.
    #[inline]
    pub fn is_complete(self) -> bool {
        self == RunStatus::Complete
    }
}

/// Process-wide monotonic anchor: deadlines are stored as nanosecond
/// offsets from this instant so the control stays const-constructible
/// (`Instant` itself cannot live in an atomic).
fn anchor() -> Instant {
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// Shared cancel-flag + optional deadline, threaded through
/// [`crate::bfs::PreparedBfs::run_batch_with`] and checked at layer
/// boundaries by every engine.
///
/// Cloneable by `Arc`: the coordinator hands one control to all workers of
/// a job, and an external caller holding the same `Arc` can cancel the
/// whole job mid-flight.
pub struct RunControl {
    cancelled: AtomicBool,
    /// Deadline as nanos-since-[`anchor`], `u64::MAX` = none armed.
    deadline_ns: AtomicU64,
    /// Monotonic progress heartbeat, bumped by every [`stop_reason`]
    /// call — i.e. at exactly the layer boundaries where cancellation is
    /// already checked, so the hot loops stay untouched. A supervisor
    /// that samples [`ticks`] and sees no movement knows the traversal
    /// stopped reaching layer boundaries (a non-cooperative hang), which
    /// no deadline can detect.
    ///
    /// [`stop_reason`]: RunControl::stop_reason
    /// [`ticks`]: RunControl::ticks
    ticks: AtomicU64,
}

impl Default for RunControl {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for RunControl {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RunControl")
            .field("cancelled", &self.is_cancelled())
            .field("deadline_armed", &(self.deadline_ns.load(Ordering::Relaxed) != u64::MAX))
            .finish()
    }
}

impl RunControl {
    /// A fresh control: not cancelled, no deadline.
    pub const fn new() -> Self {
        RunControl {
            cancelled: AtomicBool::new(false),
            deadline_ns: AtomicU64::new(u64::MAX),
            ticks: AtomicU64::new(0),
        }
    }

    /// The shared "never stop" control — what the plain
    /// [`crate::bfs::PreparedBfs::run`] entry points pass down, so
    /// uncontrolled callers never allocate one.
    pub fn unbounded() -> &'static RunControl {
        static UNBOUNDED: RunControl = RunControl::new();
        &UNBOUNDED
    }

    /// Ask every traversal sharing this control to stop at its next check.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Relaxed);
    }

    /// True once [`RunControl::cancel`] was called.
    #[inline]
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Relaxed)
    }

    /// Arm (or re-arm) the deadline `d` from now. A zero `d` trips at the
    /// very next check — useful for deterministic tests.
    pub fn arm_deadline_in(&self, d: Duration) {
        let now = anchor().elapsed().as_nanos() as u64;
        let ns = now.saturating_add(d.as_nanos().min(u64::MAX as u128) as u64);
        // MAX means "none", so a pathological far-future deadline clamps
        // one tick below it
        self.deadline_ns.store(ns.min(u64::MAX - 1), Ordering::Relaxed);
    }

    /// True when a deadline is armed and has passed.
    #[inline]
    pub fn deadline_exceeded(&self) -> bool {
        let deadline = self.deadline_ns.load(Ordering::Relaxed);
        deadline != u64::MAX && anchor().elapsed().as_nanos() as u64 >= deadline
    }

    /// Time left until the armed deadline: `None` when no deadline is
    /// armed, zero once it has passed. Lets retry backoff truncate its
    /// sleeps to the job's remaining budget instead of sleeping through it.
    pub fn deadline_remaining(&self) -> Option<Duration> {
        let deadline = self.deadline_ns.load(Ordering::Relaxed);
        if deadline == u64::MAX {
            return None;
        }
        let now = anchor().elapsed().as_nanos() as u64;
        Some(Duration::from_nanos(deadline.saturating_sub(now)))
    }

    /// The per-layer check: why (if at all) the traversal should stop now.
    /// Cancellation wins over the deadline; the `Instant::now` for the
    /// deadline test is only taken when one is armed. Every call bumps the
    /// progress heartbeat — reaching a control check *is* progress.
    #[inline]
    pub fn stop_reason(&self) -> Option<RunStatus> {
        self.ticks.fetch_add(1, Ordering::Relaxed);
        if self.is_cancelled() {
            return Some(RunStatus::Cancelled);
        }
        if self.deadline_exceeded() {
            return Some(RunStatus::TimedOut);
        }
        None
    }

    /// The heartbeat counter: how many control checks the traversals
    /// sharing this control have reached. A watchdog samples this — two
    /// identical readings a liveness budget apart mean the run made no
    /// layer progress in between. Reading never ticks; only
    /// [`RunControl::stop_reason`] does.
    #[inline]
    pub fn ticks(&self) -> u64 {
        self.ticks.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_control_never_stops() {
        let c = RunControl::new();
        assert_eq!(c.stop_reason(), None);
        assert!(!c.is_cancelled());
        assert!(!c.deadline_exceeded());
        assert_eq!(RunControl::unbounded().stop_reason(), None);
    }

    #[test]
    fn cancel_is_sticky_and_wins_over_deadline() {
        let c = RunControl::new();
        c.arm_deadline_in(Duration::ZERO);
        c.cancel();
        assert_eq!(c.stop_reason(), Some(RunStatus::Cancelled));
        assert_eq!(c.stop_reason(), Some(RunStatus::Cancelled), "sticky");
    }

    #[test]
    fn zero_deadline_trips_immediately() {
        let c = RunControl::new();
        assert_eq!(c.stop_reason(), None);
        c.arm_deadline_in(Duration::ZERO);
        assert_eq!(c.stop_reason(), Some(RunStatus::TimedOut));
    }

    #[test]
    fn future_deadline_does_not_trip() {
        let c = RunControl::new();
        c.arm_deadline_in(Duration::from_secs(3600));
        assert_eq!(c.stop_reason(), None);
    }

    #[test]
    fn deadline_remaining_tracks_the_armed_deadline() {
        let c = RunControl::new();
        assert_eq!(c.deadline_remaining(), None, "unarmed → None");
        c.arm_deadline_in(Duration::from_secs(3600));
        let rem = c.deadline_remaining().expect("armed");
        assert!(rem > Duration::from_secs(3500) && rem <= Duration::from_secs(3600));
        c.arm_deadline_in(Duration::ZERO);
        assert_eq!(c.deadline_remaining(), Some(Duration::ZERO), "passed → zero");
    }

    #[test]
    fn stop_reason_ticks_the_heartbeat_and_reads_do_not() {
        let c = RunControl::new();
        assert_eq!(c.ticks(), 0);
        assert_eq!(c.stop_reason(), None);
        assert_eq!(c.stop_reason(), None);
        assert_eq!(c.ticks(), 2, "each check is one heartbeat");
        assert_eq!(c.ticks(), 2, "reading the heartbeat must not tick it");
        // interrupted checks still count as heartbeats: the worker reached
        // a layer boundary, which is exactly the progress being measured
        c.cancel();
        assert_eq!(c.stop_reason(), Some(RunStatus::Cancelled));
        assert_eq!(c.ticks(), 3);
    }

    #[test]
    fn status_default_is_complete() {
        assert_eq!(RunStatus::default(), RunStatus::Complete);
        assert!(RunStatus::Complete.is_complete());
        assert!(!RunStatus::TimedOut.is_complete());
        assert!(!RunStatus::Cancelled.is_complete());
    }
}
