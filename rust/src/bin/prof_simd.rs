//! Standalone profiling driver for the vectorized hot path (used with
//! `perf record` during the §Perf pass; see EXPERIMENTS.md §Perf).
use phi_bfs::bfs::policy::LayerPolicy;
use phi_bfs::bfs::vectorized::{SimdOpts, VectorizedBfs};
use phi_bfs::bfs::BfsEngine;
use phi_bfs::graph::{Csr, RmatConfig};

fn main() {
    let scale: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(15);
    let iters: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(20);
    let el = RmatConfig::graph500(scale, 16).generate(1);
    let g = Csr::from_edge_list(scale, &el);
    let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
    let alg = VectorizedBfs {
        num_threads: 1,
        opts: SimdOpts::full(),
        policy: LayerPolicy::All,
        ..Default::default()
    };
    // prepare once outside the timed loop — profile the traversal hot path
    let prepared = alg.prepare(&g).expect("prepare");
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        std::hint::black_box(prepared.run(root));
    }
    println!("{} iters in {:.3?} ({:.3?}/iter)", iters, t0.elapsed(), t0.elapsed() / iters as u32);
}
