//! Artifact manifest: which AOT layer-step executables exist and how to
//! pick one for a graph.
//!
//! `make artifacts` writes `artifacts/manifest.txt` with one line per
//! compiled size bucket:
//!
//! ```text
//! bfs_layer <N> <C> <W> <filename>
//! ```
//!
//! (Plain text rather than JSON because serde is not in the offline crate
//! registry — and four fields don't need it.)

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One compiled size bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Vertex capacity (bitmap geometry; `nodes` constant baked in).
    pub n: usize,
    /// Adjacency chunks (rows of 16 lanes) per executable call.
    pub chunks: usize,
    /// Bitmap words = ceil(n / 32).
    pub words: usize,
    /// HLO text file, relative to the artifact directory.
    pub filename: String,
}

impl ArtifactSpec {
    /// Lanes per call.
    pub fn lanes_per_call(&self) -> usize {
        self.chunks * 16
    }
}

/// Parsed manifest plus its directory.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        let specs = Self::parse(&text)?;
        Ok(ArtifactManifest { dir, specs })
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str) -> Result<Vec<ArtifactSpec>> {
        let mut specs = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 5 || parts[0] != "bfs_layer" {
                bail!("manifest line {}: expected `bfs_layer N C W file`, got {line:?}", lineno + 1);
            }
            let spec = ArtifactSpec {
                n: parts[1].parse().context("N")?,
                chunks: parts[2].parse().context("C")?,
                words: parts[3].parse().context("W")?,
                filename: parts[4].to_string(),
            };
            if spec.words != spec.n.div_ceil(32) {
                bail!("manifest line {}: W={} inconsistent with N={}", lineno + 1, spec.words, spec.n);
            }
            specs.push(spec);
        }
        if specs.is_empty() {
            bail!("manifest contains no artifacts");
        }
        Ok(specs)
    }

    /// Smallest bucket able to hold a graph of `num_vertices`.
    pub fn pick(&self, num_vertices: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.n >= num_vertices)
            .min_by_key(|s| s.n)
    }

    /// Absolute path of a spec's HLO file.
    pub fn path_of(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.filename)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
bfs_layer 1024 64 32 bfs_layer_n1024_c64.hlo.txt
bfs_layer 4096 128 128 bfs_layer_n4096_c128.hlo.txt
bfs_layer 16384 256 512 bfs_layer_n16384_c256.hlo.txt
";

    #[test]
    fn parses_sample() {
        let specs = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0], ArtifactSpec { n: 1024, chunks: 64, words: 32, filename: "bfs_layer_n1024_c64.hlo.txt".into() });
        assert_eq!(specs[2].lanes_per_call(), 4096);
    }

    #[test]
    fn pick_smallest_fitting() {
        let m = ArtifactManifest { dir: "/x".into(), specs: ArtifactManifest::parse(SAMPLE).unwrap() };
        assert_eq!(m.pick(100).unwrap().n, 1024);
        assert_eq!(m.pick(1024).unwrap().n, 1024);
        assert_eq!(m.pick(1025).unwrap().n, 4096);
        assert_eq!(m.pick(16384).unwrap().n, 16384);
        assert!(m.pick(1 << 20).is_none());
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(ArtifactManifest::parse("bfs_layer 10 2").is_err());
        assert!(ArtifactManifest::parse("other 1 2 3 f").is_err());
        assert!(ArtifactManifest::parse("").is_err());
        // inconsistent W
        assert!(ArtifactManifest::parse("bfs_layer 1024 64 31 f.hlo.txt").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let text = format!("# header\n\n{SAMPLE}");
        assert_eq!(ArtifactManifest::parse(&text).unwrap().len(), 3);
    }
}
