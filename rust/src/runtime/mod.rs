//! PJRT runtime: loads the AOT-compiled JAX/Pallas layer-step artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and executes
//! them from Rust. Python is never on this path.
//!
//! Pipeline (see /opt/xla-example/load_hlo and DESIGN.md §2):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `executable.execute`.
//!
//! * [`artifacts`] — manifest parsing + size-bucket selection.
//! * [`engine`] — the compiled-executable cache and the typed
//!   `layer_step` call.
//! * [`bfs`] — a [`crate::bfs::BfsEngine`] that runs the whole
//!   traversal through the artifact, proving the three layers compose.

pub mod artifacts;
pub mod bfs;
pub mod engine;

pub use artifacts::{ArtifactManifest, ArtifactSpec};
pub use engine::PjrtEngine;
