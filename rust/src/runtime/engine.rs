//! The PJRT execution engine: one CPU client, one compiled executable per
//! artifact bucket (compiled lazily, cached), and the typed layer-step
//! call used by the PJRT-backed BFS engine and the `pjrt_bfs` example.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::artifacts::{ArtifactManifest, ArtifactSpec};

/// Inputs of one layer-step call, all in artifact geometry (padded).
#[derive(Clone, Debug)]
pub struct LayerStepArgs {
    /// `C*16` adjacency lanes, -1 padded (row-major `[C][16]`).
    pub neigh: Vec<i32>,
    /// `C*16` parent lanes, -1 padded.
    pub parents: Vec<i32>,
    /// `W` visited bitmap words (bit patterns).
    pub vis_words: Vec<i32>,
    /// `W` output-queue words.
    pub out_words: Vec<i32>,
    /// `N` predecessor entries.
    pub pred: Vec<i32>,
}

/// Outputs of one layer-step call.
#[derive(Clone, Debug)]
pub struct LayerStepResult {
    pub out_words: Vec<i32>,
    pub vis_words: Vec<i32>,
    pub pred: Vec<i32>,
    /// Wall time of the on-device execution (excludes literal transfer).
    pub exec_time: std::time::Duration,
}

/// PJRT CPU client + executable cache.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(manifest: ArtifactManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine { client, manifest, executables: HashMap::new() })
    }

    /// Convenience: load the manifest from `dir` and build the engine.
    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Self::new(ArtifactManifest::load(dir)?)
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the executable for a bucket.
    pub fn executable(&mut self, spec: &ArtifactSpec) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(&spec.filename) {
            let path = self.manifest.path_of(spec);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.filename))?;
            self.executables.insert(spec.filename.clone(), exe);
        }
        Ok(&self.executables[&spec.filename])
    }

    /// Execute one layer step through the artifact.
    pub fn layer_step(&mut self, spec: &ArtifactSpec, args: &LayerStepArgs) -> Result<LayerStepResult> {
        let lanes = spec.lanes_per_call();
        anyhow::ensure!(args.neigh.len() == lanes, "neigh: {} != {}", args.neigh.len(), lanes);
        anyhow::ensure!(args.parents.len() == lanes, "parents len");
        anyhow::ensure!(args.vis_words.len() == spec.words, "vis len");
        anyhow::ensure!(args.out_words.len() == spec.words, "out len");
        anyhow::ensure!(args.pred.len() == spec.n, "pred len");

        let neigh = xla::Literal::vec1(&args.neigh).reshape(&[spec.chunks as i64, 16])?;
        let parents = xla::Literal::vec1(&args.parents).reshape(&[spec.chunks as i64, 16])?;
        let vis = xla::Literal::vec1(&args.vis_words);
        let out = xla::Literal::vec1(&args.out_words);
        let pred = xla::Literal::vec1(&args.pred);

        let spec = spec.clone();
        let exe = self.executable(&spec)?;
        let t0 = Instant::now();
        let result = exe
            .execute::<xla::Literal>(&[neigh, parents, vis, out, pred])?[0][0]
            .to_literal_sync()?;
        let exec_time = t0.elapsed();
        // aot.py lowers with return_tuple=True → 3-tuple
        let (out_l, vis_l, pred_l) = result.to_tuple3().context("expected a 3-tuple result")?;
        Ok(LayerStepResult {
            out_words: out_l.to_vec::<i32>()?,
            vis_words: vis_l.to_vec::<i32>()?,
            pred: pred_l.to_vec::<i32>()?,
            exec_time,
        })
    }
}

#[cfg(test)]
mod tests {
    // The engine needs built artifacts; full coverage lives in
    // rust/tests/pjrt_integration.rs (run after `make artifacts`).
}
