//! A [`BfsEngine`] whose per-layer hot loop is the AOT-compiled
//! JAX/Pallas kernel executed through PJRT — the end-to-end proof that the
//! three layers (Rust coordinator → jax graph → Pallas kernel) compose.
//!
//! The Rust side keeps the traversal state (bitmaps, predecessors) and, per
//! layer, packs the frontier's adjacency lists into 16-lane chunks, batches
//! them to the artifact's `C` capacity, and calls the executable; the
//! kernel performs Listing 1's explore + the restoration, returning
//! consistent state for the next layer.
//!
//! Chunk packing is the raw-CSR peel/full/remainder structure of §4.2: a
//! vertex's adjacency is cut at `rows`-array 16-element boundaries, so a
//! lane layout valid for the emulated VPU is valid here; distances always
//! agree with the native explorer (asserted by the integration test and
//! the `pjrt_bfs` example).

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::artifacts::{ArtifactManifest, ArtifactSpec};
use super::engine::{LayerStepArgs, PjrtEngine};
use crate::bfs::{
    BfsEngine, BfsResult, BfsTree, GraphArtifacts, LayerTrace, PreparedBfs, RunControl, RunStatus,
    RunTrace,
};
use crate::graph::{Bitmap, Csr};
use crate::{Pred, Vertex, PRED_INFINITY};

const LANES: usize = 16;

/// BFS engine backed by the PJRT-compiled layer step.
///
/// The engine value only carries the artifact manifest; the PJRT client
/// and the compiled executable for the graph's bucket are created by
/// [`BfsEngine::prepare`] — once per graph, failing fast if the runtime is
/// unavailable or no bucket fits. The PJRT client is not `Sync`-friendly,
/// so the prepared instance serializes device calls behind a `Mutex`
/// (one CPU device anyway) while still satisfying the shared-`PreparedBfs`
/// contract.
pub struct PjrtBfs {
    manifest: ArtifactManifest,
}

impl PjrtBfs {
    /// Wrap an existing engine's manifest. (The engine's client handle is
    /// not reused — each prepare builds its own.)
    pub fn new(engine: PjrtEngine) -> Self {
        PjrtBfs { manifest: engine.manifest().clone() }
    }

    pub fn from_dir(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(PjrtBfs { manifest: ArtifactManifest::load(dir)? })
    }

    /// Prepare for `g`: create the client, pick the bucket, compile.
    fn prepare_pjrt<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<PreparedPjrt<'g>> {
        let mut engine = PjrtEngine::new(self.manifest.clone())?;
        let n = g.num_vertices();
        let spec = engine
            .manifest()
            .pick(n)
            .ok_or_else(|| anyhow!("no artifact bucket fits {n} vertices; rebuild with --buckets"))?
            .clone();
        engine.executable(&spec)?;
        Ok(PreparedPjrt { g, engine: Mutex::new(engine), spec, artifacts })
    }

    /// One-shot prepare + traverse with error propagation.
    pub fn run_checked(&self, g: &Csr, root: Vertex) -> Result<BfsResult> {
        self.prepare_pjrt(g, Arc::new(GraphArtifacts::for_graph(g)))?.run_checked(root)
    }

    /// Pack one frontier's adjacency lists into (neigh, parent) lane pairs,
    /// chunked at the CSR `rows` 16-element boundaries (peel / full /
    /// remainder, §4.2) — each chunk belongs to exactly one frontier vertex.
    pub fn pack_frontier(g: &Csr, frontier: &Bitmap) -> Vec<([i32; LANES], [i32; LANES])> {
        let mut chunks = Vec::new();
        for u in frontier.iter_set_bits() {
            let (start, end) = g.adjacency_range(u);
            let mut off = start;
            while off < end {
                // cut at the next 16-aligned boundary of `rows`
                let boundary = (off / LANES + 1) * LANES;
                let stop = boundary.min(end);
                let mut neigh = [-1i32; LANES];
                let mut parent = [-1i32; LANES];
                for (lane, idx) in (off..stop).enumerate() {
                    neigh[lane] = g.rows[idx] as i32;
                    parent[lane] = u as i32;
                }
                chunks.push((neigh, parent));
                off = stop;
            }
        }
        chunks
    }
}

/// A [`PjrtBfs`] bound to one graph: compiled executable for the graph's
/// bucket, device calls serialized behind a `Mutex`.
///
/// Serialization trade-off: multi-worker jobs on the PJRT engine now
/// share one executable (compiled once, in prepare) instead of compiling
/// per worker, but roots execute one at a time. Time spent waiting for
/// the device lock is measured separately and reported in
/// [`RunTrace::lock_wait_ns`], so a root's traversal seconds cover
/// execution only — queueing behind other workers no longer inflates
/// per-root TEPS. The target is a single CPU device, so concurrent
/// clients bought little — a per-worker executable cache is the recorded
/// follow-up if a multi-device backend lands.
pub struct PreparedPjrt<'g> {
    g: &'g Csr,
    engine: Mutex<PjrtEngine>,
    spec: ArtifactSpec,
    artifacts: Arc<GraphArtifacts>,
}

impl PreparedPjrt<'_> {
    /// Run the traversal, returning the trace with per-call execution times.
    pub fn run_checked(&self, root: Vertex) -> Result<BfsResult> {
        self.run_checked_with(root, RunControl::unbounded())
    }

    /// [`PreparedPjrt::run_checked`] under a [`RunControl`]: checked at
    /// layer boundaries like every native engine.
    pub fn run_checked_with(&self, root: Vertex, ctl: &RunControl) -> Result<BfsResult> {
        let g = self.g;
        let n = g.num_vertices();
        // A worker panicking between layer_step calls (caught upstream by
        // the coordinator) must not poison the device for later roots:
        // recover the guard — PjrtEngine keeps no partial traversal state.
        // The wait for the lock is queueing, not traversal: time it apart
        // so the trace can exclude it from per-root seconds.
        let t_lock = Instant::now();
        let mut engine = self.engine.lock().unwrap_or_else(|p| p.into_inner());
        let lock_wait_ns = t_lock.elapsed().as_nanos() as u64;
        let spec = &self.spec;

        // state in artifact geometry (padded to spec.n / spec.words)
        let mut vis_words = vec![0i32; spec.words];
        let mut out_words = vec![0i32; spec.words];
        let mut pred = vec![PRED_INFINITY; spec.n];
        let mut frontier = Bitmap::new(n);
        frontier.set_bit(root);
        vis_words[root as usize / 32] |= 1 << (root % 32);
        pred[root as usize] = root as Pred;

        let mut layers = Vec::new();
        let mut layer = 0usize;
        let mut status = RunStatus::Complete;
        while frontier.count_ones() != 0 {
            if let Some(s) = ctl.stop_reason() {
                status = s;
                break;
            }
            let t0 = Instant::now();
            let chunks = PjrtBfs::pack_frontier(g, &frontier);
            let edges_scanned: usize = frontier.iter_set_bits().map(|u| g.degree(u)).sum();
            // batch chunks through the executable, carrying state
            for batch in chunks.chunks(spec.chunks) {
                let mut neigh = vec![-1i32; spec.lanes_per_call()];
                let mut parents = vec![-1i32; spec.lanes_per_call()];
                for (i, (nrow, prow)) in batch.iter().enumerate() {
                    neigh[i * LANES..(i + 1) * LANES].copy_from_slice(nrow);
                    parents[i * LANES..(i + 1) * LANES].copy_from_slice(prow);
                }
                let args = LayerStepArgs {
                    neigh,
                    parents,
                    vis_words: vis_words.clone(),
                    out_words: out_words.clone(),
                    pred: pred.clone(),
                };
                let r = engine.layer_step(spec, &args)?;
                vis_words = r.vis_words;
                out_words = r.out_words;
                pred = r.pred;
            }
            // swap: next frontier = out, clear out
            // out_words is in padded artifact geometry; words beyond the
            // graph's bitmap are always zero (no neighbor id reaches them)
            let mut next = Bitmap::new(n);
            for (w, &bits) in out_words.iter().enumerate().take(next.num_words()) {
                next.set_word(w, bits as u32);
            }
            let traversed = next.count_ones();
            layers.push(LayerTrace {
                layer,
                input_vertices: frontier.count_ones(),
                edges_scanned,
                traversed,
                vectorized: true,
                wall_ns: t0.elapsed().as_nanos() as u64,
                ..Default::default()
            });
            out_words.fill(0);
            frontier = next;
            layer += 1;
        }

        pred.truncate(n);
        Ok(BfsResult {
            tree: BfsTree::new(root, pred),
            trace: RunTrace { layers, num_threads: 1, status, lock_wait_ns, ..Default::default() },
        })
    }
}

impl PreparedBfs for PreparedPjrt<'_> {
    fn name(&self) -> &'static str {
        "pjrt-simd"
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        self.run_checked_with(root, ctl).expect("PJRT BFS failed")
    }

    fn artifacts(&self) -> &GraphArtifacts {
        &self.artifacts
    }
}

impl BfsEngine for PjrtBfs {
    fn name(&self) -> &'static str {
        "pjrt-simd"
    }

    fn prepare_with<'g>(
        &self,
        g: &'g Csr,
        artifacts: Arc<GraphArtifacts>,
    ) -> Result<Box<dyn PreparedBfs + 'g>> {
        Ok(Box::new(self.prepare_pjrt(g, artifacts)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::EdgeList;

    #[test]
    fn pack_frontier_respects_boundaries() {
        // star: vertex 0 with 20 children → rows[0..20] for vertex 0
        let el = EdgeList::with_edges(32, (1..=20).map(|i| (0u32, i as Vertex)).collect());
        let g = Csr::from_edge_list(0, &el);
        let mut f = Bitmap::new(32);
        f.set_bit(0);
        let chunks = PjrtBfs::pack_frontier(&g, &f);
        // vertex 0's adjacency starts at rows[0]: full chunk of 16 + remainder 4
        assert_eq!(chunks.len(), 2);
        let valid0 = chunks[0].0.iter().filter(|&&v| v >= 0).count();
        let valid1 = chunks[1].0.iter().filter(|&&v| v >= 0).count();
        assert_eq!((valid0, valid1), (16, 4));
        assert!(chunks[0].1[..16].iter().all(|&p| p == 0));
        assert_eq!(chunks[1].1[4], -1); // padding lanes carry -1 parents
    }

    #[test]
    fn pack_frontier_peel_structure() {
        // two vertices: v1 with degree 5 (rows 0..5), v2 with degree 30
        // (rows 5..35) → v2's first chunk is a peel of 11 (5→16)
        let mut edges: Vec<(Vertex, Vertex)> = (10..15).map(|i| (0u32, i)).collect();
        edges.extend((10..40).map(|i| (1u32, i)));
        let el = EdgeList::with_edges(64, edges);
        let g = Csr::from_edge_list(0, &el);
        let mut f = Bitmap::new(64);
        f.set_bit(0);
        f.set_bit(1);
        let chunks = PjrtBfs::pack_frontier(&g, &f);
        let sizes: Vec<usize> =
            chunks.iter().map(|(n, _)| n.iter().filter(|&&v| v >= 0).count()).collect();
        // v0: rows 0..5 → one chunk of 5 (to boundary 16 cut at end=5)
        // v1: rows 5..35 → peel 5..16 (11), full 16..32 (16), rem 32..35 (3)
        assert_eq!(sizes, vec![5, 11, 16, 3]);
    }

    #[test]
    fn pack_empty_frontier() {
        let el = EdgeList::with_edges(8, vec![(0, 1)]);
        let g = Csr::from_edge_list(0, &el);
        let f = Bitmap::new(8);
        assert!(PjrtBfs::pack_frontier(&g, &f).is_empty());
    }
}
