//! Layer-3 coordinator.
//!
//! The paper's contribution lives in the kernel (L1/L2), so per the
//! architecture rules this layer is a driver, not a serving stack: it owns
//! process lifecycle, turns CLI requests into [`job::BfsJob`]s, schedules
//! the 64-root Graph500 experiment over a small worker pool (roots are
//! independent, so the scheduling unit is a **root batch** — one root by
//! default, up to [`job::BatchPolicy`]-many through the batch-first
//! [`crate::bfs::PreparedBfs::run_batch`] entry point), selects the BFS
//! engine, and aggregates [`metrics`].
//!
//! The coordinator is the crate's **fault boundary**: requests that cannot
//! run are rejected up front as structured [`error::CoordinatorError`]s,
//! worker panics are caught and retried down a degradation ladder, and
//! deadlines/cancellation ([`job::RunPolicy`]) stop traversals at layer
//! boundaries with well-formed partial results — so one bad root (or one
//! buggy engine) never takes down a 64-root job, let alone the process.
//!
//! * [`engine`] — engine registry: every algorithm of the ladder plus the
//!   PJRT-backed kernel engine, behind one constructor.
//! * [`job`] — job + result types, including the [`job::BatchPolicy`],
//!   the [`job::RunPolicy`] fault policy, and per-root
//!   [`job::RootOutcome`]s.
//! * [`error`] — the job-level [`error::CoordinatorError`] taxonomy.
//! * [`fault`] — deterministic fault injection for the chaos suite.
//! * [`governor`] — the byte-accounted memory budget: ledger, watermarks,
//!   admission estimates, and structured pressure events.
//! * [`scheduler`] — root-batch worker pool + the content-addressed
//!   artifact cache (LRU-bounded).
//! * [`metrics`] — run counters, TEPS aggregation, and fault/retry
//!   accounting.
//! * [`watchdog`] — supervised execution: a liveness monitor that cancels
//!   waves whose heartbeat stalls and abandons (then replaces) workers
//!   that ignore the cancel.

pub mod engine;
pub mod error;
pub mod fault;
pub mod governor;
pub mod job;
pub mod metrics;
pub mod scheduler;
pub mod watchdog;

pub use engine::{make_engine, EngineKind};
pub use error::CoordinatorError;
pub use fault::{FaultInjector, FaultKind, FaultPlan};
pub use governor::{AdmissionPolicy, LedgerHold, ResourceGovernor, ResourcePressure};
pub use job::{BatchPolicy, BfsJob, DepthSummary, JobOutcome, RootOutcome, RootRun, RunPolicy};
pub use metrics::MetricsSnapshot;
pub use scheduler::{retry_backoff, Coordinator};
pub use watchdog::Supervisor;
