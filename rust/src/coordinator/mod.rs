//! Layer-3 coordinator.
//!
//! The paper's contribution lives in the kernel (L1/L2), so per the
//! architecture rules this layer is a driver, not a serving stack: it owns
//! process lifecycle, turns CLI requests into [`job::BfsJob`]s, schedules
//! the 64-root Graph500 experiment over a small worker pool (roots are
//! independent, so the scheduling unit is a **root batch** — one root by
//! default, up to [`job::BatchPolicy`]-many through the batch-first
//! [`crate::bfs::PreparedBfs::run_batch`] entry point), selects the BFS
//! engine, and aggregates [`metrics`].
//!
//! * [`engine`] — engine registry: every algorithm of the ladder plus the
//!   PJRT-backed kernel engine, behind one constructor.
//! * [`job`] — job + result types, including the [`job::BatchPolicy`].
//! * [`scheduler`] — root-batch worker pool + the content-addressed
//!   artifact cache.
//! * [`metrics`] — run counters and TEPS aggregation.

pub mod engine;
pub mod job;
pub mod metrics;
pub mod scheduler;

pub use engine::{make_engine, EngineKind};
pub use job::{BatchPolicy, BfsJob, JobOutcome, RootRun};
pub use scheduler::Coordinator;
