//! Layer-3 coordinator.
//!
//! The paper's contribution lives in the kernel (L1/L2), so per the
//! architecture rules this layer is a driver, not a serving stack: it owns
//! process lifecycle, turns CLI requests into [`job::BfsJob`]s, schedules
//! the 64-root Graph500 experiment over a small worker pool (roots are
//! independent, so the batch unit is a root), selects the BFS engine, and
//! aggregates [`metrics`].
//!
//! * [`engine`] — engine registry: every algorithm of the ladder plus the
//!   PJRT-backed kernel engine, behind one constructor.
//! * [`job`] — job + result types.
//! * [`scheduler`] — root-batching worker pool.
//! * [`metrics`] — run counters and TEPS aggregation.

pub mod engine;
pub mod job;
pub mod metrics;
pub mod scheduler;

pub use engine::{make_engine, EngineKind};
pub use job::{BfsJob, JobOutcome, RootRun};
pub use scheduler::Coordinator;
