//! Job and result types for the coordinator.

use std::sync::Arc;
use std::time::Duration;

use super::engine::EngineKind;
use super::fault::FaultPlan;
use super::governor::ResourcePressure;
use crate::bfs::validate::ValidationReport;
use crate::bfs::{BfsTree, GraphArtifacts, RunControl, RunStatus, RunTrace};
use crate::graph::Csr;
use crate::Vertex;

/// How the coordinator groups a job's roots into traversal batches.
///
/// Per-root scheduling (the default) hands a worker one root per
/// iteration — the pre-batch behaviour, byte-for-byte. `Fixed(w)` hands
/// each worker a contiguous group of up to `w` roots, traversed through
/// [`crate::bfs::PreparedBfs::run_batch`]: engines with a genuinely
/// batched implementation (`hybrid-sell-ms`) share one traversal across
/// the group, every other engine loops `run` internally, so any engine
/// accepts any policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum BatchPolicy {
    /// One root per worker iteration (batch width 1).
    #[default]
    PerRoot,
    /// Contiguous batches of up to this many roots per worker iteration
    /// (clamped to ≥ 1).
    Fixed(usize),
}

impl BatchPolicy {
    /// Roots per batch (≥ 1).
    pub fn width(&self) -> usize {
        match *self {
            BatchPolicy::PerRoot => 1,
            BatchPolicy::Fixed(w) => w.max(1),
        }
    }

    /// Number of batches a `roots`-long job splits into.
    pub fn num_batches(&self, roots: usize) -> usize {
        roots.div_ceil(self.width())
    }
}

/// Fault-handling policy for one job: how long it may run, how it can be
/// cancelled, how hard the coordinator retries a failed root, and (chaos
/// harness only) which fault to inject.
#[derive(Clone, Debug)]
pub struct RunPolicy {
    /// Bound on the job's *traversal* phase (preparation is excluded):
    /// armed on the job's [`RunControl`] right before workers spawn, so
    /// engines stop at their next layer boundary once it passes and
    /// return [`RunStatus::TimedOut`] partial results.
    pub deadline: Option<Duration>,
    /// External control handle. A caller holding the same `Arc` can
    /// [`RunControl::cancel`] the whole job mid-flight; `None` gives the
    /// job a private control (still used for `deadline`).
    pub control: Option<Arc<RunControl>>,
    /// Total attempts per root (first run included) before the root is
    /// reported as [`RootOutcome::Failed`]; clamped to ≥ 1. Attempt 2
    /// retries on the job's engine degraded to the counted VPU backend,
    /// later attempts fall back to the serial reference engine.
    pub max_attempts: usize,
    /// Chaos-harness fault to inject ([`FaultPlan`]); `None` in production.
    pub fault: Option<FaultPlan>,
    /// Liveness budget for supervised execution
    /// ([`super::watchdog::Supervisor`]): if the job's heartbeat
    /// ([`RunControl::ticks`]) stops advancing for this long, the watchdog
    /// fires the cancel; after a further grace window it abandons the wave
    /// outright. `None` (the default) means unsupervised — the watchdog
    /// leaves the job alone even when run through a supervisor.
    pub liveness: Option<Duration>,
    /// Digest each root's distance vector into a [`DepthSummary`] on
    /// [`RootRun::depths`]. Off by default — the harness compares whole
    /// trees itself — and switched on by serving callers
    /// ([`BfsJob::wave`]) that need a compact per-request answer without
    /// shipping the tree out of the coordinator.
    pub report_depths: bool,
}

impl Default for RunPolicy {
    fn default() -> Self {
        RunPolicy {
            deadline: None,
            control: None,
            max_attempts: 3,
            fault: None,
            liveness: None,
            report_depths: false,
        }
    }
}

/// Compact digest of one root's BFS distance vector: the eccentricity of
/// the root within its component plus an order-sensitive FNV-1a checksum
/// of the full `u32` distance array (unreached = `u32::MAX` sentinel
/// included). Two traversals agree on every per-vertex depth iff their
/// summaries are equal, so a serving client can verify a reply against an
/// oracle without transferring |V| distances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DepthSummary {
    /// Deepest BFS layer reached (0 for an isolated root; the unreached
    /// sentinel never counts).
    pub max_depth: u32,
    /// FNV-1a over the little-endian bytes of the distance vector.
    pub checksum: u64,
}

impl DepthSummary {
    /// Digest a distance vector (`u32::MAX` = unreached).
    pub fn from_distances(dist: &[u32]) -> Self {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut max_depth = 0u32;
        for &d in dist {
            for b in d.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
            if d != u32::MAX && d > max_depth {
                max_depth = d;
            }
        }
        DepthSummary { max_depth, checksum: h }
    }

    /// Digest a BFS tree's distances; `None` when the tree's predecessor
    /// chains do not resolve (a corrupt tree never digests).
    pub fn from_tree(tree: &BfsTree) -> Option<Self> {
        tree.distances().map(|d| Self::from_distances(&d))
    }
}

/// One unit of coordinator work: run BFS from each of `roots` over `graph`
/// with `engine`, optionally validating every tree. `batch` groups the
/// roots into [`crate::bfs::PreparedBfs::run_batch`] calls; `run` carries
/// the fault-handling policy (deadline, cancellation, retries).
#[derive(Clone)]
pub struct BfsJob {
    pub id: u64,
    pub graph: Arc<Csr>,
    pub roots: Vec<Vertex>,
    pub engine: EngineKind,
    pub validate: bool,
    pub batch: BatchPolicy,
    pub run: RunPolicy,
}

impl BfsJob {
    /// A serving wave: one externally-accumulated batch of roots traversed
    /// as a single [`BatchPolicy::Fixed`] group (the MS-BFS wave shape),
    /// with depth digests reported per root and no validation — the
    /// serving layer checks replies against its own oracle, not per wave.
    /// `deadline` is the tightest remaining budget among the wave's
    /// requests; `control` lets the caller cancel the whole wave.
    pub fn wave(
        id: u64,
        graph: Arc<Csr>,
        roots: Vec<Vertex>,
        engine: EngineKind,
        deadline: Option<Duration>,
        control: Option<Arc<RunControl>>,
        max_attempts: usize,
    ) -> Self {
        let width = roots.len().max(1);
        BfsJob {
            id,
            graph,
            roots,
            engine,
            validate: false,
            batch: BatchPolicy::Fixed(width),
            run: RunPolicy {
                deadline,
                control,
                max_attempts,
                report_depths: true,
                ..RunPolicy::default()
            },
        }
    }
}

/// Result of one root's traversal.
#[derive(Clone, Debug)]
pub struct RootRun {
    pub root: Vertex,
    /// Edges *traversed* in Graph500's TEPS convention: the number of
    /// undirected input edges within the reached component, approximated as
    /// scanned-directed-edges / 2 (the reference uses m = |E| of the
    /// component; scans count each direction once).
    pub edges_traversed: usize,
    pub reached: usize,
    /// Pure traversal seconds (Graph500's kernel-2 analogue): this root's
    /// equal share of its batch's traversal wall time. Under the default
    /// per-root [`BatchPolicy`] the batch is the root itself, so this is
    /// the root's own time; under wider batches the share makes batch
    /// amortization visible in per-root TEPS. Per-graph preparation is
    /// *not* included — see `preparation_seconds`.
    pub seconds: f64,
    /// This root's amortized share of the job's one-time preparation
    /// (engine construction + `prepare`: layouts, stats, compiled
    /// kernels) — the Graph500 kernel-1-style split that shows what the
    /// prepare-once architecture saves per root.
    pub preparation_seconds: f64,
    pub trace: RunTrace,
    /// This root ran on the counted emulator as a [`VpuMode::Auto`]
    /// warm-up (copied from the trace): its `seconds` are emulation
    /// timings, so TEPS aggregates exclude it
    /// ([`crate::harness::stats::TepsStats`]).
    ///
    /// [`VpuMode::Auto`]: crate::simd::VpuMode::Auto
    pub counted_warmup: bool,
    /// Validation report (None when the job ran with validate=false).
    pub validation: Option<ValidationReport>,
    /// Distance-vector digest, present when the job's
    /// [`RunPolicy::report_depths`] asked for one and the tree resolved
    /// (interrupted prefixes still digest — the digest then covers the
    /// partial distances).
    pub depths: Option<DepthSummary>,
}

impl RootRun {
    /// TEPS for this root (0 when the root reached nothing — the paper
    /// keeps those zeros in the harmonic mean, §5.3).
    pub fn teps(&self) -> f64 {
        if self.seconds > 0.0 {
            self.edges_traversed as f64 / self.seconds
        } else {
            0.0
        }
    }

    /// How the traversal ended (from the trace): `Complete`, or the
    /// interruption reason when a deadline/cancellation stopped it early —
    /// in which case `reached`/`edges_traversed` cover only the visited
    /// prefix.
    pub fn status(&self) -> RunStatus {
        self.trace.status
    }
}

/// Per-root outcome inside a completed job: the traversal result, or a
/// structured failure record when the root's worker panicked (or dropped
/// its result) and every retry down the degradation ladder failed too. A
/// missing result is **never** a coordinator panic — it is a `Failed`
/// entry here, and the rest of the job's roots report normally.
#[derive(Clone, Debug)]
pub enum RootOutcome {
    /// The root ran (possibly on a degraded retry; possibly interrupted —
    /// see [`RootRun::status`]).
    Ran(RootRun),
    /// All `attempts` attempts failed; `error` describes the last failure.
    Failed { root: Vertex, error: String, attempts: usize },
}

impl RootOutcome {
    /// The root this outcome belongs to.
    pub fn root(&self) -> Vertex {
        match self {
            RootOutcome::Ran(r) => r.root,
            RootOutcome::Failed { root, .. } => *root,
        }
    }

    /// The run, when the root ran.
    pub fn run(&self) -> Option<&RootRun> {
        match self {
            RootOutcome::Ran(r) => Some(r),
            RootOutcome::Failed { .. } => None,
        }
    }

    /// Consume into the run, when the root ran.
    pub fn into_run(self) -> Option<RootRun> {
        match self {
            RootOutcome::Ran(r) => Some(r),
            RootOutcome::Failed { .. } => None,
        }
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, RootOutcome::Failed { .. })
    }
}

/// Completed job. A `JobOutcome` is **always well-formed**: exactly one
/// [`RootOutcome`] per requested root, in root order, even when workers
/// panicked or the job was interrupted — job-level errors are reserved for
/// requests that could not run at all
/// ([`super::error::CoordinatorError`]).
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: u64,
    /// One entry per root, in root order.
    pub outcomes: Vec<RootOutcome>,
    pub all_valid: bool,
    /// Wall seconds the job spent in its one-time prepare phase (engine
    /// construction + per-graph artifact build) before any root ran.
    pub preparation_seconds: f64,
    /// The per-graph artifacts the job prepared once and every root
    /// shared: layouts, degree stats, build counters, and the cross-root
    /// policy-feedback channel — inspectable for reuse and for the
    /// built-exactly-once guarantee.
    pub artifacts: Arc<GraphArtifacts>,
    /// Structured degradation events raised while this job ran: each one
    /// names an optional artifact the governor skipped under memory
    /// pressure (the job still completed, on its fallback paths).
    pub pressure: Vec<ResourcePressure>,
}

impl JobOutcome {
    /// The successful runs, in root order (failed roots skipped).
    pub fn runs(&self) -> impl Iterator<Item = &RootRun> {
        self.outcomes.iter().filter_map(RootOutcome::run)
    }

    /// The failed roots, in root order.
    pub fn failures(&self) -> impl Iterator<Item = &RootOutcome> {
        self.outcomes.iter().filter(|o| o.is_failed())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_policy_widths_and_counts() {
        assert_eq!(BatchPolicy::PerRoot.width(), 1);
        assert_eq!(BatchPolicy::Fixed(16).width(), 16);
        assert_eq!(BatchPolicy::Fixed(0).width(), 1, "zero width clamps to 1");
        assert_eq!(BatchPolicy::default(), BatchPolicy::PerRoot);
        assert_eq!(BatchPolicy::PerRoot.num_batches(5), 5);
        assert_eq!(BatchPolicy::Fixed(16).num_batches(64), 4);
        assert_eq!(BatchPolicy::Fixed(16).num_batches(17), 2);
        assert_eq!(BatchPolicy::Fixed(16).num_batches(0), 0);
    }

    #[test]
    fn teps_zero_for_empty_run() {
        let r = RootRun {
            root: 0,
            edges_traversed: 0,
            reached: 1,
            seconds: 0.01,
            preparation_seconds: 0.0,
            trace: RunTrace::default(),
            counted_warmup: false,
            validation: None,
            depths: None,
        };
        assert_eq!(r.teps(), 0.0);
    }

    #[test]
    fn teps_computes() {
        let r = RootRun {
            root: 0,
            edges_traversed: 1_000_000,
            reached: 100,
            seconds: 0.5,
            preparation_seconds: 0.0,
            trace: RunTrace::default(),
            counted_warmup: false,
            validation: None,
            depths: None,
        };
        assert_eq!(r.teps(), 2_000_000.0);
    }

    #[test]
    fn depth_summary_digests_distances() {
        let a = DepthSummary::from_distances(&[0, 1, 2, u32::MAX]);
        let b = DepthSummary::from_distances(&[0, 1, 2, u32::MAX]);
        assert_eq!(a, b, "the digest is deterministic");
        assert_eq!(a.max_depth, 2, "the unreached sentinel is not a depth");
        let c = DepthSummary::from_distances(&[0, 1, 3, u32::MAX]);
        assert_ne!(a.checksum, c.checksum, "one changed depth changes the checksum");
        // order sensitivity: same multiset of depths, different vertices
        let d = DepthSummary::from_distances(&[0, 2, 1, u32::MAX]);
        assert_ne!(a.checksum, d.checksum);
        assert_eq!(DepthSummary::from_distances(&[]).max_depth, 0);
    }

    #[test]
    fn wave_constructor_sets_serving_policy() {
        let el = crate::graph::RmatConfig::graph500(7, 8).generate(5);
        let g = Arc::new(Csr::from_edge_list(7, &el));
        let j = BfsJob::wave(9, g, vec![0, 1, 2], EngineKind::SerialLayered, None, None, 2);
        assert_eq!(j.id, 9);
        assert_eq!(j.batch, BatchPolicy::Fixed(3), "one batch spanning the whole wave");
        assert!(j.run.report_depths);
        assert!(!j.validate);
        assert_eq!(j.run.max_attempts, 2);
    }
}
