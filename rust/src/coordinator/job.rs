//! Job and result types for the coordinator.

use std::sync::Arc;

use super::engine::EngineKind;
use crate::bfs::validate::ValidationReport;
use crate::bfs::{GraphArtifacts, RunTrace};
use crate::graph::Csr;
use crate::Vertex;

/// One unit of coordinator work: run BFS from each of `roots` over `graph`
/// with `engine`, optionally validating every tree.
#[derive(Clone)]
pub struct BfsJob {
    pub id: u64,
    pub graph: Arc<Csr>,
    pub roots: Vec<Vertex>,
    pub engine: EngineKind,
    pub validate: bool,
}

/// Result of one root's traversal.
#[derive(Clone, Debug)]
pub struct RootRun {
    pub root: Vertex,
    /// Edges *traversed* in Graph500's TEPS convention: the number of
    /// undirected input edges within the reached component, approximated as
    /// scanned-directed-edges / 2 (the reference uses m = |E| of the
    /// component; scans count each direction once).
    pub edges_traversed: usize,
    pub reached: usize,
    /// Pure traversal seconds (Graph500's kernel-2 analogue). Per-graph
    /// preparation is *not* included — see `preparation_seconds`.
    pub seconds: f64,
    /// This root's amortized share of the job's one-time preparation
    /// (engine construction + `prepare`: layouts, stats, compiled
    /// kernels) — the Graph500 kernel-1-style split that shows what the
    /// prepare-once architecture saves per root.
    pub preparation_seconds: f64,
    pub trace: RunTrace,
    /// Validation report (None when the job ran with validate=false).
    pub validation: Option<ValidationReport>,
}

impl RootRun {
    /// TEPS for this root (0 when the root reached nothing — the paper
    /// keeps those zeros in the harmonic mean, §5.3).
    pub fn teps(&self) -> f64 {
        if self.seconds > 0.0 {
            self.edges_traversed as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: u64,
    pub runs: Vec<RootRun>,
    pub all_valid: bool,
    /// Wall seconds the job spent in its one-time prepare phase (engine
    /// construction + per-graph artifact build) before any root ran.
    pub preparation_seconds: f64,
    /// The per-graph artifacts the job prepared once and every root
    /// shared: layouts, degree stats, build counters, and the cross-root
    /// policy-feedback channel — inspectable for reuse and for the
    /// built-exactly-once guarantee.
    pub artifacts: Arc<GraphArtifacts>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teps_zero_for_empty_run() {
        let r = RootRun {
            root: 0,
            edges_traversed: 0,
            reached: 1,
            seconds: 0.01,
            preparation_seconds: 0.0,
            trace: RunTrace::default(),
            validation: None,
        };
        assert_eq!(r.teps(), 0.0);
    }

    #[test]
    fn teps_computes() {
        let r = RootRun {
            root: 0,
            edges_traversed: 1_000_000,
            reached: 100,
            seconds: 0.5,
            preparation_seconds: 0.0,
            trace: RunTrace::default(),
            validation: None,
        };
        assert_eq!(r.teps(), 2_000_000.0);
    }
}
