//! Job and result types for the coordinator.

use std::sync::Arc;

use super::engine::EngineKind;
use crate::bfs::validate::ValidationReport;
use crate::bfs::RunTrace;
use crate::graph::Csr;
use crate::Vertex;

/// One unit of coordinator work: run BFS from each of `roots` over `graph`
/// with `engine`, optionally validating every tree.
#[derive(Clone)]
pub struct BfsJob {
    pub id: u64,
    pub graph: Arc<Csr>,
    pub roots: Vec<Vertex>,
    pub engine: EngineKind,
    pub validate: bool,
}

/// Result of one root's traversal.
#[derive(Clone, Debug)]
pub struct RootRun {
    pub root: Vertex,
    /// Edges *traversed* in Graph500's TEPS convention: the number of
    /// undirected input edges within the reached component, approximated as
    /// scanned-directed-edges / 2 (the reference uses m = |E| of the
    /// component; scans count each direction once).
    pub edges_traversed: usize,
    pub reached: usize,
    pub seconds: f64,
    pub trace: RunTrace,
    /// Validation report (None when the job ran with validate=false).
    pub validation: Option<ValidationReport>,
}

impl RootRun {
    /// TEPS for this root (0 when the root reached nothing — the paper
    /// keeps those zeros in the harmonic mean, §5.3).
    pub fn teps(&self) -> f64 {
        if self.seconds > 0.0 {
            self.edges_traversed as f64 / self.seconds
        } else {
            0.0
        }
    }
}

/// Completed job.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub id: u64,
    pub runs: Vec<RootRun>,
    pub all_valid: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teps_zero_for_empty_run() {
        let r = RootRun {
            root: 0,
            edges_traversed: 0,
            reached: 1,
            seconds: 0.01,
            trace: RunTrace::default(),
            validation: None,
        };
        assert_eq!(r.teps(), 0.0);
    }

    #[test]
    fn teps_computes() {
        let r = RootRun {
            root: 0,
            edges_traversed: 1_000_000,
            reached: 100,
            seconds: 0.5,
            trace: RunTrace::default(),
            validation: None,
        };
        assert_eq!(r.teps(), 2_000_000.0);
    }
}
