//! Coordinator metrics: cheap atomic counters aggregated across jobs.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use super::job::RootRun;

/// Live counters (interior-mutable; the coordinator is shared by reference).
#[derive(Default)]
pub struct Metrics {
    jobs: AtomicUsize,
    roots: AtomicUsize,
    /// Traversal batches dispatched (== roots under the default per-root
    /// batch policy; fewer when jobs batch their roots).
    batches: AtomicUsize,
    edges: AtomicU64,
    /// Total traversal nanoseconds (sum over roots, not wall).
    nanos: AtomicU64,
    /// Total one-time preparation nanoseconds (once per job).
    prep_nanos: AtomicU64,
    /// Jobs whose per-graph artifacts came from the coordinator's keyed
    /// cache (serving scenario: repeated jobs on a hot graph skip
    /// preparation).
    artifact_cache_hits: AtomicUsize,
    /// The subset of cache hits served by the *content* key (same graph
    /// bytes, different allocation — a reloaded graph) rather than the
    /// identity fast-path.
    artifact_cache_content_hits: AtomicUsize,
    /// Entries evicted from the artifact cache (LRU, at capacity).
    artifact_cache_evictions: AtomicUsize,
    /// Worker batches that panicked and were caught by the coordinator.
    worker_panics: AtomicUsize,
    /// Retry attempts dispatched for failed roots (each rung of the
    /// degradation ladder counts once per root).
    root_retries: AtomicUsize,
    /// Roots that ultimately succeeded on a degraded retry (counted VPU or
    /// serial fallback) rather than the job's requested engine.
    degraded_roots: AtomicUsize,
    /// Roots that exhausted every attempt and were reported as
    /// [`super::job::RootOutcome::Failed`].
    failed_roots: AtomicUsize,
    /// Jobs shed by the resource governor before any traversal ran
    /// ([`super::error::CoordinatorError::Rejected`] /
    /// [`super::error::CoordinatorError::OverBudget`]). Shed jobs never
    /// touch the throughput aggregates: no roots, no edges, no seconds.
    jobs_shed: AtomicUsize,
    /// Gauge: retained bytes currently accounted to the artifact cache
    /// (sum of each entry's built artifacts).
    cache_bytes: AtomicUsize,
    /// Total bytes released by byte-accounted cache evictions.
    bytes_evicted: AtomicU64,
    /// Structured [`super::governor::ResourcePressure`] degradation events
    /// (optional artifacts skipped under memory pressure).
    pressure_events: AtomicUsize,
    /// Watchdog liveness trips: a supervised wave missed its heartbeat
    /// budget and had its [`crate::bfs::RunControl`] cancel fired.
    watchdog_fires: AtomicUsize,
    /// Waves abandoned after the post-cancel grace window also expired —
    /// the worker never returned and its results were discarded.
    hung_waves: AtomicUsize,
    /// Replacement workers spawned for abandoned ones, restoring the
    /// supervised pool to full capacity.
    workers_replaced: AtomicUsize,
}

/// Point-in-time copy of the counters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MetricsSnapshot {
    pub jobs: usize,
    pub roots: usize,
    /// Traversal batches dispatched across all jobs.
    pub batches: usize,
    pub edges_traversed: u64,
    pub total_seconds: f64,
    /// Seconds spent preparing graphs (kernel-1-style, once per job) —
    /// kept separate from traversal time so amortization is visible.
    pub preparation_seconds: f64,
    /// Aggregate TEPS over everything the coordinator has run.
    pub aggregate_teps: f64,
    /// Jobs served from the keyed artifact cache.
    pub artifact_cache_hits: usize,
    /// Cache hits that matched by graph *content* (reloaded graphs).
    pub artifact_cache_content_hits: usize,
    /// Entries the artifact cache evicted (LRU, at capacity).
    pub artifact_cache_evictions: usize,
    /// Worker batch panics caught and contained.
    pub worker_panics: usize,
    /// Retry attempts dispatched for failed roots.
    pub root_retries: usize,
    /// Roots recovered on a degraded engine.
    pub degraded_roots: usize,
    /// Roots that exhausted every attempt.
    pub failed_roots: usize,
    /// Jobs shed by admission control / the memory budget (never counted
    /// in `jobs`, `roots`, or the TEPS aggregates).
    pub jobs_shed: usize,
    /// Bytes currently retained by the artifact cache (gauge).
    pub cache_bytes: usize,
    /// Bytes released by byte-accounted cache evictions (cumulative).
    pub bytes_evicted: u64,
    /// Optional-artifact skips under memory pressure (cumulative).
    pub pressure_events: usize,
    /// Waves whose liveness budget lapsed (watchdog fired their cancel).
    pub watchdog_fires: usize,
    /// Waves abandoned outright after the grace window.
    pub hung_waves: usize,
    /// Replacement workers spawned for abandoned ones.
    pub workers_replaced: usize,
}

impl std::fmt::Display for MetricsSnapshot {
    /// One `key=value` line — the single rendering shared by the serve
    /// daemon's `STATS` reply and shutdown summary and the CLI's
    /// end-of-run print, so the three never drift apart.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "jobs={} roots={} batches={} edges={} traversal_s={:.3} prep_s={:.3} \
             teps={:.3e} cache_hits={} cache_content_hits={} cache_evictions={} \
             cache_bytes={} bytes_evicted={} worker_panics={} root_retries={} \
             degraded_roots={} failed_roots={} jobs_shed={} pressure_events={} \
             watchdog_fires={} hung_waves={} workers_replaced={}",
            self.jobs,
            self.roots,
            self.batches,
            self.edges_traversed,
            self.total_seconds,
            self.preparation_seconds,
            self.aggregate_teps,
            self.artifact_cache_hits,
            self.artifact_cache_content_hits,
            self.artifact_cache_evictions,
            self.cache_bytes,
            self.bytes_evicted,
            self.worker_panics,
            self.root_retries,
            self.degraded_roots,
            self.failed_roots,
            self.jobs_shed,
            self.pressure_events,
            self.watchdog_fires,
            self.hung_waves,
            self.workers_replaced,
        )
    }
}

impl Metrics {
    /// Record one completed job's successful runs (failed roots are
    /// recorded separately via [`Metrics::record_failed_root`], so the
    /// throughput aggregates only ever see real traversals).
    pub fn record_job(&self, runs: &[&RootRun], preparation_seconds: f64, batches: usize) {
        self.jobs.fetch_add(1, Ordering::Relaxed);
        self.roots.fetch_add(runs.len(), Ordering::Relaxed);
        self.batches.fetch_add(batches, Ordering::Relaxed);
        // counted warm-up roots (`--vpu auto`) carry emulation timings;
        // keep them out of the throughput aggregate — same rule as
        // `TepsStats`, including the all-warm-up fallback so a job made
        // entirely of warm-ups still registers
        let any_measured = runs.iter().any(|r| !r.counted_warmup);
        let measured = runs.iter().filter(|r| !any_measured || !r.counted_warmup);
        let mut edges = 0u64;
        let mut nanos = 0u64;
        for r in measured {
            edges += r.edges_traversed as u64;
            nanos += (r.seconds * 1e9) as u64;
        }
        self.edges.fetch_add(edges, Ordering::Relaxed);
        self.nanos.fetch_add(nanos, Ordering::Relaxed);
        self.prep_nanos.fetch_add((preparation_seconds * 1e9) as u64, Ordering::Relaxed);
    }

    /// Count one job whose artifacts were served from the keyed cache.
    /// `by_content` marks hits that matched the content key (a reloaded
    /// graph) rather than the identity fast-path.
    pub fn record_artifact_cache_hit(&self, by_content: bool) {
        self.artifact_cache_hits.fetch_add(1, Ordering::Relaxed);
        if by_content {
            self.artifact_cache_content_hits.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one LRU eviction from the artifact cache.
    pub fn record_artifact_cache_eviction(&self) {
        self.artifact_cache_evictions.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one caught worker-batch panic.
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one retry attempt for a failed root.
    pub fn record_root_retry(&self) {
        self.root_retries.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one root recovered on a degraded engine.
    pub fn record_degraded_root(&self) {
        self.degraded_roots.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one root that exhausted every attempt.
    pub fn record_failed_root(&self) {
        self.failed_roots.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one job shed by admission control or the memory budget.
    pub fn record_job_shed(&self) {
        self.jobs_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Set the artifact-cache retained-bytes gauge.
    pub fn set_cache_bytes(&self, bytes: usize) {
        self.cache_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Count `bytes` released by one byte-accounted cache eviction.
    pub fn record_bytes_evicted(&self, bytes: usize) {
        self.bytes_evicted.fetch_add(bytes as u64, Ordering::Relaxed);
    }

    /// Count one optional-artifact skip under memory pressure.
    pub fn record_pressure_event(&self) {
        self.pressure_events.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one watchdog liveness trip (missed heartbeats → cancel fired).
    pub fn record_watchdog_fire(&self) {
        self.watchdog_fires.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one wave abandoned after the grace window.
    pub fn record_hung_wave(&self) {
        self.hung_waves.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one replacement worker spawned for an abandoned one.
    pub fn record_worker_replaced(&self) {
        self.workers_replaced.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let edges = self.edges.load(Ordering::Relaxed);
        let secs = self.nanos.load(Ordering::Relaxed) as f64 / 1e9;
        MetricsSnapshot {
            jobs: self.jobs.load(Ordering::Relaxed),
            roots: self.roots.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            edges_traversed: edges,
            total_seconds: secs,
            preparation_seconds: self.prep_nanos.load(Ordering::Relaxed) as f64 / 1e9,
            aggregate_teps: if secs > 0.0 { edges as f64 / secs } else { 0.0 },
            artifact_cache_hits: self.artifact_cache_hits.load(Ordering::Relaxed),
            artifact_cache_content_hits: self
                .artifact_cache_content_hits
                .load(Ordering::Relaxed),
            artifact_cache_evictions: self.artifact_cache_evictions.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            root_retries: self.root_retries.load(Ordering::Relaxed),
            degraded_roots: self.degraded_roots.load(Ordering::Relaxed),
            failed_roots: self.failed_roots.load(Ordering::Relaxed),
            jobs_shed: self.jobs_shed.load(Ordering::Relaxed),
            cache_bytes: self.cache_bytes.load(Ordering::Relaxed),
            bytes_evicted: self.bytes_evicted.load(Ordering::Relaxed),
            pressure_events: self.pressure_events.load(Ordering::Relaxed),
            watchdog_fires: self.watchdog_fires.load(Ordering::Relaxed),
            hung_waves: self.hung_waves.load(Ordering::Relaxed),
            workers_replaced: self.workers_replaced.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::RunTrace;

    fn run(edges: usize, seconds: f64) -> RootRun {
        RootRun {
            root: 0,
            edges_traversed: edges,
            reached: 1,
            seconds,
            preparation_seconds: 0.0,
            trace: RunTrace::default(),
            counted_warmup: false,
            validation: None,
            depths: None,
        }
    }

    #[test]
    fn aggregates() {
        let m = Metrics::default();
        m.record_job(&[&run(100, 0.5), &run(300, 0.5)], 0.25, 2);
        let s = m.snapshot();
        assert_eq!(s.jobs, 1);
        assert_eq!(s.roots, 2);
        assert_eq!(s.batches, 2);
        assert_eq!(s.edges_traversed, 400);
        assert!((s.total_seconds - 1.0).abs() < 1e-6);
        assert!((s.preparation_seconds - 0.25).abs() < 1e-6);
        assert!((s.aggregate_teps - 400.0).abs() < 1e-6);
    }

    #[test]
    fn empty_snapshot_no_nan() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.aggregate_teps, 0.0);
        assert_eq!(s.batches, 0);
    }

    #[test]
    fn batched_jobs_record_fewer_batches_than_roots() {
        let m = Metrics::default();
        m.record_job(&[&run(10, 0.1), &run(10, 0.1), &run(10, 0.1)], 0.0, 1);
        let s = m.snapshot();
        assert_eq!(s.roots, 3);
        assert_eq!(s.batches, 1);
    }

    #[test]
    fn warmup_roots_excluded_from_aggregate_teps() {
        let warm = |edges: usize, seconds: f64| RootRun {
            counted_warmup: true,
            ..run(edges, seconds)
        };
        let m = Metrics::default();
        // two slow emulated warm-ups + one fast hw root: the aggregate
        // must reflect only the hw root
        m.record_job(&[&warm(100, 10.0), &warm(100, 10.0), &run(1000, 0.001)], 0.0, 3);
        let s = m.snapshot();
        assert_eq!(s.roots, 3);
        assert_eq!(s.edges_traversed, 1000);
        assert!(s.aggregate_teps > 100_000.0, "warm-ups dragged TEPS: {}", s.aggregate_teps);
        // all-warm-up fallback: the emulated numbers still register
        let m = Metrics::default();
        m.record_job(&[&warm(100, 1.0)], 0.0, 1);
        assert_eq!(m.snapshot().edges_traversed, 100);
    }

    #[test]
    fn fault_counters_accumulate() {
        let m = Metrics::default();
        m.record_worker_panic();
        m.record_root_retry();
        m.record_root_retry();
        m.record_degraded_root();
        m.record_failed_root();
        m.record_artifact_cache_eviction();
        let s = m.snapshot();
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.root_retries, 2);
        assert_eq!(s.degraded_roots, 1);
        assert_eq!(s.failed_roots, 1);
        assert_eq!(s.artifact_cache_evictions, 1);
    }

    #[test]
    fn shedding_counters_never_touch_throughput_aggregates() {
        let m = Metrics::default();
        m.record_job_shed();
        m.record_job_shed();
        m.record_pressure_event();
        m.record_bytes_evicted(1024);
        m.set_cache_bytes(4096);
        let s = m.snapshot();
        assert_eq!(s.jobs_shed, 2);
        assert_eq!(s.pressure_events, 1);
        assert_eq!(s.bytes_evicted, 1024);
        assert_eq!(s.cache_bytes, 4096);
        // shed jobs are not jobs: the TEPS aggregates stay untouched
        assert_eq!(s.jobs, 0);
        assert_eq!(s.roots, 0);
        assert_eq!(s.edges_traversed, 0);
        assert_eq!(s.preparation_seconds, 0.0);
        assert_eq!(s.aggregate_teps, 0.0);
        // the gauge overwrites rather than accumulates
        m.set_cache_bytes(100);
        assert_eq!(m.snapshot().cache_bytes, 100);
    }

    #[test]
    fn snapshot_renders_one_line_of_key_values() {
        let m = Metrics::default();
        m.record_job(&[&run(100, 0.5)], 0.25, 1);
        m.record_job_shed();
        let line = m.snapshot().to_string();
        assert!(!line.contains('\n'), "one line, embeddable in a protocol reply");
        let keys = [
            "jobs=1",
            "roots=1",
            "edges=100",
            "jobs_shed=1",
            "teps=",
            "cache_hits=0",
            "watchdog_fires=0",
            "hung_waves=0",
            "workers_replaced=0",
        ];
        for key in keys {
            assert!(line.contains(key), "{line:?} missing {key}");
        }
    }

    #[test]
    fn supervision_counters_accumulate() {
        let m = Metrics::default();
        m.record_watchdog_fire();
        m.record_watchdog_fire();
        m.record_hung_wave();
        m.record_worker_replaced();
        let s = m.snapshot();
        assert_eq!(s.watchdog_fires, 2);
        assert_eq!(s.hung_waves, 1);
        assert_eq!(s.workers_replaced, 1);
    }

    #[test]
    fn cache_hit_kinds_are_distinguished() {
        let m = Metrics::default();
        m.record_artifact_cache_hit(false);
        m.record_artifact_cache_hit(true);
        let s = m.snapshot();
        assert_eq!(s.artifact_cache_hits, 2);
        assert_eq!(s.artifact_cache_content_hits, 1);
    }
}
