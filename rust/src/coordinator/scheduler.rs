//! The root-batching scheduler.
//!
//! A Graph500 job is 64 independent single-root traversals over one shared
//! read-only CSR, so the natural scheduling unit is the **root batch**
//! ([`crate::coordinator::job::BatchPolicy`]: one root by default, up to a
//! fixed width when the job opts into batching). The job runs in the
//! engine API's two phases:
//!
//! 1. **Prepare (once, before any worker spawns).** The engine is
//!    constructed and `prepare`d against the job's graph — building the
//!    shared [`crate::bfs::GraphArtifacts`] (SELL layout, padded-CSR view,
//!    degree stats, the cross-root policy-feedback channel). A bad engine
//!    configuration therefore fails *here*, immediately, instead of racing
//!    through per-thread error plumbing.
//! 2. **Run (per batch).** `workers` threads share the one prepared
//!    instance (`PreparedBfs` is `Sync`) and pull batch indices from a
//!    shared cursor, traversing each batch through
//!    [`crate::bfs::PreparedBfs::run_batch`] until the job drains. Each
//!    root's reported seconds are its equal share of its batch's wall
//!    time; results arrive in root order regardless of completion order.
//!
//! The run phase is **fault-isolated**: each batch traversal runs inside
//! `catch_unwind`, a panicking batch poisons nothing (both shared locks
//! recover), and its roots are retried down a degradation ladder — the
//! job's engine on the counted VPU backend first, the serial reference
//! engine after that — bounded by [`super::job::RunPolicy::max_attempts`],
//! with a bounded, jittered, deadline-aware exponential backoff between
//! rungs. A root that exhausts its attempts becomes a
//! [`super::job::RootOutcome::Failed`] entry; the job itself still returns
//! a well-formed [`JobOutcome`]. Job-level failures (corrupt graph,
//! out-of-range root, unbuildable engine) are rejected up front as
//! [`CoordinatorError`] before any worker spawns.
//!
//! The scheduler is additionally **resource-governed** (see
//! [`super::governor`]): admission control bounds in-flight jobs and
//! checks each job's estimated footprint — mandatory layout bytes plus
//! per-traversal working set, both derived from degree stats before any
//! allocation — against the coordinator's byte budget, shedding load as
//! [`CoordinatorError::Rejected`] / [`CoordinatorError::OverBudget`].
//! Admitted jobs reserve their working set on the shared ledger for their
//! lifetime, and the artifact cache is byte-accounted: evictions release
//! an entry's retained bytes and run until the ledger is back under the
//! governor's low watermark (the entry-count cap stays as a backstop).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::{Duration, Instant};

use super::engine::{make_engine, EngineKind};
use super::error::CoordinatorError;
use super::fault::{FaultKind, FaultPlan};
use super::governor::{
    estimate_working_set, AdmissionPolicy, LedgerHold, ResourceGovernor, OVER_BUDGET_MARKER,
};
use super::job::{BfsJob, JobOutcome, RootOutcome, RootRun};
use super::metrics::Metrics;
use crate::bfs::footprint::planned_sell_bytes;
use crate::bfs::sell_vectorized::SIGMA_AUTO;
use crate::bfs::serial::SerialLayeredBfs;
use crate::bfs::validate::validate;
use crate::bfs::{
    BfsEngine, BfsResult, DegreeStats, GraphArtifacts, HeapFootprint, PreparedBfs, RunControl,
};
use crate::graph::Csr;
use crate::rng::Xoshiro256;
use crate::simd::VpuMode;
use crate::Vertex;

/// Lock a mutex, recovering the data if a previous holder panicked. Both
/// structures this guards (the result slots, the artifact cache) are valid
/// after any interrupted write — a panicking worker is contained by
/// `catch_unwind` and must not wedge every later job on a poisoned lock.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One root's result slot while workers run: unfilled, a finished run, or
/// the error text of the failure that will drive its retry.
type RootSlot = Option<Result<RootRun, String>>;

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Entries the artifact cache holds at most — a serving deployment repeats
/// jobs over a handful of hot graphs, not hundreds. With a bounded
/// governor this is only a backstop: the byte-accounted watermark
/// eviction usually fires first.
const ARTIFACT_CACHE_CAP: usize = 8;

/// First inter-attempt retry pause of the degradation ladder; doubles
/// each further attempt.
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(2);
/// Ceiling on the exponential component of an inter-attempt pause (the
/// jitter factor can stretch a capped pause to at most 1.5× this).
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(50);

/// Backoff before retry `attempt` of a root (the ladder calls this with
/// `attempt` ≥ 2, so attempt 2 pauses around `RETRY_BACKOFF_BASE`,
/// 2 ms). Jittered by a uniform factor in [0.5, 1.5) so coordinators
/// retrying a contended resource do not stampede in lockstep; truncated
/// to the control's remaining deadline and skipped entirely once the
/// control already says stop — a retry must never sleep through the time
/// budget it is trying to beat.
///
/// Public because every caller that re-submits a
/// [`CoordinatorError::Rejected`] job (the serve dispatcher, the
/// harness's one-shot path) spaces its attempts with the same schedule,
/// taking the larger of this backoff and the rejection's
/// `retry_after_hint`.
pub fn retry_backoff(attempt: usize, rng: &mut Xoshiro256, ctl: &RunControl) -> Duration {
    if ctl.stop_reason().is_some() {
        return Duration::ZERO;
    }
    let exp = attempt.saturating_sub(2).min(10) as u32;
    let raw = RETRY_BACKOFF_BASE.saturating_mul(1 << exp).min(RETRY_BACKOFF_CAP);
    let mut pause = raw.mul_f64(0.5 + rng.next_f64());
    if let Some(remaining) = ctl.deadline_remaining() {
        pause = pause.min(remaining);
    }
    pause
}

/// RAII in-flight slot: acquired at admission, released on every exit
/// path of `run_job` (shed, job-level error, success) when dropped.
struct InflightGuard<'a> {
    counter: &'a AtomicUsize,
}

impl<'a> InflightGuard<'a> {
    fn acquire(counter: &'a AtomicUsize, max_inflight: usize) -> Option<Self> {
        counter
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                (cur < max_inflight).then_some(cur + 1)
            })
            .ok()
            .map(|_| InflightGuard { counter })
    }
}

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.counter.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Bytes of mandatory layout the job's engine will charge in its prepare
/// phase: the SELL layout for the sell-routed kinds when the job's
/// artifacts have not built it yet, zero otherwise. Uses the exact
/// pre-build planner, so the admission estimate matches the later charge
/// byte-for-byte.
fn planned_mandatory_bytes(job: &BfsJob, artifacts: &GraphArtifacts, stats: &DegreeStats) -> usize {
    let sell_engine = matches!(job.engine, EngineKind::Sell { .. } | EngineKind::MultiSource { .. })
        || matches!(job.engine, EngineKind::Hybrid { sell, bu_sell, .. } if sell || bu_sell);
    if !sell_engine || artifacts.built_sell().is_some() {
        return 0;
    }
    let sigma = match job.engine.sigma_key() {
        SIGMA_AUTO => stats.suggested_sigma(),
        s => s,
    };
    planned_sell_bytes(&job.graph, sigma)
}

/// One cached per-graph preparation. The durable key is `(content, sigma)`
/// — a 64-bit fingerprint of the graph's degree sequence + adjacency
/// stream ([`Csr::content_hash`]) — so a *reloaded* graph (new `Arc`, same
/// bytes) still hits. `graph` additionally remembers the last allocation
/// the entry served, weakly, as a hash-free identity fast-path.
struct ArtifactCacheEntry {
    graph: Weak<Csr>,
    content: u64,
    sigma: usize,
    artifacts: Arc<GraphArtifacts>,
}

/// How a cache lookup was (or wasn't) served.
enum CacheOutcome {
    /// Same live allocation — no hashing needed.
    IdentityHit,
    /// Same content, different allocation (a reloaded graph).
    ContentHit,
    Miss,
}

/// The L3 driver: runs jobs, keeps metrics.
pub struct Coordinator {
    /// Worker threads per job.
    pub workers: usize,
    metrics: Metrics,
    /// Keyed [`GraphArtifacts`] cache: repeated jobs on the same graph —
    /// the serving scenario — skip layout/stats construction entirely and
    /// keep accumulating the same cross-root
    /// [`crate::bfs::policy::PolicyFeedback`] channel. Keys are **content
    /// addressed** (graph fingerprint + σ), with a `Weak` identity
    /// fast-path per entry, so entries deliberately outlive their graphs:
    /// dropping and reloading a graph between jobs still hits. The vec is
    /// kept in recency order (front = least recently used); the LRU entry
    /// is evicted at [`ARTIFACT_CACHE_CAP`], which bounds the retained
    /// layouts no matter how many distinct graphs a long-lived coordinator
    /// sees. Under a bounded governor entries are additionally
    /// byte-accounted: eviction releases an entry's retained bytes and
    /// runs until the ledger is back under the low watermark.
    artifact_cache: Mutex<Vec<ArtifactCacheEntry>>,
    /// Shared byte ledger every piece of memory governance flows through:
    /// artifact builds, cache retention, per-job working-set holds,
    /// injected synthetic pressure. Unbounded for [`Coordinator::new`].
    governor: Arc<ResourceGovernor>,
    /// Admission policy (the in-flight cap; the estimated-footprint check
    /// rides the governor's budget).
    admission: AdmissionPolicy,
    /// Jobs currently inside `run_job`.
    inflight: AtomicUsize,
}

impl Coordinator {
    /// An ungoverned coordinator: no memory budget, no in-flight cap.
    pub fn new(workers: usize) -> Self {
        Self::with_limits(workers, None, AdmissionPolicy::default())
    }

    /// A resource-governed coordinator: `budget_bytes` bounds every
    /// byte-accounted allocation (`None` = unbounded) and `admission`
    /// bounds concurrently running jobs.
    pub fn with_limits(
        workers: usize,
        budget_bytes: Option<usize>,
        admission: AdmissionPolicy,
    ) -> Self {
        Coordinator {
            workers: workers.max(1),
            metrics: Metrics::default(),
            artifact_cache: Mutex::new(Vec::new()),
            governor: Arc::new(
                budget_bytes
                    .map_or_else(ResourceGovernor::unbounded, ResourceGovernor::with_budget),
            ),
            admission,
            inflight: AtomicUsize::new(0),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The coordinator's shared byte ledger.
    pub fn governor(&self) -> &Arc<ResourceGovernor> {
        &self.governor
    }

    /// Backpressure hint for a shed job, scaled with the current load so
    /// callers of a busier coordinator back off harder.
    fn retry_hint(&self) -> Duration {
        Duration::from_millis(25 * self.inflight.load(Ordering::Relaxed).max(1) as u64)
    }

    /// Drop the LRU cache entry, returning its retained bytes to the
    /// ledger. A job still holding the entry's `Arc` keeps the structures
    /// alive; the accounting stops the moment the cache lets go — the
    /// bytes die with the job, not with the cache.
    fn evict_lru(&self, cache: &mut Vec<ArtifactCacheEntry>) {
        let e = cache.remove(0);
        let bytes = e.artifacts.heap_bytes();
        self.governor.release(bytes);
        self.metrics.record_bytes_evicted(bytes);
        self.metrics.record_artifact_cache_eviction();
    }

    /// Evict LRU entries until the ledger is back under the governor's
    /// low watermark (no-op for an unbounded governor), then refresh the
    /// retained-bytes gauge. Runs at the end of every job, after that
    /// job's working-set hold released.
    fn enforce_watermark(&self) {
        let mut cache = lock_unpoisoned(&self.artifact_cache);
        if self.governor.is_bounded() {
            while self.governor.used() > self.governor.low_watermark() && !cache.is_empty() {
                self.evict_lru(&mut cache);
            }
        }
        self.metrics.set_cache_bytes(cache.iter().map(|e| e.artifacts.heap_bytes()).sum());
    }

    /// The cached artifacts for `(graph, sigma)`, or a fresh entry.
    ///
    /// Lookup order: the identity fast-path first (`Arc::ptr_eq` through
    /// the stored `Weak` — a reused allocation address can never alias a
    /// dropped graph), then the content key ([`Csr::content_hash`],
    /// computed only when identity missed — and *outside* the lock, so
    /// concurrent jobs never serialize behind an O(V + E) hash). A
    /// content hit refreshes the entry's identity fast-path so the
    /// following jobs on the same reloaded `Arc` skip hashing again.
    ///
    /// Every hit (and every insert) moves its entry to the back of the
    /// vec, so the front is always the least-recently-used entry — the one
    /// evicted at capacity.
    fn artifacts_for(&self, graph: &Arc<Csr>, sigma: usize) -> (Arc<GraphArtifacts>, CacheOutcome) {
        // positions rather than references, so a hit can be re-queued
        let identity_pos = |cache: &[ArtifactCacheEntry]| {
            cache.iter().position(|e| {
                e.sigma == sigma
                    && e.graph.upgrade().map(|g| Arc::ptr_eq(&g, graph)).unwrap_or(false)
            })
        };
        // move entry `i` to the MRU end and return its artifacts
        fn touch(cache: &mut Vec<ArtifactCacheEntry>, i: usize) -> Arc<GraphArtifacts> {
            let e = cache.remove(i);
            let artifacts = Arc::clone(&e.artifacts);
            cache.push(e);
            artifacts
        }
        {
            let mut cache = lock_unpoisoned(&self.artifact_cache);
            if let Some(i) = identity_pos(&cache) {
                return (touch(&mut cache, i), CacheOutcome::IdentityHit);
            }
        }
        // hash without the lock, then re-check: another worker may have
        // inserted (or re-pointed) an entry for this graph meanwhile
        let content = graph.content_hash();
        let mut cache = lock_unpoisoned(&self.artifact_cache);
        if let Some(i) = identity_pos(&cache) {
            return (touch(&mut cache, i), CacheOutcome::IdentityHit);
        }
        if let Some(i) = cache.iter().position(|e| e.sigma == sigma && e.content == content) {
            cache[i].graph = Arc::downgrade(graph);
            return (touch(&mut cache, i), CacheOutcome::ContentHit);
        }
        let artifacts = Arc::new(GraphArtifacts::for_graph(graph));
        // every artifact this entry builds charges the coordinator's
        // ledger (and is refused under pressure)
        artifacts.install_governor(Arc::clone(&self.governor));
        if cache.len() >= ARTIFACT_CACHE_CAP {
            self.evict_lru(&mut cache);
        }
        cache.push(ArtifactCacheEntry {
            graph: Arc::downgrade(graph),
            content,
            sigma,
            artifacts: Arc::clone(&artifacts),
        });
        (artifacts, CacheOutcome::Miss)
    }

    /// Package one engine result as a [`RootRun`]. Interrupted runs carry
    /// a true visited *prefix* but not a complete BFS tree, so validation
    /// (when the job asks for it) only judges complete traversals.
    ///
    /// Device-lock wait ([`crate::bfs::RunTrace::lock_wait_ns`]) is
    /// subtracted from the measured seconds: a PJRT root queueing behind
    /// another worker's execution did no traversal work during that time,
    /// and counting it would deflate per-root TEPS by the worker count.
    fn root_run(
        job: &BfsJob,
        root: Vertex,
        r: BfsResult,
        seconds: f64,
        prep_share: f64,
    ) -> RootRun {
        let validation = (job.validate && r.trace.status.is_complete())
            .then(|| validate(&job.graph, &r.tree));
        let depths = if job.run.report_depths {
            super::job::DepthSummary::from_tree(&r.tree)
        } else {
            None
        };
        RootRun {
            root,
            // Graph500 TEPS: undirected edges of the
            // reached component ≈ directed scans / 2
            edges_traversed: r.trace.total_edges_scanned() / 2,
            reached: r.tree.reached_count(),
            seconds: (seconds - r.trace.lock_wait_ns as f64 * 1e-9).max(0.0),
            preparation_seconds: prep_share,
            counted_warmup: r.trace.counted_warmup,
            trace: r.trace,
            validation,
            depths,
        }
    }

    /// Execute a job to completion. `Err` means the *request* could not
    /// run (corrupt graph, bad root, unbuildable engine); once workers
    /// start, every per-root failure is contained inside the returned
    /// [`JobOutcome`].
    pub fn run_job(&self, job: &BfsJob) -> Result<JobOutcome, CoordinatorError> {
        // Phase 0 — reject malformed requests before any engine touches
        // them: a corrupt CSR would otherwise surface as an out-of-bounds
        // panic deep inside whichever engine hit it first.
        job.graph.validate_structure()?;
        let vertices = job.graph.num_vertices();
        if let Some(&root) = job.roots.iter().find(|&&r| r as usize >= vertices) {
            return Err(CoordinatorError::RootOutOfBounds { root, vertices });
        }

        // Phase 0.5 — admission control. The in-flight slot is RAII, so
        // every exit path below releases it.
        let Some(_inflight) = InflightGuard::acquire(&self.inflight, self.admission.max_inflight)
        else {
            self.metrics.record_job_shed();
            return Err(CoordinatorError::Rejected { retry_after_hint: self.retry_hint() });
        };
        // chaos hook: synthetic ledger pressure held for the whole job,
        // clamped so the ledger never observes more than the budget
        let _synthetic: Option<LedgerHold> = match job.run.fault {
            Some(FaultPlan { kind: FaultKind::MemoryPressure { bytes }, .. }) => {
                Some(self.governor.hold_clamped(bytes))
            }
            _ => None,
        };

        // Phase 1 — fail fast: construct the engine and prepare the graph
        // once, before any worker spawns. The PJRT engine compiles its
        // executable here; the sell engines build their Sell16 layout here
        // — exactly once per *graph content*: repeated jobs on a cached
        // (or reloaded) graph reuse the artifacts and skip the build.
        let t_prep = Instant::now();
        let engine = make_engine(&job.engine).map_err(CoordinatorError::EngineConstruction)?;
        let (artifacts, outcome) = self.artifacts_for(&job.graph, job.engine.sigma_key());
        match outcome {
            CacheOutcome::IdentityHit => self.metrics.record_artifact_cache_hit(false),
            CacheOutcome::ContentHit => self.metrics.record_artifact_cache_hit(true),
            CacheOutcome::Miss => {}
        }

        // Estimated-footprint admission check, from degree stats alone —
        // before the engine allocates anything. A job that can never fit
        // the budget sheds structurally as OverBudget; one that merely
        // does not fit *right now* sheds as Rejected (released holds and
        // cache evictions can admit a retry). An admitted job reserves
        // its working-set estimate on the ledger for its lifetime.
        let working_set: Option<LedgerHold> = if self.governor.is_bounded() {
            let stats = artifacts.stats(&job.graph);
            let ws = estimate_working_set(stats, job.roots.len(), self.workers);
            let layout = planned_mandatory_bytes(job, &artifacts, stats);
            if layout.saturating_add(ws) > self.governor.budget() {
                self.metrics.record_job_shed();
                return Err(CoordinatorError::OverBudget {
                    detail: format!(
                        "estimated footprint {} B (mandatory layout {layout} B + \
                         working set {ws} B) exceeds the {} B budget",
                        layout.saturating_add(ws),
                        self.governor.budget()
                    ),
                });
            }
            let Some(hold) =
                self.governor.try_hold(ws).filter(|_| layout <= self.governor.remaining())
            else {
                self.metrics.record_job_shed();
                return Err(CoordinatorError::Rejected { retry_after_hint: self.retry_hint() });
            };
            Some(hold)
        } else {
            None
        };

        let prepared = engine.prepare_with(&job.graph, Arc::clone(&artifacts)).map_err(|e| {
            // a mandatory artifact that lost a charge race after passing
            // admission still surfaces as the structured shedding error
            let rendered = format!("{e:#}");
            if rendered.contains(OVER_BUDGET_MARKER) {
                self.metrics.record_job_shed();
                CoordinatorError::OverBudget { detail: rendered }
            } else {
                CoordinatorError::Preparation(e)
            }
        })?;
        let preparation_seconds = t_prep.elapsed().as_secs_f64();
        let prep_share = preparation_seconds / job.roots.len().max(1) as f64;

        // The job's run control: the caller's handle when one was passed
        // (external cancellation), else a private one. The deadline is
        // armed *after* preparation so it bounds traversal time only, and
        // before any worker spawns so every batch observes it.
        let ctl: Arc<RunControl> = job.run.control.clone().unwrap_or_default();
        if let Some(d) = job.run.deadline {
            ctl.arm_deadline_in(d);
        }

        // Phase 2 — workers share the prepared engine by reference and
        // pull root batches from a common cursor. Each batch runs inside
        // `catch_unwind`: a panicking engine fails its own batch's slots
        // and nothing else.
        let prepared: &dyn PreparedBfs = prepared.as_ref();
        let width = job.batch.width();
        let num_batches = job.batch.num_batches(job.roots.len());
        let cursor = AtomicUsize::new(0);
        let slots: Mutex<Vec<RootSlot>> = Mutex::new(vec![None; job.roots.len()]);

        std::thread::scope(|s| {
            for _ in 0..self.workers.min(num_batches.max(1)) {
                s.spawn(|| loop {
                    let b = cursor.fetch_add(1, Ordering::Relaxed);
                    if b >= num_batches {
                        break;
                    }
                    let start = b * width;
                    let end = (start + width).min(job.roots.len());
                    let batch_roots = &job.roots[start..end];
                    let t0 = Instant::now();
                    let caught = catch_unwind(AssertUnwindSafe(|| match &job.run.fault {
                        Some(plan) => {
                            plan.apply(b, || prepared.run_batch_with(batch_roots, &ctl))
                        }
                        None => prepared.run_batch_with(batch_roots, &ctl),
                    }));
                    // per-batch timing, amortized equally over its roots
                    let seconds = t0.elapsed().as_secs_f64() / batch_roots.len() as f64;
                    let batch: Vec<Result<RootRun, String>> = match caught {
                        Ok(rs) if rs.len() == batch_roots.len() => rs
                            .into_iter()
                            .zip(batch_roots.iter())
                            .map(|(r, &root)| {
                                Ok(Self::root_run(job, root, r, seconds, prep_share))
                            })
                            .collect(),
                        Ok(rs) => {
                            // the old coordinator asserted here; a hole is
                            // now a per-root failure, not a process abort
                            let msg = format!(
                                "engine returned {} results for a {}-root batch",
                                rs.len(),
                                batch_roots.len()
                            );
                            batch_roots.iter().map(|_| Err(msg.clone())).collect()
                        }
                        Err(payload) => {
                            self.metrics.record_worker_panic();
                            let msg =
                                format!("worker panicked: {}", panic_message(payload.as_ref()));
                            batch_roots.iter().map(|_| Err(msg.clone())).collect()
                        }
                    };
                    let mut locked = lock_unpoisoned(&slots);
                    for (i, r) in batch.into_iter().enumerate() {
                        locked[start + i] = Some(r);
                    }
                });
            }
        });

        // Phase 3 — retry failed roots down the degradation ladder,
        // sequentially on this thread (failures are the rare path;
        // isolation matters more than parallelism here). Rung 2 is the
        // job's engine on the counted VPU backend — it sidesteps hardware
        // SIMD faults and, for scalar engines, simply retries. Rung 3+ is
        // the serial reference engine. Fallbacks are prepared lazily, once,
        // against the job's already-built artifacts.
        let slot_results = slots.into_inner().unwrap_or_else(|p| p.into_inner());
        let max_attempts = job.run.max_attempts.max(1);
        let mut counted_rung: Option<Box<dyn PreparedBfs + '_>> = None;
        let mut serial_rung: Option<Box<dyn PreparedBfs + '_>> = None;
        let mut outcomes: Vec<RootOutcome> = Vec::with_capacity(job.roots.len());
        for (i, slot) in slot_results.into_iter().enumerate() {
            let root = job.roots[i];
            let mut attempts = 1usize;
            let mut last =
                slot.unwrap_or_else(|| Err("scheduler left an unfilled slot".to_string()));
            // a sticky injected fault follows its roots through every
            // retry — the attempt-exhaustion scenario of the chaos suite
            let sticky_fault =
                job.run.fault.filter(|p| p.sticky && p.fires_at(i / width));
            // deterministic per-(job, root) jitter stream for the backoff
            let mut backoff_rng =
                Xoshiro256::seed_from_u64(job.id ^ ((root as u64) << 20) ^ 0x9e37_79b9);
            while last.is_err() && attempts < max_attempts {
                attempts += 1;
                self.metrics.record_root_retry();
                // space the rungs out: a fault that needs a moment to
                // clear (device contention, a stalled sibling) is not
                // hammered at full rate
                let pause = retry_backoff(attempts, &mut backoff_rng, &ctl);
                if !pause.is_zero() {
                    std::thread::sleep(pause);
                }
                let rung: Option<&dyn PreparedBfs> = if attempts == 2 {
                    if counted_rung.is_none() {
                        let mut kind = job.engine.clone();
                        kind.set_vpu(VpuMode::Counted);
                        counted_rung = make_engine(&kind).ok().and_then(|e| {
                            e.prepare_with(&job.graph, Arc::clone(&artifacts)).ok()
                        });
                    }
                    counted_rung.as_deref()
                } else {
                    if serial_rung.is_none() {
                        serial_rung = SerialLayeredBfs
                            .prepare_with(&job.graph, Arc::clone(&artifacts))
                            .ok();
                    }
                    serial_rung.as_deref()
                };
                let Some(rung) = rung else {
                    last = Err("fallback engine preparation failed".to_string());
                    continue;
                };
                let t0 = Instant::now();
                let caught = catch_unwind(AssertUnwindSafe(|| match sticky_fault {
                    Some(plan) => {
                        plan.apply(plan.at_batch, || rung.run_batch_with(&[root], &ctl))
                    }
                    None => rung.run_batch_with(&[root], &ctl),
                }));
                let seconds = t0.elapsed().as_secs_f64();
                last = match caught {
                    Ok(mut rs) if rs.len() == 1 => {
                        let r = rs.pop().expect("len checked");
                        Ok(Self::root_run(job, root, r, seconds, prep_share))
                    }
                    Ok(rs) => {
                        Err(format!("retry returned {} results for one root", rs.len()))
                    }
                    Err(payload) => {
                        self.metrics.record_worker_panic();
                        Err(format!("worker panicked: {}", panic_message(payload.as_ref())))
                    }
                };
            }
            match last {
                Ok(run) => {
                    if attempts > 1 {
                        self.metrics.record_degraded_root();
                    }
                    outcomes.push(RootOutcome::Ran(run));
                }
                Err(error) => {
                    self.metrics.record_failed_root();
                    outcomes.push(RootOutcome::Failed { root, error, attempts });
                }
            }
        }

        let all_valid = outcomes.iter().all(|o| match o {
            RootOutcome::Ran(r) => {
                r.validation.as_ref().map(|v| v.all_passed()).unwrap_or(true)
            }
            RootOutcome::Failed { .. } => false,
        });
        let runs: Vec<&RootRun> = outcomes.iter().filter_map(RootOutcome::run).collect();
        self.metrics.record_job(&runs, preparation_seconds, num_batches);

        // Release the working-set reservation, then re-balance the cache
        // against the ledger and surface this job's structured pressure
        // events (metrics counter + outcome field).
        drop(working_set);
        self.enforce_watermark();
        let pressure = self.governor.drain_events();
        for _ in &pressure {
            self.metrics.record_pressure_event();
        }
        Ok(JobOutcome {
            id: job.id,
            outcomes,
            all_valid,
            preparation_seconds,
            artifacts,
            pressure,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::coordinator::fault::FaultPlan;
    use crate::coordinator::job::{BatchPolicy, RunPolicy};
    use crate::graph::{Csr, RmatConfig};
    use std::sync::Arc;

    fn job(engine: EngineKind, roots: Vec<u32>) -> BfsJob {
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        BfsJob {
            id: 1,
            graph: g,
            roots,
            engine,
            validate: true,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        }
    }

    #[test]
    fn runs_all_roots_in_order() {
        let j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.runs().count(), 8);
        for (i, r) in out.runs().enumerate() {
            assert_eq!(r.root, j.roots[i]);
        }
        assert!(out.all_valid);
    }

    #[test]
    fn lock_wait_is_excluded_from_root_seconds() {
        let j = job(EngineKind::SerialLayered, vec![0]);
        let n = j.graph.num_vertices();
        let mut pred = vec![crate::PRED_INFINITY; n];
        pred[0] = 0;
        let mk = |lock_wait_ns: u64| BfsResult {
            tree: crate::bfs::BfsTree::new(0, pred.clone()),
            trace: crate::bfs::RunTrace { lock_wait_ns, ..Default::default() },
        };
        // half a second of queueing inside a 2-second measurement: only
        // the executing 1.5 s count toward the root
        let r = Coordinator::root_run(&j, 0, mk(500_000_000), 2.0, 0.0);
        assert!((r.seconds - 1.5).abs() < 1e-12, "got {}", r.seconds);
        // no lock wait → unchanged
        let r = Coordinator::root_run(&j, 0, mk(0), 2.0, 0.0);
        assert!((r.seconds - 2.0).abs() < 1e-12);
        // a wait longer than the measurement clamps at zero rather than
        // going negative
        let r = Coordinator::root_run(&j, 0, mk(5_000_000_000), 2.0, 0.0);
        assert_eq!(r.seconds, 0.0);
    }

    #[test]
    fn metrics_accumulate() {
        let c = Coordinator::new(2);
        let j = job(EngineKind::NonSimd { threads: 1 }, vec![0, 1, 2, 3]);
        c.run_job(&j).unwrap();
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 2);
        assert_eq!(m.roots, 8);
        assert_eq!(m.batches, 8, "per-root policy: one batch per root");
        assert!(m.total_seconds > 0.0);
    }

    #[test]
    fn isolated_roots_yield_zero_edges() {
        // roots with no edges produce reached==1, edges==0 (the famous
        // zero-TEPS entries of §5.3)
        let j = job(EngineKind::SerialLayered, (0..20).collect());
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert!(out.runs().any(|r| r.reached == 1 && r.edges_traversed == 0));
    }

    #[test]
    fn batched_job_matches_per_root_job() {
        // the batch policy changes scheduling, never results: same roots,
        // same trees (compared as reached/edge counts), for a looping
        // engine and for the genuinely batched MS engine
        for engine_name in ["serial", "hybrid-sell-ms"] {
            let engine = EngineKind::parse(engine_name, 2, "artifacts").unwrap();
            let mut j = job(engine, (0..10).collect());
            let per_root = Coordinator::new(2).run_job(&j).unwrap();
            j.batch = BatchPolicy::Fixed(4);
            let batched = Coordinator::new(2).run_job(&j).unwrap();
            assert!(per_root.all_valid && batched.all_valid, "{engine_name}");
            assert_eq!(per_root.runs().count(), batched.runs().count());
            for (a, b) in per_root.runs().zip(batched.runs()) {
                assert_eq!(a.root, b.root, "{engine_name}");
                assert_eq!(a.reached, b.reached, "{engine_name}");
            }
        }
    }

    #[test]
    fn batch_widths_cover_all_roots() {
        // widths 1, 16 and a non-multiple of the root count all fill
        // every result slot exactly once
        for width in [1usize, 3, 16] {
            let mut j = job(
                EngineKind::parse("hybrid-sell-ms", 1, "artifacts").unwrap(),
                (0..10).collect(),
            );
            j.batch = if width == 1 { BatchPolicy::PerRoot } else { BatchPolicy::Fixed(width) };
            let out = Coordinator::new(3).run_job(&j).unwrap();
            assert_eq!(out.runs().count(), 10, "width {width}");
            for (i, r) in out.runs().enumerate() {
                assert_eq!(r.root, j.roots[i], "width {width}");
                assert!(r.seconds >= 0.0);
            }
            assert!(out.all_valid, "width {width}");
        }
    }

    #[test]
    fn batch_metrics_count_batches_not_roots() {
        let c = Coordinator::new(2);
        let mut j = job(EngineKind::SerialLayered, (0..10).collect());
        j.batch = BatchPolicy::Fixed(4);
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.roots, 10);
        assert_eq!(m.batches, 3, "10 roots in batches of 4 → 3 batches");
    }

    #[test]
    fn sell_layout_built_exactly_once_per_job() {
        // the tentpole guarantee: a multi-root sell job constructs its
        // Sell16 layout once, in the prepare phase, no matter how many
        // roots or workers run (PR 1 rebuilt it per root — 64× per job)
        let j = job(
            EngineKind::parse("sell", 2, "artifacts").unwrap(),
            (0..8).collect(),
        );
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.artifacts.sell_builds(), 1, "{:?}", out.artifacts);
        assert!(out.all_valid);
        assert!(out.preparation_seconds > 0.0);
        for r in out.runs() {
            assert!((r.preparation_seconds - out.preparation_seconds / 8.0).abs() < 1e-12);
        }
        // the cross-root feedback channel saw every root
        assert_eq!(out.artifacts.feedback().roots_done(), 8);
    }

    #[test]
    fn artifact_cache_reuses_preparation_across_jobs() {
        // the serving scenario: repeated jobs on one hot graph share one
        // prepared GraphArtifacts — layout built once, feedback persistent
        let c = Coordinator::new(2);
        let el = RmatConfig::graph500(9, 8).generate(61);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        let engine = EngineKind::parse("sell", 2, "artifacts").unwrap();
        let j1 = BfsJob {
            id: 1,
            graph: Arc::clone(&g),
            roots: (0..4).collect(),
            engine,
            validate: true,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let j2 = BfsJob { id: 2, ..j1.clone() };
        let a = c.run_job(&j1).unwrap();
        let b = c.run_job(&j2).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert_eq!(b.artifacts.sell_builds(), 1, "layout must not rebuild on a cache hit");
        // the cross-root feedback channel kept accumulating across jobs
        assert_eq!(b.artifacts.feedback().roots_done(), 8);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 0, "same Arc → identity fast-path");
        assert!(b.all_valid);
    }

    #[test]
    fn artifact_cache_hits_reloaded_graph_by_content() {
        // the ROADMAP item: dropping a graph and reloading it from the
        // same source must hit the cache — the durable key is the content
        // fingerprint, not the allocation
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
        let mk = |graph: Arc<Csr>| BfsJob {
            id: 0,
            graph,
            roots: vec![0, 1],
            engine: engine.clone(),
            validate: false,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let a = {
            // this Arc is dropped before the second job — only content
            // can match it
            let g1 = Arc::new(Csr::from_edge_list(9, &el));
            c.run_job(&mk(Arc::clone(&g1))).unwrap()
        };
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        let b = c.run_job(&mk(Arc::clone(&g2))).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts), "reloaded graph must hit");
        assert_eq!(b.artifacts.sell_builds(), 1);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 1);
        // a third job on the same reloaded Arc takes the refreshed
        // identity fast-path — a hit, but not a content hit
        c.run_job(&mk(Arc::clone(&g2))).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2);
        assert_eq!(m.artifact_cache_content_hits, 1);
    }

    #[test]
    fn artifact_cache_distinguishes_content_and_sigma() {
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let g1 = Arc::new(Csr::from_edge_list(9, &el));
        // equal content, different identity — must alias via the content key
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        // different content — must not alias
        let el3 = RmatConfig::graph500(9, 8).generate(63);
        let g3 = Arc::new(Csr::from_edge_list(9, &el3));
        let mk = |graph: &Arc<Csr>, sigma: usize| {
            let mut engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
            if let EngineKind::Sell { sigma: s, .. } = &mut engine {
                *s = sigma;
            }
            BfsJob {
                id: 0,
                graph: Arc::clone(graph),
                roots: vec![0, 1],
                engine,
                validate: false,
                batch: BatchPolicy::PerRoot,
                run: RunPolicy::default(),
            }
        };
        let a = c.run_job(&mk(&g1, 64)).unwrap();
        let b = c.run_job(&mk(&g2, 64)).unwrap(); // same content → content hit
        let d = c.run_job(&mk(&g1, 128)).unwrap(); // different σ → miss
        let e = c.run_job(&mk(&g3, 64)).unwrap(); // different content → miss
        // g2's content hit re-pointed the identity fast-path at g2, so g1
        // matches by content again
        let f = c.run_job(&mk(&g1, 64)).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &d.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &e.artifacts));
        assert!(Arc::ptr_eq(&a.artifacts, &f.artifacts));
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2, "b and f hit");
        assert_eq!(m.artifact_cache_content_hits, 2, "both via the content key");
    }

    #[test]
    fn bad_engine_fails_fast_before_workers() {
        // a PJRT config with no artifacts errors in the prepare phase
        let j = job(
            EngineKind::Pjrt { artifact_dir: "/nonexistent-artifacts".into() },
            vec![0, 1],
        );
        let err = Coordinator::new(2).run_job(&j).unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn single_worker_deterministic() {
        let j = job(
            EngineKind::Simd {
                threads: 1,
                opts: crate::bfs::vectorized::SimdOpts::full(),
                policy: crate::bfs::policy::LayerPolicy::All,
                vpu: crate::simd::VpuMode::default(),
            },
            vec![3, 9],
        );
        let a = Coordinator::new(1).run_job(&j).unwrap();
        let b = Coordinator::new(1).run_job(&j).unwrap();
        for (x, y) in a.runs().zip(b.runs()) {
            assert_eq!(x.reached, y.reached);
            assert_eq!(x.edges_traversed, y.edges_traversed);
        }
    }

    #[test]
    fn out_of_range_root_is_rejected() {
        let j = job(EngineKind::SerialLayered, vec![0, 1_000_000]);
        let err = Coordinator::new(1).run_job(&j).unwrap_err();
        assert!(matches!(err, CoordinatorError::RootOutOfBounds { root: 1_000_000, .. }));
    }

    #[test]
    fn injected_panic_is_contained_and_retried() {
        // batch 1 (root index 1) panics once; the coordinator catches it,
        // retries the root on the degradation ladder, and both the job and
        // the coordinator (its locks included) stay fully usable
        let mut j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3]);
        j.run.fault = Some(FaultPlan::panic_at(1));
        let c = Coordinator::new(2);
        let out = c.run_job(&j).unwrap();
        assert_eq!(out.outcomes.len(), 4);
        assert!(out.outcomes.iter().all(|o| !o.is_failed()), "one-shot fault recovers");
        assert!(out.all_valid, "retried root still validates against the oracle");
        let m = c.metrics().snapshot();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.root_retries, 1);
        assert_eq!(m.degraded_roots, 1);
        assert_eq!(m.failed_roots, 0);
        let ok = c.run_job(&job(EngineKind::SerialLayered, vec![0])).unwrap();
        assert!(ok.all_valid, "coordinator survives for the next job");
    }

    #[test]
    fn artifact_cache_evicts_least_recently_used() {
        let c = Coordinator::new(1);
        let mk_graph = |seed: u64| {
            let el = RmatConfig::graph500(7, 8).generate(seed);
            Arc::new(Csr::from_edge_list(7, &el))
        };
        let mk_job = |g: &Arc<Csr>| BfsJob {
            id: 0,
            graph: Arc::clone(g),
            roots: vec![0],
            engine: EngineKind::SerialLayered,
            validate: false,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let graphs: Vec<_> =
            (0..=ARTIFACT_CACHE_CAP as u64).map(|s| mk_graph(100 + s)).collect();
        // fill the cache exactly to capacity
        let first = c.run_job(&mk_job(&graphs[0])).unwrap();
        for g in &graphs[1..ARTIFACT_CACHE_CAP] {
            c.run_job(&mk_job(g)).unwrap();
        }
        assert_eq!(c.metrics().snapshot().artifact_cache_evictions, 0);
        // touch graph 0 — it becomes the most recently used entry
        let touched = c.run_job(&mk_job(&graphs[0])).unwrap();
        assert!(Arc::ptr_eq(&first.artifacts, &touched.artifacts));
        // one more graph evicts the LRU entry: graph 1, not the
        // just-touched graph 0 (insertion order would evict 0)
        c.run_job(&mk_job(&graphs[ARTIFACT_CACHE_CAP])).unwrap();
        assert_eq!(c.metrics().snapshot().artifact_cache_evictions, 1);
        let again = c.run_job(&mk_job(&graphs[0])).unwrap();
        assert!(
            Arc::ptr_eq(&first.artifacts, &again.artifacts),
            "recently-used entry survived the eviction"
        );
        // graph 1 really is gone: rerunning it misses (no hit recorded)
        // and evicts the next LRU entry in turn
        let hits_before = c.metrics().snapshot().artifact_cache_hits;
        c.run_job(&mk_job(&graphs[1])).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, hits_before, "evicted entry must miss");
        assert_eq!(m.artifact_cache_evictions, 2);
    }

    #[test]
    fn admission_rejects_at_inflight_cap() {
        let c = Coordinator::with_limits(1, None, AdmissionPolicy { max_inflight: 0 });
        let err = c.run_job(&job(EngineKind::SerialLayered, vec![0])).unwrap_err();
        assert!(
            matches!(err, CoordinatorError::Rejected { retry_after_hint }
                if retry_after_hint > Duration::ZERO),
            "{err}"
        );
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs_shed, 1);
        assert_eq!(m.jobs, 0, "shed jobs never count as jobs");
    }

    #[test]
    fn over_budget_job_sheds_structurally_without_polluting_aggregates() {
        // a budget far below even the scale-9 working set: the footprint
        // estimate sheds the job before any allocation, structurally
        let c = Coordinator::with_limits(2, Some(1024), AdmissionPolicy::default());
        let err = c.run_job(&job(EngineKind::SerialLayered, vec![0, 1])).unwrap_err();
        assert!(matches!(err, CoordinatorError::OverBudget { .. }), "{err}");
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs_shed, 1);
        assert_eq!(m.jobs, 0);
        assert_eq!(m.roots, 0);
        assert_eq!(m.edges_traversed, 0);
        assert_eq!(m.preparation_seconds, 0.0, "shed jobs never record preparation");
        assert_eq!(m.aggregate_teps, 0.0);
        assert_eq!(c.governor().used(), 0, "shedding leaves the ledger clean");
    }

    #[test]
    fn transient_pressure_sheds_with_retry_hint_then_admits() {
        let c = Coordinator::with_limits(1, Some(1 << 20), AdmissionPolicy::default());
        let mut j = job(EngineKind::SerialLayered, vec![0]);
        // fill the whole budget: the working-set hold cannot fit, but the
        // job itself is not structurally over budget → transient shed
        j.run.fault = Some(FaultPlan::memory_pressure(usize::MAX));
        let err = c.run_job(&j).unwrap_err();
        assert!(
            matches!(err, CoordinatorError::Rejected { retry_after_hint }
                if retry_after_hint > Duration::ZERO),
            "{err}"
        );
        assert_eq!(c.metrics().snapshot().jobs_shed, 1);
        // the synthetic hold died with the shed job: the same request
        // without the fault is admitted and completes
        j.run.fault = None;
        assert!(c.run_job(&j).unwrap().all_valid);
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 1);
        assert_eq!(m.jobs_shed, 1);
    }

    #[test]
    fn governed_job_reconciles_ledger_cache_and_gauge() {
        // generous budget: everything builds, nothing sheds, and at job
        // end the ledger holds exactly the cache's retained bytes
        let c = Coordinator::with_limits(2, Some(64 << 20), AdmissionPolicy::default());
        let j = job(EngineKind::parse("sell", 2, "artifacts").unwrap(), (0..4).collect());
        let out = c.run_job(&j).unwrap();
        assert!(out.all_valid);
        assert!(out.pressure.is_empty(), "no pressure under a generous budget");
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs_shed, 0);
        assert_eq!(m.pressure_events, 0);
        assert!(m.cache_bytes > 0, "the cache retains the built layouts");
        assert_eq!(
            c.governor().used(),
            m.cache_bytes,
            "working set released, only cached artifacts remain charged"
        );
        assert_eq!(m.cache_bytes, crate::bfs::HeapFootprint::heap_bytes(&*out.artifacts));
    }

    #[test]
    fn synthetic_pressure_skips_optional_artifacts_but_job_completes() {
        // position the ledger so the mandatory SELL layout lands exactly
        // on the high watermark: optional builds (the padded CSR of the
        // aligned sell engine) are refused with structured events, while
        // the job itself completes — oracle-valid — on fallback paths
        let budget: usize = 4 << 20;
        let engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
        let j = job(engine, vec![0, 1]);
        let stats = DegreeStats::compute(&j.graph);
        let sell = planned_sell_bytes(&j.graph, stats.suggested_sigma());
        let ws = estimate_working_set(&stats, j.roots.len(), 1);
        let c = Coordinator::with_limits(1, Some(budget), AdmissionPolicy::default());
        let pressure_bytes = c.governor().high_watermark() - sell - ws;
        let mut j = j;
        j.run.fault = Some(FaultPlan::memory_pressure(pressure_bytes));
        let out = c.run_job(&j).unwrap();
        assert!(out.all_valid, "the job completes on its fallback paths");
        assert!(!out.pressure.is_empty(), "skips surface as structured events");
        assert!(
            out.pressure.iter().any(|p| p.artifact == "padded-csr"),
            "{:?}",
            out.pressure
        );
        for p in &out.pressure {
            assert!(p.requested_bytes > 0);
            assert_eq!(p.budget_bytes, budget);
            assert!(p.ledger_bytes <= budget, "the ledger never exceeds the budget");
        }
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs_shed, 0, "a degraded job is not a shed job");
        assert_eq!(m.pressure_events, out.pressure.len());
        assert!(out.artifacts.built_sell().is_some(), "mandatory layout still built");
        assert!(out.artifacts.built_padded().is_none(), "optional build was skipped");
    }

    #[test]
    fn cache_evicts_by_bytes_until_under_low_watermark() {
        // two sell-noopt jobs on two distinct graphs: each layout fits
        // alone, both together cross the low watermark — finishing the
        // second job evicts the first entry and returns exactly its bytes
        let mk_graph = |seed: u64| {
            let el = RmatConfig::graph500(9, 8).generate(seed);
            Arc::new(Csr::from_edge_list(9, &el))
        };
        let (g1, g2) = (mk_graph(70), mk_graph(71));
        let engine = EngineKind::parse("sell-noopt", 1, "artifacts").unwrap();
        let sigma = DegreeStats::compute(&g1).suggested_sigma();
        let s1 = planned_sell_bytes(&g1, sigma);
        let s2 = planned_sell_bytes(&g2, sigma);
        let ws = estimate_working_set(&DegreeStats::compute(&g1), 1, 1);
        let budget = s1 + s2 + ws + 1024;
        let c = Coordinator::with_limits(1, Some(budget), AdmissionPolicy::default());
        assert!(s1.max(s2) <= c.governor().low_watermark(), "each entry fits alone");
        assert!(s1 + s2 > c.governor().low_watermark(), "together they cross it");
        let mk_job = |g: &Arc<Csr>| BfsJob {
            id: 0,
            graph: Arc::clone(g),
            roots: vec![0],
            engine: engine.clone(),
            validate: true,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        assert!(c.run_job(&mk_job(&g1)).unwrap().all_valid);
        assert_eq!(c.metrics().snapshot().artifact_cache_evictions, 0);
        assert_eq!(c.governor().used(), s1, "exact planned bytes stay charged");
        assert!(c.run_job(&mk_job(&g2)).unwrap().all_valid);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_evictions, 1, "watermark eviction, not the count cap");
        assert_eq!(m.bytes_evicted, s1 as u64, "LRU entry released exactly its bytes");
        assert_eq!(c.governor().used(), s2);
        assert_eq!(m.cache_bytes, s2);
        assert!(c.governor().used() <= c.governor().low_watermark());
    }

    #[test]
    fn wave_job_reports_depth_summaries() {
        use crate::coordinator::job::DepthSummary;
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        let j = BfsJob::wave(
            7,
            Arc::clone(&g),
            vec![0, 1, 2],
            EngineKind::SerialLayered,
            None,
            None,
            3,
        );
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert_eq!(out.runs().count(), 3);
        for r in out.runs() {
            let d = r.depths.expect("wave jobs digest every root's distances");
            // the digest agrees with one computed straight from an
            // independent serial traversal of the same root
            let oracle = SerialLayeredBfs.run(&g, r.root);
            assert_eq!(d, DepthSummary::from_tree(&oracle.tree).unwrap(), "root {}", r.root);
        }
        // the default policy stays lean: no digests unless asked
        let plain = job(EngineKind::SerialLayered, vec![0]);
        let out = Coordinator::new(1).run_job(&plain).unwrap();
        assert!(out.runs().all(|r| r.depths.is_none()));
    }

    #[test]
    fn retry_backoff_grows_jittered_and_respects_deadline() {
        let ctl = RunControl::new();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let p2 = retry_backoff(2, &mut rng, &ctl);
        assert!(p2 >= RETRY_BACKOFF_BASE.mul_f64(0.5), "jitter floor is 0.5×");
        assert!(p2 < RETRY_BACKOFF_BASE.mul_f64(1.5), "jitter ceiling is 1.5×");
        let p5 = retry_backoff(5, &mut rng, &ctl);
        assert!(p5 >= RETRY_BACKOFF_BASE.mul_f64(8.0 * 0.5), "attempt 5 → 8× base");
        let p20 = retry_backoff(20, &mut rng, &ctl);
        assert!(p20 <= RETRY_BACKOFF_CAP.mul_f64(1.5), "the cap bounds late attempts");
        // a nearly-expired deadline truncates the pause…
        ctl.arm_deadline_in(Duration::from_micros(100));
        assert!(retry_backoff(2, &mut rng, &ctl) <= Duration::from_micros(100));
        // …and a tripped control skips the sleep entirely
        ctl.cancel();
        assert_eq!(retry_backoff(2, &mut rng, &ctl), Duration::ZERO);
    }
}
