//! The root-batching scheduler.
//!
//! A Graph500 job is 64 independent single-root traversals over one shared
//! read-only CSR, so the natural batch unit is the root: `workers` threads
//! each construct their own engine (the PJRT engine is not `Sync`) and pull
//! root indices from a shared cursor until the job drains. Results arrive
//! in root order regardless of completion order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::engine::make_engine;
use super::job::{BfsJob, JobOutcome, RootRun};
use super::metrics::Metrics;
use crate::bfs::validate::validate;

/// The L3 driver: runs jobs, keeps metrics.
pub struct Coordinator {
    /// Worker threads per job.
    pub workers: usize,
    metrics: Metrics,
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator { workers: workers.max(1), metrics: Metrics::default() }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Execute a job to completion.
    pub fn run_job(&self, job: &BfsJob) -> Result<JobOutcome> {
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<RootRun>>> = Mutex::new(vec![None; job.roots.len()]);
        let first_error: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        std::thread::scope(|s| {
            for _ in 0..self.workers.min(job.roots.len().max(1)) {
                s.spawn(|| {
                    // per-worker engine (PJRT compiles its executable here, once)
                    let engine = match make_engine(&job.engine) {
                        Ok(e) => e,
                        Err(e) => {
                            first_error.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    };
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= job.roots.len() {
                            break;
                        }
                        let root = job.roots[i];
                        let t0 = Instant::now();
                        let r = engine.run(&job.graph, root);
                        let seconds = t0.elapsed().as_secs_f64();
                        let validation =
                            job.validate.then(|| validate(&job.graph, &r.tree));
                        let run = RootRun {
                            root,
                            // Graph500 TEPS: undirected edges of the reached
                            // component ≈ directed scans / 2
                            edges_traversed: r.trace.total_edges_scanned() / 2,
                            reached: r.tree.reached_count(),
                            seconds,
                            trace: r.trace,
                            validation,
                        };
                        results.lock().unwrap()[i] = Some(run);
                    }
                });
            }
        });

        if let Some(e) = first_error.into_inner().unwrap() {
            return Err(e);
        }
        let runs: Vec<RootRun> = results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("worker left a hole"))
            .collect();
        let all_valid = runs
            .iter()
            .all(|r| r.validation.as_ref().map(|v| v.all_passed()).unwrap_or(true));
        self.metrics.record_job(&runs);
        Ok(JobOutcome { id: job.id, runs, all_valid })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::graph::{Csr, RmatConfig};
    use std::sync::Arc;

    fn job(engine: EngineKind, roots: Vec<u32>) -> BfsJob {
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        BfsJob { id: 1, graph: g, roots, engine, validate: true }
    }

    #[test]
    fn runs_all_roots_in_order() {
        let j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.runs.len(), 8);
        for (i, r) in out.runs.iter().enumerate() {
            assert_eq!(r.root, j.roots[i]);
        }
        assert!(out.all_valid);
    }

    #[test]
    fn metrics_accumulate() {
        let c = Coordinator::new(2);
        let j = job(EngineKind::NonSimd { threads: 1 }, vec![0, 1, 2, 3]);
        c.run_job(&j).unwrap();
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 2);
        assert_eq!(m.roots, 8);
        assert!(m.total_seconds > 0.0);
    }

    #[test]
    fn isolated_roots_yield_zero_edges() {
        // roots with no edges produce reached==1, edges==0 (the famous
        // zero-TEPS entries of §5.3)
        let j = job(EngineKind::SerialLayered, (0..20).collect());
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert!(out.runs.iter().any(|r| r.reached == 1 && r.edges_traversed == 0));
    }

    #[test]
    fn single_worker_deterministic() {
        let j = job(
            EngineKind::Simd {
                threads: 1,
                opts: crate::bfs::vectorized::SimdOpts::full(),
                policy: crate::bfs::policy::LayerPolicy::All,
            },
            vec![3, 9],
        );
        let a = Coordinator::new(1).run_job(&j).unwrap();
        let b = Coordinator::new(1).run_job(&j).unwrap();
        for (x, y) in a.runs.iter().zip(b.runs.iter()) {
            assert_eq!(x.reached, y.reached);
            assert_eq!(x.edges_traversed, y.edges_traversed);
        }
    }
}
