//! The root-batching scheduler.
//!
//! A Graph500 job is 64 independent single-root traversals over one shared
//! read-only CSR, so the natural scheduling unit is the **root batch**
//! ([`crate::coordinator::job::BatchPolicy`]: one root by default, up to a
//! fixed width when the job opts into batching). The job runs in the
//! engine API's two phases:
//!
//! 1. **Prepare (once, before any worker spawns).** The engine is
//!    constructed and `prepare`d against the job's graph — building the
//!    shared [`crate::bfs::GraphArtifacts`] (SELL layout, padded-CSR view,
//!    degree stats, the cross-root policy-feedback channel). A bad engine
//!    configuration therefore fails *here*, immediately, instead of racing
//!    through per-thread error plumbing.
//! 2. **Run (per batch).** `workers` threads share the one prepared
//!    instance (`PreparedBfs` is `Sync`) and pull batch indices from a
//!    shared cursor, traversing each batch through
//!    [`crate::bfs::PreparedBfs::run_batch`] until the job drains. Each
//!    root's reported seconds are its equal share of its batch's wall
//!    time; results arrive in root order regardless of completion order.
//!
//! The run phase is **fault-isolated**: each batch traversal runs inside
//! `catch_unwind`, a panicking batch poisons nothing (both shared locks
//! recover), and its roots are retried down a degradation ladder — the
//! job's engine on the counted VPU backend first, the serial reference
//! engine after that — bounded by [`super::job::RunPolicy::max_attempts`].
//! A root that exhausts its attempts becomes a
//! [`super::job::RootOutcome::Failed`] entry; the job itself still returns
//! a well-formed [`JobOutcome`]. Job-level failures (corrupt graph,
//! out-of-range root, unbuildable engine) are rejected up front as
//! [`CoordinatorError`] before any worker spawns.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::Instant;

use super::engine::make_engine;
use super::error::CoordinatorError;
use super::job::{BfsJob, JobOutcome, RootOutcome, RootRun};
use super::metrics::Metrics;
use crate::bfs::serial::SerialLayeredBfs;
use crate::bfs::validate::validate;
use crate::bfs::{BfsEngine, BfsResult, GraphArtifacts, PreparedBfs, RunControl};
use crate::graph::Csr;
use crate::simd::VpuMode;
use crate::Vertex;

/// Lock a mutex, recovering the data if a previous holder panicked. Both
/// structures this guards (the result slots, the artifact cache) are valid
/// after any interrupted write — a panicking worker is contained by
/// `catch_unwind` and must not wedge every later job on a poisoned lock.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// One root's result slot while workers run: unfilled, a finished run, or
/// the error text of the failure that will drive its retry.
type RootSlot = Option<Result<RootRun, String>>;

/// Best-effort text of a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Entries the artifact cache holds at most — a serving deployment repeats
/// jobs over a handful of hot graphs, not hundreds.
const ARTIFACT_CACHE_CAP: usize = 8;

/// One cached per-graph preparation. The durable key is `(content, sigma)`
/// — a 64-bit fingerprint of the graph's degree sequence + adjacency
/// stream ([`Csr::content_hash`]) — so a *reloaded* graph (new `Arc`, same
/// bytes) still hits. `graph` additionally remembers the last allocation
/// the entry served, weakly, as a hash-free identity fast-path.
struct ArtifactCacheEntry {
    graph: Weak<Csr>,
    content: u64,
    sigma: usize,
    artifacts: Arc<GraphArtifacts>,
}

/// How a cache lookup was (or wasn't) served.
enum CacheOutcome {
    /// Same live allocation — no hashing needed.
    IdentityHit,
    /// Same content, different allocation (a reloaded graph).
    ContentHit,
    Miss,
}

/// The L3 driver: runs jobs, keeps metrics.
pub struct Coordinator {
    /// Worker threads per job.
    pub workers: usize,
    metrics: Metrics,
    /// Keyed [`GraphArtifacts`] cache: repeated jobs on the same graph —
    /// the serving scenario — skip layout/stats construction entirely and
    /// keep accumulating the same cross-root
    /// [`crate::bfs::policy::PolicyFeedback`] channel. Keys are **content
    /// addressed** (graph fingerprint + σ), with a `Weak` identity
    /// fast-path per entry, so entries deliberately outlive their graphs:
    /// dropping and reloading a graph between jobs still hits. The vec is
    /// kept in recency order (front = least recently used); the LRU entry
    /// is evicted at [`ARTIFACT_CACHE_CAP`], which bounds the retained
    /// layouts no matter how many distinct graphs a long-lived coordinator
    /// sees.
    artifact_cache: Mutex<Vec<ArtifactCacheEntry>>,
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator {
            workers: workers.max(1),
            metrics: Metrics::default(),
            artifact_cache: Mutex::new(Vec::new()),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The cached artifacts for `(graph, sigma)`, or a fresh entry.
    ///
    /// Lookup order: the identity fast-path first (`Arc::ptr_eq` through
    /// the stored `Weak` — a reused allocation address can never alias a
    /// dropped graph), then the content key ([`Csr::content_hash`],
    /// computed only when identity missed — and *outside* the lock, so
    /// concurrent jobs never serialize behind an O(V + E) hash). A
    /// content hit refreshes the entry's identity fast-path so the
    /// following jobs on the same reloaded `Arc` skip hashing again.
    ///
    /// Every hit (and every insert) moves its entry to the back of the
    /// vec, so the front is always the least-recently-used entry — the one
    /// evicted at capacity.
    fn artifacts_for(&self, graph: &Arc<Csr>, sigma: usize) -> (Arc<GraphArtifacts>, CacheOutcome) {
        // positions rather than references, so a hit can be re-queued
        let identity_pos = |cache: &[ArtifactCacheEntry]| {
            cache.iter().position(|e| {
                e.sigma == sigma
                    && e.graph.upgrade().map(|g| Arc::ptr_eq(&g, graph)).unwrap_or(false)
            })
        };
        // move entry `i` to the MRU end and return its artifacts
        fn touch(cache: &mut Vec<ArtifactCacheEntry>, i: usize) -> Arc<GraphArtifacts> {
            let e = cache.remove(i);
            let artifacts = Arc::clone(&e.artifacts);
            cache.push(e);
            artifacts
        }
        {
            let mut cache = lock_unpoisoned(&self.artifact_cache);
            if let Some(i) = identity_pos(&cache) {
                return (touch(&mut cache, i), CacheOutcome::IdentityHit);
            }
        }
        // hash without the lock, then re-check: another worker may have
        // inserted (or re-pointed) an entry for this graph meanwhile
        let content = graph.content_hash();
        let mut cache = lock_unpoisoned(&self.artifact_cache);
        if let Some(i) = identity_pos(&cache) {
            return (touch(&mut cache, i), CacheOutcome::IdentityHit);
        }
        if let Some(i) = cache.iter().position(|e| e.sigma == sigma && e.content == content) {
            cache[i].graph = Arc::downgrade(graph);
            return (touch(&mut cache, i), CacheOutcome::ContentHit);
        }
        let artifacts = Arc::new(GraphArtifacts::for_graph(graph));
        if cache.len() >= ARTIFACT_CACHE_CAP {
            cache.remove(0);
            self.metrics.record_artifact_cache_eviction();
        }
        cache.push(ArtifactCacheEntry {
            graph: Arc::downgrade(graph),
            content,
            sigma,
            artifacts: Arc::clone(&artifacts),
        });
        (artifacts, CacheOutcome::Miss)
    }

    /// Package one engine result as a [`RootRun`]. Interrupted runs carry
    /// a true visited *prefix* but not a complete BFS tree, so validation
    /// (when the job asks for it) only judges complete traversals.
    ///
    /// Device-lock wait ([`crate::bfs::RunTrace::lock_wait_ns`]) is
    /// subtracted from the measured seconds: a PJRT root queueing behind
    /// another worker's execution did no traversal work during that time,
    /// and counting it would deflate per-root TEPS by the worker count.
    fn root_run(
        job: &BfsJob,
        root: Vertex,
        r: BfsResult,
        seconds: f64,
        prep_share: f64,
    ) -> RootRun {
        let validation = (job.validate && r.trace.status.is_complete())
            .then(|| validate(&job.graph, &r.tree));
        RootRun {
            root,
            // Graph500 TEPS: undirected edges of the
            // reached component ≈ directed scans / 2
            edges_traversed: r.trace.total_edges_scanned() / 2,
            reached: r.tree.reached_count(),
            seconds: (seconds - r.trace.lock_wait_ns as f64 * 1e-9).max(0.0),
            preparation_seconds: prep_share,
            counted_warmup: r.trace.counted_warmup,
            trace: r.trace,
            validation,
        }
    }

    /// Execute a job to completion. `Err` means the *request* could not
    /// run (corrupt graph, bad root, unbuildable engine); once workers
    /// start, every per-root failure is contained inside the returned
    /// [`JobOutcome`].
    pub fn run_job(&self, job: &BfsJob) -> Result<JobOutcome, CoordinatorError> {
        // Phase 0 — reject malformed requests before any engine touches
        // them: a corrupt CSR would otherwise surface as an out-of-bounds
        // panic deep inside whichever engine hit it first.
        job.graph.validate_structure()?;
        let vertices = job.graph.num_vertices();
        if let Some(&root) = job.roots.iter().find(|&&r| r as usize >= vertices) {
            return Err(CoordinatorError::RootOutOfBounds { root, vertices });
        }

        // Phase 1 — fail fast: construct the engine and prepare the graph
        // once, before any worker spawns. The PJRT engine compiles its
        // executable here; the sell engines build their Sell16 layout here
        // — exactly once per *graph content*: repeated jobs on a cached
        // (or reloaded) graph reuse the artifacts and skip the build.
        let t_prep = Instant::now();
        let engine = make_engine(&job.engine).map_err(CoordinatorError::EngineConstruction)?;
        let (artifacts, outcome) = self.artifacts_for(&job.graph, job.engine.sigma_key());
        match outcome {
            CacheOutcome::IdentityHit => self.metrics.record_artifact_cache_hit(false),
            CacheOutcome::ContentHit => self.metrics.record_artifact_cache_hit(true),
            CacheOutcome::Miss => {}
        }
        let prepared = engine
            .prepare_with(&job.graph, Arc::clone(&artifacts))
            .map_err(CoordinatorError::Preparation)?;
        let preparation_seconds = t_prep.elapsed().as_secs_f64();
        let prep_share = preparation_seconds / job.roots.len().max(1) as f64;

        // The job's run control: the caller's handle when one was passed
        // (external cancellation), else a private one. The deadline is
        // armed *after* preparation so it bounds traversal time only, and
        // before any worker spawns so every batch observes it.
        let ctl: Arc<RunControl> = job.run.control.clone().unwrap_or_default();
        if let Some(d) = job.run.deadline {
            ctl.arm_deadline_in(d);
        }

        // Phase 2 — workers share the prepared engine by reference and
        // pull root batches from a common cursor. Each batch runs inside
        // `catch_unwind`: a panicking engine fails its own batch's slots
        // and nothing else.
        let prepared: &dyn PreparedBfs = prepared.as_ref();
        let width = job.batch.width();
        let num_batches = job.batch.num_batches(job.roots.len());
        let cursor = AtomicUsize::new(0);
        let slots: Mutex<Vec<RootSlot>> = Mutex::new(vec![None; job.roots.len()]);

        std::thread::scope(|s| {
            for _ in 0..self.workers.min(num_batches.max(1)) {
                s.spawn(|| loop {
                    let b = cursor.fetch_add(1, Ordering::Relaxed);
                    if b >= num_batches {
                        break;
                    }
                    let start = b * width;
                    let end = (start + width).min(job.roots.len());
                    let batch_roots = &job.roots[start..end];
                    let t0 = Instant::now();
                    let caught = catch_unwind(AssertUnwindSafe(|| match &job.run.fault {
                        Some(plan) => {
                            plan.apply(b, || prepared.run_batch_with(batch_roots, &ctl))
                        }
                        None => prepared.run_batch_with(batch_roots, &ctl),
                    }));
                    // per-batch timing, amortized equally over its roots
                    let seconds = t0.elapsed().as_secs_f64() / batch_roots.len() as f64;
                    let batch: Vec<Result<RootRun, String>> = match caught {
                        Ok(rs) if rs.len() == batch_roots.len() => rs
                            .into_iter()
                            .zip(batch_roots.iter())
                            .map(|(r, &root)| {
                                Ok(Self::root_run(job, root, r, seconds, prep_share))
                            })
                            .collect(),
                        Ok(rs) => {
                            // the old coordinator asserted here; a hole is
                            // now a per-root failure, not a process abort
                            let msg = format!(
                                "engine returned {} results for a {}-root batch",
                                rs.len(),
                                batch_roots.len()
                            );
                            batch_roots.iter().map(|_| Err(msg.clone())).collect()
                        }
                        Err(payload) => {
                            self.metrics.record_worker_panic();
                            let msg =
                                format!("worker panicked: {}", panic_message(payload.as_ref()));
                            batch_roots.iter().map(|_| Err(msg.clone())).collect()
                        }
                    };
                    let mut locked = lock_unpoisoned(&slots);
                    for (i, r) in batch.into_iter().enumerate() {
                        locked[start + i] = Some(r);
                    }
                });
            }
        });

        // Phase 3 — retry failed roots down the degradation ladder,
        // sequentially on this thread (failures are the rare path;
        // isolation matters more than parallelism here). Rung 2 is the
        // job's engine on the counted VPU backend — it sidesteps hardware
        // SIMD faults and, for scalar engines, simply retries. Rung 3+ is
        // the serial reference engine. Fallbacks are prepared lazily, once,
        // against the job's already-built artifacts.
        let slot_results = slots.into_inner().unwrap_or_else(|p| p.into_inner());
        let max_attempts = job.run.max_attempts.max(1);
        let mut counted_rung: Option<Box<dyn PreparedBfs + '_>> = None;
        let mut serial_rung: Option<Box<dyn PreparedBfs + '_>> = None;
        let mut outcomes: Vec<RootOutcome> = Vec::with_capacity(job.roots.len());
        for (i, slot) in slot_results.into_iter().enumerate() {
            let root = job.roots[i];
            let mut attempts = 1usize;
            let mut last =
                slot.unwrap_or_else(|| Err("scheduler left an unfilled slot".to_string()));
            // a sticky injected fault follows its roots through every
            // retry — the attempt-exhaustion scenario of the chaos suite
            let sticky_fault =
                job.run.fault.filter(|p| p.sticky && p.fires_at(i / width));
            while last.is_err() && attempts < max_attempts {
                attempts += 1;
                self.metrics.record_root_retry();
                let rung: Option<&dyn PreparedBfs> = if attempts == 2 {
                    if counted_rung.is_none() {
                        let mut kind = job.engine.clone();
                        kind.set_vpu(VpuMode::Counted);
                        counted_rung = make_engine(&kind).ok().and_then(|e| {
                            e.prepare_with(&job.graph, Arc::clone(&artifacts)).ok()
                        });
                    }
                    counted_rung.as_deref()
                } else {
                    if serial_rung.is_none() {
                        serial_rung = SerialLayeredBfs
                            .prepare_with(&job.graph, Arc::clone(&artifacts))
                            .ok();
                    }
                    serial_rung.as_deref()
                };
                let Some(rung) = rung else {
                    last = Err("fallback engine preparation failed".to_string());
                    continue;
                };
                let t0 = Instant::now();
                let caught = catch_unwind(AssertUnwindSafe(|| match sticky_fault {
                    Some(plan) => {
                        plan.apply(plan.at_batch, || rung.run_batch_with(&[root], &ctl))
                    }
                    None => rung.run_batch_with(&[root], &ctl),
                }));
                let seconds = t0.elapsed().as_secs_f64();
                last = match caught {
                    Ok(mut rs) if rs.len() == 1 => {
                        let r = rs.pop().expect("len checked");
                        Ok(Self::root_run(job, root, r, seconds, prep_share))
                    }
                    Ok(rs) => {
                        Err(format!("retry returned {} results for one root", rs.len()))
                    }
                    Err(payload) => {
                        self.metrics.record_worker_panic();
                        Err(format!("worker panicked: {}", panic_message(payload.as_ref())))
                    }
                };
            }
            match last {
                Ok(run) => {
                    if attempts > 1 {
                        self.metrics.record_degraded_root();
                    }
                    outcomes.push(RootOutcome::Ran(run));
                }
                Err(error) => {
                    self.metrics.record_failed_root();
                    outcomes.push(RootOutcome::Failed { root, error, attempts });
                }
            }
        }

        let all_valid = outcomes.iter().all(|o| match o {
            RootOutcome::Ran(r) => {
                r.validation.as_ref().map(|v| v.all_passed()).unwrap_or(true)
            }
            RootOutcome::Failed { .. } => false,
        });
        let runs: Vec<&RootRun> = outcomes.iter().filter_map(RootOutcome::run).collect();
        self.metrics.record_job(&runs, preparation_seconds, num_batches);
        Ok(JobOutcome { id: job.id, outcomes, all_valid, preparation_seconds, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::coordinator::fault::FaultPlan;
    use crate::coordinator::job::{BatchPolicy, RunPolicy};
    use crate::graph::{Csr, RmatConfig};
    use std::sync::Arc;

    fn job(engine: EngineKind, roots: Vec<u32>) -> BfsJob {
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        BfsJob {
            id: 1,
            graph: g,
            roots,
            engine,
            validate: true,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        }
    }

    #[test]
    fn runs_all_roots_in_order() {
        let j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.runs().count(), 8);
        for (i, r) in out.runs().enumerate() {
            assert_eq!(r.root, j.roots[i]);
        }
        assert!(out.all_valid);
    }

    #[test]
    fn lock_wait_is_excluded_from_root_seconds() {
        let j = job(EngineKind::SerialLayered, vec![0]);
        let n = j.graph.num_vertices();
        let mut pred = vec![crate::PRED_INFINITY; n];
        pred[0] = 0;
        let mk = |lock_wait_ns: u64| BfsResult {
            tree: crate::bfs::BfsTree::new(0, pred.clone()),
            trace: crate::bfs::RunTrace { lock_wait_ns, ..Default::default() },
        };
        // half a second of queueing inside a 2-second measurement: only
        // the executing 1.5 s count toward the root
        let r = Coordinator::root_run(&j, 0, mk(500_000_000), 2.0, 0.0);
        assert!((r.seconds - 1.5).abs() < 1e-12, "got {}", r.seconds);
        // no lock wait → unchanged
        let r = Coordinator::root_run(&j, 0, mk(0), 2.0, 0.0);
        assert!((r.seconds - 2.0).abs() < 1e-12);
        // a wait longer than the measurement clamps at zero rather than
        // going negative
        let r = Coordinator::root_run(&j, 0, mk(5_000_000_000), 2.0, 0.0);
        assert_eq!(r.seconds, 0.0);
    }

    #[test]
    fn metrics_accumulate() {
        let c = Coordinator::new(2);
        let j = job(EngineKind::NonSimd { threads: 1 }, vec![0, 1, 2, 3]);
        c.run_job(&j).unwrap();
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 2);
        assert_eq!(m.roots, 8);
        assert_eq!(m.batches, 8, "per-root policy: one batch per root");
        assert!(m.total_seconds > 0.0);
    }

    #[test]
    fn isolated_roots_yield_zero_edges() {
        // roots with no edges produce reached==1, edges==0 (the famous
        // zero-TEPS entries of §5.3)
        let j = job(EngineKind::SerialLayered, (0..20).collect());
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert!(out.runs().any(|r| r.reached == 1 && r.edges_traversed == 0));
    }

    #[test]
    fn batched_job_matches_per_root_job() {
        // the batch policy changes scheduling, never results: same roots,
        // same trees (compared as reached/edge counts), for a looping
        // engine and for the genuinely batched MS engine
        for engine_name in ["serial", "hybrid-sell-ms"] {
            let engine = EngineKind::parse(engine_name, 2, "artifacts").unwrap();
            let mut j = job(engine, (0..10).collect());
            let per_root = Coordinator::new(2).run_job(&j).unwrap();
            j.batch = BatchPolicy::Fixed(4);
            let batched = Coordinator::new(2).run_job(&j).unwrap();
            assert!(per_root.all_valid && batched.all_valid, "{engine_name}");
            assert_eq!(per_root.runs().count(), batched.runs().count());
            for (a, b) in per_root.runs().zip(batched.runs()) {
                assert_eq!(a.root, b.root, "{engine_name}");
                assert_eq!(a.reached, b.reached, "{engine_name}");
            }
        }
    }

    #[test]
    fn batch_widths_cover_all_roots() {
        // widths 1, 16 and a non-multiple of the root count all fill
        // every result slot exactly once
        for width in [1usize, 3, 16] {
            let mut j = job(
                EngineKind::parse("hybrid-sell-ms", 1, "artifacts").unwrap(),
                (0..10).collect(),
            );
            j.batch = if width == 1 { BatchPolicy::PerRoot } else { BatchPolicy::Fixed(width) };
            let out = Coordinator::new(3).run_job(&j).unwrap();
            assert_eq!(out.runs().count(), 10, "width {width}");
            for (i, r) in out.runs().enumerate() {
                assert_eq!(r.root, j.roots[i], "width {width}");
                assert!(r.seconds >= 0.0);
            }
            assert!(out.all_valid, "width {width}");
        }
    }

    #[test]
    fn batch_metrics_count_batches_not_roots() {
        let c = Coordinator::new(2);
        let mut j = job(EngineKind::SerialLayered, (0..10).collect());
        j.batch = BatchPolicy::Fixed(4);
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.roots, 10);
        assert_eq!(m.batches, 3, "10 roots in batches of 4 → 3 batches");
    }

    #[test]
    fn sell_layout_built_exactly_once_per_job() {
        // the tentpole guarantee: a multi-root sell job constructs its
        // Sell16 layout once, in the prepare phase, no matter how many
        // roots or workers run (PR 1 rebuilt it per root — 64× per job)
        let j = job(
            EngineKind::parse("sell", 2, "artifacts").unwrap(),
            (0..8).collect(),
        );
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.artifacts.sell_builds(), 1, "{:?}", out.artifacts);
        assert!(out.all_valid);
        assert!(out.preparation_seconds > 0.0);
        for r in out.runs() {
            assert!((r.preparation_seconds - out.preparation_seconds / 8.0).abs() < 1e-12);
        }
        // the cross-root feedback channel saw every root
        assert_eq!(out.artifacts.feedback().roots_done(), 8);
    }

    #[test]
    fn artifact_cache_reuses_preparation_across_jobs() {
        // the serving scenario: repeated jobs on one hot graph share one
        // prepared GraphArtifacts — layout built once, feedback persistent
        let c = Coordinator::new(2);
        let el = RmatConfig::graph500(9, 8).generate(61);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        let engine = EngineKind::parse("sell", 2, "artifacts").unwrap();
        let j1 = BfsJob {
            id: 1,
            graph: Arc::clone(&g),
            roots: (0..4).collect(),
            engine,
            validate: true,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let j2 = BfsJob { id: 2, ..j1.clone() };
        let a = c.run_job(&j1).unwrap();
        let b = c.run_job(&j2).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert_eq!(b.artifacts.sell_builds(), 1, "layout must not rebuild on a cache hit");
        // the cross-root feedback channel kept accumulating across jobs
        assert_eq!(b.artifacts.feedback().roots_done(), 8);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 0, "same Arc → identity fast-path");
        assert!(b.all_valid);
    }

    #[test]
    fn artifact_cache_hits_reloaded_graph_by_content() {
        // the ROADMAP item: dropping a graph and reloading it from the
        // same source must hit the cache — the durable key is the content
        // fingerprint, not the allocation
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
        let mk = |graph: Arc<Csr>| BfsJob {
            id: 0,
            graph,
            roots: vec![0, 1],
            engine: engine.clone(),
            validate: false,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let a = {
            // this Arc is dropped before the second job — only content
            // can match it
            let g1 = Arc::new(Csr::from_edge_list(9, &el));
            c.run_job(&mk(Arc::clone(&g1))).unwrap()
        };
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        let b = c.run_job(&mk(Arc::clone(&g2))).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts), "reloaded graph must hit");
        assert_eq!(b.artifacts.sell_builds(), 1);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 1);
        // a third job on the same reloaded Arc takes the refreshed
        // identity fast-path — a hit, but not a content hit
        c.run_job(&mk(Arc::clone(&g2))).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2);
        assert_eq!(m.artifact_cache_content_hits, 1);
    }

    #[test]
    fn artifact_cache_distinguishes_content_and_sigma() {
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let g1 = Arc::new(Csr::from_edge_list(9, &el));
        // equal content, different identity — must alias via the content key
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        // different content — must not alias
        let el3 = RmatConfig::graph500(9, 8).generate(63);
        let g3 = Arc::new(Csr::from_edge_list(9, &el3));
        let mk = |graph: &Arc<Csr>, sigma: usize| {
            let mut engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
            if let EngineKind::Sell { sigma: s, .. } = &mut engine {
                *s = sigma;
            }
            BfsJob {
                id: 0,
                graph: Arc::clone(graph),
                roots: vec![0, 1],
                engine,
                validate: false,
                batch: BatchPolicy::PerRoot,
                run: RunPolicy::default(),
            }
        };
        let a = c.run_job(&mk(&g1, 64)).unwrap();
        let b = c.run_job(&mk(&g2, 64)).unwrap(); // same content → content hit
        let d = c.run_job(&mk(&g1, 128)).unwrap(); // different σ → miss
        let e = c.run_job(&mk(&g3, 64)).unwrap(); // different content → miss
        // g2's content hit re-pointed the identity fast-path at g2, so g1
        // matches by content again
        let f = c.run_job(&mk(&g1, 64)).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &d.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &e.artifacts));
        assert!(Arc::ptr_eq(&a.artifacts, &f.artifacts));
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2, "b and f hit");
        assert_eq!(m.artifact_cache_content_hits, 2, "both via the content key");
    }

    #[test]
    fn bad_engine_fails_fast_before_workers() {
        // a PJRT config with no artifacts errors in the prepare phase
        let j = job(
            EngineKind::Pjrt { artifact_dir: "/nonexistent-artifacts".into() },
            vec![0, 1],
        );
        let err = Coordinator::new(2).run_job(&j).unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn single_worker_deterministic() {
        let j = job(
            EngineKind::Simd {
                threads: 1,
                opts: crate::bfs::vectorized::SimdOpts::full(),
                policy: crate::bfs::policy::LayerPolicy::All,
                vpu: crate::simd::VpuMode::default(),
            },
            vec![3, 9],
        );
        let a = Coordinator::new(1).run_job(&j).unwrap();
        let b = Coordinator::new(1).run_job(&j).unwrap();
        for (x, y) in a.runs().zip(b.runs()) {
            assert_eq!(x.reached, y.reached);
            assert_eq!(x.edges_traversed, y.edges_traversed);
        }
    }

    #[test]
    fn out_of_range_root_is_rejected() {
        let j = job(EngineKind::SerialLayered, vec![0, 1_000_000]);
        let err = Coordinator::new(1).run_job(&j).unwrap_err();
        assert!(matches!(err, CoordinatorError::RootOutOfBounds { root: 1_000_000, .. }));
    }

    #[test]
    fn injected_panic_is_contained_and_retried() {
        // batch 1 (root index 1) panics once; the coordinator catches it,
        // retries the root on the degradation ladder, and both the job and
        // the coordinator (its locks included) stay fully usable
        let mut j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3]);
        j.run.fault = Some(FaultPlan::panic_at(1));
        let c = Coordinator::new(2);
        let out = c.run_job(&j).unwrap();
        assert_eq!(out.outcomes.len(), 4);
        assert!(out.outcomes.iter().all(|o| !o.is_failed()), "one-shot fault recovers");
        assert!(out.all_valid, "retried root still validates against the oracle");
        let m = c.metrics().snapshot();
        assert_eq!(m.worker_panics, 1);
        assert_eq!(m.root_retries, 1);
        assert_eq!(m.degraded_roots, 1);
        assert_eq!(m.failed_roots, 0);
        let ok = c.run_job(&job(EngineKind::SerialLayered, vec![0])).unwrap();
        assert!(ok.all_valid, "coordinator survives for the next job");
    }

    #[test]
    fn artifact_cache_evicts_least_recently_used() {
        let c = Coordinator::new(1);
        let mk_graph = |seed: u64| {
            let el = RmatConfig::graph500(7, 8).generate(seed);
            Arc::new(Csr::from_edge_list(7, &el))
        };
        let mk_job = |g: &Arc<Csr>| BfsJob {
            id: 0,
            graph: Arc::clone(g),
            roots: vec![0],
            engine: EngineKind::SerialLayered,
            validate: false,
            batch: BatchPolicy::PerRoot,
            run: RunPolicy::default(),
        };
        let graphs: Vec<_> =
            (0..=ARTIFACT_CACHE_CAP as u64).map(|s| mk_graph(100 + s)).collect();
        // fill the cache exactly to capacity
        let first = c.run_job(&mk_job(&graphs[0])).unwrap();
        for g in &graphs[1..ARTIFACT_CACHE_CAP] {
            c.run_job(&mk_job(g)).unwrap();
        }
        assert_eq!(c.metrics().snapshot().artifact_cache_evictions, 0);
        // touch graph 0 — it becomes the most recently used entry
        let touched = c.run_job(&mk_job(&graphs[0])).unwrap();
        assert!(Arc::ptr_eq(&first.artifacts, &touched.artifacts));
        // one more graph evicts the LRU entry: graph 1, not the
        // just-touched graph 0 (insertion order would evict 0)
        c.run_job(&mk_job(&graphs[ARTIFACT_CACHE_CAP])).unwrap();
        assert_eq!(c.metrics().snapshot().artifact_cache_evictions, 1);
        let again = c.run_job(&mk_job(&graphs[0])).unwrap();
        assert!(
            Arc::ptr_eq(&first.artifacts, &again.artifacts),
            "recently-used entry survived the eviction"
        );
        // graph 1 really is gone: rerunning it misses (no hit recorded)
        // and evicts the next LRU entry in turn
        let hits_before = c.metrics().snapshot().artifact_cache_hits;
        c.run_job(&mk_job(&graphs[1])).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, hits_before, "evicted entry must miss");
        assert_eq!(m.artifact_cache_evictions, 2);
    }
}
