//! The root-batching scheduler.
//!
//! A Graph500 job is 64 independent single-root traversals over one shared
//! read-only CSR, so the natural batch unit is the root. The job runs in
//! the engine API's two phases:
//!
//! 1. **Prepare (once, before any worker spawns).** The engine is
//!    constructed and `prepare`d against the job's graph — building the
//!    shared [`crate::bfs::GraphArtifacts`] (SELL layout, padded-CSR view,
//!    degree stats, the cross-root policy-feedback channel). A bad engine
//!    configuration therefore fails *here*, immediately, instead of racing
//!    through per-thread error plumbing.
//! 2. **Run (per root).** `workers` threads share the one prepared
//!    instance (`PreparedBfs` is `Sync`) and pull root indices from a
//!    shared cursor until the job drains. Results arrive in root order
//!    regardless of completion order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use anyhow::Result;

use super::engine::make_engine;
use super::job::{BfsJob, JobOutcome, RootRun};
use super::metrics::Metrics;
use crate::bfs::validate::validate;
use crate::bfs::{GraphArtifacts, PreparedBfs};
use crate::graph::Csr;

/// Entries the artifact cache holds at most — a serving deployment repeats
/// jobs over a handful of hot graphs, not hundreds.
const ARTIFACT_CACHE_CAP: usize = 8;

/// One cached per-graph preparation: the graph it belongs to (held weakly —
/// the cache must not keep dropped graphs alive) plus the σ the entry was
/// keyed under.
struct ArtifactCacheEntry {
    graph: Weak<Csr>,
    sigma: usize,
    artifacts: Arc<GraphArtifacts>,
}

/// The L3 driver: runs jobs, keeps metrics.
pub struct Coordinator {
    /// Worker threads per job.
    pub workers: usize,
    metrics: Metrics,
    /// Keyed [`GraphArtifacts`] cache (graph identity + σ): repeated jobs
    /// on the same graph — the serving scenario — skip layout/stats
    /// construction entirely and keep accumulating the same cross-root
    /// [`crate::bfs::policy::PolicyFeedback`] channel. Insertion order,
    /// oldest evicted at [`ARTIFACT_CACHE_CAP`]. Entries whose graph was
    /// dropped are pruned on the next `run_job` (every job passes through
    /// the cache), so a fully idle coordinator can pin at most
    /// [`ARTIFACT_CACHE_CAP`] dead graphs' artifacts until its next job.
    artifact_cache: Mutex<Vec<ArtifactCacheEntry>>,
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator {
            workers: workers.max(1),
            metrics: Metrics::default(),
            artifact_cache: Mutex::new(Vec::new()),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The cached artifacts for `(graph, sigma)`, or a fresh entry.
    /// Identity is the graph's allocation (`Arc::ptr_eq`), verified through
    /// the stored `Weak` so a reused allocation address can never alias a
    /// dropped graph. Returns `(artifacts, was_cached)`.
    fn artifacts_for(&self, graph: &Arc<Csr>, sigma: usize) -> (Arc<GraphArtifacts>, bool) {
        let mut cache = self.artifact_cache.lock().unwrap();
        cache.retain(|e| e.graph.strong_count() > 0);
        if let Some(e) = cache.iter().find(|e| {
            e.sigma == sigma
                && e.graph.upgrade().map(|g| Arc::ptr_eq(&g, graph)).unwrap_or(false)
        }) {
            return (Arc::clone(&e.artifacts), true);
        }
        let artifacts = Arc::new(GraphArtifacts::for_graph(graph));
        if cache.len() >= ARTIFACT_CACHE_CAP {
            cache.remove(0);
        }
        cache.push(ArtifactCacheEntry {
            graph: Arc::downgrade(graph),
            sigma,
            artifacts: Arc::clone(&artifacts),
        });
        (artifacts, false)
    }

    /// Execute a job to completion.
    pub fn run_job(&self, job: &BfsJob) -> Result<JobOutcome> {
        // Phase 1 — fail fast: construct the engine and prepare the graph
        // once, before any worker spawns. The PJRT engine compiles its
        // executable here; the sell engines build their Sell16 layout here
        // — exactly once per *graph*: repeated jobs on a cached graph
        // reuse the artifacts and skip the build entirely.
        let t_prep = Instant::now();
        let engine = make_engine(&job.engine)?;
        let (artifacts, cached) = self.artifacts_for(&job.graph, job.engine.sigma_key());
        if cached {
            self.metrics.record_artifact_cache_hit();
        }
        let prepared = engine.prepare_with(&job.graph, Arc::clone(&artifacts))?;
        let preparation_seconds = t_prep.elapsed().as_secs_f64();
        let prep_share = preparation_seconds / job.roots.len().max(1) as f64;

        // Phase 2 — workers share the prepared engine by reference and
        // pull roots from a common cursor.
        let prepared: &dyn PreparedBfs = prepared.as_ref();
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<RootRun>>> = Mutex::new(vec![None; job.roots.len()]);

        std::thread::scope(|s| {
            for _ in 0..self.workers.min(job.roots.len().max(1)) {
                s.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= job.roots.len() {
                        break;
                    }
                    let root = job.roots[i];
                    let t0 = Instant::now();
                    let r = prepared.run(root);
                    let seconds = t0.elapsed().as_secs_f64();
                    let validation = job.validate.then(|| validate(&job.graph, &r.tree));
                    let run = RootRun {
                        root,
                        // Graph500 TEPS: undirected edges of the reached
                        // component ≈ directed scans / 2
                        edges_traversed: r.trace.total_edges_scanned() / 2,
                        reached: r.tree.reached_count(),
                        seconds,
                        preparation_seconds: prep_share,
                        trace: r.trace,
                        validation,
                    };
                    results.lock().unwrap()[i] = Some(run);
                });
            }
        });

        let runs: Vec<RootRun> = results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("worker left a hole"))
            .collect();
        let all_valid = runs
            .iter()
            .all(|r| r.validation.as_ref().map(|v| v.all_passed()).unwrap_or(true));
        self.metrics.record_job(&runs, preparation_seconds);
        Ok(JobOutcome { id: job.id, runs, all_valid, preparation_seconds, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::graph::{Csr, RmatConfig};
    use std::sync::Arc;

    fn job(engine: EngineKind, roots: Vec<u32>) -> BfsJob {
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        BfsJob { id: 1, graph: g, roots, engine, validate: true }
    }

    #[test]
    fn runs_all_roots_in_order() {
        let j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.runs.len(), 8);
        for (i, r) in out.runs.iter().enumerate() {
            assert_eq!(r.root, j.roots[i]);
        }
        assert!(out.all_valid);
    }

    #[test]
    fn metrics_accumulate() {
        let c = Coordinator::new(2);
        let j = job(EngineKind::NonSimd { threads: 1 }, vec![0, 1, 2, 3]);
        c.run_job(&j).unwrap();
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 2);
        assert_eq!(m.roots, 8);
        assert!(m.total_seconds > 0.0);
    }

    #[test]
    fn isolated_roots_yield_zero_edges() {
        // roots with no edges produce reached==1, edges==0 (the famous
        // zero-TEPS entries of §5.3)
        let j = job(EngineKind::SerialLayered, (0..20).collect());
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert!(out.runs.iter().any(|r| r.reached == 1 && r.edges_traversed == 0));
    }

    #[test]
    fn sell_layout_built_exactly_once_per_job() {
        // the tentpole guarantee: a multi-root sell job constructs its
        // Sell16 layout once, in the prepare phase, no matter how many
        // roots or workers run (PR 1 rebuilt it per root — 64× per job)
        let j = job(
            EngineKind::parse("sell", 2, "artifacts").unwrap(),
            (0..8).collect(),
        );
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.artifacts.sell_builds(), 1, "{:?}", out.artifacts);
        assert!(out.all_valid);
        assert!(out.preparation_seconds > 0.0);
        for r in &out.runs {
            assert!((r.preparation_seconds - out.preparation_seconds / 8.0).abs() < 1e-12);
        }
        // the cross-root feedback channel saw every root
        assert_eq!(out.artifacts.feedback().roots_done(), 8);
    }

    #[test]
    fn artifact_cache_reuses_preparation_across_jobs() {
        // the serving scenario: repeated jobs on one hot graph share one
        // prepared GraphArtifacts — layout built once, feedback persistent
        let c = Coordinator::new(2);
        let el = RmatConfig::graph500(9, 8).generate(61);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        let engine = EngineKind::parse("sell", 2, "artifacts").unwrap();
        let j1 = BfsJob {
            id: 1,
            graph: Arc::clone(&g),
            roots: (0..4).collect(),
            engine,
            validate: true,
        };
        let j2 = BfsJob { id: 2, ..j1.clone() };
        let a = c.run_job(&j1).unwrap();
        let b = c.run_job(&j2).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert_eq!(b.artifacts.sell_builds(), 1, "layout must not rebuild on a cache hit");
        // the cross-root feedback channel kept accumulating across jobs
        assert_eq!(b.artifacts.feedback().roots_done(), 8);
        assert_eq!(c.metrics().snapshot().artifact_cache_hits, 1);
        assert!(b.all_valid);
    }

    #[test]
    fn artifact_cache_distinguishes_graph_and_sigma() {
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let g1 = Arc::new(Csr::from_edge_list(9, &el));
        // equal content, different identity — must not alias
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        let mk = |graph: &Arc<Csr>, sigma: usize| {
            let mut engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
            if let EngineKind::Sell { sigma: s, .. } = &mut engine {
                *s = sigma;
            }
            BfsJob {
                id: 0,
                graph: Arc::clone(graph),
                roots: vec![0, 1],
                engine,
                validate: false,
            }
        };
        let a = c.run_job(&mk(&g1, 64)).unwrap();
        let b = c.run_job(&mk(&g2, 64)).unwrap(); // different graph → miss
        let d = c.run_job(&mk(&g1, 128)).unwrap(); // different σ → miss
        let e = c.run_job(&mk(&g1, 64)).unwrap(); // same graph + σ → hit
        assert!(!Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &d.artifacts));
        assert!(Arc::ptr_eq(&a.artifacts, &e.artifacts));
        assert_eq!(c.metrics().snapshot().artifact_cache_hits, 1);
    }

    #[test]
    fn bad_engine_fails_fast_before_workers() {
        // a PJRT config with no artifacts errors in the prepare phase
        let j = job(
            EngineKind::Pjrt { artifact_dir: "/nonexistent-artifacts".into() },
            vec![0, 1],
        );
        let err = Coordinator::new(2).run_job(&j).unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn single_worker_deterministic() {
        let j = job(
            EngineKind::Simd {
                threads: 1,
                opts: crate::bfs::vectorized::SimdOpts::full(),
                policy: crate::bfs::policy::LayerPolicy::All,
            },
            vec![3, 9],
        );
        let a = Coordinator::new(1).run_job(&j).unwrap();
        let b = Coordinator::new(1).run_job(&j).unwrap();
        for (x, y) in a.runs.iter().zip(b.runs.iter()) {
            assert_eq!(x.reached, y.reached);
            assert_eq!(x.edges_traversed, y.edges_traversed);
        }
    }
}
