//! The root-batching scheduler.
//!
//! A Graph500 job is 64 independent single-root traversals over one shared
//! read-only CSR, so the natural scheduling unit is the **root batch**
//! ([`crate::coordinator::job::BatchPolicy`]: one root by default, up to a
//! fixed width when the job opts into batching). The job runs in the
//! engine API's two phases:
//!
//! 1. **Prepare (once, before any worker spawns).** The engine is
//!    constructed and `prepare`d against the job's graph — building the
//!    shared [`crate::bfs::GraphArtifacts`] (SELL layout, padded-CSR view,
//!    degree stats, the cross-root policy-feedback channel). A bad engine
//!    configuration therefore fails *here*, immediately, instead of racing
//!    through per-thread error plumbing.
//! 2. **Run (per batch).** `workers` threads share the one prepared
//!    instance (`PreparedBfs` is `Sync`) and pull batch indices from a
//!    shared cursor, traversing each batch through
//!    [`crate::bfs::PreparedBfs::run_batch`] until the job drains. Each
//!    root's reported seconds are its equal share of its batch's wall
//!    time; results arrive in root order regardless of completion order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Instant;

use anyhow::Result;

use super::engine::make_engine;
use super::job::{BfsJob, JobOutcome, RootRun};
use super::metrics::Metrics;
use crate::bfs::validate::validate;
use crate::bfs::{GraphArtifacts, PreparedBfs};
use crate::graph::Csr;

/// Entries the artifact cache holds at most — a serving deployment repeats
/// jobs over a handful of hot graphs, not hundreds.
const ARTIFACT_CACHE_CAP: usize = 8;

/// One cached per-graph preparation. The durable key is `(content, sigma)`
/// — a 64-bit fingerprint of the graph's degree sequence + adjacency
/// stream ([`Csr::content_hash`]) — so a *reloaded* graph (new `Arc`, same
/// bytes) still hits. `graph` additionally remembers the last allocation
/// the entry served, weakly, as a hash-free identity fast-path.
struct ArtifactCacheEntry {
    graph: Weak<Csr>,
    content: u64,
    sigma: usize,
    artifacts: Arc<GraphArtifacts>,
}

/// How a cache lookup was (or wasn't) served.
enum CacheOutcome {
    /// Same live allocation — no hashing needed.
    IdentityHit,
    /// Same content, different allocation (a reloaded graph).
    ContentHit,
    Miss,
}

/// The L3 driver: runs jobs, keeps metrics.
pub struct Coordinator {
    /// Worker threads per job.
    pub workers: usize,
    metrics: Metrics,
    /// Keyed [`GraphArtifacts`] cache: repeated jobs on the same graph —
    /// the serving scenario — skip layout/stats construction entirely and
    /// keep accumulating the same cross-root
    /// [`crate::bfs::policy::PolicyFeedback`] channel. Keys are **content
    /// addressed** (graph fingerprint + σ), with a `Weak` identity
    /// fast-path per entry, so entries deliberately outlive their graphs:
    /// dropping and reloading a graph between jobs still hits. Insertion
    /// order, oldest evicted at [`ARTIFACT_CACHE_CAP`], which bounds the
    /// retained layouts.
    artifact_cache: Mutex<Vec<ArtifactCacheEntry>>,
}

impl Coordinator {
    pub fn new(workers: usize) -> Self {
        Coordinator {
            workers: workers.max(1),
            metrics: Metrics::default(),
            artifact_cache: Mutex::new(Vec::new()),
        }
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// The cached artifacts for `(graph, sigma)`, or a fresh entry.
    ///
    /// Lookup order: the identity fast-path first (`Arc::ptr_eq` through
    /// the stored `Weak` — a reused allocation address can never alias a
    /// dropped graph), then the content key ([`Csr::content_hash`],
    /// computed only when identity missed — and *outside* the lock, so
    /// concurrent jobs never serialize behind an O(V + E) hash). A
    /// content hit refreshes the entry's identity fast-path so the
    /// following jobs on the same reloaded `Arc` skip hashing again.
    fn artifacts_for(&self, graph: &Arc<Csr>, sigma: usize) -> (Arc<GraphArtifacts>, CacheOutcome) {
        let identity_hit = |cache: &[ArtifactCacheEntry]| {
            cache
                .iter()
                .find(|e| {
                    e.sigma == sigma
                        && e.graph.upgrade().map(|g| Arc::ptr_eq(&g, graph)).unwrap_or(false)
                })
                .map(|e| Arc::clone(&e.artifacts))
        };
        if let Some(artifacts) = identity_hit(&self.artifact_cache.lock().unwrap()) {
            return (artifacts, CacheOutcome::IdentityHit);
        }
        // hash without the lock, then re-check: another worker may have
        // inserted (or re-pointed) an entry for this graph meanwhile
        let content = graph.content_hash();
        let mut cache = self.artifact_cache.lock().unwrap();
        if let Some(artifacts) = identity_hit(&cache) {
            return (artifacts, CacheOutcome::IdentityHit);
        }
        if let Some(e) = cache
            .iter_mut()
            .find(|e| e.sigma == sigma && e.content == content)
        {
            e.graph = Arc::downgrade(graph);
            return (Arc::clone(&e.artifacts), CacheOutcome::ContentHit);
        }
        let artifacts = Arc::new(GraphArtifacts::for_graph(graph));
        if cache.len() >= ARTIFACT_CACHE_CAP {
            cache.remove(0);
        }
        cache.push(ArtifactCacheEntry {
            graph: Arc::downgrade(graph),
            content,
            sigma,
            artifacts: Arc::clone(&artifacts),
        });
        (artifacts, CacheOutcome::Miss)
    }

    /// Execute a job to completion.
    pub fn run_job(&self, job: &BfsJob) -> Result<JobOutcome> {
        // Phase 1 — fail fast: construct the engine and prepare the graph
        // once, before any worker spawns. The PJRT engine compiles its
        // executable here; the sell engines build their Sell16 layout here
        // — exactly once per *graph content*: repeated jobs on a cached
        // (or reloaded) graph reuse the artifacts and skip the build.
        let t_prep = Instant::now();
        let engine = make_engine(&job.engine)?;
        let (artifacts, outcome) = self.artifacts_for(&job.graph, job.engine.sigma_key());
        match outcome {
            CacheOutcome::IdentityHit => self.metrics.record_artifact_cache_hit(false),
            CacheOutcome::ContentHit => self.metrics.record_artifact_cache_hit(true),
            CacheOutcome::Miss => {}
        }
        let prepared = engine.prepare_with(&job.graph, Arc::clone(&artifacts))?;
        let preparation_seconds = t_prep.elapsed().as_secs_f64();
        let prep_share = preparation_seconds / job.roots.len().max(1) as f64;

        // Phase 2 — workers share the prepared engine by reference and
        // pull root batches from a common cursor.
        let prepared: &dyn PreparedBfs = prepared.as_ref();
        let width = job.batch.width();
        let num_batches = job.batch.num_batches(job.roots.len());
        let cursor = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<RootRun>>> = Mutex::new(vec![None; job.roots.len()]);

        std::thread::scope(|s| {
            for _ in 0..self.workers.min(num_batches.max(1)) {
                s.spawn(|| loop {
                    let b = cursor.fetch_add(1, Ordering::Relaxed);
                    if b >= num_batches {
                        break;
                    }
                    let start = b * width;
                    let end = (start + width).min(job.roots.len());
                    let batch_roots = &job.roots[start..end];
                    let t0 = Instant::now();
                    let batch_results = prepared.run_batch(batch_roots);
                    // per-batch timing, amortized equally over its roots
                    let seconds = t0.elapsed().as_secs_f64() / batch_roots.len() as f64;
                    assert_eq!(
                        batch_results.len(),
                        batch_roots.len(),
                        "run_batch must return one result per root"
                    );
                    let runs: Vec<RootRun> = batch_results
                        .into_iter()
                        .zip(batch_roots.iter())
                        .map(|(r, &root)| {
                            let validation =
                                job.validate.then(|| validate(&job.graph, &r.tree));
                            RootRun {
                                root,
                                // Graph500 TEPS: undirected edges of the
                                // reached component ≈ directed scans / 2
                                edges_traversed: r.trace.total_edges_scanned() / 2,
                                reached: r.tree.reached_count(),
                                seconds,
                                preparation_seconds: prep_share,
                                counted_warmup: r.trace.counted_warmup,
                                trace: r.trace,
                                validation,
                            }
                        })
                        .collect();
                    let mut slots = results.lock().unwrap();
                    for (i, run) in runs.into_iter().enumerate() {
                        slots[start + i] = Some(run);
                    }
                });
            }
        });

        let runs: Vec<RootRun> = results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("worker left a hole"))
            .collect();
        let all_valid = runs
            .iter()
            .all(|r| r.validation.as_ref().map(|v| v.all_passed()).unwrap_or(true));
        self.metrics.record_job(&runs, preparation_seconds, num_batches);
        Ok(JobOutcome { id: job.id, runs, all_valid, preparation_seconds, artifacts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::coordinator::job::BatchPolicy;
    use crate::graph::{Csr, RmatConfig};
    use std::sync::Arc;

    fn job(engine: EngineKind, roots: Vec<u32>) -> BfsJob {
        let el = RmatConfig::graph500(9, 8).generate(60);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        BfsJob { id: 1, graph: g, roots, engine, validate: true, batch: BatchPolicy::PerRoot }
    }

    #[test]
    fn runs_all_roots_in_order() {
        let j = job(EngineKind::SerialLayered, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.runs.len(), 8);
        for (i, r) in out.runs.iter().enumerate() {
            assert_eq!(r.root, j.roots[i]);
        }
        assert!(out.all_valid);
    }

    #[test]
    fn metrics_accumulate() {
        let c = Coordinator::new(2);
        let j = job(EngineKind::NonSimd { threads: 1 }, vec![0, 1, 2, 3]);
        c.run_job(&j).unwrap();
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.jobs, 2);
        assert_eq!(m.roots, 8);
        assert_eq!(m.batches, 8, "per-root policy: one batch per root");
        assert!(m.total_seconds > 0.0);
    }

    #[test]
    fn isolated_roots_yield_zero_edges() {
        // roots with no edges produce reached==1, edges==0 (the famous
        // zero-TEPS entries of §5.3)
        let j = job(EngineKind::SerialLayered, (0..20).collect());
        let out = Coordinator::new(2).run_job(&j).unwrap();
        assert!(out.runs.iter().any(|r| r.reached == 1 && r.edges_traversed == 0));
    }

    #[test]
    fn batched_job_matches_per_root_job() {
        // the batch policy changes scheduling, never results: same roots,
        // same trees (compared as reached/edge counts), for a looping
        // engine and for the genuinely batched MS engine
        for engine_name in ["serial", "hybrid-sell-ms"] {
            let engine = EngineKind::parse(engine_name, 2, "artifacts").unwrap();
            let mut j = job(engine, (0..10).collect());
            let per_root = Coordinator::new(2).run_job(&j).unwrap();
            j.batch = BatchPolicy::Fixed(4);
            let batched = Coordinator::new(2).run_job(&j).unwrap();
            assert!(per_root.all_valid && batched.all_valid, "{engine_name}");
            assert_eq!(per_root.runs.len(), batched.runs.len());
            for (a, b) in per_root.runs.iter().zip(batched.runs.iter()) {
                assert_eq!(a.root, b.root, "{engine_name}");
                assert_eq!(a.reached, b.reached, "{engine_name}");
            }
        }
    }

    #[test]
    fn batch_widths_cover_all_roots() {
        // widths 1, 16 and a non-multiple of the root count all fill
        // every result slot exactly once
        for width in [1usize, 3, 16] {
            let mut j = job(
                EngineKind::parse("hybrid-sell-ms", 1, "artifacts").unwrap(),
                (0..10).collect(),
            );
            j.batch = if width == 1 { BatchPolicy::PerRoot } else { BatchPolicy::Fixed(width) };
            let out = Coordinator::new(3).run_job(&j).unwrap();
            assert_eq!(out.runs.len(), 10, "width {width}");
            for (i, r) in out.runs.iter().enumerate() {
                assert_eq!(r.root, j.roots[i], "width {width}");
                assert!(r.seconds >= 0.0);
            }
            assert!(out.all_valid, "width {width}");
        }
    }

    #[test]
    fn batch_metrics_count_batches_not_roots() {
        let c = Coordinator::new(2);
        let mut j = job(EngineKind::SerialLayered, (0..10).collect());
        j.batch = BatchPolicy::Fixed(4);
        c.run_job(&j).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.roots, 10);
        assert_eq!(m.batches, 3, "10 roots in batches of 4 → 3 batches");
    }

    #[test]
    fn sell_layout_built_exactly_once_per_job() {
        // the tentpole guarantee: a multi-root sell job constructs its
        // Sell16 layout once, in the prepare phase, no matter how many
        // roots or workers run (PR 1 rebuilt it per root — 64× per job)
        let j = job(
            EngineKind::parse("sell", 2, "artifacts").unwrap(),
            (0..8).collect(),
        );
        let out = Coordinator::new(3).run_job(&j).unwrap();
        assert_eq!(out.artifacts.sell_builds(), 1, "{:?}", out.artifacts);
        assert!(out.all_valid);
        assert!(out.preparation_seconds > 0.0);
        for r in &out.runs {
            assert!((r.preparation_seconds - out.preparation_seconds / 8.0).abs() < 1e-12);
        }
        // the cross-root feedback channel saw every root
        assert_eq!(out.artifacts.feedback().roots_done(), 8);
    }

    #[test]
    fn artifact_cache_reuses_preparation_across_jobs() {
        // the serving scenario: repeated jobs on one hot graph share one
        // prepared GraphArtifacts — layout built once, feedback persistent
        let c = Coordinator::new(2);
        let el = RmatConfig::graph500(9, 8).generate(61);
        let g = Arc::new(Csr::from_edge_list(9, &el));
        let engine = EngineKind::parse("sell", 2, "artifacts").unwrap();
        let j1 = BfsJob {
            id: 1,
            graph: Arc::clone(&g),
            roots: (0..4).collect(),
            engine,
            validate: true,
            batch: BatchPolicy::PerRoot,
        };
        let j2 = BfsJob { id: 2, ..j1.clone() };
        let a = c.run_job(&j1).unwrap();
        let b = c.run_job(&j2).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert_eq!(b.artifacts.sell_builds(), 1, "layout must not rebuild on a cache hit");
        // the cross-root feedback channel kept accumulating across jobs
        assert_eq!(b.artifacts.feedback().roots_done(), 8);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 0, "same Arc → identity fast-path");
        assert!(b.all_valid);
    }

    #[test]
    fn artifact_cache_hits_reloaded_graph_by_content() {
        // the ROADMAP item: dropping a graph and reloading it from the
        // same source must hit the cache — the durable key is the content
        // fingerprint, not the allocation
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
        let mk = |graph: Arc<Csr>| BfsJob {
            id: 0,
            graph,
            roots: vec![0, 1],
            engine: engine.clone(),
            validate: false,
            batch: BatchPolicy::PerRoot,
        };
        let a = {
            // this Arc is dropped before the second job — only content
            // can match it
            let g1 = Arc::new(Csr::from_edge_list(9, &el));
            c.run_job(&mk(Arc::clone(&g1))).unwrap()
        };
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        let b = c.run_job(&mk(Arc::clone(&g2))).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts), "reloaded graph must hit");
        assert_eq!(b.artifacts.sell_builds(), 1);
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 1);
        assert_eq!(m.artifact_cache_content_hits, 1);
        // a third job on the same reloaded Arc takes the refreshed
        // identity fast-path — a hit, but not a content hit
        c.run_job(&mk(Arc::clone(&g2))).unwrap();
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2);
        assert_eq!(m.artifact_cache_content_hits, 1);
    }

    #[test]
    fn artifact_cache_distinguishes_content_and_sigma() {
        let c = Coordinator::new(1);
        let el = RmatConfig::graph500(9, 8).generate(62);
        let g1 = Arc::new(Csr::from_edge_list(9, &el));
        // equal content, different identity — must alias via the content key
        let g2 = Arc::new(Csr::from_edge_list(9, &el));
        // different content — must not alias
        let el3 = RmatConfig::graph500(9, 8).generate(63);
        let g3 = Arc::new(Csr::from_edge_list(9, &el3));
        let mk = |graph: &Arc<Csr>, sigma: usize| {
            let mut engine = EngineKind::parse("sell", 1, "artifacts").unwrap();
            if let EngineKind::Sell { sigma: s, .. } = &mut engine {
                *s = sigma;
            }
            BfsJob {
                id: 0,
                graph: Arc::clone(graph),
                roots: vec![0, 1],
                engine,
                validate: false,
                batch: BatchPolicy::PerRoot,
            }
        };
        let a = c.run_job(&mk(&g1, 64)).unwrap();
        let b = c.run_job(&mk(&g2, 64)).unwrap(); // same content → content hit
        let d = c.run_job(&mk(&g1, 128)).unwrap(); // different σ → miss
        let e = c.run_job(&mk(&g3, 64)).unwrap(); // different content → miss
        // g2's content hit re-pointed the identity fast-path at g2, so g1
        // matches by content again
        let f = c.run_job(&mk(&g1, 64)).unwrap();
        assert!(Arc::ptr_eq(&a.artifacts, &b.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &d.artifacts));
        assert!(!Arc::ptr_eq(&a.artifacts, &e.artifacts));
        assert!(Arc::ptr_eq(&a.artifacts, &f.artifacts));
        let m = c.metrics().snapshot();
        assert_eq!(m.artifact_cache_hits, 2, "b and f hit");
        assert_eq!(m.artifact_cache_content_hits, 2, "both via the content key");
    }

    #[test]
    fn bad_engine_fails_fast_before_workers() {
        // a PJRT config with no artifacts errors in the prepare phase
        let j = job(
            EngineKind::Pjrt { artifact_dir: "/nonexistent-artifacts".into() },
            vec![0, 1],
        );
        let err = Coordinator::new(2).run_job(&j).unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn single_worker_deterministic() {
        let j = job(
            EngineKind::Simd {
                threads: 1,
                opts: crate::bfs::vectorized::SimdOpts::full(),
                policy: crate::bfs::policy::LayerPolicy::All,
                vpu: crate::simd::VpuMode::default(),
            },
            vec![3, 9],
        );
        let a = Coordinator::new(1).run_job(&j).unwrap();
        let b = Coordinator::new(1).run_job(&j).unwrap();
        for (x, y) in a.runs.iter().zip(b.runs.iter()) {
            assert_eq!(x.reached, y.reached);
            assert_eq!(x.edges_traversed, y.edges_traversed);
        }
    }
}
