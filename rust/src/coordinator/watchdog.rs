//! Supervised execution: a liveness watchdog over the coordinator.
//!
//! Every protection below this layer is *cooperative* — deadlines and
//! cancellation are checked at layer boundaries, so a wave that stops
//! reaching them (a wedged gather loop, a stuck device call, a worker
//! sleeping inside an injected [`super::FaultKind::Hang`]) is invisible to
//! all of it. The [`Supervisor`] detects and heals around exactly that
//! failure mode:
//!
//! 1. Supervised jobs run on a pool of detachable worker threads, each
//!    executing whole [`Coordinator::run_job`] calls (the coordinator's
//!    own scoped workers *join*, so a non-cooperative hang would wedge
//!    `run_job` itself — supervision has to live above it).
//! 2. A monitor thread samples each wave's heartbeat
//!    ([`crate::bfs::RunControl::ticks`], bumped at every layer-boundary
//!    control check). No movement for the wave's liveness budget
//!    ([`super::RunPolicy::liveness`]) means the wave stopped making layer
//!    progress: the monitor fires the wave's cancel (`watchdog_fires`), so
//!    a merely *slow* cooperative wave stops at its next boundary and
//!    returns partial results normally.
//! 3. If the worker still does not return within a grace window (the
//!    cancel was ignored — a true hang), the wave is **abandoned**: its
//!    caller gets a well-formed [`JobOutcome`] of structured
//!    [`RootOutcome::Failed`] entries (`hung_waves`), the hung thread is
//!    condemned and left detached (it can never be joined), and a
//!    replacement worker is spawned so pool capacity self-heals
//!    (`workers_replaced`).
//!
//! Jobs without a liveness budget bypass the pool entirely and run inline
//! on the caller's thread — unsupervised callers pay nothing.
//!
//! The liveness budget must cover the job's one-time prepare phase (no
//! heartbeats tick while layouts build); serving deployments amortize
//! preparation through the artifact cache, so in practice the budget only
//! has to cover the longest layer.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::bfs::{GraphArtifacts, RunControl};
use crate::graph::Csr;
use crate::Vertex;

use super::error::CoordinatorError;
use super::job::{BfsJob, JobOutcome, RootOutcome};
use super::scheduler::{lock_unpoisoned, Coordinator};

/// Monitor poll bounds: the scan interval adapts to a quarter of the
/// tightest watched liveness budget, clamped into this range.
const POLL_MIN: Duration = Duration::from_millis(1);
const POLL_MAX: Duration = Duration::from_millis(50);

/// One supervised job waiting for (or holding) its result.
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
    /// Set when the monitor abandoned the wave (the stored outcome is
    /// synthesized, and the worker's late result — if it ever comes —
    /// will be discarded).
    abandoned: AtomicBool,
}

enum SlotState {
    Pending,
    Done(Result<JobOutcome, CoordinatorError>),
}

impl Slot {
    fn new() -> Self {
        Slot {
            state: Mutex::new(SlotState::Pending),
            cv: Condvar::new(),
            abandoned: AtomicBool::new(false),
        }
    }

    /// Fill the slot unless it already holds a result. Returns whether
    /// this call won the race (the loser's result is discarded).
    fn fill(&self, result: Result<JobOutcome, CoordinatorError>) -> bool {
        let mut state = lock_unpoisoned(&self.state);
        let won = matches!(*state, SlotState::Pending);
        if won {
            *state = SlotState::Done(result);
        }
        self.cv.notify_all();
        won
    }

    fn wait(&self) -> Result<JobOutcome, CoordinatorError> {
        let mut state = lock_unpoisoned(&self.state);
        loop {
            match std::mem::replace(&mut *state, SlotState::Pending) {
                SlotState::Done(result) => return result,
                SlotState::Pending => {
                    state = self
                        .cv
                        .wait(state)
                        .unwrap_or_else(|poisoned| poisoned.into_inner());
                }
            }
        }
    }
}

/// Per-worker condemnation flag: set by the monitor when the worker's
/// wave is abandoned. A condemned worker that eventually returns exits
/// instead of pulling more work (its replacement already took its seat);
/// one that never returns stays detached forever.
struct WorkerCell {
    condemned: AtomicBool,
}

/// A queued supervised job.
struct Ticket {
    job: BfsJob,
    slot: Arc<Slot>,
}

/// A wave currently executing with a liveness budget armed.
struct WatchEntry {
    id: u64,
    control: Arc<RunControl>,
    liveness: Duration,
    /// Extra time after the cancel fires before the wave is abandoned;
    /// equal to the liveness budget, so abandonment lands at 2× liveness.
    grace: Duration,
    slot: Arc<Slot>,
    worker: Arc<WorkerCell>,
    // enough of the job to synthesize a well-formed outcome on abandonment
    job_id: u64,
    roots: Vec<Vertex>,
    graph: Arc<Csr>,
    // monitor-private progress tracking
    last_ticks: u64,
    last_progress: Instant,
    fired_at: Option<Instant>,
}

struct Inner {
    coordinator: Arc<Coordinator>,
    queue: Mutex<VecDeque<Ticket>>,
    queue_cv: Condvar,
    watched: Mutex<Vec<WatchEntry>>,
    watched_cv: Condvar,
    shutdown: AtomicBool,
    entry_seq: AtomicU64,
    /// Workers currently able to serve waves (spawned minus condemned).
    capacity: AtomicUsize,
}

/// The supervision layer: a self-healing worker pool plus the liveness
/// monitor. Construct one per daemon (or per harness run) around a shared
/// [`Coordinator`]; submit work with [`Supervisor::run_job`].
pub struct Supervisor {
    inner: Arc<Inner>,
    monitor: Option<std::thread::JoinHandle<()>>,
}

impl Supervisor {
    /// A supervisor over `coordinator` with `workers` pool threads
    /// (clamped to ≥ 1). Pool threads only execute jobs that carry a
    /// liveness budget; unsupervised jobs run inline in the caller.
    pub fn new(coordinator: Arc<Coordinator>, workers: usize) -> Self {
        let inner = Arc::new(Inner {
            coordinator,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            watched: Mutex::new(Vec::new()),
            watched_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            entry_seq: AtomicU64::new(0),
            capacity: AtomicUsize::new(0),
        });
        for _ in 0..workers.max(1) {
            Inner::spawn_worker(&inner);
        }
        let monitor_inner = Arc::clone(&inner);
        let monitor = std::thread::Builder::new()
            .name("phi-bfs-watchdog".into())
            .spawn(move || monitor_loop(&monitor_inner))
            .expect("spawn watchdog monitor");
        Supervisor { inner, monitor: Some(monitor) }
    }

    /// The shared coordinator every supervised job runs on.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.inner.coordinator
    }

    /// Workers currently able to serve waves. After an abandonment this
    /// returns to its original value: the condemned worker left the pool
    /// and its replacement joined it.
    pub fn capacity(&self) -> usize {
        self.inner.capacity.load(Ordering::Relaxed)
    }

    /// Run `job` under supervision, blocking until it completes or is
    /// abandoned. Jobs without [`super::RunPolicy::liveness`] run inline
    /// (identical to [`Coordinator::run_job`]); jobs with one run on the
    /// pool and are guaranteed to return within roughly 2× the budget of
    /// the moment they stop making progress — abandoned waves yield a
    /// well-formed outcome whose every root is [`RootOutcome::Failed`].
    pub fn run_job(&self, job: BfsJob) -> Result<JobOutcome, CoordinatorError> {
        if job.run.liveness.is_none() {
            return self.inner.coordinator.run_job(&job);
        }
        let slot = Arc::new(Slot::new());
        {
            let mut q = lock_unpoisoned(&self.inner.queue);
            q.push_back(Ticket { job, slot: Arc::clone(&slot) });
        }
        self.inner.queue_cv.notify_one();
        slot.wait()
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Relaxed);
        // fail any still-queued tickets so no submitter waits forever
        let stranded: Vec<Ticket> = lock_unpoisoned(&self.inner.queue).drain(..).collect();
        for t in stranded {
            t.slot.abandoned.store(true, Ordering::Relaxed);
            t.slot.fill(Ok(abandoned_outcome(&t.job, "supervisor shutting down")));
        }
        self.inner.queue_cv.notify_all();
        self.inner.watched_cv.notify_all();
        if let Some(m) = self.monitor.take() {
            m.join().ok();
        }
        // workers are detached by design (a hung one can never be joined);
        // idle ones exit at their next queue wakeup
    }
}

impl Inner {
    fn spawn_worker(inner: &Arc<Inner>) {
        let cell = Arc::new(WorkerCell { condemned: AtomicBool::new(false) });
        inner.capacity.fetch_add(1, Ordering::Relaxed);
        let worker_inner = Arc::clone(inner);
        std::thread::Builder::new()
            .name("phi-bfs-supervised".into())
            .spawn(move || worker_loop(&worker_inner, &cell))
            .expect("spawn supervised worker");
    }

    /// Register a running wave with the monitor; returns the entry id.
    fn watch(
        &self,
        job: &BfsJob,
        liveness: Duration,
        control: &Arc<RunControl>,
        slot: &Arc<Slot>,
        worker: &Arc<WorkerCell>,
    ) -> u64 {
        let id = self.entry_seq.fetch_add(1, Ordering::Relaxed);
        let entry = WatchEntry {
            id,
            control: Arc::clone(control),
            liveness,
            grace: liveness,
            slot: Arc::clone(slot),
            worker: Arc::clone(worker),
            job_id: job.id,
            roots: job.roots.clone(),
            graph: Arc::clone(&job.graph),
            last_ticks: control.ticks(),
            last_progress: Instant::now(),
            fired_at: None,
        };
        lock_unpoisoned(&self.watched).push(entry);
        self.watched_cv.notify_all();
        id
    }

    fn unwatch(&self, id: u64) {
        lock_unpoisoned(&self.watched).retain(|e| e.id != id);
    }

    /// The abandonment path: synthesize the failure outcome, hand it to
    /// the waiting submitter, condemn the hung worker, and restore pool
    /// capacity with a replacement.
    fn abandon(self: &Arc<Self>, entry: WatchEntry) {
        let metrics = self.coordinator.metrics();
        metrics.record_hung_wave();
        for _ in &entry.roots {
            metrics.record_failed_root();
        }
        entry.worker.condemned.store(true, Ordering::Relaxed);
        self.capacity.fetch_sub(1, Ordering::Relaxed);
        let detail = format!(
            "wave abandoned by watchdog: no layer progress within {:?} and cancellation \
             ignored for a further {:?} (hung worker detached)",
            entry.liveness, entry.grace
        );
        let job = FakeJob { id: entry.job_id, roots: &entry.roots, graph: &entry.graph };
        entry.slot.abandoned.store(true, Ordering::Relaxed);
        entry.slot.fill(Ok(abandoned_outcome_parts(job, &detail)));
        if !self.shutdown.load(Ordering::Relaxed) {
            Inner::spawn_worker(self);
            metrics.record_worker_replaced();
        }
    }
}

/// The fields of a job the abandonment synthesizer needs (the real
/// [`BfsJob`] is owned by the hung worker at that point).
struct FakeJob<'a> {
    id: u64,
    roots: &'a [Vertex],
    graph: &'a Arc<Csr>,
}

fn abandoned_outcome_parts(job: FakeJob<'_>, detail: &str) -> JobOutcome {
    JobOutcome {
        id: job.id,
        outcomes: job
            .roots
            .iter()
            .map(|&root| RootOutcome::Failed {
                root,
                error: detail.to_string(),
                attempts: 1,
            })
            .collect(),
        all_valid: false,
        preparation_seconds: 0.0,
        artifacts: Arc::new(GraphArtifacts::for_graph(job.graph)),
        pressure: Vec::new(),
    }
}

fn abandoned_outcome(job: &BfsJob, detail: &str) -> JobOutcome {
    abandoned_outcome_parts(
        FakeJob { id: job.id, roots: &job.roots, graph: &job.graph },
        detail,
    )
}

fn worker_loop(inner: &Arc<Inner>, cell: &Arc<WorkerCell>) {
    loop {
        let ticket = {
            let mut q = lock_unpoisoned(&inner.queue);
            loop {
                if inner.shutdown.load(Ordering::Relaxed)
                    || cell.condemned.load(Ordering::Relaxed)
                {
                    return;
                }
                if let Some(t) = q.pop_front() {
                    break t;
                }
                q = inner
                    .queue_cv
                    .wait(q)
                    .unwrap_or_else(|poisoned| poisoned.into_inner());
            }
        };
        execute(inner, cell, ticket);
        if cell.condemned.load(Ordering::Relaxed) {
            // the replacement already took this seat
            return;
        }
    }
}

fn execute(inner: &Arc<Inner>, cell: &Arc<WorkerCell>, ticket: Ticket) {
    let Ticket { mut job, slot } = ticket;
    // the heartbeat lives on the control: give the job a dedicated one if
    // the caller didn't supply a shared handle
    let control = Arc::clone(job.run.control.get_or_insert_with(Arc::default));
    let watch_id = job
        .run
        .liveness
        .map(|budget| inner.watch(&job, budget, &control, &slot, cell));
    let result = inner.coordinator.run_job(&job);
    if let Some(id) = watch_id {
        inner.unwatch(id);
    }
    // a worker returning after abandonment loses the race; its result is
    // discarded (the submitter already got the synthesized failure)
    slot.fill(result);
}

fn monitor_loop(inner: &Arc<Inner>) {
    let mut watched = lock_unpoisoned(&inner.watched);
    loop {
        if inner.shutdown.load(Ordering::Relaxed) {
            return;
        }
        if watched.is_empty() {
            // idle: sleep until a wave registers or shutdown
            watched = inner
                .watched_cv
                .wait(watched)
                .unwrap_or_else(|poisoned| poisoned.into_inner());
            continue;
        }
        let poll = watched
            .iter()
            .map(|e| e.liveness / 4)
            .min()
            .unwrap_or(POLL_MAX)
            .clamp(POLL_MIN, POLL_MAX);
        let (guard, _) = inner
            .watched_cv
            .wait_timeout(watched, poll)
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        watched = guard;
        if inner.shutdown.load(Ordering::Relaxed) {
            return;
        }
        let now = Instant::now();
        let mut abandoned: Vec<WatchEntry> = Vec::new();
        let mut i = 0;
        while i < watched.len() {
            let ticks = watched[i].control.ticks();
            if ticks != watched[i].last_ticks {
                // the wave reached a layer boundary since the last scan —
                // that is liveness, whatever the wall clock says
                let e = &mut watched[i];
                e.last_ticks = ticks;
                e.last_progress = now;
                e.fired_at = None;
                i += 1;
                continue;
            }
            let idle = now.saturating_duration_since(watched[i].last_progress);
            let liveness = watched[i].liveness;
            let grace = watched[i].grace;
            match watched[i].fired_at {
                None if idle >= liveness => {
                    watched[i].control.cancel();
                    inner.coordinator.metrics().record_watchdog_fire();
                    watched[i].fired_at = Some(now);
                    i += 1;
                }
                Some(fired) if now.saturating_duration_since(fired) >= grace => {
                    abandoned.push(watched.swap_remove(i));
                    // no i += 1: swap_remove moved a fresh entry into i
                }
                _ => i += 1,
            }
        }
        if !abandoned.is_empty() {
            // abandon outside the watched lock: spawning workers and
            // filling slots must not block the next scan
            drop(watched);
            for e in abandoned {
                inner.abandon(e);
            }
            watched = lock_unpoisoned(&inner.watched);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::EngineKind;
    use crate::coordinator::{BatchPolicy, FaultPlan, RunPolicy};
    use crate::graph::RmatConfig;

    fn graph() -> Arc<Csr> {
        let el = RmatConfig::graph500(8, 8).generate(3);
        Arc::new(Csr::from_edge_list(8, &el))
    }

    fn job(graph: &Arc<Csr>, liveness: Option<Duration>) -> BfsJob {
        BfsJob {
            id: 1,
            graph: Arc::clone(graph),
            roots: vec![0, 1, 2],
            engine: EngineKind::SerialLayered,
            validate: false,
            batch: BatchPolicy::Fixed(3),
            run: RunPolicy { liveness, ..RunPolicy::default() },
        }
    }

    fn supervisor(workers: usize) -> Supervisor {
        Supervisor::new(Arc::new(Coordinator::new(1)), workers)
    }

    #[test]
    fn unsupervised_jobs_run_inline_and_complete() {
        let sup = supervisor(1);
        let g = graph();
        let outcome = sup.run_job(job(&g, None)).expect("admitted");
        assert_eq!(outcome.outcomes.len(), 3);
        assert!(outcome.failures().next().is_none());
        let snap = sup.coordinator().metrics().snapshot();
        assert_eq!(snap.watchdog_fires, 0);
        assert_eq!(snap.hung_waves, 0);
    }

    #[test]
    fn healthy_supervised_jobs_complete_without_watchdog_fires() {
        let sup = supervisor(2);
        let g = graph();
        for _ in 0..4 {
            let outcome =
                sup.run_job(job(&g, Some(Duration::from_secs(5)))).expect("admitted");
            assert!(outcome.failures().next().is_none());
        }
        let snap = sup.coordinator().metrics().snapshot();
        assert_eq!(snap.watchdog_fires, 0, "healthy waves must never trip the watchdog");
        assert_eq!(snap.workers_replaced, 0);
        assert_eq!(sup.capacity(), 2);
    }

    #[test]
    fn hung_wave_is_abandoned_and_the_pool_self_heals() {
        let sup = supervisor(1);
        let g = graph();
        let liveness = Duration::from_millis(40);
        let mut hung = job(&g, Some(liveness));
        hung.run.fault = Some(FaultPlan::hang_at(0));
        let t0 = Instant::now();
        let outcome = sup.run_job(hung).expect("abandonment is not a job error");
        let elapsed = t0.elapsed();
        assert_eq!(outcome.outcomes.len(), 3, "well-formed: one outcome per root");
        assert!(outcome.outcomes.iter().all(|o| o.is_failed()));
        assert!(!outcome.all_valid);
        match &outcome.outcomes[0] {
            RootOutcome::Failed { error, .. } => {
                assert!(error.contains("watchdog"), "structured error: {error}")
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        // detection fires within the budget (+ one poll); abandonment adds
        // the grace window. Generous wall bound: CI schedulers are noisy.
        assert!(
            elapsed >= liveness,
            "cannot abandon before the budget lapses ({elapsed:?})"
        );
        assert!(
            elapsed < liveness * 20,
            "abandonment took {elapsed:?}, way past 2x the {liveness:?} budget"
        );
        let snap = sup.coordinator().metrics().snapshot();
        assert_eq!(snap.watchdog_fires, 1);
        assert_eq!(snap.hung_waves, 1);
        assert_eq!(snap.workers_replaced, 1);
        assert_eq!(snap.failed_roots, 3);
        assert_eq!(sup.capacity(), 1, "replacement restored the pool");
        // the replacement worker actually serves: the next supervised job
        // on the same (single-seat) pool completes
        let outcome = sup.run_job(job(&g, Some(Duration::from_secs(5)))).expect("admitted");
        assert!(outcome.failures().next().is_none(), "pool recovered");
    }

    #[test]
    fn cooperative_slow_wave_is_cancelled_not_abandoned() {
        let sup = supervisor(1);
        let g = graph();
        // a bounded stall longer than the liveness budget but shorter than
        // budget + grace: the worker sleeps through the budget (watchdog
        // fires its cancel), then *does* reach its control checks and
        // stops cooperatively before the grace window lapses — so nothing
        // is abandoned
        let mut slow = job(&g, Some(Duration::from_millis(150)));
        slow.run.fault = Some(FaultPlan::stall_at(0, Duration::from_millis(200)));
        slow.run.max_attempts = 1;
        let outcome = sup.run_job(slow).expect("admitted");
        let snap = sup.coordinator().metrics().snapshot();
        assert!(snap.watchdog_fires >= 1, "the stall must trip the liveness budget");
        assert_eq!(snap.hung_waves, 0, "a cooperative wave is never abandoned");
        assert_eq!(snap.workers_replaced, 0);
        assert_eq!(sup.capacity(), 1);
        // the wave returned through the normal path: outcomes are Ran
        // (cancelled partial prefixes), not synthesized failures
        for o in &outcome.outcomes {
            if let Some(run) = o.run() {
                assert!(!run.status().is_complete() || run.reached > 0);
            }
        }
    }

    #[test]
    fn idle_supervisor_drops_cleanly() {
        // the monitor sleeps on its condvar while nothing is watched; Drop
        // must wake and join it without a wave ever having run
        let sup = supervisor(2);
        assert_eq!(sup.capacity(), 2);
        drop(sup);
    }

    #[test]
    fn fail_waves_fault_surfaces_structured_failures_not_hangs() {
        let sup = supervisor(1);
        let g = graph();
        let mut failing = job(&g, Some(Duration::from_secs(5)));
        failing.run.fault = Some(FaultPlan::fail_waves(2));
        failing.run.max_attempts = 2;
        let outcome = sup.run_job(failing).expect("admitted");
        assert!(outcome.outcomes.iter().all(|o| o.is_failed()), "every root exhausts");
        let snap = sup.coordinator().metrics().snapshot();
        assert_eq!(snap.hung_waves, 0, "FailWaves returns promptly — never a hang");
        assert_eq!(snap.failed_roots, 3);
    }
}
