//! Resource governance: a byte-accounted memory budget for the runtime.
//!
//! The prepared layouts ([`crate::graph::Sell16`], [`crate::graph::PaddedCsr`],
//! the hub/component bitmaps) are memory-hungry by design, and ROADMAP
//! item 2's serving scenario cannot let an overloaded daemon OOM-kill the
//! process. The [`ResourceGovernor`] makes memory a first-class bounded
//! resource: one shared atomic **ledger** of charged bytes, checked
//! against a configurable **budget** with two watermarks.
//!
//! The discipline is *charge before allocate*: every charge is a
//! compare-and-swap that fails rather than exceeds the budget, and the
//! planned sizes come from [`crate::bfs::footprint`]'s exact pre-build
//! planners — so the ledger can never be observed above the budget.
//! Three outcomes fall out of a charge that does not fit:
//!
//! - **optional artifact** (padded CSR, hub bitmap, component map): the
//!   build is *skipped* with a structured [`ResourcePressure`] event; the
//!   engines all tolerate the absence through their scalar/CSR fallback
//!   paths. Skipping starts at the **high watermark**, before the budget
//!   is actually exhausted, so mandatory work keeps headroom.
//! - **mandatory allocation** (the SELL layout of a `sell`/`hybrid-sell`
//!   engine): preparation fails with a marked error the coordinator maps
//!   to [`crate::coordinator::CoordinatorError::OverBudget`].
//! - **per-traversal working set**: reserved at admission by the
//!   scheduler ([`LedgerHold`]); a reservation that does not fit sheds
//!   the job with [`crate::coordinator::CoordinatorError::Rejected`].
//!
//! The artifact cache releases its entries' bytes on eviction and evicts
//! until the ledger is back under the **low watermark** (see
//! [`crate::coordinator::Coordinator`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::bfs::DegreeStats;

/// Sentinel embedded in preparation errors raised by a mandatory artifact
/// build that cannot fit the budget; the scheduler maps any preparation
/// error whose chain contains it to
/// [`crate::coordinator::CoordinatorError::OverBudget`].
pub const OVER_BUDGET_MARKER: &str = "mandatory allocation over memory budget";

/// Pressure (skip optional artifact builds) starts at this share of the
/// budget…
const HIGH_WATERMARK_PCT: usize = 85;
/// …and cache eviction runs until the ledger is back under this share.
const LOW_WATERMARK_PCT: usize = 70;

/// Rough per-vertex bytes of one root's traversal state (parent array,
/// distance-ish scratch, visited/frontier bitmaps) used by the admission
/// estimate — deliberately a smooth overestimate, not an exact plan.
const WORKING_SET_BYTES_PER_ROOT_VERTEX: usize = 12;

/// A structured degradation event: an optional artifact build was skipped
/// because charging it would push the ledger over the high watermark (or
/// over the budget outright).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResourcePressure {
    /// Which artifact was skipped (`"padded-csr"`, `"hub-bits"`,
    /// `"component-map"`).
    pub artifact: &'static str,
    /// Bytes the skipped build would have retained.
    pub requested_bytes: usize,
    /// Ledger at the decision point.
    pub ledger_bytes: usize,
    /// The configured budget.
    pub budget_bytes: usize,
}

/// Admission policy for [`crate::coordinator::Coordinator::run_job`]:
/// bound the number of concurrently running jobs (the estimated-footprint
/// check rides the governor's budget, not this struct).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// Maximum jobs allowed in flight at once (`usize::MAX` = unlimited).
    pub max_inflight: usize,
}

impl Default for AdmissionPolicy {
    fn default() -> Self {
        AdmissionPolicy { max_inflight: usize::MAX }
    }
}

/// Shared atomic byte ledger + watermarks. See the module docs for the
/// charging discipline; one governor is shared by a coordinator, its
/// artifact cache, and every `GraphArtifacts` it hands to engines.
pub struct ResourceGovernor {
    /// Budget in bytes; `usize::MAX` means unbounded (every charge
    /// succeeds, no pressure, no eviction).
    budget: usize,
    ledger: AtomicUsize,
    pressure_count: AtomicUsize,
    events: Mutex<Vec<ResourcePressure>>,
}

impl std::fmt::Debug for ResourceGovernor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ResourceGovernor")
            .field("budget", &self.budget)
            .field("used", &self.used())
            .field("pressure_events", &self.pressure_events())
            .finish()
    }
}

impl ResourceGovernor {
    /// A governor with no budget: the ledger still counts, but nothing is
    /// ever refused. The default for `Coordinator::new`.
    pub fn unbounded() -> Self {
        Self::with_budget(usize::MAX)
    }

    /// A governor enforcing `budget` bytes.
    pub fn with_budget(budget: usize) -> Self {
        ResourceGovernor {
            budget,
            ledger: AtomicUsize::new(0),
            pressure_count: AtomicUsize::new(0),
            events: Mutex::new(Vec::new()),
        }
    }

    /// True when a finite budget is being enforced.
    #[inline]
    pub fn is_bounded(&self) -> bool {
        self.budget != usize::MAX
    }

    /// The configured budget in bytes (`usize::MAX` = unbounded).
    #[inline]
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently charged to the ledger.
    #[inline]
    pub fn used(&self) -> usize {
        self.ledger.load(Ordering::Relaxed)
    }

    /// Bytes still chargeable before the budget refuses.
    #[inline]
    pub fn remaining(&self) -> usize {
        self.budget.saturating_sub(self.used())
    }

    /// Ledger level above which optional artifact builds are skipped.
    #[inline]
    pub fn high_watermark(&self) -> usize {
        watermark(self.budget, HIGH_WATERMARK_PCT)
    }

    /// Ledger level cache eviction drives the ledger back under.
    #[inline]
    pub fn low_watermark(&self) -> usize {
        watermark(self.budget, LOW_WATERMARK_PCT)
    }

    /// Charge `bytes` iff the ledger stays within the budget. Never
    /// overshoots: the check-and-add is one CAS.
    pub fn try_charge(&self, bytes: usize) -> bool {
        self.ledger
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                let next = cur.checked_add(bytes)?;
                (next <= self.budget).then_some(next)
            })
            .is_ok()
    }

    /// Return `bytes` to the ledger (saturating — releasing more than was
    /// charged clamps at zero rather than wrapping).
    pub fn release(&self, bytes: usize) {
        let _ = self.ledger.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some(cur.saturating_sub(bytes))
        });
    }

    /// Gate an optional artifact build: charge `bytes` unless doing so
    /// would push the ledger over the **high watermark**. On refusal a
    /// [`ResourcePressure`] event is recorded and the build must be
    /// skipped. Returns whether the build may proceed (and, if so, the
    /// bytes are already charged).
    pub fn optional_build_allowed(&self, bytes: usize, artifact: &'static str) -> bool {
        if !self.is_bounded() {
            return true;
        }
        let high = self.high_watermark();
        let ok = self
            .ledger
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                let next = cur.checked_add(bytes)?;
                (next <= high).then_some(next)
            })
            .is_ok();
        if !ok {
            self.record_pressure(artifact, bytes);
        }
        ok
    }

    /// Charge a **mandatory** allocation; failure is an error carrying
    /// [`OVER_BUDGET_MARKER`] so the coordinator can surface it as
    /// [`crate::coordinator::CoordinatorError::OverBudget`].
    pub fn charge_mandatory(&self, bytes: usize, what: &str) -> anyhow::Result<()> {
        if self.try_charge(bytes) {
            Ok(())
        } else {
            anyhow::bail!(
                "{OVER_BUDGET_MARKER}: {what} needs {bytes} B, \
                 ledger {} B of {} B budget",
                self.used(),
                self.budget
            )
        }
    }

    /// Record a [`ResourcePressure`] degradation event.
    pub fn record_pressure(&self, artifact: &'static str, requested_bytes: usize) {
        self.pressure_count.fetch_add(1, Ordering::Relaxed);
        let ev = ResourcePressure {
            artifact,
            requested_bytes,
            ledger_bytes: self.used(),
            budget_bytes: self.budget,
        };
        lock_events(&self.events).push(ev);
    }

    /// Total [`ResourcePressure`] events recorded so far.
    pub fn pressure_events(&self) -> usize {
        self.pressure_count.load(Ordering::Relaxed)
    }

    /// Take the events recorded since the last drain (the count above is
    /// cumulative and unaffected).
    pub fn drain_events(&self) -> Vec<ResourcePressure> {
        std::mem::take(&mut *lock_events(&self.events))
    }

    /// Reserve `bytes` on the ledger, released when the hold drops. Fails
    /// (None) if the reservation does not fit the budget.
    pub fn try_hold(self: &Arc<Self>, bytes: usize) -> Option<LedgerHold> {
        self.try_charge(bytes)
            .then(|| LedgerHold { governor: Arc::clone(self), bytes })
    }

    /// Reserve up to `bytes`, clamped to what fits — the synthetic-pressure
    /// fault injection hook ([`crate::coordinator::FaultKind::MemoryPressure`]):
    /// it fills the ledger deterministically without ever overshooting the
    /// budget.
    pub fn hold_clamped(self: &Arc<Self>, bytes: usize) -> LedgerHold {
        let mut charged = 0usize;
        let _ = self.ledger.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            charged = bytes.min(self.budget.saturating_sub(cur));
            cur.checked_add(charged)
        });
        LedgerHold { governor: Arc::clone(self), bytes: charged }
    }
}

/// RAII ledger reservation (a per-job working set, or injected synthetic
/// pressure); the bytes return to the ledger on drop.
#[derive(Debug)]
pub struct LedgerHold {
    governor: Arc<ResourceGovernor>,
    bytes: usize,
}

impl LedgerHold {
    /// Bytes this hold has reserved.
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

impl Drop for LedgerHold {
    fn drop(&mut self) {
        self.governor.release(self.bytes);
    }
}

fn watermark(budget: usize, pct: usize) -> usize {
    if budget == usize::MAX {
        usize::MAX
    } else {
        (budget as u128 * pct as u128 / 100) as usize
    }
}

/// Pushing a pressure event never panics while holding the lock, so a
/// poisoned mutex only ever means a panicking *reader* test — recover the
/// data rather than cascading.
fn lock_events(
    m: &Mutex<Vec<ResourcePressure>>,
) -> std::sync::MutexGuard<'_, Vec<ResourcePressure>> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Estimated bytes of a job's per-traversal working set, from
/// [`DegreeStats`] alone — the admission check runs it **before any
/// allocation**. Dominated by the retained per-root parent arrays
/// (`roots × V × 8`) plus per-worker traversal scratch.
pub fn estimate_working_set(stats: &DegreeStats, roots: usize, workers: usize) -> usize {
    let n = stats.num_vertices;
    roots
        .saturating_mul(n)
        .saturating_mul(std::mem::size_of::<crate::Pred>())
        .saturating_add(
            workers.max(1).saturating_mul(n).saturating_mul(WORKING_SET_BYTES_PER_ROOT_VERTEX),
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbounded_governor_never_refuses() {
        let g = ResourceGovernor::unbounded();
        assert!(!g.is_bounded());
        assert!(g.try_charge(usize::MAX / 2));
        assert!(g.optional_build_allowed(usize::MAX / 4, "padded-csr"));
        assert_eq!(g.pressure_events(), 0);
        assert!(g.charge_mandatory(1, "sell").is_ok());
    }

    #[test]
    fn charges_never_exceed_budget() {
        let g = ResourceGovernor::with_budget(1000);
        assert!(g.try_charge(600));
        assert!(!g.try_charge(500), "600 + 500 > 1000");
        assert_eq!(g.used(), 600, "failed charge leaves the ledger untouched");
        assert!(g.try_charge(400));
        assert_eq!(g.used(), 1000);
        assert_eq!(g.remaining(), 0);
        g.release(250);
        assert_eq!(g.used(), 750);
        g.release(10_000);
        assert_eq!(g.used(), 0, "over-release clamps at zero");
    }

    #[test]
    fn watermarks_order_and_scale() {
        let g = ResourceGovernor::with_budget(100 * 1024 * 1024);
        assert!(g.low_watermark() < g.high_watermark());
        assert!(g.high_watermark() < g.budget());
        let unbounded = ResourceGovernor::unbounded();
        assert_eq!(unbounded.high_watermark(), usize::MAX);
    }

    #[test]
    fn optional_builds_skip_at_high_watermark_with_event() {
        let g = ResourceGovernor::with_budget(1000);
        assert!(g.try_charge(800), "800 <= budget");
        // 800 is under budget but any meaningful optional build now
        // crosses the 85% watermark.
        assert!(!g.optional_build_allowed(100, "hub-bits"));
        assert_eq!(g.used(), 800, "refused build charges nothing");
        assert_eq!(g.pressure_events(), 1);
        let evs = g.drain_events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].artifact, "hub-bits");
        assert_eq!(evs[0].requested_bytes, 100);
        assert_eq!(evs[0].budget_bytes, 1000);
        assert!(g.drain_events().is_empty(), "drain takes");
        assert_eq!(g.pressure_events(), 1, "count is cumulative");
        // under the watermark the charge goes through
        g.release(800);
        assert!(g.optional_build_allowed(100, "hub-bits"));
        assert_eq!(g.used(), 100);
    }

    #[test]
    fn mandatory_failure_carries_the_marker() {
        let g = ResourceGovernor::with_budget(10);
        let err = g.charge_mandatory(100, "SELL layout").unwrap_err();
        assert!(format!("{err:#}").contains(OVER_BUDGET_MARKER));
        assert_eq!(g.used(), 0);
    }

    #[test]
    fn holds_release_on_drop_and_clamp() {
        let g = Arc::new(ResourceGovernor::with_budget(100));
        let h = g.try_hold(60).expect("fits");
        assert_eq!(g.used(), 60);
        assert!(g.try_hold(60).is_none(), "second hold does not fit");
        drop(h);
        assert_eq!(g.used(), 0);
        let clamped = g.hold_clamped(1_000_000);
        assert_eq!(clamped.bytes(), 100, "clamped to the budget");
        assert_eq!(g.used(), 100);
        drop(clamped);
        assert_eq!(g.used(), 0);
    }

    #[test]
    fn working_set_estimate_scales_with_roots_and_vertices() {
        let stats = DegreeStats {
            num_vertices: 1 << 10,
            num_directed_edges: 1 << 13,
            min: 0,
            max: 64,
            mean: 8.0,
            top1pct_edge_share: 0.3,
            isolated: 10,
        };
        let one = estimate_working_set(&stats, 1, 1);
        let many = estimate_working_set(&stats, 64, 1);
        assert!(many > one);
        assert!(one >= (1 << 10) * std::mem::size_of::<crate::Pred>());
    }
}
