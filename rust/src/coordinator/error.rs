//! Structured coordinator errors.
//!
//! `run_job` used to surface every failure as a stringly `anyhow` chain,
//! which a serving front end cannot dispatch on (is the *request* bad, or
//! the *runtime*?). [`CoordinatorError`] classifies the job-level failure
//! modes instead; per-root failures never reach this type — they are
//! reported as [`super::job::RootOutcome::Failed`] entries inside a
//! well-formed [`super::job::JobOutcome`].
//!
//! The enum implements [`std::error::Error`], so callers living on
//! `anyhow` keep composing with `?` through the blanket conversion.

use std::time::Duration;

use crate::graph::CsrStructureError;
use crate::Vertex;

/// Why a job could not run (or could not even start). Most variants are
/// *job-level* faults: nothing there is retried, because retrying cannot
/// help — the graph is corrupt, the request is malformed, or the engine
/// cannot be built for this configuration. The two shedding variants
/// ([`CoordinatorError::Rejected`], [`CoordinatorError::OverBudget`]) are
/// the exception a serving front end dispatches on: `Rejected` is
/// transient (retry after the hint), `OverBudget` is structural (the job
/// can never fit the configured memory budget).
#[derive(Debug)]
pub enum CoordinatorError {
    /// The job's CSR failed [`crate::graph::Csr::validate_structure`] —
    /// rejected before any engine touches it.
    InvalidGraph(CsrStructureError),
    /// A requested root names a vertex outside the graph.
    RootOutOfBounds { root: Vertex, vertices: usize },
    /// The engine registry could not construct the requested engine.
    EngineConstruction(anyhow::Error),
    /// The engine's per-graph prepare phase failed (bad thresholds,
    /// missing PJRT artifacts, ...).
    Preparation(anyhow::Error),
    /// Admission control shed the job: the coordinator is at its in-flight
    /// cap, or the current memory-ledger occupancy leaves no room for the
    /// job's estimated footprint right now. Transient — a retry after
    /// `retry_after_hint` may be admitted once holds release and the
    /// artifact cache evicts.
    Rejected { retry_after_hint: Duration },
    /// A mandatory allocation (SELL layout, per-root working set) cannot
    /// fit the configured memory budget even on an idle coordinator.
    /// Structural — retrying cannot help; raise the budget or shrink the
    /// job.
    OverBudget { detail: String },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::InvalidGraph(e) => write!(f, "invalid graph: {e}"),
            CoordinatorError::RootOutOfBounds { root, vertices } => {
                write!(f, "root {root} out of bounds for a {vertices}-vertex graph")
            }
            // the vendored anyhow::Error is not a std error, so its causes
            // are folded into the message here instead of source()
            CoordinatorError::EngineConstruction(e) => {
                write!(f, "engine construction failed: {e:#}")
            }
            CoordinatorError::Preparation(e) => write!(f, "engine preparation failed: {e:#}"),
            CoordinatorError::Rejected { retry_after_hint } => {
                write!(
                    f,
                    "job rejected by admission control; retry after ~{} ms",
                    retry_after_hint.as_millis()
                )
            }
            CoordinatorError::OverBudget { detail } => {
                write!(f, "job over memory budget: {detail}")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CoordinatorError::InvalidGraph(e) => Some(e),
            _ => None,
        }
    }
}

impl From<CsrStructureError> for CoordinatorError {
    fn from(e: CsrStructureError) -> Self {
        CoordinatorError::InvalidGraph(e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_failure_mode() {
        let e = CoordinatorError::RootOutOfBounds { root: 9, vertices: 4 };
        assert!(e.to_string().contains("root 9"));
        let e = CoordinatorError::InvalidGraph(CsrStructureError::EmptyOffsets);
        assert!(e.to_string().contains("invalid graph"));
        assert!(std::error::Error::source(&e).is_some());
        let e = CoordinatorError::Rejected { retry_after_hint: Duration::from_millis(25) };
        assert!(e.to_string().contains("rejected"));
        assert!(e.to_string().contains("25"));
        let e = CoordinatorError::OverBudget { detail: "layout needs 8 MiB".into() };
        assert!(e.to_string().contains("over memory budget"));
        assert!(e.to_string().contains("8 MiB"));
    }

    #[test]
    fn converts_into_anyhow() {
        fn takes_anyhow() -> anyhow::Result<()> {
            Err(CoordinatorError::RootOutOfBounds { root: 1, vertices: 1 })?;
            Ok(())
        }
        let err = takes_anyhow().unwrap_err();
        assert!(err.to_string().contains("out of bounds"));
    }
}
