//! Fault injection for the traversal runtime — the chaos harness.
//!
//! A fault-tolerant coordinator is only trustworthy if its failure paths
//! are *exercised*, not just written. A [`FaultPlan`] describes one
//! deterministic fault — a worker panic, a deadline-blowing stall, or a
//! dropped result vector — fired at a chosen batch of a job
//! ([`super::job::RunPolicy::fault`]). The scheduler applies the plan
//! around its normal `run_batch_with` call, so the injected fault travels
//! the exact code path a real one would: `catch_unwind`, per-root error
//! slots, the degradation-ladder retry.
//!
//! [`FaultInjector`] additionally packages the same plan as a
//! [`PreparedBfs`] wrapper for tests that drive an engine directly,
//! without a coordinator.
//!
//! Injection is test infrastructure, but it is compiled unconditionally:
//! the integration chaos suite (a separate crate) needs it, and an unused
//! `None` plan costs one branch per batch.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Duration;

use crate::bfs::{BfsResult, GraphArtifacts, PreparedBfs, RunControl};
use crate::Vertex;

/// What the injected fault does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic inside the worker (exercises `catch_unwind` + retry).
    Panic,
    /// Sleep this long, then run normally (blows a deadline without
    /// violating any engine invariant).
    Stall(Duration),
    /// Run the batch, then return an empty result vector (exercises the
    /// missing-result hole path that used to be a coordinator panic).
    DropResults,
    /// Synthetic memory pressure: the scheduler charges this many bytes
    /// against the job's [`super::governor::ResourceGovernor`] ledger for
    /// the whole job (clamped to the remaining budget, released at job
    /// end). Deterministically drives the optional-artifact-skip and
    /// admission-shedding paths without needing a graph big enough to
    /// fill the budget for real. Unlike the other kinds it fires at
    /// admission, not per batch — [`FaultPlan::apply`] passes through.
    MemoryPressure { bytes: usize },
    /// A non-cooperative hang: the worker never returns and never checks
    /// its [`RunControl`], unlike the bounded [`FaultKind::Stall`]. No
    /// deadline or cancel can stop it — only the watchdog's
    /// abandon-and-replace path ends the wave (the hung thread itself is
    /// leaked, exactly like a real wedged gather loop or stuck device
    /// call).
    Hang,
    /// Deterministic wave failure: the traversal is skipped and an empty
    /// result vector returned, on every batch and every retry (plans with
    /// this kind are sticky), so all roots exhaust their attempts and the
    /// wave surfaces as structured failures. The count is carried for the
    /// serve layer, which injects the plan into the first `n` waves of a
    /// chaos-target graph to drive a circuit breaker open and then closed
    /// again.
    FailWaves(u64),
}

/// One deterministic injected fault: `kind` fires at batch `at_batch`.
/// When `sticky`, the fault also fires for every later batch *and* for
/// every retry of the affected roots — the attempt-exhaustion scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    pub at_batch: usize,
    pub kind: FaultKind,
    pub sticky: bool,
}

impl FaultPlan {
    /// A one-shot panic at batch `b` (retries succeed).
    pub fn panic_at(b: usize) -> Self {
        FaultPlan { at_batch: b, kind: FaultKind::Panic, sticky: false }
    }

    /// A panic at batch `b` that also fails every retry — the root can
    /// only exhaust its attempts.
    pub fn sticky_panic_at(b: usize) -> Self {
        FaultPlan { at_batch: b, kind: FaultKind::Panic, sticky: true }
    }

    /// A stall of `d` at batch `b` (the batch then runs normally).
    pub fn stall_at(b: usize, d: Duration) -> Self {
        FaultPlan { at_batch: b, kind: FaultKind::Stall(d), sticky: false }
    }

    /// Run batch `b` but drop its results.
    pub fn drop_results_at(b: usize) -> Self {
        FaultPlan { at_batch: b, kind: FaultKind::DropResults, sticky: false }
    }

    /// Hold `bytes` of synthetic ledger pressure for the whole job.
    pub fn memory_pressure(bytes: usize) -> Self {
        FaultPlan { at_batch: 0, kind: FaultKind::MemoryPressure { bytes }, sticky: true }
    }

    /// Hang forever at batch `b` — the worker stops heartbeating and
    /// ignores cancellation, so only watchdog abandonment ends the wave.
    pub fn hang_at(b: usize) -> Self {
        FaultPlan { at_batch: b, kind: FaultKind::Hang, sticky: false }
    }

    /// Fail every batch and retry of the job (empty results until the
    /// roots exhaust their attempts); `n` tells the serve layer how many
    /// consecutive waves to poison.
    pub fn fail_waves(n: u64) -> Self {
        FaultPlan { at_batch: 0, kind: FaultKind::FailWaves(n), sticky: true }
    }

    /// Does this plan fire for batch index `b`?
    pub fn fires_at(&self, b: usize) -> bool {
        b == self.at_batch || (self.sticky && b >= self.at_batch)
    }

    /// Run `go` (the real batch traversal) under this plan for batch `b`:
    /// panic, stall-then-run, drop the results, or pass through untouched.
    pub fn apply<F: FnOnce() -> Vec<BfsResult>>(&self, b: usize, go: F) -> Vec<BfsResult> {
        if self.fires_at(b) {
            match self.kind {
                FaultKind::Panic => panic!("injected fault: panic at batch {b}"),
                FaultKind::Stall(d) => std::thread::sleep(d),
                FaultKind::DropResults => {
                    let _ = go();
                    return Vec::new();
                }
                // applied by the scheduler at admission, not per batch
                FaultKind::MemoryPressure { .. } => {}
                FaultKind::Hang => loop {
                    // no ctl check on purpose: this models a worker that
                    // stopped reaching layer boundaries entirely
                    std::thread::sleep(Duration::from_millis(50));
                },
                FaultKind::FailWaves(_) => return Vec::new(),
            }
        }
        go()
    }
}

/// A [`PreparedBfs`] wrapper applying a [`FaultPlan`] by dispatch order:
/// the Nth `run_batch_with` call fires the plan's batch-N fault. For
/// engine-level tests without a coordinator; the scheduler itself injects
/// by exact batch index instead (dispatch order races under multiple
/// workers).
pub struct FaultInjector<'a> {
    inner: &'a dyn PreparedBfs,
    plan: FaultPlan,
    dispatched: AtomicUsize,
}

impl<'a> FaultInjector<'a> {
    pub fn new(inner: &'a dyn PreparedBfs, plan: FaultPlan) -> Self {
        FaultInjector { inner, plan, dispatched: AtomicUsize::new(0) }
    }
}

impl PreparedBfs for FaultInjector<'_> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn run_with(&self, root: Vertex, ctl: &RunControl) -> BfsResult {
        self.inner.run_with(root, ctl)
    }

    fn run_batch_with(&self, roots: &[Vertex], ctl: &RunControl) -> Vec<BfsResult> {
        let idx = self.dispatched.fetch_add(1, Ordering::Relaxed);
        self.plan.apply(idx, || self.inner.run_batch_with(roots, ctl))
    }

    fn artifacts(&self) -> &GraphArtifacts {
        self.inner.artifacts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fires_at_matches_plan() {
        let p = FaultPlan::panic_at(2);
        assert!(!p.fires_at(1));
        assert!(p.fires_at(2));
        assert!(!p.fires_at(3), "one-shot plans fire once");
        let s = FaultPlan::sticky_panic_at(2);
        assert!(!s.fires_at(1));
        assert!(s.fires_at(2) && s.fires_at(7), "sticky plans stay fired");
    }

    #[test]
    fn apply_passes_through_when_not_firing() {
        let p = FaultPlan::panic_at(5);
        let out = p.apply(0, Vec::new);
        assert!(out.is_empty());
    }

    #[test]
    fn apply_panics_when_firing() {
        let p = FaultPlan::panic_at(0);
        let r = std::panic::catch_unwind(|| p.apply(0, Vec::new));
        assert!(r.is_err());
    }

    #[test]
    fn memory_pressure_is_sticky_and_passes_batches_through() {
        let p = FaultPlan::memory_pressure(1 << 20);
        assert!(p.sticky, "pressure holds for the whole job");
        assert!(p.fires_at(0) && p.fires_at(9));
        let mut ran = false;
        let out = p.apply(0, || {
            ran = true;
            Vec::new()
        });
        assert!(ran, "batches run normally under synthetic pressure");
        assert!(out.is_empty());
    }

    #[test]
    fn fail_waves_is_sticky_and_skips_the_traversal() {
        let p = FaultPlan::fail_waves(3);
        assert!(p.sticky, "every retry must fail too");
        assert!(p.fires_at(0) && p.fires_at(5));
        let mut ran = false;
        let out = p.apply(0, || {
            ran = true;
            vec![]
        });
        assert!(!ran, "FailWaves must not run the traversal");
        assert!(out.is_empty());
    }

    #[test]
    fn apply_drops_results() {
        let p = FaultPlan::drop_results_at(0);
        let mut ran = false;
        let out = p.apply(0, || {
            ran = true;
            Vec::new()
        });
        assert!(ran, "DropResults still runs the traversal");
        assert!(out.is_empty());
    }
}
