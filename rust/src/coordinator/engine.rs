//! Engine registry: one place that knows how to construct every BFS
//! implementation in the repository — the algorithm ladder of §3–§4 plus
//! the PJRT-compiled kernel engine.

use anyhow::Result;

use crate::bfs::bitrace_free::BitRaceFreeBfs;
use crate::bfs::bottom_up::HybridBfs;
use crate::bfs::parallel::ParallelBfs;
use crate::bfs::policy::LayerPolicy;
use crate::bfs::serial::{SerialLayeredBfs, SerialQueueBfs};
use crate::bfs::vectorized::{SimdOpts, VectorizedBfs};
use crate::bfs::BfsAlgorithm;
use crate::runtime::bfs::PjrtBfs;

/// Which engine a job should run on.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineKind {
    /// Algorithm 1, queue form.
    SerialQueue,
    /// Algorithm 1, layered form.
    SerialLayered,
    /// Algorithm 2 — the `non-simd` baseline.
    NonSimd { threads: usize },
    /// Algorithm 3 — scalar, no atomics, restoration.
    BitRaceFree { threads: usize },
    /// §4 — the vectorized algorithm (the `simd` curve).
    Simd { threads: usize, opts: SimdOpts, policy: LayerPolicy },
    /// §8 extension — direction-optimizing hybrid (Beamer-style) with a
    /// vectorized bottom-up scan.
    Hybrid { threads: usize, simd: bool },
    /// The AOT JAX/Pallas kernel through PJRT.
    Pjrt { artifact_dir: String },
}

impl EngineKind {
    /// Parse a CLI name: `serial`, `serial-queue`, `non-simd`,
    /// `bitrace-free`, `simd`, `simd-noopt`, `simd-nopf`, `pjrt`.
    pub fn parse(name: &str, threads: usize, artifact_dir: &str) -> Result<Self> {
        Ok(match name {
            "serial" | "serial-layered" => EngineKind::SerialLayered,
            "serial-queue" => EngineKind::SerialQueue,
            "non-simd" | "parallel" => EngineKind::NonSimd { threads },
            "bitrace-free" => EngineKind::BitRaceFree { threads },
            "simd" => EngineKind::Simd {
                threads,
                opts: SimdOpts::full(),
                policy: LayerPolicy::heavy(),
            },
            "simd-noopt" => EngineKind::Simd {
                threads,
                opts: SimdOpts::none(),
                policy: LayerPolicy::heavy(),
            },
            "simd-nopf" => EngineKind::Simd {
                threads,
                opts: SimdOpts::aligned_masks(),
                policy: LayerPolicy::heavy(),
            },
            "hybrid" => EngineKind::Hybrid { threads, simd: true },
            "hybrid-scalar" => EngineKind::Hybrid { threads, simd: false },
            "pjrt" => EngineKind::Pjrt { artifact_dir: artifact_dir.to_string() },
            other => anyhow::bail!(
                "unknown engine {other:?} (expected serial, serial-queue, non-simd, \
                 bitrace-free, simd, simd-noopt, simd-nopf, hybrid, hybrid-scalar, pjrt)"
            ),
        })
    }
}

/// Instantiate an engine. (Engines are constructed per worker thread —
/// the PJRT engine holds a client handle that is not `Sync`.)
pub fn make_engine(kind: &EngineKind) -> Result<Box<dyn BfsAlgorithm>> {
    Ok(match kind {
        EngineKind::SerialQueue => Box::new(SerialQueueBfs),
        EngineKind::SerialLayered => Box::new(SerialLayeredBfs),
        EngineKind::NonSimd { threads } => Box::new(ParallelBfs { num_threads: *threads }),
        EngineKind::BitRaceFree { threads } => {
            Box::new(BitRaceFreeBfs { num_threads: *threads })
        }
        EngineKind::Simd { threads, opts, policy } => Box::new(VectorizedBfs {
            num_threads: *threads,
            opts: *opts,
            policy: *policy,
        }),
        EngineKind::Hybrid { threads, simd } => Box::new(HybridBfs {
            num_threads: *threads,
            simd: *simd,
            ..Default::default()
        }),
        EngineKind::Pjrt { artifact_dir } => Box::new(PjrtBfs::from_dir(artifact_dir)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for name in ["serial", "serial-queue", "non-simd", "bitrace-free", "simd", "simd-noopt", "simd-nopf", "hybrid", "hybrid-scalar", "pjrt"] {
            assert!(EngineKind::parse(name, 4, "artifacts").is_ok(), "{name}");
        }
        assert!(EngineKind::parse("nope", 4, "artifacts").is_err());
    }

    #[test]
    fn make_native_engines() {
        for kind in [
            EngineKind::SerialQueue,
            EngineKind::SerialLayered,
            EngineKind::NonSimd { threads: 2 },
            EngineKind::BitRaceFree { threads: 2 },
            EngineKind::Simd { threads: 2, opts: SimdOpts::full(), policy: LayerPolicy::All },
        ] {
            assert!(make_engine(&kind).is_ok(), "{kind:?}");
        }
    }

    #[test]
    fn engines_run_and_agree() {
        use crate::graph::{Csr, RmatConfig};
        let el = RmatConfig::graph500(9, 8).generate(50);
        let g = Csr::from_edge_list(9, &el);
        let reference = make_engine(&EngineKind::SerialLayered).unwrap().run(&g, 0);
        for kind in [
            EngineKind::SerialQueue,
            EngineKind::NonSimd { threads: 2 },
            EngineKind::BitRaceFree { threads: 2 },
            EngineKind::Simd { threads: 2, opts: SimdOpts::full(), policy: LayerPolicy::All },
            EngineKind::Hybrid { threads: 2, simd: true },
            EngineKind::Hybrid { threads: 2, simd: false },
        ] {
            let r = make_engine(&kind).unwrap().run(&g, 0);
            assert_eq!(
                r.tree.distances().unwrap(),
                reference.tree.distances().unwrap(),
                "{kind:?}"
            );
        }
    }
}
