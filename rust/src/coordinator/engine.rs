//! Engine registry: one place that knows how to construct every BFS
//! implementation in the repository — the algorithm ladder of §3–§4 plus
//! the PJRT-compiled kernel engine.
//!
//! Engines are **two-phase** ([`crate::bfs::BfsEngine`]): [`make_engine`]
//! returns the cheap configuration value; the coordinator then calls
//! `prepare` once per job to build the per-graph artifacts (SELL layout,
//! padded-CSR view, policy feedback) that every root's run shares.
//!
//! | name | engine | paper artifact |
//! |---|---|---|
//! | `serial`, `serial-queue` | [`SerialLayeredBfs`] / [`SerialQueueBfs`] | Algorithm 1 |
//! | `non-simd` | [`ParallelBfs`] | Algorithm 2 |
//! | `bitrace-free` | [`BitRaceFreeBfs`] | Algorithm 3 (restoration) |
//! | `simd`, `simd-noopt`, `simd-nopf` | [`VectorizedBfs`] | §4 Listing 1 |
//! | `sell`, `sell-noopt` | [`SellBfs`] | SELL-16-σ lane packing |
//! | `hybrid`, `hybrid-scalar`, `hybrid-sell` | [`HybridBfs`] | §8 direction optimization |
//! | `hybrid-sell-bu` | [`HybridBfs`] | SELL-packed bottom-up + occupancy-fed α/β switches |
//! | `hybrid-sell-ms` | [`MultiSourceSellBfs`] | batch-first MS-BFS: 16 roots per shared SELL traversal |
//! | `pjrt` | [`PjrtBfs`] | AOT JAX/Pallas kernel |

use anyhow::Result;

use crate::bfs::bitrace_free::BitRaceFreeBfs;
use crate::bfs::bottom_up::HybridBfs;
use crate::bfs::multi_source::MultiSourceSellBfs;
use crate::bfs::parallel::ParallelBfs;
use crate::bfs::policy::LayerPolicy;
use crate::bfs::sell_vectorized::{SellBfs, SIGMA_AUTO};
use crate::bfs::serial::{SerialLayeredBfs, SerialQueueBfs};
use crate::bfs::vectorized::{SimdOpts, VectorizedBfs, PREFETCH_DIST_AUTO};
use crate::bfs::BfsEngine;
use crate::runtime::bfs::PjrtBfs;
use crate::simd::VpuMode;

/// Which engine a job should run on.
#[derive(Clone, Debug, PartialEq)]
pub enum EngineKind {
    /// Algorithm 1, queue form.
    SerialQueue,
    /// Algorithm 1, layered form.
    SerialLayered,
    /// Algorithm 2 — the `non-simd` baseline.
    NonSimd { threads: usize },
    /// Algorithm 3 — scalar, no atomics, restoration.
    BitRaceFree { threads: usize },
    /// §4 — the vectorized algorithm (the `simd` curve). `vpu` selects
    /// the backend mode (counted emulation / hardware SIMD / auto) for
    /// this and every vectorized kind below.
    Simd { threads: usize, opts: SimdOpts, policy: LayerPolicy, vpu: VpuMode },
    /// SELL-16-σ extension — lane-packed exploration over the sliced-
    /// ELLPACK layout (16 distinct frontier vertices per VPU issue).
    Sell { threads: usize, opts: SimdOpts, policy: LayerPolicy, sigma: usize, vpu: VpuMode },
    /// §8 extension — direction-optimizing hybrid (Beamer-style) with a
    /// vectorized bottom-up scan; `sell` routes the top-down phases through
    /// the SELL lane-packed step, `bu_sell` lane-packs the bottom-up phase
    /// too and feeds measured occupancy into the α switch. `sigma` is the
    /// SELL sort window ([`SIGMA_AUTO`] = per-scale default); `alpha`/
    /// `beta` are Beamer's switch thresholds.
    Hybrid {
        threads: usize,
        simd: bool,
        sell: bool,
        bu_sell: bool,
        sigma: usize,
        alpha: usize,
        beta: usize,
        vpu: VpuMode,
        /// Hub-adjacency bitmap size (`--hub-bits`): top-k highest-degree
        /// vertices cached for the SELL bottom-up parent check. `0`
        /// disables; only read when `bu_sell` is on.
        hub_bits: usize,
        /// Software prefetch look-ahead in SELL rows (`--prefetch-dist`);
        /// [`PREFETCH_DIST_AUTO`] runs the warm-up sweep.
        prefetch_dist: usize,
    },
    /// Batch-first MS-BFS extension — up to 16 roots traverse the SELL
    /// layout concurrently (one visit-mask bit per root); single roots run
    /// as a one-bit wave. `sigma`/`alpha`/`beta`/`prefetch_dist` as for
    /// `Hybrid`.
    MultiSource {
        threads: usize,
        sigma: usize,
        alpha: usize,
        beta: usize,
        vpu: VpuMode,
        prefetch_dist: usize,
    },
    /// The AOT JAX/Pallas kernel through PJRT.
    Pjrt { artifact_dir: String },
}

impl EngineKind {
    /// Canonical names of every engine that runs without PJRT artifacts —
    /// the single source the CLI help, tests, and the cross-engine
    /// property suite draw from. (`pjrt` is parseable too but needs
    /// `artifacts/manifest.txt`.)
    pub const NATIVE_NAMES: &[&str] = &[
        "serial",
        "serial-queue",
        "non-simd",
        "bitrace-free",
        "simd",
        "simd-noopt",
        "simd-nopf",
        "sell",
        "sell-noopt",
        "hybrid",
        "hybrid-scalar",
        "hybrid-sell",
        "hybrid-sell-bu",
        "hybrid-sell-ms",
    ];

    /// A hybrid kind with the default switch thresholds and auto σ.
    fn hybrid(threads: usize, simd: bool, sell: bool, bu_sell: bool) -> Self {
        EngineKind::Hybrid {
            threads,
            simd,
            sell,
            bu_sell,
            sigma: SIGMA_AUTO,
            alpha: HybridBfs::DEFAULT_ALPHA,
            beta: HybridBfs::DEFAULT_BETA,
            vpu: VpuMode::default(),
            hub_bits: 0,
            prefetch_dist: PREFETCH_DIST_AUTO,
        }
    }

    /// Set the VPU backend mode on kinds that drive the vector unit.
    /// Returns `false` (and leaves the kind untouched) for the scalar
    /// rungs of the ladder and `pjrt`, which have no VPU.
    pub fn set_vpu(&mut self, mode: VpuMode) -> bool {
        match self {
            EngineKind::Simd { vpu, .. }
            | EngineKind::Sell { vpu, .. }
            | EngineKind::Hybrid { vpu, .. }
            | EngineKind::MultiSource { vpu, .. } => {
                *vpu = mode;
                true
            }
            _ => false,
        }
    }

    /// Set the software-prefetch look-ahead distance (in SELL rows; the
    /// raw-CSR explorer scales it the same way) on kinds that issue
    /// prefetches. Returns `false` for scalar kinds and `pjrt`.
    pub fn set_prefetch_dist(&mut self, dist: usize) -> bool {
        match self {
            EngineKind::Simd { opts, .. } | EngineKind::Sell { opts, .. } => {
                opts.prefetch_dist = dist;
                true
            }
            EngineKind::Hybrid { prefetch_dist, .. }
            | EngineKind::MultiSource { prefetch_dist, .. } => {
                *prefetch_dist = dist;
                true
            }
            _ => false,
        }
    }

    /// Set the hub-adjacency bitmap size. Only the SELL-packed bottom-up
    /// hybrid (`hybrid-sell-bu`) consults the bitmap, so every other kind
    /// returns `false` and is left untouched.
    pub fn set_hub_bits(&mut self, k: usize) -> bool {
        match self {
            EngineKind::Hybrid { hub_bits, bu_sell: true, .. } => {
                *hub_bits = k;
                true
            }
            _ => false,
        }
    }

    /// The σ sort window this kind would build a SELL layout with —
    /// [`SIGMA_AUTO`] for kinds that resolve it per scale or build none.
    /// Together with the graph it keys the coordinator's artifact cache.
    pub fn sigma_key(&self) -> usize {
        match self {
            EngineKind::Sell { sigma, .. }
            | EngineKind::Hybrid { sigma, .. }
            | EngineKind::MultiSource { sigma, .. } => *sigma,
            _ => SIGMA_AUTO,
        }
    }

    /// Parse a CLI name: any of [`Self::NATIVE_NAMES`] or `pjrt`.
    pub fn parse(name: &str, threads: usize, artifact_dir: &str) -> Result<Self> {
        Ok(match name {
            "serial" | "serial-layered" => EngineKind::SerialLayered,
            "serial-queue" => EngineKind::SerialQueue,
            "non-simd" | "parallel" => EngineKind::NonSimd { threads },
            "bitrace-free" => EngineKind::BitRaceFree { threads },
            "simd" => EngineKind::Simd {
                threads,
                opts: SimdOpts::full(),
                policy: LayerPolicy::heavy(),
                vpu: VpuMode::default(),
            },
            "simd-noopt" => EngineKind::Simd {
                threads,
                opts: SimdOpts::none(),
                policy: LayerPolicy::heavy(),
                vpu: VpuMode::default(),
            },
            "simd-nopf" => EngineKind::Simd {
                threads,
                opts: SimdOpts::aligned_masks(),
                policy: LayerPolicy::heavy(),
                vpu: VpuMode::default(),
            },
            // lane packing keeps low-degree layers efficient, so the sell
            // engines vectorize every layer (no §4.1 scalar fallback); σ is
            // resolved per scale at prepare time
            "sell" => EngineKind::Sell {
                threads,
                opts: SimdOpts::full(),
                policy: LayerPolicy::All,
                sigma: SIGMA_AUTO,
                vpu: VpuMode::default(),
            },
            "sell-noopt" => EngineKind::Sell {
                threads,
                opts: SimdOpts::none(),
                policy: LayerPolicy::All,
                sigma: SIGMA_AUTO,
                vpu: VpuMode::default(),
            },
            "hybrid" => Self::hybrid(threads, true, false, false),
            "hybrid-scalar" => Self::hybrid(threads, false, false, false),
            "hybrid-sell" => Self::hybrid(threads, true, true, false),
            // the full single-root configuration: SELL-packed top-down AND
            // bottom-up, occupancy-fed direction switches
            "hybrid-sell-bu" => Self::hybrid(threads, true, true, true),
            // the batch-first configuration: 16 roots per shared traversal
            "hybrid-sell-ms" => EngineKind::MultiSource {
                threads,
                sigma: SIGMA_AUTO,
                alpha: HybridBfs::DEFAULT_ALPHA,
                beta: HybridBfs::DEFAULT_BETA,
                vpu: VpuMode::default(),
                prefetch_dist: PREFETCH_DIST_AUTO,
            },
            "pjrt" => EngineKind::Pjrt { artifact_dir: artifact_dir.to_string() },
            other => anyhow::bail!(
                "unknown engine {other:?} (expected serial, serial-queue, non-simd, \
                 bitrace-free, simd, simd-noopt, simd-nopf, sell, sell-noopt, hybrid, \
                 hybrid-scalar, hybrid-sell, hybrid-sell-bu, hybrid-sell-ms, pjrt)"
            ),
        })
    }
}

/// Instantiate an engine configuration. The result is cheap — per-graph
/// state (layouts, compiled executables) is built by
/// [`crate::bfs::BfsEngine::prepare`], once per job, and shared across
/// worker threads through the returned [`crate::bfs::PreparedBfs`].
pub fn make_engine(kind: &EngineKind) -> Result<Box<dyn BfsEngine>> {
    Ok(match kind {
        EngineKind::SerialQueue => Box::new(SerialQueueBfs),
        EngineKind::SerialLayered => Box::new(SerialLayeredBfs),
        EngineKind::NonSimd { threads } => Box::new(ParallelBfs { num_threads: *threads }),
        EngineKind::BitRaceFree { threads } => {
            Box::new(BitRaceFreeBfs { num_threads: *threads })
        }
        EngineKind::Simd { threads, opts, policy, vpu } => Box::new(VectorizedBfs {
            num_threads: *threads,
            opts: *opts,
            policy: *policy,
            vpu: *vpu,
        }),
        EngineKind::Sell { threads, opts, policy, sigma, vpu } => Box::new(SellBfs {
            num_threads: *threads,
            opts: *opts,
            policy: *policy,
            sigma: *sigma,
            vpu: *vpu,
        }),
        EngineKind::Hybrid {
            threads,
            simd,
            sell,
            bu_sell,
            sigma,
            alpha,
            beta,
            vpu,
            hub_bits,
            prefetch_dist,
        } => {
            let mut e = HybridBfs {
                num_threads: *threads,
                simd: *simd,
                sell: *sell,
                bu_sell: *bu_sell,
                sigma: *sigma,
                alpha: *alpha,
                beta: *beta,
                vpu: *vpu,
                hub_bits: *hub_bits,
                ..Default::default()
            };
            e.opts.prefetch_dist = *prefetch_dist;
            Box::new(e)
        }
        EngineKind::MultiSource { threads, sigma, alpha, beta, vpu, prefetch_dist } => {
            let mut e = MultiSourceSellBfs {
                num_threads: *threads,
                sigma: *sigma,
                alpha: *alpha,
                beta: *beta,
                vpu: *vpu,
                ..Default::default()
            };
            e.opts.prefetch_dist = *prefetch_dist;
            Box::new(e)
        }
        EngineKind::Pjrt { artifact_dir } => Box::new(PjrtBfs::from_dir(artifact_dir)?),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_names() {
        for name in EngineKind::NATIVE_NAMES.iter().chain(&["pjrt"]) {
            assert!(EngineKind::parse(name, 4, "artifacts").is_ok(), "{name}");
        }
        assert!(EngineKind::parse("nope", 4, "artifacts").is_err());
    }

    #[test]
    fn native_names_construct_native_engines() {
        // every canonical name must build an engine with no artifacts
        for name in EngineKind::NATIVE_NAMES {
            let kind = EngineKind::parse(name, 2, "artifacts").unwrap();
            assert!(make_engine(&kind).is_ok(), "{name}");
        }
    }

    #[test]
    fn make_native_engines() {
        for kind in [
            EngineKind::SerialQueue,
            EngineKind::SerialLayered,
            EngineKind::NonSimd { threads: 2 },
            EngineKind::BitRaceFree { threads: 2 },
            EngineKind::Simd {
                threads: 2,
                opts: SimdOpts::full(),
                policy: LayerPolicy::All,
                vpu: VpuMode::default(),
            },
            EngineKind::Sell {
                threads: 2,
                opts: SimdOpts::full(),
                policy: LayerPolicy::All,
                sigma: SIGMA_AUTO,
                vpu: VpuMode::default(),
            },
        ] {
            assert!(make_engine(&kind).is_ok(), "{kind:?}");
        }
    }

    #[test]
    fn hybrid_sell_ms_parses_to_multi_source() {
        let kind = EngineKind::parse("hybrid-sell-ms", 4, "artifacts").unwrap();
        match kind {
            EngineKind::MultiSource { threads: 4, sigma, alpha, beta, .. } => {
                assert_eq!(sigma, SIGMA_AUTO);
                assert_eq!(alpha, HybridBfs::DEFAULT_ALPHA);
                assert_eq!(beta, HybridBfs::DEFAULT_BETA);
            }
            other => panic!("unexpected kind {other:?}"),
        }
    }

    #[test]
    fn hybrid_sell_bu_parses_to_full_config() {
        let kind = EngineKind::parse("hybrid-sell-bu", 4, "artifacts").unwrap();
        match kind {
            EngineKind::Hybrid {
                simd: true,
                sell: true,
                bu_sell: true,
                alpha,
                beta,
                sigma,
                ..
            } => {
                assert_eq!(alpha, HybridBfs::DEFAULT_ALPHA);
                assert_eq!(beta, HybridBfs::DEFAULT_BETA);
                assert_eq!(sigma, SIGMA_AUTO);
            }
            other => panic!("unexpected kind {other:?}"),
        }
    }

    #[test]
    fn sigma_key_covers_sell_layout_kinds() {
        let mut sell = EngineKind::parse("sell", 2, "a").unwrap();
        if let EngineKind::Sell { sigma, .. } = &mut sell {
            *sigma = 128;
        }
        assert_eq!(sell.sigma_key(), 128);
        let mut hybrid = EngineKind::parse("hybrid-sell-bu", 2, "a").unwrap();
        if let EngineKind::Hybrid { sigma, .. } = &mut hybrid {
            *sigma = 256;
        }
        assert_eq!(hybrid.sigma_key(), 256);
        let mut ms = EngineKind::parse("hybrid-sell-ms", 2, "a").unwrap();
        if let EngineKind::MultiSource { sigma, .. } = &mut ms {
            *sigma = 64;
        }
        assert_eq!(ms.sigma_key(), 64);
        assert_eq!(EngineKind::SerialLayered.sigma_key(), SIGMA_AUTO);
    }

    #[test]
    fn set_vpu_covers_exactly_the_vpu_engines() {
        for name in EngineKind::NATIVE_NAMES {
            let mut kind = EngineKind::parse(name, 2, "artifacts").unwrap();
            let has_vpu = !matches!(
                *name,
                "serial" | "serial-queue" | "non-simd" | "bitrace-free"
            );
            assert_eq!(kind.set_vpu(VpuMode::Hw), has_vpu, "{name}");
        }
        let mut pjrt = EngineKind::Pjrt { artifact_dir: "artifacts".into() };
        assert!(!pjrt.set_vpu(VpuMode::Hw));
    }

    #[test]
    fn set_prefetch_dist_covers_the_prefetching_engines() {
        for name in EngineKind::NATIVE_NAMES {
            let mut kind = EngineKind::parse(name, 2, "artifacts").unwrap();
            let prefetches = !matches!(
                *name,
                "serial" | "serial-queue" | "non-simd" | "bitrace-free"
            );
            assert_eq!(kind.set_prefetch_dist(6), prefetches, "{name}");
        }
        let mut simd = EngineKind::parse("simd", 2, "artifacts").unwrap();
        assert!(simd.set_prefetch_dist(6));
        match simd {
            EngineKind::Simd { opts, .. } => assert_eq!(opts.prefetch_dist, 6),
            other => panic!("unexpected kind {other:?}"),
        }
        let mut pjrt = EngineKind::Pjrt { artifact_dir: "artifacts".into() };
        assert!(!pjrt.set_prefetch_dist(6));
    }

    #[test]
    fn set_hub_bits_only_on_sell_bottom_up() {
        for name in EngineKind::NATIVE_NAMES {
            let mut kind = EngineKind::parse(name, 2, "artifacts").unwrap();
            assert_eq!(kind.set_hub_bits(16), *name == "hybrid-sell-bu", "{name}");
        }
        let mut bu = EngineKind::parse("hybrid-sell-bu", 2, "artifacts").unwrap();
        assert!(bu.set_hub_bits(16));
        match bu {
            EngineKind::Hybrid { hub_bits: 16, .. } => {}
            other => panic!("unexpected kind {other:?}"),
        }
    }

    #[test]
    fn engines_run_and_agree() {
        use crate::graph::{Csr, RmatConfig};
        let el = RmatConfig::graph500(9, 8).generate(50);
        let g = Csr::from_edge_list(9, &el);
        let reference = make_engine(&EngineKind::SerialLayered).unwrap().run(&g, 0);
        for kind in [
            EngineKind::SerialQueue,
            EngineKind::NonSimd { threads: 2 },
            EngineKind::BitRaceFree { threads: 2 },
            EngineKind::Simd {
                threads: 2,
                opts: SimdOpts::full(),
                policy: LayerPolicy::All,
                vpu: VpuMode::default(),
            },
            EngineKind::Sell {
                threads: 2,
                opts: SimdOpts::full(),
                policy: LayerPolicy::All,
                sigma: SIGMA_AUTO,
                vpu: VpuMode::default(),
            },
            EngineKind::Sell {
                threads: 2,
                opts: SimdOpts::none(),
                policy: LayerPolicy::heavy(),
                sigma: SIGMA_AUTO,
                vpu: VpuMode::default(),
            },
            EngineKind::hybrid(2, true, false, false),
            EngineKind::hybrid(2, false, false, false),
            EngineKind::hybrid(2, true, true, false),
            EngineKind::hybrid(2, true, true, true),
            EngineKind::parse("hybrid-sell-ms", 2, "artifacts").unwrap(),
        ] {
            let r = make_engine(&kind).unwrap().run(&g, 0);
            assert_eq!(
                r.tree.distances().unwrap(),
                reference.tree.distances().unwrap(),
                "{kind:?}"
            );
        }
    }
}
