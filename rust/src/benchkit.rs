//! A miniature criterion stand-in (criterion is not in the offline crate
//! registry): warmup, timed iterations, robust statistics, fixed-width
//! reporting. Used by every `benches/*.rs` target (`harness = false`).

use std::time::{Duration, Instant};

/// Result of one measured benchmark.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iterations: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Measurement {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    /// Events per second given `events` per iteration (e.g. TEPS).
    pub fn rate(&self, events: f64) -> f64 {
        if self.mean_secs() > 0.0 {
            events / self.mean_secs()
        } else {
            0.0
        }
    }

    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10.3?} ±{:>9.3?}  (n={}, min {:?}, max {:?})",
            self.name, self.mean, self.stddev, self.iterations, self.min, self.max
        )
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once total measured time exceeds this budget.
    pub time_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            time_budget: Duration::from_secs(5),
        }
    }
}

impl Bench {
    /// Quick profile for heavyweight end-to-end benches.
    pub fn quick() -> Self {
        Bench { warmup_iters: 1, min_iters: 2, max_iters: 10, time_budget: Duration::from_secs(3) }
    }

    /// Measure `f`, preventing the result from being optimized away via
    /// `std::hint::black_box`.
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let budget_start = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters || budget_start.elapsed() < self.time_budget)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        Measurement {
            name: name.to_string(),
            iterations: samples.len(),
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(samples.iter().copied().fold(f64::INFINITY, f64::min)),
            max: Duration::from_secs_f64(samples.iter().copied().fold(0.0, f64::max)),
        }
    }
}

/// Print a bench-section header the way the bench binaries expect.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Read a bench parameter from the environment (`PHIBFS_SCALE=20 cargo
/// bench` runs the paper-scale configuration).
pub fn env_param<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_measures_and_bounds_iterations() {
        let b = Bench { warmup_iters: 1, min_iters: 3, max_iters: 5, time_budget: Duration::from_millis(50) };
        let mut count = 0usize;
        let m = b.run("spin", || {
            count += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert!(m.iterations >= 3 && m.iterations <= 5);
        assert!(m.mean >= Duration::from_millis(1));
        assert!(m.min <= m.mean);
        assert!(count >= m.iterations); // warmup included
    }

    #[test]
    fn rate_computes() {
        let m = Measurement {
            name: "x".into(),
            iterations: 1,
            mean: Duration::from_millis(100),
            stddev: Duration::ZERO,
            min: Duration::from_millis(100),
            max: Duration::from_millis(100),
        };
        assert!((m.rate(1000.0) - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn env_param_fallback() {
        assert_eq!(env_param::<u32>("PHIBFS_DOES_NOT_EXIST", 7), 7);
    }
}
