//! Hand-rolled CLI argument parsing (clap is not in the offline registry).
//!
//! Grammar: `phi-bfs <command> [--flag value]...` — see `phi-bfs help`.

use std::collections::HashMap;

use anyhow::{bail, Result};

/// Parsed command line: a command word plus `--key value` flags.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_else(|| "help".to_string());
        if command.starts_with("--") {
            bail!("expected a command before flags (try `phi-bfs help`)");
        }
        let mut flags = HashMap::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument {arg:?}");
            };
            // `--flag=value` or `--flag value` or boolean `--flag`
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                flags.insert(key.to_string(), it.next().unwrap());
            } else {
                flags.insert(key.to_string(), "true".to_string());
            }
        }
        Ok(Args { command, flags })
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Typed flag with default.
    pub fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    /// String flag with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Boolean flag (present or `--flag true/false`).
    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }

    /// Flags that were provided but not consumed by the command — callers
    /// can use this to reject typos.
    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.flags.keys()
    }
}

pub const USAGE: &str = "\
phi-bfs — BFS vectorization on the (modelled) Xeon Phi

Engines prepare per-graph state once per experiment (SELL layout,
padded-CSR view, degree stats), then share it across all roots; per-root
times report pure traversal, preparation is reported separately.

ENGINES (--engine):
    serial, serial-queue     Algorithm 1 — serial top-down (layered/queue)
    non-simd                 Algorithm 2 — parallel top-down, atomics
    bitrace-free             Algorithm 3 — no atomics + restoration
    simd, simd-noopt,        §4 Listing 1 — vectorized explorer
      simd-nopf                (full / no-opt / no-prefetch)
    sell, sell-noopt         SELL-16-σ lane packing, cross-root
                               occupancy-feedback chunking
    hybrid, hybrid-scalar,   §8 direction-optimizing (Beamer) hybrid;
      hybrid-sell              -sell packs top-down phases
    hybrid-sell-bu           hybrid-sell + SELL-packed bottom-up scan
                               (16 unvisited vertices per VPU issue) and
                               occupancy-fed α/β switches
    hybrid-sell-ms           batch-first MS-BFS: 16 roots traverse one
                               shared SELL walk (visit-mask propagation);
                               pair with --batch-roots 16
    pjrt                     AOT JAX/Pallas kernel via PJRT

COMMANDS:
    run        Run a Graph500-style experiment
               --scale N (16) --edgefactor N (16) --roots N (64)
               --engine NAME (simd) --threads N (4) --workers N (1)
               --seed N (1) --artifacts DIR (artifacts) --no-validate
               --batch-roots N (1)  roots per traversal batch; engines
                        without a batched traversal loop internally,
                        hybrid-sell-ms shares one walk per 16-root wave
               --deadline-ms N (unbounded)  traversal-phase deadline:
                        engines stop at the next layer boundary once it
                        passes; interrupted roots keep their visited
                        prefix and are excluded from TEPS statistics
               --max-attempts N (3)  attempts per root before it counts
                        as failed; retries degrade counted VPU -> serial
               --liveness-ms N (off)  watchdog liveness budget: the job
                        runs on a supervised worker, a wave that makes no
                        layer progress for N ms is cancelled, and one that
                        ignores cancellation for a further N ms is
                        abandoned (structured per-root failures, worker
                        replaced)
               --mem-budget-mb N (unbounded)  memory budget for the
                        resource governor: artifact builds and per-job
                        working sets are byte-accounted against it,
                        optional artifacts (padded CSR, hub bitmap,
                        component map) are skipped under pressure with a
                        structured report, and jobs that cannot fit are
                        shed with an over-budget error instead of
                        thrashing
               --max-inflight N (unbounded)  admission cap on
                        concurrently running jobs; excess jobs are
                        rejected with a retry hint instead of queueing
               --sigma N|global|auto (auto)  SELL σ sort window
                        (engines with a SELL layout: sell, sell-noopt,
                         hybrid-sell, hybrid-sell-bu, hybrid-sell-ms;
                         others reject it)
               --alpha N (14) --beta N (24)  Beamer switch thresholds
                        (hybrid engines only; must be >= 1)
               --prefetch-dist auto|N (auto)  software prefetch look-ahead
                        in SELL rows for the hardware VPU tiers; `auto`
                        sweeps 1,2,4,8 on warm-up roots and settles on the
                        fastest (ns/edge); 0 disables distance prefetch.
                        Counted emulation keeps the modelled schedule
                        regardless. VPU engines only.
               --hub-bits N (0)  cache the top-N highest-degree vertices
                        (<= 32) in a packed hub-adjacency bitmap so the
                        SELL bottom-up parent check skips the adjacency
                        stream for hub-adjacent candidates; 0 disables.
                        hybrid-sell-bu only.
               --vpu counted|hw|auto (counted)  VPU backend: counted
                        emulation (feeds cost model + occupancy feedback),
                        hardware SIMD (AVX-512/AVX2/portable, counters
                        off), or auto (counted warm-up roots feed the
                        policy, steady-state roots run hw and warm-ups
                        are excluded from TEPS). VPU engines only.
                        PHIBFS_VPU sets the process-wide default.
    model      Predict Xeon Phi TEPS for a thread/affinity sweep
               --scale N (20: uses the paper's Table 1 profile)
               --threads-list 1,2,48,236 --affinity balanced|compact|
                        scatter|1t/c..4t/c (balanced) --engine simd|non-simd
    table1     Print the Table-1 layer profile of a generated graph
               --scale N (20) --edgefactor N (16) --seed N (1)
    analyze    Graph analytics (components, shortest paths, betweenness)
               --input FILE (SNAP-style edge list; omit to generate RMAT)
               --scale N (12) --edgefactor N (16) --seed N (1)
               --engine ... (simd) --threads N (4) --bc-sources N (32)
               --batch-roots N (1)  seeds per component-sweep batch
                        (betweenness always batches its sources)
    serve      BFS-as-a-service daemon: newline-delimited text protocol
               (LOAD <path|rmat:S:EF:SEED> [sigma] / BFS <gid> <root>
               [deadline-ms] / STATS / HEALTH / SHUTDOWN), one reply line
               per request (request lines are capped at 64 KiB —
               oversize lines get ERR parse line-too-long). BFS requests
               accumulate per graph and flush as a wave at --batch-width
               or at the oldest request's deadline margin, whichever
               first; requests whose deadline lapses in the queue get ERR
               expired; SHUTDOWN drains pending waves before exit and
               prints a stats summary.
               --host ADDR (127.0.0.1) --port N (0 = ephemeral)
               --engine NAME (hybrid-sell-ms) --threads N (4)
               --workers N (2)  coordinator workers per wave
               --dispatchers N (2)  concurrent waves in traversal
               --batch-width N (16)  roots per width-triggered wave
               --batch-deadline-ms N (10)  max accumulation wait
               --max-attempts N (3)  per-root retries; also bounds wave
                        re-submissions after admission-control rejections
               --mem-budget-mb N (unbounded) --max-inflight N (unbounded)
               --liveness-ms N (off)  per-wave watchdog budget: waves run
                        on the supervised self-healing pool; a hung wave
                        is cancelled at N ms without layer progress and
                        abandoned (worker detached + replaced, structured
                        ERR failed replies) after a further N ms
               --breaker-threshold N (3)  consecutive wave failures that
                        trip a graph's circuit breaker open; while open,
                        that graph's BFS requests fast-fail with
                        ERR unavailable <retry-after-ms> and a
                        server-driven half-open probe wave closes the
                        breaker once the graph traverses again
               --breaker-cooldown-ms N (500)  open time before the probe
               --fault-reject-waves N (0)  chaos: shed the first N waves
                        as Rejected to exercise the retry path (needs
                        --mem-budget-mb)
               --fault-hang-waves N (0)  chaos: the first N waves on the
                        first-loaded graph hang non-cooperatively to
                        exercise the watchdog (needs --liveness-ms)
               --fault-fail-waves N (0)  chaos: the next N waves on the
                        first-loaded graph fail deterministically to
                        exercise the circuit breaker
    client     One-shot driver for a running serve daemon (CI smoke)
               --addr HOST:PORT (required)
               --send \"CMD;CMD;...\"  request lines, ';'-separated,
                        sent in order; each reply line is printed
    info       Print artifact manifest + PJRT platform
               --artifacts DIR (artifacts)
    help       This text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_command_and_flags() {
        let a = parse("run --scale 18 --engine simd --no-validate");
        assert_eq!(a.command, "run");
        assert_eq!(a.get::<u32>("scale", 16).unwrap(), 18);
        assert_eq!(a.get_str("engine", "serial"), "simd");
        assert!(a.get_bool("no-validate"));
        assert!(!a.get_bool("validate"));
    }

    #[test]
    fn equals_form() {
        let a = parse("model --threads-list=1,2,4 --affinity=compact");
        assert_eq!(a.get_str("threads-list", ""), "1,2,4");
        assert_eq!(a.get_str("affinity", "balanced"), "compact");
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get::<u32>("scale", 16).unwrap(), 16);
    }

    #[test]
    fn bad_value_errors() {
        let a = parse("run --scale banana");
        assert!(a.get::<u32>("scale", 16).is_err());
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(vec!["run".into(), "oops".into()]).is_err());
    }

    #[test]
    fn no_args_is_help() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "help");
    }
}
