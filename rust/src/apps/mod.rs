//! Graph-analytics applications built on the BFS building block.
//!
//! §3 of the paper motivates BFS as "one of the building blocks for graph
//! analysis algorithms including betweenness centrality, shortest path and
//! connected components". This module implements those three consumers on
//! top of the library's engines, so the repository demonstrates the
//! downstream uses the paper's introduction appeals to:
//!
//! * [`components`] — connected components by repeated BFS sweeps
//!   (optionally batching seeds through `run_batch`);
//! * [`sssp`] — unweighted single-source shortest paths (distances +
//!   path extraction) from any [`crate::bfs::BfsEngine`], single- or
//!   many-source;
//! * [`betweenness`] — Brandes' betweenness centrality, whose forward
//!   phase is layer-synchronous BFS run batched on the engines (and
//!   therefore reuses the paper's frontier machinery).

pub mod betweenness;
pub mod components;
pub mod sssp;

pub use betweenness::betweenness_centrality;
pub use components::{connected_components, connected_components_batched};
pub use sssp::ShortestPaths;
