//! Connected components via repeated BFS sweeps: pick the smallest
//! unassigned vertex, traverse with any engine, label everything reached,
//! repeat. (On undirected graphs BFS reachability = connectivity.)
//!
//! A component sweep is a many-roots workload over one graph — exactly
//! what the two-phase engine API exists for — so the engine is prepared
//! once and every sweep reuses the prepared instance. The sweep can also
//! batch its seeds through the batch-first
//! [`crate::bfs::PreparedBfs::run_batch`] entry point
//! ([`connected_components_batched`]): labels are provably identical to
//! the sequential sweep, and a genuinely batched engine
//! (`hybrid-sell-ms`) shares one traversal per seed wave.

use crate::bfs::BfsEngine;
use crate::graph::Csr;
use crate::Vertex;

/// Component labelling result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Components {
    /// `label[v]` = component id (ids are the component roots' vertex ids).
    pub label: Vec<Vertex>,
    /// Number of distinct components.
    pub count: usize,
}

impl Components {
    /// Size of each component, keyed by label.
    pub fn sizes(&self) -> std::collections::HashMap<Vertex, usize> {
        let mut m = std::collections::HashMap::new();
        for &l in &self.label {
            *m.entry(l).or_insert(0) += 1;
        }
        m
    }

    /// Size of the largest component (RMAT's "giant component").
    pub fn giant_size(&self) -> usize {
        self.sizes().values().copied().max().unwrap_or(0)
    }
}

/// Label the connected components of `g` using `engine` for each sweep.
/// The engine is prepared once; all sweeps share the prepared state.
pub fn connected_components(g: &Csr, engine: &dyn BfsEngine) -> Components {
    connected_components_batched(g, engine, 1)
}

/// Label components, sweeping up to `batch` unlabeled seeds per
/// [`crate::bfs::PreparedBfs::run_batch`] call.
///
/// Labels are identical to the sequential sweep: seeds are collected and
/// processed in ascending vertex order, and a seed already labeled by an
/// earlier seed of the same batch (they share a component) is skipped, so
/// every component keeps its smallest vertex as its label. Widths > 1
/// only pay off with engines whose `run_batch` genuinely shares the
/// traversal (`hybrid-sell-ms`) — a looping engine would traverse the
/// giant component once per co-batched seed.
pub fn connected_components_batched(g: &Csr, engine: &dyn BfsEngine, batch: usize) -> Components {
    let n = g.num_vertices();
    let batch = batch.max(1);
    let prepared = engine.prepare(g).expect("engine preparation failed");
    let mut label: Vec<Option<Vertex>> = vec![None; n];
    let mut count = 0usize;
    let mut cursor = 0usize;
    while cursor < n {
        // the next up-to-`batch` unlabeled seeds, in ascending order;
        // every skipped vertex is already labeled, so the cursor never
        // needs to revisit it
        let mut seeds = Vec::with_capacity(batch);
        while cursor < n && seeds.len() < batch {
            if label[cursor].is_none() {
                seeds.push(cursor as Vertex);
            }
            cursor += 1;
        }
        if seeds.is_empty() {
            break;
        }
        let results = prepared.run_batch(&seeds);
        for (&seed, result) in seeds.iter().zip(results.iter()) {
            if label[seed as usize].is_some() {
                // an earlier seed of this batch owns the component
                continue;
            }
            count += 1;
            for u in 0..n as Vertex {
                if result.tree.reached(u) && label[u as usize].is_none() {
                    label[u as usize] = Some(seed);
                }
            }
        }
    }
    Components { label: label.into_iter().map(|l| l.unwrap()).collect(), count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialQueueBfs;
    use crate::bfs::vectorized::VectorizedBfs;
    use crate::graph::{EdgeList, RmatConfig};

    #[test]
    fn two_components_plus_isolated() {
        // {0,1,2}, {3,4}, {5}
        let el = EdgeList::with_edges(6, vec![(0, 1), (1, 2), (3, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let c = connected_components(&g, &SerialQueueBfs);
        assert_eq!(c.count, 3);
        assert_eq!(c.label[0], c.label[1]);
        assert_eq!(c.label[1], c.label[2]);
        assert_eq!(c.label[3], c.label[4]);
        assert_ne!(c.label[0], c.label[3]);
        assert_ne!(c.label[5], c.label[0]);
        assert_eq!(c.giant_size(), 3);
    }

    #[test]
    fn engines_agree_on_component_structure() {
        let el = RmatConfig::graph500(9, 4).generate(81);
        let g = Csr::from_edge_list(9, &el);
        let a = connected_components(&g, &SerialQueueBfs);
        let b = connected_components(&g, &VectorizedBfs::default());
        assert_eq!(a.count, b.count);
        // same partition (labels are both root ids under ascending sweeps)
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn batched_sweep_labels_equal_sequential() {
        // the label-equivalence guarantee, for a looping engine and for
        // the genuinely batched MS engine, across batch widths
        let el = RmatConfig::graph500(9, 4).generate(83);
        let g = Csr::from_edge_list(9, &el);
        let sequential = connected_components(&g, &SerialQueueBfs);
        for width in [2usize, 16, 64] {
            let batched = connected_components_batched(&g, &SerialQueueBfs, width);
            assert_eq!(batched.count, sequential.count, "width {width}");
            assert_eq!(batched.label, sequential.label, "width {width}");
        }
        let ms = crate::bfs::multi_source::MultiSourceSellBfs {
            num_threads: 2,
            ..Default::default()
        };
        let batched = connected_components_batched(&g, &ms, 16);
        assert_eq!(batched.count, sequential.count);
        assert_eq!(batched.label, sequential.label);
    }

    #[test]
    fn rmat_has_giant_component_and_isolated_vertices() {
        // the §5.3 story: RMAT leaves unconnected vertices (zero-TEPS roots)
        let el = RmatConfig::graph500(10, 16).generate(82);
        let g = Csr::from_edge_list(10, &el);
        let c = connected_components(&g, &SerialQueueBfs);
        assert!(c.count > 1, "expected isolated vertices");
        assert!(c.giant_size() > g.num_vertices() / 2, "expected a giant component");
    }
}
