//! Connected components via repeated BFS sweeps: pick the smallest
//! unassigned vertex, traverse with any engine, label everything reached,
//! repeat. (On undirected graphs BFS reachability = connectivity.)
//!
//! A component sweep is a many-roots workload over one graph — exactly
//! what the two-phase engine API exists for — so the engine is prepared
//! once and every sweep reuses the prepared instance.

use crate::bfs::BfsEngine;
use crate::graph::Csr;
use crate::Vertex;

/// Component labelling result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Components {
    /// `label[v]` = component id (ids are the component roots' vertex ids).
    pub label: Vec<Vertex>,
    /// Number of distinct components.
    pub count: usize,
}

impl Components {
    /// Size of each component, keyed by label.
    pub fn sizes(&self) -> std::collections::HashMap<Vertex, usize> {
        let mut m = std::collections::HashMap::new();
        for &l in &self.label {
            *m.entry(l).or_insert(0) += 1;
        }
        m
    }

    /// Size of the largest component (RMAT's "giant component").
    pub fn giant_size(&self) -> usize {
        self.sizes().values().copied().max().unwrap_or(0)
    }
}

/// Label the connected components of `g` using `engine` for each sweep.
/// The engine is prepared once; all sweeps share the prepared state.
pub fn connected_components(g: &Csr, engine: &dyn BfsEngine) -> Components {
    let n = g.num_vertices();
    let prepared = engine.prepare(g).expect("engine preparation failed");
    let mut label: Vec<Option<Vertex>> = vec![None; n];
    let mut count = 0usize;
    for v in 0..n as Vertex {
        if label[v as usize].is_some() {
            continue;
        }
        count += 1;
        let result = prepared.run(v);
        for u in 0..n as Vertex {
            if result.tree.reached(u) && label[u as usize].is_none() {
                label[u as usize] = Some(v);
            }
        }
    }
    Components { label: label.into_iter().map(|l| l.unwrap()).collect(), count }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialQueueBfs;
    use crate::bfs::vectorized::VectorizedBfs;
    use crate::graph::{EdgeList, RmatConfig};

    #[test]
    fn two_components_plus_isolated() {
        // {0,1,2}, {3,4}, {5}
        let el = EdgeList::with_edges(6, vec![(0, 1), (1, 2), (3, 4)]);
        let g = Csr::from_edge_list(0, &el);
        let c = connected_components(&g, &SerialQueueBfs);
        assert_eq!(c.count, 3);
        assert_eq!(c.label[0], c.label[1]);
        assert_eq!(c.label[1], c.label[2]);
        assert_eq!(c.label[3], c.label[4]);
        assert_ne!(c.label[0], c.label[3]);
        assert_ne!(c.label[5], c.label[0]);
        assert_eq!(c.giant_size(), 3);
    }

    #[test]
    fn engines_agree_on_component_structure() {
        let el = RmatConfig::graph500(9, 4).generate(81);
        let g = Csr::from_edge_list(9, &el);
        let a = connected_components(&g, &SerialQueueBfs);
        let b = connected_components(&g, &VectorizedBfs::default());
        assert_eq!(a.count, b.count);
        // same partition (labels are both root ids under ascending sweeps)
        assert_eq!(a.label, b.label);
    }

    #[test]
    fn rmat_has_giant_component_and_isolated_vertices() {
        // the §5.3 story: RMAT leaves unconnected vertices (zero-TEPS roots)
        let el = RmatConfig::graph500(10, 16).generate(82);
        let g = Csr::from_edge_list(10, &el);
        let c = connected_components(&g, &SerialQueueBfs);
        assert!(c.count > 1, "expected isolated vertices");
        assert!(c.giant_size() > g.num_vertices() / 2, "expected a giant component");
    }
}
