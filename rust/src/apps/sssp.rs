//! Unweighted single-source shortest paths on top of any BFS engine:
//! the spanning tree's distance map *is* the shortest-path metric, and
//! the predecessor array encodes one shortest path per vertex.

use crate::bfs::{BfsEngine, BfsTree};
use crate::graph::Csr;
use crate::Vertex;

/// Shortest-path answers from one source.
pub struct ShortestPaths {
    pub source: Vertex,
    pub tree: BfsTree,
    dist: Vec<u32>,
}

impl ShortestPaths {
    /// Compute with the given engine.
    pub fn compute(g: &Csr, source: Vertex, engine: &dyn BfsEngine) -> Self {
        let result = engine.run(g, source);
        let dist = result.tree.distances().expect("engine produced a corrupt tree");
        ShortestPaths { source, tree: result.tree, dist }
    }

    /// Shortest paths from many sources through one prepared engine. The
    /// sources go through the batch-first
    /// [`crate::bfs::PreparedBfs::run_batch`] entry point, so a batched
    /// engine (`hybrid-sell-ms`) answers a whole 16-source wave with one
    /// shared traversal; every other engine loops internally. Returns one
    /// answer per source, in order — note every answer holds its own
    /// O(V) tree/distance arrays, so callers that only fold over the
    /// answers should chunk their source list.
    pub fn compute_many(g: &Csr, sources: &[Vertex], engine: &dyn BfsEngine) -> Vec<Self> {
        let prepared = engine.prepare(g).expect("engine preparation failed");
        prepared
            .run_batch(sources)
            .into_iter()
            .zip(sources.iter())
            .map(|(result, &source)| {
                let dist = result.tree.distances().expect("engine produced a corrupt tree");
                ShortestPaths { source, tree: result.tree, dist }
            })
            .collect()
    }

    /// Hop distance to `v`, or `None` if unreachable.
    pub fn distance(&self, v: Vertex) -> Option<u32> {
        match self.dist[v as usize] {
            u32::MAX => None,
            d => Some(d),
        }
    }

    /// One shortest path `source → v` (inclusive), or `None` if
    /// unreachable.
    pub fn path_to(&self, v: Vertex) -> Option<Vec<Vertex>> {
        self.distance(v)?;
        let mut path = vec![v];
        let mut cur = v;
        while cur != self.source {
            cur = self.tree.parent(cur)?;
            path.push(cur);
        }
        path.reverse();
        Some(path)
    }

    /// Eccentricity of the source (max finite distance).
    pub fn eccentricity(&self) -> u32 {
        self.dist.iter().copied().filter(|&d| d != u32::MAX).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::serial::SerialQueueBfs;
    use crate::bfs::vectorized::VectorizedBfs;
    use crate::graph::{EdgeList, RmatConfig};

    fn grid3x3() -> Csr {
        // 0-1-2 / 3-4-5 / 6-7-8 grid
        let mut e = Vec::new();
        for r in 0..3u32 {
            for c in 0..3u32 {
                let v = r * 3 + c;
                if c < 2 {
                    e.push((v, v + 1));
                }
                if r < 2 {
                    e.push((v, v + 3));
                }
            }
        }
        Csr::from_edge_list(0, &EdgeList::with_edges(9, e))
    }

    #[test]
    fn grid_distances_and_paths() {
        let g = grid3x3();
        let sp = ShortestPaths::compute(&g, 0, &SerialQueueBfs);
        assert_eq!(sp.distance(8), Some(4)); // manhattan distance
        assert_eq!(sp.distance(4), Some(2));
        assert_eq!(sp.eccentricity(), 4);
        let p = sp.path_to(8).unwrap();
        assert_eq!(p.len(), 5);
        assert_eq!(p[0], 0);
        assert_eq!(*p.last().unwrap(), 8);
        // every hop is a real edge
        for w in p.windows(2) {
            assert!(g.has_edge(w[0], w[1]));
        }
    }

    #[test]
    fn unreachable_is_none() {
        let el = EdgeList::with_edges(4, vec![(0, 1)]);
        let g = Csr::from_edge_list(0, &el);
        let sp = ShortestPaths::compute(&g, 0, &SerialQueueBfs);
        assert_eq!(sp.distance(3), None);
        assert_eq!(sp.path_to(3), None);
    }

    #[test]
    fn compute_many_equals_per_source_compute() {
        let el = RmatConfig::graph500(9, 8).generate(92);
        let g = Csr::from_edge_list(9, &el);
        let sources: Vec<Vertex> = (0..20).map(|i| (i * 17) % g.num_vertices() as u32).collect();
        let ms = crate::bfs::multi_source::MultiSourceSellBfs {
            num_threads: 2,
            ..Default::default()
        };
        let many = ShortestPaths::compute_many(&g, &sources, &ms);
        assert_eq!(many.len(), sources.len());
        for (sp, &s) in many.iter().zip(sources.iter()) {
            assert_eq!(sp.source, s);
            let single = ShortestPaths::compute(&g, s, &SerialQueueBfs);
            for v in 0..g.num_vertices() as Vertex {
                assert_eq!(sp.distance(v), single.distance(v), "source {s}, vertex {v}");
            }
        }
    }

    #[test]
    fn vectorized_engine_gives_valid_paths() {
        let el = RmatConfig::graph500(9, 8).generate(91);
        let g = Csr::from_edge_list(9, &el);
        let root = (0..g.num_vertices() as u32).max_by_key(|&v| g.degree(v)).unwrap();
        let sp_v = ShortestPaths::compute(&g, root, &VectorizedBfs::default());
        let sp_s = ShortestPaths::compute(&g, root, &SerialQueueBfs);
        for v in 0..g.num_vertices() as Vertex {
            assert_eq!(sp_v.distance(v), sp_s.distance(v), "distance({v})");
            if let Some(p) = sp_v.path_to(v) {
                assert_eq!(p.len() as u32 - 1, sp_v.distance(v).unwrap());
                for w in p.windows(2) {
                    assert!(g.has_edge(w[0], w[1]));
                }
            }
        }
    }
}
