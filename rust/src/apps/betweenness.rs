//! Brandes' betweenness centrality (unweighted graphs).
//!
//! The forward phase of Brandes' algorithm is exactly a layer-synchronous
//! BFS that also counts shortest paths (`sigma`); the backward phase
//! accumulates pair dependencies over the layers in reverse. This is the
//! flagship "BFS as a building block" application the paper's §3 cites.
//!
//! The forward BFS runs on the library's engines through the batch-first
//! entry point: sources go through one prepared engine in wave-sized
//! [`crate::bfs::PreparedBfs::run_batch`] chunks, so a batched engine
//! (`hybrid-sell-ms`) answers 16 sources per shared traversal while the
//! resident result set stays O(wave × V) even for exact all-sources
//! runs. Path counts and dependencies are then recovered per source from
//! the exact BFS depth map, level by level — mathematically identical to
//! Brandes' queue-order recurrences, which only ever read across
//! adjacent levels.
//!
//! Exact computation is O(V·E); `betweenness_centrality` therefore takes
//! the set of source vertices, so callers can do exact (all sources) or
//! sampled/approximate (k random sources, Bader-style) centrality.

use crate::bfs::BfsEngine;
use crate::graph::Csr;
use crate::Vertex;

/// Brandes' algorithm from the given sources, with the forward BFS run
/// (batched) on `engine`. Returns per-vertex scores (divide by
/// `sources.len()` for a sampled estimate; exact undirected betweenness
/// conventionally halves the total as well).
pub fn betweenness_centrality(g: &Csr, sources: &[Vertex], engine: &dyn BfsEngine) -> Vec<f64> {
    let n = g.num_vertices();
    let mut bc = vec![0.0f64; n];
    // reused scratch
    let mut sigma = vec![0.0f64; n];
    let mut delta = vec![0.0f64; n];
    let mut levels: Vec<Vec<Vertex>> = Vec::new();

    let prepared = engine.prepare(g).expect("engine preparation failed");
    // one wave-sized run_batch call at a time: each result holds an
    // n-length predecessor array, so batching ALL sources at once would
    // make the exact (all-sources) use O(V²) resident — chunking keeps
    // the shared-traversal win with O(wave × V) memory
    for chunk in sources.chunks(crate::bfs::multi_source::MS_WAVE) {
        for (result, &s) in prepared.run_batch(chunk).into_iter().zip(chunk.iter()) {
            accumulate_source(g, s, &result, &mut bc, &mut sigma, &mut delta, &mut levels);
        }
    }
    bc
}

/// One source's Brandes forward/backward accumulation from its exact BFS
/// depth map, level by level.
fn accumulate_source(
    g: &Csr,
    s: Vertex,
    result: &crate::bfs::BfsResult,
    bc: &mut [f64],
    sigma: &mut [f64],
    delta: &mut [f64],
    levels: &mut Vec<Vec<Vertex>>,
) {
    let dist = result.tree.distances().expect("engine produced a corrupt tree");
    // bucket reached vertices by depth — the layer-synchronous order
    // both Brandes phases need
    for level in levels.iter_mut() {
        level.clear();
    }
    for (v, &d) in dist.iter().enumerate() {
        if d == u32::MAX {
            continue;
        }
        let d = d as usize;
        while levels.len() <= d {
            levels.push(Vec::new());
        }
        levels[d].push(v as Vertex);
    }
    let depth = dist
        .iter()
        .filter(|&&d| d != u32::MAX)
        .max()
        .map(|&d| d as usize + 1)
        .unwrap_or(0);

    sigma.fill(0.0);
    delta.fill(0.0);
    sigma[s as usize] = 1.0;

    // forward: path counts, level by level (a vertex at depth d only
    // reads depth d-1, so within-level order is irrelevant)
    for d in 1..depth {
        for &v in &levels[d] {
            for &u in g.neighbors(v) {
                if dist[u as usize] == (d - 1) as u32 {
                    sigma[v as usize] += sigma[u as usize];
                }
            }
        }
    }

    // backward: dependency accumulation, deepest level first
    for d in (1..depth).rev() {
        for &w in &levels[d] {
            for &v in g.neighbors(w) {
                if dist[v as usize] == (d - 1) as u32 {
                    let share =
                        sigma[v as usize] / sigma[w as usize] * (1.0 + delta[w as usize]);
                    delta[v as usize] += share;
                }
            }
            if w != s {
                bc[w as usize] += delta[w as usize];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfs::multi_source::MultiSourceSellBfs;
    use crate::bfs::serial::SerialQueueBfs;
    use crate::graph::{EdgeList, RmatConfig};

    fn csr(n: usize, edges: Vec<(Vertex, Vertex)>) -> Csr {
        Csr::from_edge_list(0, &EdgeList::with_edges(n, edges))
    }

    fn exact(g: &Csr) -> Vec<f64> {
        let all: Vec<Vertex> = (0..g.num_vertices() as Vertex).collect();
        // undirected convention: halve (each pair counted from both ends)
        betweenness_centrality(g, &all, &SerialQueueBfs)
            .into_iter()
            .map(|x| x / 2.0)
            .collect()
    }

    #[test]
    fn path_graph_center_dominates() {
        // 0-1-2-3-4: bc(2) = 4 (pairs {0,3},{0,4},{1,3},{1,4} ... exactly
        // the pairs whose unique path crosses 2)
        let g = csr(5, vec![(0, 1), (1, 2), (2, 3), (3, 4)]);
        let bc = exact(&g);
        assert!((bc[2] - 4.0).abs() < 1e-9, "{bc:?}");
        assert!((bc[1] - 3.0).abs() < 1e-9);
        assert!((bc[0] - 0.0).abs() < 1e-9);
        assert_eq!(bc[1], bc[3]);
    }

    #[test]
    fn star_hub_gets_all_pairs() {
        // hub 0 with 4 leaves: every leaf pair's unique path crosses the
        // hub → bc(0) = C(4,2) = 6, leaves 0.
        let g = csr(5, vec![(0, 1), (0, 2), (0, 3), (0, 4)]);
        let bc = exact(&g);
        assert!((bc[0] - 6.0).abs() < 1e-9, "{bc:?}");
        for v in 1..5 {
            assert!((bc[v] - 0.0).abs() < 1e-9);
        }
    }

    #[test]
    fn cycle_symmetric() {
        // all vertices of a cycle are equivalent
        let n = 7;
        let g = csr(n, (0..n as u32).map(|i| (i, (i + 1) % n as u32)).collect());
        let bc = exact(&g);
        for v in 1..n {
            assert!((bc[v] - bc[0]).abs() < 1e-9, "{bc:?}");
        }
    }

    #[test]
    fn multiple_shortest_paths_split_credit() {
        // square 0-1, 0-2, 1-3, 2-3: by symmetry every vertex carries one
        // half-credit — pair {0,3} splits over {1,2}, pair {1,2} splits
        // over {0,3}.
        let g = csr(4, vec![(0, 1), (0, 2), (1, 3), (2, 3)]);
        let bc = exact(&g);
        for v in 0..4 {
            assert!((bc[v] - 0.5).abs() < 1e-9, "{bc:?}");
        }
    }

    #[test]
    fn sampled_subset_is_partial_sum() {
        let el = RmatConfig::graph500(8, 8).generate(93);
        let g = Csr::from_edge_list(8, &el);
        let all: Vec<Vertex> = (0..g.num_vertices() as Vertex).collect();
        let full = betweenness_centrality(&g, &all, &SerialQueueBfs);
        let half = betweenness_centrality(&g, &all[..all.len() / 2], &SerialQueueBfs);
        let rest = betweenness_centrality(&g, &all[all.len() / 2..], &SerialQueueBfs);
        for v in 0..g.num_vertices() {
            assert!((full[v] - half[v] - rest[v]).abs() < 1e-6);
        }
    }

    #[test]
    fn batched_engine_agrees_with_serial() {
        // the batch-first path: MS waves must produce the same scores as
        // the serial per-source forward passes (identical depth maps →
        // identical recurrences; only FP summation order may differ)
        let el = RmatConfig::graph500(9, 8).generate(95);
        let g = Csr::from_edge_list(9, &el);
        let sources: Vec<Vertex> = (0..40).map(|i| (i * 13) % g.num_vertices() as u32).collect();
        let serial = betweenness_centrality(&g, &sources, &SerialQueueBfs);
        let ms = MultiSourceSellBfs { num_threads: 2, ..Default::default() };
        let batched = betweenness_centrality(&g, &sources, &ms);
        for v in 0..g.num_vertices() {
            assert!(
                (serial[v] - batched[v]).abs() < 1e-6,
                "vertex {v}: serial {} vs batched {}",
                serial[v],
                batched[v]
            );
        }
    }

    #[test]
    fn hubs_rank_high_on_rmat() {
        let el = RmatConfig::graph500(9, 8).generate(94);
        let g = Csr::from_edge_list(9, &el);
        let sources: Vec<Vertex> = (0..64).collect();
        let bc = betweenness_centrality(&g, &sources, &SerialQueueBfs);
        let top_bc = (0..g.num_vertices()).max_by(|&a, &b| bc[a].total_cmp(&bc[b])).unwrap();
        let deg_rank_of_top = {
            let mut by_deg: Vec<usize> = (0..g.num_vertices()).collect();
            by_deg.sort_by_key(|&v| std::cmp::Reverse(g.degree(v as Vertex)));
            by_deg.iter().position(|&v| v == top_bc).unwrap()
        };
        assert!(
            deg_rank_of_top < g.num_vertices() / 10,
            "top-bc vertex degree rank {deg_rank_of_top}"
        );
    }
}
