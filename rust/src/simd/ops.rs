//! The vector unit itself: one `Vpu` per hardware thread, with methods
//! named after the AVX-512 intrinsics of the paper's Listing 1.
//!
//! Semantics notes (all load-bearing for the reproduction):
//!
//! * **Masked ops** write only the lanes whose mask bit is set; other lanes
//!   take the `src` operand's value (`_mm512_mask_or_epi32(src, k, a, b)`).
//! * **Gather** (`_mm512_i32gather_epi32`) reads `base[idx[lane]]` per lane.
//! * **Scatter** (`_mm512_mask_i32scatter_epi32`) processes lanes from 0
//!   upward; when two enabled lanes carry the same index the higher lane's
//!   value lands last and *wins* — the lower lane's update is lost. This is
//!   the architectural behaviour that makes the paper's word-granularity
//!   bitmap updates racy even within a single thread, and is why the
//!   restoration process exists. `scatter_conflicts` counts the lost lanes.
//! * **Prefetches** are architectural no-ops that only move data earlier in
//!   time; the emulator records them so the cost model can credit latency
//!   hiding (§4.2 Prefetching) and tests can assert coverage.

use std::sync::atomic::{AtomicI32, AtomicU32, Ordering};

use super::counters::VpuCounters;
use super::vec512::{Mask16, VecI32x16, LANES};

/// One emulated VPU (one per worker thread).
#[derive(Clone, Debug, Default)]
pub struct Vpu {
    /// Event counters; read by the performance model after a run.
    pub counters: VpuCounters,
}

impl Vpu {
    pub fn new() -> Self {
        Vpu { counters: VpuCounters::new() }
    }

    // ---- register initialisation --------------------------------------

    /// `_mm512_set1_epi32`.
    #[inline(always)]
    pub fn set1_epi32(&mut self, x: i32) -> VecI32x16 {
        self.counters.alu_ops += 1;
        VecI32x16::splat(x)
    }

    // ---- loads ---------------------------------------------------------

    /// `_mm512_load_epi32` — full 16-lane aligned load from `src[offset..]`.
    #[inline(always)]
    pub fn load_epi32(&mut self, src: &[i32], offset: usize) -> VecI32x16 {
        self.counters.vector_loads += 1;
        let mut out = [0i32; LANES];
        out.copy_from_slice(&src[offset..offset + LANES]);
        VecI32x16(out)
    }

    /// `_mm512_mask_loadu_epi32` — masked (possibly partial) load; disabled
    /// lanes read as 0. Used for peel/remainder chunks (§4.2).
    #[inline(always)]
    pub fn mask_load_epi32(&mut self, mask: Mask16, src: &[i32], offset: usize) -> VecI32x16 {
        self.counters.masked_loads += 1;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = src[offset + i];
            }
        }
        VecI32x16(out)
    }

    // ---- lanewise ALU ----------------------------------------------------

    /// `_mm512_div_epi32` (SVML) — lanewise signed division.
    #[inline(always)]
    pub fn div_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x / y)
    }

    /// `_mm512_rem_epi32` (SVML) — lanewise signed remainder.
    #[inline(always)]
    pub fn rem_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x % y)
    }

    /// `_mm512_sllv_epi32` — lanewise variable left shift.
    #[inline(always)]
    pub fn sllv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&counts, |x, c| ((x as u32) << (c as u32 & 31)) as i32)
    }

    /// `_mm512_srlv_epi32` — lanewise variable logical right shift (used by
    /// the vectorized restoration to walk word halves).
    #[inline(always)]
    pub fn srlv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&counts, |x, c| ((x as u32) >> (c as u32 & 31)) as i32)
    }

    /// `_mm512_and_epi32`.
    #[inline(always)]
    pub fn and_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x & y)
    }

    /// `_mm512_andnot_epi32(a, b)` — lanewise `(!a) & b`. The MS-BFS
    /// visit-mask filter: bits of `b` (the frontier masks) not yet present
    /// in `a` (the visit masks).
    #[inline(always)]
    pub fn andnot_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| !x & y)
    }

    /// `_mm512_or_epi32`.
    #[inline(always)]
    pub fn or_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x | y)
    }

    /// `_mm512_add_epi32`.
    #[inline(always)]
    pub fn add_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x.wrapping_add(y))
    }

    /// `_mm512_sub_epi32`.
    #[inline(always)]
    pub fn sub_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        a.zip(&b, |x, y| x.wrapping_sub(y))
    }

    /// `_mm512_mask_or_epi32(src, k, a, b)` — OR where masked, pass `src`
    /// through elsewhere. Listing 1 uses this to merge new bits into the
    /// gathered output-queue words.
    #[inline(always)]
    pub fn mask_or_epi32(&mut self, src: VecI32x16, mask: Mask16, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        self.counters.alu_ops += 1;
        let mut out = src.0;
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = a.0[i] | b.0[i];
            }
        }
        VecI32x16(out)
    }

    // ---- mask ops --------------------------------------------------------

    /// `_mm512_test_epi32_mask(a, b)` — per-lane `(a & b) != 0` into a mask.
    #[inline(always)]
    pub fn test_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        self.counters.mask_ops += 1;
        let mut m = 0u16;
        for i in 0..LANES {
            if a.0[i] & b.0[i] != 0 {
                m |= 1 << i;
            }
        }
        Mask16(m)
    }

    /// `_mm512_cmplt_epi32_mask(a, b)` — per-lane `a < b` (restoration's
    /// negative-predecessor test).
    #[inline(always)]
    pub fn cmplt_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        self.counters.mask_ops += 1;
        let mut m = 0u16;
        for i in 0..LANES {
            if a.0[i] < b.0[i] {
                m |= 1 << i;
            }
        }
        Mask16(m)
    }

    /// `_mm512_kor`.
    #[inline(always)]
    pub fn kor(&mut self, a: Mask16, b: Mask16) -> Mask16 {
        self.counters.mask_ops += 1;
        Mask16(a.0 | b.0)
    }

    /// `_mm512_kand`.
    #[inline(always)]
    pub fn kand(&mut self, a: Mask16, b: Mask16) -> Mask16 {
        self.counters.mask_ops += 1;
        Mask16(a.0 & b.0)
    }

    /// `_mm512_knot`.
    #[inline(always)]
    pub fn knot(&mut self, a: Mask16) -> Mask16 {
        self.counters.mask_ops += 1;
        Mask16(!a.0)
    }

    // ---- gather / scatter -------------------------------------------------

    /// `_mm512_i32gather_epi32(vindex, base, scale)` over an `i32` array.
    #[inline(always)]
    pub fn i32gather_epi32(&mut self, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += LANES as u64;
        let mut out = [0i32; LANES];
        for (o, &idx) in out.iter_mut().zip(vindex.0.iter()) {
            *o = base[idx as usize];
        }
        VecI32x16(out)
    }

    /// Masked gather; disabled lanes read as 0. (The paper's peel/remainder
    /// handling filters "according to the precalculated mask", §4.2.)
    #[inline(always)]
    pub fn mask_i32gather_epi32(&mut self, mask: Mask16, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += mask.count() as u64;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = base[vindex.0[i] as usize];
            }
        }
        VecI32x16(out)
    }

    /// Gather over a `u32` word array (the bitmap words). Bit patterns pass
    /// through unchanged.
    #[inline(always)]
    pub fn i32gather_words(&mut self, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += LANES as u64;
        let mut out = [0i32; LANES];
        for (o, &idx) in out.iter_mut().zip(vindex.0.iter()) {
            *o = base[idx as usize] as i32;
        }
        VecI32x16(out)
    }

    /// Masked variant of [`Self::i32gather_words`].
    #[inline(always)]
    pub fn mask_i32gather_words(&mut self, mask: Mask16, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += mask.count() as u64;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = base[vindex.0[i] as usize] as i32;
            }
        }
        VecI32x16(out)
    }

    /// `_mm512_mask_i32scatter_epi32(base, k, vindex, v, scale)` over `i32`.
    ///
    /// Lanes are committed in ascending order, so with duplicate indices the
    /// **highest enabled lane wins**; every overwritten store is counted in
    /// `scatter_conflicts`. This is the precise mechanism behind Fig 6's
    /// "visited bitmap race" when the paper scatters whole 32-bit words.
    #[inline(always)]
    pub fn mask_i32scatter_epi32(&mut self, base: &mut [i32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        self.counters.scatters += 1;
        self.counters.scatter_lanes += mask.count() as u64;
        for i in 0..LANES {
            if mask.test_lane(i) {
                // conflict detection: does any higher enabled lane target the
                // same slot?
                for j in (i + 1)..LANES {
                    if mask.test_lane(j) && vindex.0[j] == vindex.0[i] {
                        self.counters.scatter_conflicts += 1;
                        break;
                    }
                }
                base[vindex.0[i] as usize] = v.0[i];
            }
        }
    }

    /// Masked scatter into a `u32` word array (bitmap words).
    #[inline(always)]
    pub fn mask_i32scatter_words(&mut self, base: &mut [u32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        self.counters.scatters += 1;
        self.counters.scatter_lanes += mask.count() as u64;
        for i in 0..LANES {
            if mask.test_lane(i) {
                for j in (i + 1)..LANES {
                    if mask.test_lane(j) && vindex.0[j] == vindex.0[i] {
                        self.counters.scatter_conflicts += 1;
                        break;
                    }
                }
                base[vindex.0[i] as usize] = v.0[i] as u32;
            }
        }
    }

    /// Full 16-lane load from a `u32` vertex array (the CSR `rows` array;
    /// vertex ids < 2³¹ so the i32 reinterpretation is lossless).
    #[inline(always)]
    pub fn load_vertices(&mut self, src: &[u32], offset: usize) -> VecI32x16 {
        self.counters.vector_loads += 1;
        let mut out = [0i32; LANES];
        for (o, &x) in out.iter_mut().zip(src[offset..offset + LANES].iter()) {
            *o = x as i32;
        }
        VecI32x16(out)
    }

    /// Masked load from a `u32` vertex array (peel/remainder chunks).
    #[inline(always)]
    pub fn mask_load_vertices(&mut self, mask: Mask16, src: &[u32], offset: usize) -> VecI32x16 {
        self.counters.masked_loads += 1;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = src[offset + i] as u32 as i32;
            }
        }
        VecI32x16(out)
    }

    // ---- shared-memory (multi-thread) gather / scatter ---------------------
    //
    // Same instructions as above, but against the `AtomicU32`/`AtomicI32`
    // cells the threaded algorithms share. All accesses are `Relaxed` plain
    // loads/stores — the *algorithmic* races of the paper are preserved
    // (whole-word racy stores), only the language-level UB is removed.

    /// Masked gather of bitmap words shared across threads.
    #[inline(always)]
    pub fn mask_gather_shared_words(&mut self, mask: Mask16, vindex: VecI32x16, base: &[AtomicU32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += mask.count() as u64;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = base[vindex.0[i] as usize].load(Ordering::Relaxed) as i32;
            }
        }
        VecI32x16(out)
    }

    /// Masked scatter of whole bitmap words shared across threads — the
    /// racy store at the heart of §3.3.2. Highest enabled lane wins on
    /// intra-vector duplicates; across threads, last store wins. Both kinds
    /// of lost update are repaired by restoration.
    #[inline(always)]
    pub fn mask_scatter_shared_words(&mut self, base: &[AtomicU32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        self.counters.scatters += 1;
        let enabled = mask.count();
        self.counters.scatter_lanes += enabled as u64;
        let check_conflicts = enabled > 1;
        for i in 0..LANES {
            if mask.test_lane(i) {
                if check_conflicts {
                    for j in (i + 1)..LANES {
                        if mask.test_lane(j) && vindex.0[j] == vindex.0[i] {
                            self.counters.scatter_conflicts += 1;
                            break;
                        }
                    }
                }
                base[vindex.0[i] as usize].store(v.0[i] as u32, Ordering::Relaxed);
            }
        }
    }

    /// Masked gather from a shared `i32` array (predecessors).
    #[inline(always)]
    pub fn mask_gather_shared_i32(&mut self, mask: Mask16, vindex: VecI32x16, base: &[AtomicI32]) -> VecI32x16 {
        self.counters.gathers += 1;
        self.counters.gather_lanes += mask.count() as u64;
        let mut out = [0i32; LANES];
        for (i, o) in out.iter_mut().enumerate() {
            if mask.test_lane(i) {
                *o = base[vindex.0[i] as usize].load(Ordering::Relaxed);
            }
        }
        VecI32x16(out)
    }

    /// Masked scatter into a shared `i32` array (predecessors). Duplicate
    /// vertex ids within the vector reproduce the benign race of §3.2:
    /// the highest lane's parent wins.
    #[inline(always)]
    pub fn mask_scatter_shared_i32(&mut self, base: &[AtomicI32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        self.counters.scatters += 1;
        let enabled = mask.count();
        self.counters.scatter_lanes += enabled as u64;
        let check_conflicts = enabled > 1;
        for i in 0..LANES {
            if mask.test_lane(i) {
                if check_conflicts {
                    for j in (i + 1)..LANES {
                        if mask.test_lane(j) && vindex.0[j] == vindex.0[i] {
                            self.counters.scatter_conflicts += 1;
                            break;
                        }
                    }
                }
                base[vindex.0[i] as usize].store(v.0[i], Ordering::Relaxed);
            }
        }
    }

    /// `_mm512_mask_reduce_or_epi32` — horizontal OR of the enabled lanes
    /// (used by the vectorized restoration to rebuild a bitmap word).
    #[inline(always)]
    pub fn mask_reduce_or_epi32(&mut self, mask: Mask16, v: VecI32x16) -> i32 {
        self.counters.mask_ops += 1;
        let mut acc = 0i32;
        for i in 0..LANES {
            if mask.test_lane(i) {
                acc |= v.0[i];
            }
        }
        acc
    }

    // ---- prefetch ----------------------------------------------------------

    /// `_mm512_prefetch_i32gather_ps(vindex, base, scale, hint)` — gather
    /// prefetch; `_MM_HINT_T0` targets L1, `_MM_HINT_T1` targets L2 (§4.2).
    #[inline(always)]
    pub fn prefetch_i32gather(&mut self, _vindex: VecI32x16, hint: PrefetchHint) {
        match hint {
            PrefetchHint::T0 => self.counters.prefetch_l1 += 1,
            PrefetchHint::T1 => self.counters.prefetch_l2 += 1,
        }
    }

    /// `_mm512_mask_prefetch_i32scatter_ps`.
    #[inline(always)]
    pub fn mask_prefetch_i32scatter(&mut self, _mask: Mask16, _vindex: VecI32x16, hint: PrefetchHint) {
        match hint {
            PrefetchHint::T0 => self.counters.prefetch_l1 += 1,
            PrefetchHint::T1 => self.counters.prefetch_l2 += 1,
        }
    }

    /// Scalar `_mm_prefetch` (next-iteration rows prefetch, after [14]).
    #[inline(always)]
    pub fn prefetch_scalar(&mut self, hint: PrefetchHint) {
        match hint {
            PrefetchHint::T0 => self.counters.prefetch_l1 += 1,
            PrefetchHint::T1 => self.counters.prefetch_l2 += 1,
        }
    }

    // ---- chunk accounting ---------------------------------------------------

    /// Record a full 16-lane chunk (used by the explorer's chunk loop).
    #[inline(always)]
    pub fn note_full_chunk(&mut self) {
        self.counters.full_chunks += 1;
    }

    /// Record `n` peel lanes.
    #[inline(always)]
    pub fn note_peel(&mut self, n: usize) {
        self.counters.peel_lanes += n as u64;
    }

    /// Record `n` remainder lanes.
    #[inline(always)]
    pub fn note_remainder(&mut self, n: usize) {
        self.counters.remainder_lanes += n as u64;
    }

    /// Record one explore issue carrying `active` real-work lanes (the
    /// occupancy statistic the SELL-16-σ layout targets).
    #[inline(always)]
    pub fn note_explore_issue(&mut self, active: u32) {
        self.counters.explore_issues += 1;
        self.counters.lanes_active += active as u64;
    }
}

/// `_MM_HINT_T0` / `_MM_HINT_T1` (§4.2: prefetch into L1 or L2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PrefetchHint {
    T0,
    T1,
}

// The counted backend of the pluggable-VPU design
// ([`crate::simd::backend`]): every trait method delegates to the
// counting inherent twin above, so the emulator's semantics — and its
// event stream — are byte-for-byte what they were before backends
// existed. Engines written against `VpuBackend` monomorphize onto this
// impl when the run selects `--vpu counted` (or an `auto` warm-up root).
impl super::backend::VpuBackend for Vpu {
    const NAME: &'static str = "counted";
    const COUNTED: bool = true;

    #[inline(always)]
    fn new() -> Self {
        Vpu::new()
    }

    #[inline(always)]
    fn counters(&self) -> VpuCounters {
        self.counters
    }

    #[inline(always)]
    fn set1_epi32(&mut self, x: i32) -> VecI32x16 {
        Vpu::set1_epi32(self, x)
    }

    #[inline(always)]
    fn load_epi32(&mut self, src: &[i32], offset: usize) -> VecI32x16 {
        Vpu::load_epi32(self, src, offset)
    }

    #[inline(always)]
    fn mask_load_epi32(&mut self, mask: Mask16, src: &[i32], offset: usize) -> VecI32x16 {
        Vpu::mask_load_epi32(self, mask, src, offset)
    }

    #[inline(always)]
    fn load_vertices(&mut self, src: &[u32], offset: usize) -> VecI32x16 {
        Vpu::load_vertices(self, src, offset)
    }

    #[inline(always)]
    fn mask_load_vertices(&mut self, mask: Mask16, src: &[u32], offset: usize) -> VecI32x16 {
        Vpu::mask_load_vertices(self, mask, src, offset)
    }

    #[inline(always)]
    fn div_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::div_epi32(self, a, b)
    }

    #[inline(always)]
    fn rem_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::rem_epi32(self, a, b)
    }

    #[inline(always)]
    fn sllv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        Vpu::sllv_epi32(self, a, counts)
    }

    #[inline(always)]
    fn srlv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        Vpu::srlv_epi32(self, a, counts)
    }

    #[inline(always)]
    fn and_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::and_epi32(self, a, b)
    }

    #[inline(always)]
    fn andnot_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::andnot_epi32(self, a, b)
    }

    #[inline(always)]
    fn or_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::or_epi32(self, a, b)
    }

    #[inline(always)]
    fn add_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::add_epi32(self, a, b)
    }

    #[inline(always)]
    fn sub_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::sub_epi32(self, a, b)
    }

    #[inline(always)]
    fn mask_or_epi32(&mut self, src: VecI32x16, mask: Mask16, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        Vpu::mask_or_epi32(self, src, mask, a, b)
    }

    #[inline(always)]
    fn test_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        Vpu::test_epi32_mask(self, a, b)
    }

    #[inline(always)]
    fn cmplt_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        Vpu::cmplt_epi32_mask(self, a, b)
    }

    #[inline(always)]
    fn kor(&mut self, a: Mask16, b: Mask16) -> Mask16 {
        Vpu::kor(self, a, b)
    }

    #[inline(always)]
    fn kand(&mut self, a: Mask16, b: Mask16) -> Mask16 {
        Vpu::kand(self, a, b)
    }

    #[inline(always)]
    fn knot(&mut self, a: Mask16) -> Mask16 {
        Vpu::knot(self, a)
    }

    #[inline(always)]
    fn mask_reduce_or_epi32(&mut self, mask: Mask16, v: VecI32x16) -> i32 {
        Vpu::mask_reduce_or_epi32(self, mask, v)
    }

    #[inline(always)]
    fn i32gather_epi32(&mut self, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        Vpu::i32gather_epi32(self, vindex, base)
    }

    #[inline(always)]
    fn mask_i32gather_epi32(&mut self, mask: Mask16, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        Vpu::mask_i32gather_epi32(self, mask, vindex, base)
    }

    #[inline(always)]
    fn i32gather_words(&mut self, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        Vpu::i32gather_words(self, vindex, base)
    }

    #[inline(always)]
    fn mask_i32gather_words(&mut self, mask: Mask16, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        Vpu::mask_i32gather_words(self, mask, vindex, base)
    }

    #[inline(always)]
    fn mask_i32scatter_epi32(&mut self, base: &mut [i32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        Vpu::mask_i32scatter_epi32(self, base, mask, vindex, v)
    }

    #[inline(always)]
    fn mask_i32scatter_words(&mut self, base: &mut [u32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        Vpu::mask_i32scatter_words(self, base, mask, vindex, v)
    }

    #[inline(always)]
    fn mask_gather_shared_words(&mut self, mask: Mask16, vindex: VecI32x16, base: &[AtomicU32]) -> VecI32x16 {
        Vpu::mask_gather_shared_words(self, mask, vindex, base)
    }

    #[inline(always)]
    fn mask_scatter_shared_words(&mut self, base: &[AtomicU32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        Vpu::mask_scatter_shared_words(self, base, mask, vindex, v)
    }

    #[inline(always)]
    fn mask_gather_shared_i32(&mut self, mask: Mask16, vindex: VecI32x16, base: &[AtomicI32]) -> VecI32x16 {
        Vpu::mask_gather_shared_i32(self, mask, vindex, base)
    }

    #[inline(always)]
    fn mask_scatter_shared_i32(&mut self, base: &[AtomicI32], mask: Mask16, vindex: VecI32x16, v: VecI32x16) {
        Vpu::mask_scatter_shared_i32(self, base, mask, vindex, v)
    }

    #[inline(always)]
    fn prefetch_i32gather(&mut self, vindex: VecI32x16, hint: PrefetchHint) {
        Vpu::prefetch_i32gather(self, vindex, hint)
    }

    #[inline(always)]
    fn mask_prefetch_i32scatter(&mut self, mask: Mask16, vindex: VecI32x16, hint: PrefetchHint) {
        Vpu::mask_prefetch_i32scatter(self, mask, vindex, hint)
    }

    #[inline(always)]
    fn prefetch_scalar(&mut self, hint: PrefetchHint) {
        Vpu::prefetch_scalar(self, hint)
    }

    #[inline(always)]
    fn note_full_chunk(&mut self) {
        Vpu::note_full_chunk(self)
    }

    #[inline(always)]
    fn note_peel(&mut self, n: usize) {
        Vpu::note_peel(self, n)
    }

    #[inline(always)]
    fn note_remainder(&mut self, n: usize) {
        Vpu::note_remainder(self, n)
    }

    #[inline(always)]
    fn note_explore_issue(&mut self, active: u32) {
        Vpu::note_explore_issue(self, active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vpu() -> Vpu {
        Vpu::new()
    }

    #[test]
    fn load_and_set1() {
        let mut v = vpu();
        let data: Vec<i32> = (0..32).collect();
        let r = v.load_epi32(&data, 16);
        assert_eq!(r.0[0], 16);
        assert_eq!(r.0[15], 31);
        assert_eq!(v.set1_epi32(9), VecI32x16::splat(9));
        assert_eq!(v.counters.vector_loads, 1);
    }

    #[test]
    fn mask_load_zeroes_disabled_lanes() {
        let mut v = vpu();
        let data = [5i32; 20];
        let r = v.mask_load_epi32(Mask16::first_n(3), &data, 0);
        assert_eq!(&r.0[..3], &[5, 5, 5]);
        assert_eq!(&r.0[3..], &[0; 13]);
    }

    #[test]
    fn div_rem_word_bit_decomposition() {
        // The Listing-1 word/bit split: word = v / 32, bit = v % 32.
        let mut v = vpu();
        let verts = VecI32x16([0, 1, 31, 32, 33, 63, 64, 95, 96, 100, 127, 128, 200, 255, 256, 1023]);
        let w = v.div_epi32(verts, VecI32x16::splat(32));
        let b = v.rem_epi32(verts, VecI32x16::splat(32));
        for i in 0..LANES {
            assert_eq!(w.0[i], verts.0[i] / 32);
            assert_eq!(b.0[i], verts.0[i] % 32);
            assert_eq!(w.0[i] * 32 + b.0[i], verts.0[i]);
        }
    }

    #[test]
    fn sllv_builds_bit_masks() {
        let mut v = vpu();
        let bits = VecI32x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 15, 16, 24, 30, 31, 31, 0]);
        let m = v.sllv_epi32(VecI32x16::splat(1), bits);
        for i in 0..LANES {
            assert_eq!(m.0[i] as u32, 1u32 << bits.0[i]);
        }
    }

    #[test]
    fn test_epi32_mask_matches_and() {
        let mut v = vpu();
        let a = VecI32x16([0b0100; LANES]);
        let mut b = VecI32x16::zero();
        b.0[2] = 0b0100; // overlap
        b.0[5] = 0b0011; // no overlap
        let m = v.test_epi32_mask(a, b);
        assert!(m.test_lane(2));
        assert!(!m.test_lane(5));
        assert_eq!(m.count(), 1);
    }

    #[test]
    fn kor_knot_filtering() {
        // Listing 1: mask = knot(kor(visited, in_queue)) — selects lanes
        // that are in neither set.
        let mut v = vpu();
        let visited = Mask16(0b0000_0000_0000_1111);
        let queued = Mask16(0b0000_0000_1111_0000);
        let seen = v.kor(visited, queued);
        let m = v.knot(seen);
        assert_eq!(m.0, 0b1111_1111_0000_0000);
    }

    #[test]
    fn gather_reads_indexed() {
        let mut v = vpu();
        let base: Vec<i32> = (0..100).map(|x| x * 10).collect();
        let idx = VecI32x16([0, 5, 9, 3, 7, 1, 2, 4, 6, 8, 10, 20, 30, 40, 50, 99]);
        let r = v.i32gather_epi32(idx, &base);
        for i in 0..LANES {
            assert_eq!(r.0[i], idx.0[i] * 10);
        }
        assert_eq!(v.counters.gather_lanes, 16);
    }

    #[test]
    fn masked_scatter_only_touches_enabled_lanes() {
        let mut v = vpu();
        let mut base = vec![0i32; 20];
        let idx = VecI32x16([0, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15]);
        let vals = VecI32x16::splat(7);
        v.mask_i32scatter_epi32(&mut base, Mask16(0b101), idx, vals);
        assert_eq!(base[0], 7);
        assert_eq!(base[1], 0);
        assert_eq!(base[2], 7);
        assert_eq!(v.counters.scatter_lanes, 2);
        assert_eq!(v.counters.scatter_conflicts, 0);
    }

    #[test]
    fn scatter_conflict_highest_lane_wins_and_loses_updates() {
        // THE core hazard: two lanes write different bit patterns to the
        // same bitmap word; the lower lane's bits are lost.
        let mut v = vpu();
        let mut words = vec![0u32; 4];
        let mut idx = VecI32x16::zero();
        let mut vals = VecI32x16::zero();
        // lane 3 and lane 11 both target word 2 with different single bits
        idx.0[3] = 2;
        vals.0[3] = 1 << 5; // vertex 69
        idx.0[11] = 2;
        vals.0[11] = 1 << 9; // vertex 73
        let mask = Mask16((1 << 3) | (1 << 11));
        v.mask_i32scatter_words(&mut words, mask, idx, vals);
        // highest lane (11) wins; bit 5 from lane 3 is LOST
        assert_eq!(words[2], 1 << 9);
        assert_eq!(v.counters.scatter_conflicts, 1);
    }

    #[test]
    fn mask_or_passes_src_through() {
        let mut v = vpu();
        let src = VecI32x16::splat(-1);
        let a = VecI32x16::splat(0b01);
        let b = VecI32x16::splat(0b10);
        let r = v.mask_or_epi32(src, Mask16::first_n(4), a, b);
        assert_eq!(&r.0[..4], &[0b11; 4]);
        assert_eq!(&r.0[4..], &[-1; 12]);
    }

    #[test]
    fn prefetch_counters() {
        let mut v = vpu();
        v.prefetch_i32gather(VecI32x16::zero(), PrefetchHint::T0);
        v.mask_prefetch_i32scatter(Mask16::ALL, VecI32x16::zero(), PrefetchHint::T0);
        v.prefetch_scalar(PrefetchHint::T1);
        assert_eq!(v.counters.prefetch_l1, 2);
        assert_eq!(v.counters.prefetch_l2, 1);
    }

    #[test]
    fn cmplt_mask() {
        let mut v = vpu();
        let mut a = VecI32x16::splat(5);
        a.0[0] = -3;
        a.0[7] = -1;
        let m = v.cmplt_epi32_mask(a, VecI32x16::zero());
        assert_eq!(m.0, (1 << 0) | (1 << 7));
    }

    #[test]
    fn andnot_keeps_new_bits_only() {
        let mut v = vpu();
        let seen = VecI32x16::splat(0b0110);
        let frontier = VecI32x16::splat(0b1010);
        // (!seen) & frontier = the bits still to propagate
        assert_eq!(v.andnot_epi32(seen, frontier), VecI32x16::splat(0b1000));
    }

    #[test]
    fn srlv_shifts_right() {
        let mut v = vpu();
        let a = VecI32x16::splat(0b1100);
        let r = v.srlv_epi32(a, VecI32x16::splat(2));
        assert_eq!(r, VecI32x16::splat(0b11));
    }
}
