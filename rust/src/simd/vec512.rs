//! The VPU register types: a 512-bit vector register holding 16 × 32-bit
//! integer lanes (`__m512i` in the paper's Listing 1) and a 16-bit mask
//! register (`__mmask16`).

/// Lanes per 512-bit register at 32-bit element width (§2: "16 (32-bit)
/// operations at a time").
pub const LANES: usize = 16;

/// A `__m512i` holding 16 × i32.
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct VecI32x16(pub [i32; LANES]);

impl VecI32x16 {
    /// All-zero register.
    pub fn zero() -> Self {
        VecI32x16([0; LANES])
    }

    /// Broadcast (`_mm512_set1_epi32`).
    pub fn splat(x: i32) -> Self {
        VecI32x16([x; LANES])
    }

    /// Lane accessor.
    #[inline(always)]
    pub fn lane(&self, i: usize) -> i32 {
        self.0[i]
    }

    /// Lanewise map helper used by the intrinsic implementations.
    #[inline(always)]
    pub fn map(&self, f: impl Fn(i32) -> i32) -> Self {
        let mut out = [0i32; LANES];
        for (o, &x) in out.iter_mut().zip(self.0.iter()) {
            *o = f(x);
        }
        VecI32x16(out)
    }

    /// Lanewise zip-map helper.
    #[inline(always)]
    pub fn zip(&self, other: &Self, f: impl Fn(i32, i32) -> i32) -> Self {
        let mut out = [0i32; LANES];
        for i in 0..LANES {
            out[i] = f(self.0[i], other.0[i]);
        }
        VecI32x16(out)
    }

    pub fn to_array(self) -> [i32; LANES] {
        self.0
    }
}

impl std::fmt::Debug for VecI32x16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "VecI32x16({:?})", self.0)
    }
}

/// A `__mmask16`: bit *i* steers lane *i*. Masked instructions update only
/// lanes whose bit is 1; the rest pass through unchanged (§2).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct Mask16(pub u16);

impl Mask16 {
    pub const ALL: Mask16 = Mask16(0xFFFF);
    pub const NONE: Mask16 = Mask16(0);

    /// Mask with the low `n` lanes enabled — how the paper handles peel and
    /// remainder (less-than-full-vector) chunks, §4.2.
    pub fn first_n(n: usize) -> Self {
        debug_assert!(n <= LANES);
        if n >= LANES {
            Mask16::ALL
        } else {
            Mask16(((1u32 << n) - 1) as u16)
        }
    }

    #[inline(always)]
    pub fn test_lane(&self, i: usize) -> bool {
        (self.0 >> i) & 1 == 1
    }

    /// Number of enabled lanes.
    #[inline(always)]
    pub fn count(&self) -> u32 {
        self.0.count_ones()
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.0 == 0
    }
}

impl std::fmt::Debug for Mask16 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Mask16({:#018b})", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splat_and_lane() {
        let v = VecI32x16::splat(7);
        for i in 0..LANES {
            assert_eq!(v.lane(i), 7);
        }
    }

    #[test]
    fn zip_adds() {
        let a = VecI32x16([1; LANES]);
        let b = VecI32x16::splat(2);
        assert_eq!(a.zip(&b, |x, y| x + y), VecI32x16::splat(3));
    }

    #[test]
    fn mask_first_n() {
        assert_eq!(Mask16::first_n(0), Mask16::NONE);
        assert_eq!(Mask16::first_n(16), Mask16::ALL);
        let m = Mask16::first_n(5);
        assert_eq!(m.0, 0b11111);
        assert!(m.test_lane(4));
        assert!(!m.test_lane(5));
        assert_eq!(m.count(), 5);
    }
}
