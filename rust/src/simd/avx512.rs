//! The native AVX-512 tier of the hardware VPU backend (`--features
//! avx512`, x86_64 only).
//!
//! This is the paper's actual target ISA: one 512-bit register holds all
//! 16 lanes and `__mmask16` *is* [`Mask16`], so the Listing-1 dataflow
//! maps 1:1 onto single instructions — no double-pumping, no mask
//! expansion. The tier is opt-in because the 512-bit intrinsic surface
//! stabilized in rustc 1.89; the default build ships the AVX2/portable
//! tiers so older toolchains keep compiling. [`crate::simd::hw::detect_hw_select`]
//! only returns this tier when the feature is compiled in **and** the CPU
//! reports `avx512f`.
//!
//! Scatters and the shared-memory ops inherit the scalar-unrolled
//! defaults for the same reasons as the AVX2 tier (lane-conflict rule
//! preserved bit for bit; no vector access to atomics in Rust's memory
//! model) — see [`crate::simd::hw`].
//!
//! # Safety
//!
//! All `#[target_feature(enable = "avx512f")]` helpers are only reachable
//! through [`HwAvx512`], which is only constructed after
//! `is_x86_feature_detected!("avx512f")` (debug-asserted in `new`).
//! Gathers do no bounds checks; the safe wrappers `debug_assert!` every
//! enabled lane in range, mirroring the AVX2 tier.

use core::arch::x86_64::*;

use super::backend::{gather_in_bounds, VpuBackend};
use super::counters::VpuCounters;
use super::fused::FusedTier;
use super::ops::PrefetchHint;
use super::vec512::{Mask16, VecI32x16};

/// Native AVX-512 backend: 16 lanes per instruction, counters off.
#[derive(Clone, Copy, Debug, Default)]
pub struct HwAvx512;

#[inline(always)]
fn to512(v: VecI32x16) -> __m512i {
    // SAFETY: [i32; 16] and __m512i are both 64 plain bytes
    unsafe { core::mem::transmute::<[i32; 16], __m512i>(v.0) }
}

#[inline(always)]
fn from512(x: __m512i) -> VecI32x16 {
    // SAFETY: as in to512
    VecI32x16(unsafe { core::mem::transmute::<__m512i, [i32; 16]>(x) })
}

macro_rules! avx512_binop {
    ($fn_name:ident, $intrinsic:ident) => {
        #[target_feature(enable = "avx512f")]
        unsafe fn $fn_name(a: VecI32x16, b: VecI32x16) -> VecI32x16 {
            from512($intrinsic(to512(a), to512(b)))
        }
    };
}

avx512_binop!(and_avx512, _mm512_and_epi32);
avx512_binop!(or_avx512, _mm512_or_epi32);
avx512_binop!(andnot_avx512, _mm512_andnot_epi32);
avx512_binop!(add_avx512, _mm512_add_epi32);
avx512_binop!(sub_avx512, _mm512_sub_epi32);

macro_rules! avx512_varshift {
    ($fn_name:ident, $intrinsic:ident) => {
        #[target_feature(enable = "avx512f")]
        unsafe fn $fn_name(a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
            // match the portable spec: shift counts masked to 5 bits
            let m31 = _mm512_set1_epi32(31);
            from512($intrinsic(to512(a), _mm512_and_epi32(to512(counts), m31)))
        }
    };
}

avx512_varshift!(sllv_avx512, _mm512_sllv_epi32);
avx512_varshift!(srlv_avx512, _mm512_srlv_epi32);

#[target_feature(enable = "avx512f")]
unsafe fn test_mask_avx512(a: VecI32x16, b: VecI32x16) -> Mask16 {
    Mask16(_mm512_test_epi32_mask(to512(a), to512(b)))
}

#[target_feature(enable = "avx512f")]
unsafe fn cmplt_mask_avx512(a: VecI32x16, b: VecI32x16) -> Mask16 {
    Mask16(_mm512_cmplt_epi32_mask(to512(a), to512(b)))
}

#[target_feature(enable = "avx512f")]
unsafe fn mask_or_avx512(src: VecI32x16, mask: Mask16, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
    from512(_mm512_mask_or_epi32(to512(src), mask.0, to512(a), to512(b)))
}

#[target_feature(enable = "avx512f")]
unsafe fn reduce_or_avx512(mask: Mask16, v: VecI32x16) -> i32 {
    _mm512_mask_reduce_or_epi32(mask.0, to512(v))
}

#[target_feature(enable = "avx512f")]
unsafe fn gather_avx512(base: *const u8, vindex: VecI32x16) -> VecI32x16 {
    from512(_mm512_i32gather_epi32::<4>(to512(vindex), base))
}

#[target_feature(enable = "avx512f")]
unsafe fn mask_gather_avx512(base: *const u8, vindex: VecI32x16, mask: Mask16) -> VecI32x16 {
    // disabled lanes take the zero src operand — the portable spec
    from512(_mm512_mask_i32gather_epi32::<4>(
        _mm512_setzero_si512(),
        mask.0,
        to512(vindex),
        base,
    ))
}

impl VpuBackend for HwAvx512 {
    const NAME: &'static str = "avx512";
    const COUNTED: bool = false;
    const TIER: FusedTier = FusedTier::Avx512;

    #[inline(always)]
    fn new() -> Self {
        debug_assert!(
            std::arch::is_x86_feature_detected!("avx512f"),
            "HwAvx512 constructed without AVX-512F support"
        );
        HwAvx512
    }

    #[inline(always)]
    fn counters(&self) -> VpuCounters {
        VpuCounters::default()
    }

    #[inline(always)]
    fn prefetch_addr(&mut self, p: *const u8, hint: PrefetchHint) {
        super::hw::hw_prefetch_addr(p, hint);
    }

    #[inline(always)]
    fn sllv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { sllv_avx512(a, counts) }
    }

    #[inline(always)]
    fn srlv_epi32(&mut self, a: VecI32x16, counts: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { srlv_avx512(a, counts) }
    }

    #[inline(always)]
    fn and_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { and_avx512(a, b) }
    }

    #[inline(always)]
    fn andnot_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { andnot_avx512(a, b) }
    }

    #[inline(always)]
    fn or_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { or_avx512(a, b) }
    }

    #[inline(always)]
    fn add_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { add_avx512(a, b) }
    }

    #[inline(always)]
    fn sub_epi32(&mut self, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { sub_avx512(a, b) }
    }

    #[inline(always)]
    fn mask_or_epi32(&mut self, src: VecI32x16, mask: Mask16, a: VecI32x16, b: VecI32x16) -> VecI32x16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { mask_or_avx512(src, mask, a, b) }
    }

    #[inline(always)]
    fn test_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { test_mask_avx512(a, b) }
    }

    #[inline(always)]
    fn cmplt_epi32_mask(&mut self, a: VecI32x16, b: VecI32x16) -> Mask16 {
        // SAFETY: AVX-512F detected at construction
        unsafe { cmplt_mask_avx512(a, b) }
    }

    #[inline(always)]
    fn mask_reduce_or_epi32(&mut self, mask: Mask16, v: VecI32x16) -> i32 {
        // SAFETY: AVX-512F detected at construction
        unsafe { reduce_or_avx512(mask, v) }
    }

    #[inline(always)]
    fn i32gather_epi32(&mut self, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        debug_assert!(gather_in_bounds(Mask16::ALL, &vindex, base.len()));
        // SAFETY: AVX-512F detected at construction; indices in bounds by
        // the engine invariant (debug-asserted above)
        unsafe { gather_avx512(base.as_ptr() as *const u8, vindex) }
    }

    #[inline(always)]
    fn mask_i32gather_epi32(&mut self, mask: Mask16, vindex: VecI32x16, base: &[i32]) -> VecI32x16 {
        debug_assert!(gather_in_bounds(mask, &vindex, base.len()));
        // SAFETY: as for i32gather_epi32; disabled lanes do not access
        // memory
        unsafe { mask_gather_avx512(base.as_ptr() as *const u8, vindex, mask) }
    }

    #[inline(always)]
    fn i32gather_words(&mut self, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        debug_assert!(gather_in_bounds(Mask16::ALL, &vindex, base.len()));
        // SAFETY: as for i32gather_epi32 (u32 reinterpreted as i32)
        unsafe { gather_avx512(base.as_ptr() as *const u8, vindex) }
    }

    #[inline(always)]
    fn mask_i32gather_words(&mut self, mask: Mask16, vindex: VecI32x16, base: &[u32]) -> VecI32x16 {
        debug_assert!(gather_in_bounds(mask, &vindex, base.len()));
        // SAFETY: as for mask_i32gather_epi32
        unsafe { mask_gather_avx512(base.as_ptr() as *const u8, vindex, mask) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::ops::Vpu;

    #[test]
    fn avx512_matches_counted_ops() {
        if !std::arch::is_x86_feature_detected!("avx512f") {
            eprintln!("skipping: no AVX-512F on this host");
            return;
        }
        let mut c = Vpu::new();
        let mut h = HwAvx512::new();
        let a = VecI32x16([3, -7, 0, i32::MAX, i32::MIN, 12, 99, -1, 5, 6, 7, 8, 9, 10, 11, 12]);
        let b = VecI32x16([1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 31]);
        assert_eq!(c.and_epi32(a, b), h.and_epi32(a, b));
        assert_eq!(c.or_epi32(a, b), h.or_epi32(a, b));
        assert_eq!(c.andnot_epi32(a, b), h.andnot_epi32(a, b));
        assert_eq!(c.add_epi32(a, b), h.add_epi32(a, b));
        assert_eq!(c.sub_epi32(a, b), h.sub_epi32(a, b));
        assert_eq!(c.sllv_epi32(a, b), h.sllv_epi32(a, b));
        assert_eq!(c.srlv_epi32(a, b), h.srlv_epi32(a, b));
        assert_eq!(c.test_epi32_mask(a, b), h.test_epi32_mask(a, b));
        assert_eq!(c.cmplt_epi32_mask(a, b), h.cmplt_epi32_mask(a, b));
        let m = Mask16(0b0110_1101_1011_0110);
        assert_eq!(c.mask_or_epi32(a, m, a, b), h.mask_or_epi32(a, m, a, b));
        assert_eq!(c.mask_reduce_or_epi32(m, b), h.mask_reduce_or_epi32(m, b));
        let words: Vec<u32> = (0..64u32).map(|x| x.wrapping_mul(0x9E37_79B9)).collect();
        let idx = VecI32x16([0, 5, 9, 3, 63, 1, 2, 4, 6, 8, 10, 20, 30, 40, 50, 33]);
        assert_eq!(c.i32gather_words(idx, &words), h.i32gather_words(idx, &words));
        assert_eq!(c.mask_i32gather_words(m, idx, &words), h.mask_i32gather_words(m, idx, &words));
    }
}
