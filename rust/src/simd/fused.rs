//! Whole-loop `#[target_feature]` fusion for the hardware VPU tiers.
//!
//! The intrinsic tiers in [`crate::simd::hw`] and [`crate::simd::avx512`]
//! wrap every op in its own `#[target_feature(enable = ...)]` helper.
//! That is sound, but a featureless caller cannot inline a feature-enabled
//! callee, so each intrinsic op in a hot layer loop pays a real call: the
//! gather → shift → test → scatter dataflow of Listing 1 never fuses into
//! one register-resident sequence.
//!
//! The fix inverts the arrangement. [`fuse`] runs a closure — an entire
//! monomorphized layer-loop body — *inside* a function compiled with the
//! backend's target features ([`FusedTier`], a `const` on
//! [`VpuBackend`]). Inlining is legal in that direction (a
//! feature-enabled caller may inline featureless callees), so the closure
//! body and every `#[inline(always)]` backend method collapse into one
//! AVX2/AVX-512 compilation region and the per-op call boundary
//! disappears.
//!
//! The counted emulator and the portable tier report
//! [`FusedTier::Generic`] and run the closure directly — bit-identical
//! code, bit-identical counters. The intrinsic arms re-check
//! `is_x86_feature_detected!` (cached by std, one atomic load) before
//! entering the feature-enabled envelope, so a test-constructed intrinsic
//! backend on an unsupported host degrades to the unfused path instead of
//! executing illegal instructions.
//!
//! [`force_unfused`] is the measurement escape hatch: the ablation bench
//! flips it to compare fused against PR 5's per-op dispatch on identical
//! inputs (`BENCH_fusion.json`). Fusion never changes results — only
//! codegen — so the toggle is safe to leave in any state.
//!
//! [`VpuBackend`]: super::backend::VpuBackend

use std::sync::atomic::{AtomicBool, Ordering};

use super::backend::VpuBackend;

/// The `#[target_feature]` envelope a backend's layer loops compile under.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FusedTier {
    /// No envelope: run the loop body as compiled for the base target
    /// (counted emulator, portable tier, non-x86 fallbacks).
    Generic,
    /// `#[target_feature(enable = "avx2")]` whole-loop compilation.
    Avx2,
    /// `#[target_feature(enable = "avx512f")]` whole-loop compilation.
    Avx512,
}

/// When set, [`fuse`] skips the feature-enabled envelopes and runs every
/// closure directly — PR 5's per-op dispatch, for A/B measurement.
static FORCE_UNFUSED: AtomicBool = AtomicBool::new(false);

/// Globally disable (`true`) or re-enable (`false`) whole-loop fusion.
/// Results are unaffected either way; only codegen changes.
pub fn force_unfused(on: bool) {
    FORCE_UNFUSED.store(on, Ordering::Relaxed);
}

/// Whether [`force_unfused`] is currently set.
pub fn fusion_forced_off() -> bool {
    FORCE_UNFUSED.load(Ordering::Relaxed)
}

/// Run `f` inside the `#[target_feature]` envelope of backend `V`'s tier,
/// so the whole closure body — and every `#[inline(always)]` op of `V` it
/// calls — compiles as one fused region for that ISA. Generic tiers (the
/// counted emulator, the portable tier) run `f` directly.
#[inline(always)]
pub fn fuse<V: VpuBackend, R, F: FnOnce() -> R>(f: F) -> R {
    match V::TIER {
        FusedTier::Generic => f(),
        #[cfg(target_arch = "x86_64")]
        FusedTier::Avx2 => {
            if fusion_forced_off() || !std::arch::is_x86_feature_detected!("avx2") {
                f()
            } else {
                // SAFETY: AVX2 is available on this CPU (checked above);
                // the envelope executes nothing the closure would not.
                unsafe { fuse_avx2(f) }
            }
        }
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        FusedTier::Avx512 => {
            if fusion_forced_off() || !std::arch::is_x86_feature_detected!("avx512f") {
                f()
            } else {
                // SAFETY: AVX-512F is available on this CPU (checked above)
                unsafe { fuse_avx512(f) }
            }
        }
        // Tiers whose envelope is not compiled for this target run unfused
        // (they are unreachable anyway: the hw type aliases resolve them to
        // compiled-in backends, which report their own tier).
        #[cfg(not(target_arch = "x86_64"))]
        FusedTier::Avx2 => f(),
        #[cfg(not(all(target_arch = "x86_64", feature = "avx512")))]
        FusedTier::Avx512 => f(),
    }
}

/// The AVX2 whole-loop envelope: nothing but the closure, compiled with
/// the feature enabled so the body (and its inlinees) fuse.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn fuse_avx2<R, F: FnOnce() -> R>(f: F) -> R {
    f()
}

/// The AVX-512F whole-loop envelope.
#[cfg(all(target_arch = "x86_64", feature = "avx512"))]
#[target_feature(enable = "avx512f")]
unsafe fn fuse_avx512<R, F: FnOnce() -> R>(f: F) -> R {
    f()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::hw::{BestAvx2, BestAvx512, HwPortable};
    use crate::simd::ops::Vpu;

    fn run_through<V: VpuBackend>() -> i32 {
        fuse::<V, _, _>(|| {
            let mut v = V::new();
            let a = v.set1_epi32(21);
            v.add_epi32(a, a).0[7]
        })
    }

    #[test]
    fn fuse_runs_the_closure_on_every_tier() {
        assert_eq!(run_through::<Vpu>(), 42);
        assert_eq!(run_through::<HwPortable>(), 42);
        // the intrinsic tiers guard on runtime detection internally, so
        // this is safe even on hosts without the features
        assert_eq!(run_through::<BestAvx2>(), 42);
        assert_eq!(run_through::<BestAvx512>(), 42);
    }

    #[test]
    fn force_unfused_round_trips_and_preserves_results() {
        assert!(!fusion_forced_off());
        force_unfused(true);
        assert!(fusion_forced_off());
        assert_eq!(run_through::<BestAvx2>(), 42);
        force_unfused(false);
        assert!(!fusion_forced_off());
    }

    #[test]
    fn tiers_are_declared_correctly() {
        assert_eq!(Vpu::TIER, FusedTier::Generic);
        assert_eq!(HwPortable::TIER, FusedTier::Generic);
        #[cfg(target_arch = "x86_64")]
        assert_eq!(crate::simd::hw::HwAvx2::TIER, FusedTier::Avx2);
        #[cfg(all(target_arch = "x86_64", feature = "avx512"))]
        assert_eq!(crate::simd::avx512::HwAvx512::TIER, FusedTier::Avx512);
    }

    #[test]
    fn fuse_propagates_closure_captures() {
        let mut acc = 0u64;
        fuse::<HwPortable, _, _>(|| {
            for i in 0..100u64 {
                acc += i;
            }
        });
        assert_eq!(acc, 4950);
    }
}
